//! Figure 2 bench: loss + gradient wall time vs n, per algorithm.
//!
//! `cargo bench --bench fig2_timing` prints one measurement per
//! (algorithm, n) and writes `results/bench_fig2.csv`.  Quick mode:
//! `ALLPAIRS_BENCH_QUICK=1 cargo bench --bench fig2_timing`.

use allpairs::data::Rng;
use allpairs::losses::figure2_losses;
use allpairs::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("ALLPAIRS_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: &[usize] = if quick {
        &[100, 1_000, 10_000]
    } else {
        &[100, 1_000, 10_000, 100_000, 1_000_000]
    };
    let naive_cap = if quick { 1_000 } else { 10_000 };

    let mut bench = Bench::from_env();
    let mut rng = Rng::new(20230223);
    for &n in sizes {
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let is_pos: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        for loss in figure2_losses(1.0) {
            if loss.complexity() == "O(n^2)" && n > naive_cap {
                continue;
            }
            bench.run(format!("{}/n={n}", loss.name()), || {
                loss.loss_and_grad(&scores, &is_pos).0
            });
        }
    }
    // Perf ablation: allocation-per-call (the Figure-2 PairwiseLoss
    // trait) vs the reusable LossFn workspace on the O(n log n) hinge
    // sweep (EXPERIMENTS.md §Perf).
    use allpairs::losses::functional::SquaredHinge;
    use allpairs::losses::{BatchView, LossFn, LossWorkspace, PairwiseLoss};
    let n = if quick { 10_000 } else { 1_000_000 };
    let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let is_pos: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
    let hinge = SquaredHinge::new(1.0);
    bench.run(format!("hinge_alloc_per_call/n={n}"), || {
        PairwiseLoss::loss_and_grad(&hinge, &scores, &is_pos).0
    });
    let mut ws = LossWorkspace::default();
    bench.run(format!("hinge_workspace_reuse/n={n}"), || {
        LossFn::loss_and_grad(&hinge, BatchView::new(&scores, &is_pos), &mut ws)
    });
    bench.run(format!("hinge_loss_only/n={n}"), || {
        hinge.loss_only(&scores, &is_pos)
    });

    bench.write_csv("results/bench_fig2.csv")?;
    eprintln!("wrote results/bench_fig2.csv");
    Ok(())
}
