//! AUC computation bench: the model-selection hot path of the sweep
//! (validation AUC runs once per epoch per job).  Also benches the full
//! ROC curve construction.

use allpairs::data::Rng;
use allpairs::metrics::{auc, roc_curve};
use allpairs::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("ALLPAIRS_BENCH_QUICK").as_deref() == Ok("1");
    let sizes: &[usize] = if quick {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    let mut bench = Bench::from_env();
    let mut rng = Rng::new(7);
    for &n in sizes {
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let labels: Vec<f32> = (0..n)
            .map(|_| if rng.uniform() < 0.1 { 1.0 } else { 0.0 })
            .collect();
        bench.run(format!("auc/n={n}"), || auc(&scores, &labels));
        if n <= 100_000 {
            bench.run(format!("roc_curve/n={n}"), || roc_curve(&scores, &labels).len());
        }
    }
    bench.write_csv("results/bench_auc.csv")?;
    Ok(())
}
