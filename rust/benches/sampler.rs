//! Data-path benches: synthetic generation, imbalance subsetting and the
//! batch-fill hot loop (the only host-side work between PJRT executions),
//! plus the stratified epoch-order construction of the streaming loop.

use allpairs::data::synth::{generate, SynthSpec, SYNTH_DATASETS};
use allpairs::data::{BatchPlan, EpochSampler, Rng, SamplingMode};
use allpairs::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::from_env();
    let spec = SynthSpec {
        n_train: 2_000,
        n_test: 100,
        ..SYNTH_DATASETS[0]
    };

    bench.run("synth/generate_2000_images", || {
        generate(&spec, 1).0.len()
    });

    let (pool, _) = generate(&spec, 1);
    let mut rng = Rng::new(2);
    bench.run("imbalance/to_0.01", || {
        pool.imbalance(0.01, &mut rng).len()
    });

    let train = pool.imbalance(0.1, &mut Rng::new(3));
    let indices: Vec<u32> = (0..train.len() as u32).collect();
    for &bs in &[10usize, 100, 1000] {
        let row = train.row_len();
        let mut x = vec![0.0f32; bs * row];
        let mut p = vec![0.0f32; bs];
        let mut q = vec![0.0f32; bs];
        bench.run(format!("batch_fill/epoch_bs{bs}"), || {
            let plan = BatchPlan::new(&indices, bs, &mut rng).unwrap();
            let mut iter = plan.iter(&train);
            let mut total = 0usize;
            while let Some(c) = iter.fill_next(&mut x, &mut p, &mut q) {
                total += c;
            }
            total
        });
    }

    // Streaming stratified epochs: order construction + batch fill, in
    // both composition modes (the `Trainer::fit_stream` hot path).
    for (label, mode) in [
        ("preserve", SamplingMode::Preserve),
        ("rebalance", SamplingMode::Rebalance { pos_fraction: 0.5 }),
    ] {
        for &bs in &[100usize, 1000] {
            let row = train.row_len();
            let mut x = vec![0.0f32; bs * row];
            let mut p = vec![0.0f32; bs];
            let mut q = vec![0.0f32; bs];
            let mut sampler = EpochSampler::new(&train.y, &indices, bs, mode)?;
            bench.run(format!("stratified_fill/{label}_epoch_bs{bs}"), || {
                let plan = sampler.epoch_plan(&mut rng);
                let mut iter = plan.iter(&train);
                let mut total = 0usize;
                while let Some(c) = iter.fill_next(&mut x, &mut p, &mut q) {
                    total += c;
                }
                total
            });
        }
    }
    bench.write_csv("results/bench_sampler.csv")?;
    Ok(())
}
