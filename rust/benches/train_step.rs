//! End-to-end train-step throughput: the L3 hot path per backend, batch
//! size and loss.
//!
//! Default: the native backend (no artifacts needed).  With a `pjrt`
//! build and `make artifacts`, set `ALLPAIRS_BENCH_BACKEND=pjrt` to
//! bench the PJRT path instead (host staging + one execution per step).

use allpairs::data::{Dataset, Rng};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::train::Trainer;
use allpairs::util::bench::Bench;

fn image_batch_dataset(n: usize, rng: &mut Rng) -> Dataset {
    let px = 16 * 16 * 3;
    let x: Vec<f32> = (0..n * px).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n).map(|i| (i % 5 == 0) as u8 as f32).collect();
    Dataset::new(x, y, 16, 3)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("ALLPAIRS_BENCH_QUICK").as_deref() == Ok("1");
    let spec = match std::env::var("ALLPAIRS_BENCH_BACKEND").as_deref() {
        Ok("pjrt") => BackendSpec::pjrt("artifacts"),
        _ => BackendSpec::Native(NativeSpec::default()),
    };
    if matches!(spec, BackendSpec::Pjrt { .. })
        && !std::path::Path::new("artifacts/manifest.json").exists()
    {
        eprintln!("skipping train_step bench: run `make artifacts` first");
        return Ok(());
    }
    let pjrt = matches!(spec, BackendSpec::Pjrt { .. });
    let backend = spec.connect()?;

    let batches: &[usize] = if quick { &[10, 100] } else { &[10, 100, 1000] };
    let losses: Vec<LossSpec> = if quick {
        vec![LossSpec::hinge()]
    } else if pjrt {
        vec![
            LossSpec::hinge(),
            LossSpec::square(),
            LossSpec::logistic(),
            LossSpec::aucm(),
        ]
    } else {
        // every loss with a native kernel, the weighted hinge included
        vec![
            LossSpec::hinge(),
            LossSpec::square(),
            LossSpec::logistic(),
            LossSpec::linear_hinge(),
            LossSpec::weighted_hinge(),
        ]
    };

    let mut bench = Bench::from_env();
    let mut rng = Rng::new(5);
    let data = image_batch_dataset(2000, &mut rng);

    for loss in &losses {
        for &bs in batches {
            let mut trainer = Trainer::new(backend.as_ref(), "resnet", loss, bs)?;
            trainer.init(0)?;
            let indices: Vec<u32> = (0..bs as u32).collect();
            // one epoch over exactly one batch = one train step + staging
            bench.run(format!("train_step/{loss}/bs{bs}"), || {
                trainer
                    .train_epoch(&data, &indices, 0.01, &mut rng)
                    .unwrap()
                    .mean_loss
            });
        }
    }

    // predict path (used for per-epoch validation AUC)
    let mut trainer = Trainer::new(backend.as_ref(), "resnet", &LossSpec::hinge(), 100)?;
    trainer.init(0)?;
    let eval_idx: Vec<u32> = (0..1000).collect();
    bench.run("predict/resnet/1000_examples", || {
        trainer.predict(&data, &eval_idx).unwrap().len()
    });

    bench.write_csv("results/bench_train_step.csv")?;
    Ok(())
}
