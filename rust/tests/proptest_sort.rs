//! Differential sort-correctness layer for the `SortEngine` seam.
//!
//! Every strategy — comparison (the `total_cmp` reference), LSD radix
//! on the monotone u64 key transform, and adaptive run-merge — must
//! produce the *identical* permutation: ascending by key under
//! `total_cmp`, then negatives before positives on exact-key ties (when
//! requested), then index ascending.  The training engine's bit-exact
//! reproducibility across strategies rests entirely on this invariant,
//! so these tests pin it on adversarial key distributions (ties, signed
//! zeros, subnormals, ulp-adjacent magnitudes around 2^24, near- and
//! reverse-sorted streams) and on adversarial adaptive seeds.
//!
//! Like `proptest_losses.rs`, this uses an in-tree case generator (the
//! `proptest` crate is unavailable offline): many seeded random cases,
//! shrink-free but wide.

use allpairs::data::Rng;
use allpairs::losses::sort::{key_bits, MAX_MERGE_RUNS};
use allpairs::losses::weighted::WeightedSquaredHinge;
use allpairs::losses::{
    BatchView, LossFn, LossSpec, LossWorkspace, SortEngine, SortStrategy,
};

/// Labels with roughly `pos_frac` positives.
fn labels(n: usize, pos_frac: f64, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|_| if rng.uniform() < pos_frac { 1.0 } else { 0.0 })
        .collect()
}

/// The documented canonical order relation, written independently of
/// the engine internals: `total_cmp`, then class (negatives first when
/// enabled), then index.
fn canonical_lt(keys: &[f64], is_pos: &[f32], neg_first: bool, a: u32, b: u32) -> bool {
    let (a, b) = (a as usize, b as usize);
    match keys[a].total_cmp(&keys[b]) {
        std::cmp::Ordering::Less => return true,
        std::cmp::Ordering::Greater => return false,
        std::cmp::Ordering::Equal => {}
    }
    if neg_first {
        let (ca, cb) = (is_pos[a] != 0.0, is_pos[b] != 0.0);
        if ca != cb {
            return !ca; // the negative (false) comes first
        }
    }
    a < b
}

/// Assert that `order` is exactly the canonical permutation of `keys`.
fn assert_canonical(keys: &[f64], is_pos: &[f32], neg_first: bool, order: &[u32], ctx: &str) {
    assert_eq!(order.len(), keys.len(), "{ctx}: length");
    let mut seen = vec![false; keys.len()];
    for &i in order {
        assert!(!seen[i as usize], "{ctx}: index {i} repeated");
        seen[i as usize] = true;
    }
    for pair in order.windows(2) {
        assert!(
            canonical_lt(keys, is_pos, neg_first, pair[0], pair[1]),
            "{ctx}: order[..] has {} before {} (keys {} vs {})",
            pair[0],
            pair[1],
            keys[pair[0] as usize],
            keys[pair[1] as usize]
        );
    }
}

/// Run every strategy (adaptive under several adversarial seeds) on one
/// case and require the identical permutation, which is additionally
/// validated against the independent order relation above.
fn check_case(keys: &[f64], is_pos: &[f32], ctx: &str) {
    let n = keys.len();
    for neg_first in [false, true] {
        let ctx = format!("{ctx} (neg_first={neg_first})");
        let mut reference = Vec::new();
        SortEngine::new(SortStrategy::Comparison)
            .order_by_keys(keys, is_pos, neg_first, &mut reference);
        assert_canonical(keys, is_pos, neg_first, &reference, &ctx);

        let mut order = Vec::new();
        SortEngine::new(SortStrategy::Radix).order_by_keys(keys, is_pos, neg_first, &mut order);
        assert_eq!(order, reference, "{ctx}: radix");

        // Adaptive from assorted seeds: fresh (identity), the exact
        // answer, reversed, rotated, a full shuffle (forces the
        // radix fallback once runs exceed MAX_MERGE_RUNS), and a
        // wrong-length seed that must be ignored.
        let mut seeds: Vec<(&str, Vec<u32>)> = vec![
            ("identity", (0..n as u32).collect()),
            ("exact", reference.clone()),
            ("reversed", reference.iter().rev().copied().collect()),
        ];
        if n > 1 {
            let mut rotated = reference.clone();
            rotated.rotate_left(n / 2);
            seeds.push(("rotated", rotated));
            let mut shuffled: Vec<u32> = (0..n as u32).collect();
            Rng::new(0xADA7).shuffle(&mut shuffled);
            seeds.push(("shuffled", shuffled));
        }
        for (name, seed) in &seeds {
            let mut engine = SortEngine::new(SortStrategy::Adaptive);
            engine.seed_prev(seed);
            engine.order_by_keys(keys, is_pos, neg_first, &mut order);
            assert_eq!(order, reference, "{ctx}: adaptive from {name} seed");
        }
        let mut engine = SortEngine::new(SortStrategy::Adaptive);
        let wrong_len: Vec<u32> = (0..n as u32 + 3).collect();
        engine.seed_prev(&wrong_len); // wrong length: ignored
        engine.order_by_keys(keys, is_pos, neg_first, &mut order);
        assert_eq!(order, reference, "{ctx}: adaptive with wrong-length seed");
    }
}

#[test]
fn prop_all_equal_keys_resolve_by_class_then_index() {
    let mut rng = Rng::new(1);
    for &value in &[0.0_f64, -0.0, 1.0, -3.5, f64::INFINITY, f64::NAN] {
        for n in [0usize, 1, 2, 255, 256, 257, 1000] {
            let keys = vec![value; n];
            let is_pos = labels(n, 0.3, &mut rng);
            check_case(&keys, &is_pos, &format!("all-equal {value} n={n}"));
        }
    }
}

#[test]
fn prop_quantized_heavy_ties() {
    let mut rng = Rng::new(2);
    for case in 0..30 {
        let n = rng.below(1500);
        let levels = 1 + rng.below(8); // as few as one distinct key
        let keys: Vec<f64> = (0..n)
            .map(|_| (rng.below(levels) as f64 - levels as f64 / 2.0) * 0.5)
            .collect();
        let is_pos = labels(n, [0.01, 0.1, 0.5][rng.below(3)], &mut rng);
        check_case(&keys, &is_pos, &format!("quantized case {case} (n={n})"));
    }
}

#[test]
fn prop_near_sorted_and_reverse_sorted() {
    let mut rng = Rng::new(3);
    for case in 0..20 {
        let n = 2 + rng.below(1200);
        let mut keys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        keys.sort_by(f64::total_cmp);
        let is_pos = labels(n, 0.2, &mut rng);
        check_case(&keys, &is_pos, &format!("sorted case {case}"));
        // a few adjacent transpositions: the adaptive merge regime
        let swaps = 1 + rng.below(20);
        for _ in 0..swaps {
            let i = rng.below(n - 1);
            keys.swap(i, i + 1);
        }
        check_case(&keys, &is_pos, &format!("near-sorted case {case}"));
        keys.reverse();
        check_case(&keys, &is_pos, &format!("reverse-sorted case {case}"));
    }
}

#[test]
fn prop_signed_zeros_and_subnormals() {
    let mut rng = Rng::new(4);
    let specials = [
        0.0_f64,
        -0.0,
        f64::from_bits(1),             // smallest positive subnormal
        -f64::from_bits(1),            // smallest negative subnormal
        f64::from_bits(0x000F_FFFF_FFFF_FFFF), // largest subnormal
        f64::MIN_POSITIVE,
        -f64::MIN_POSITIVE,
        f64::EPSILON,
        -f64::EPSILON,
    ];
    for case in 0..30 {
        let n = rng.below(800);
        let keys: Vec<f64> = (0..n).map(|_| specials[rng.below(specials.len())]).collect();
        let is_pos = labels(n, 0.4, &mut rng);
        check_case(&keys, &is_pos, &format!("zeros/subnormals case {case}"));
    }
    // key_bits itself must separate the signed zeros
    assert!(key_bits(-0.0) < key_bits(0.0));
}

#[test]
fn prop_ulp_adjacent_values_around_2_pow_24() {
    // The f32 sort-key precision regression family: around 2^24 the
    // augmented values differ by single f64 ulps once cast through the
    // hinge-key pipeline; the u64 transform must keep them distinct and
    // ordered exactly as total_cmp does.
    let big = 16_777_216.0_f64; // 2^24
    let mut rng = Rng::new(5);
    let family: Vec<f64> = (0..6)
        .flat_map(|k| {
            let base = big + k as f64;
            [base, f64::from_bits(base.to_bits() + 1), -base]
        })
        .collect();
    for case in 0..20 {
        let n = rng.below(600);
        let keys: Vec<f64> = (0..n).map(|_| family[rng.below(family.len())]).collect();
        let is_pos = labels(n, 0.15, &mut rng);
        check_case(&keys, &is_pos, &format!("2^24 family case {case}"));
    }
}

#[test]
fn prop_random_wide_magnitudes() {
    let mut rng = Rng::new(6);
    for case in 0..40 {
        let n = rng.below(2000);
        let scale = [1e-300, 1e-6, 1.0, 1e6, 1e300][rng.below(5)];
        let keys: Vec<f64> = (0..n).map(|_| rng.normal() * scale).collect();
        let is_pos = labels(n, [0.01, 0.3, 0.9][rng.below(3)], &mut rng);
        check_case(&keys, &is_pos, &format!("wide case {case} (scale {scale})"));
    }
}

#[test]
fn prop_key_bits_is_a_total_cmp_order_isomorphism() {
    // Random pairs across the full bit space, including NaN payloads:
    // key_bits(a) < key_bits(b) exactly when a.total_cmp(b) is Less.
    let mut rng = Rng::new(7);
    for _ in 0..20_000 {
        let a = f64::from_bits(
            ((rng.below(u32::MAX as usize) as u64) << 32) | rng.below(u32::MAX as usize) as u64,
        );
        let b = f64::from_bits(
            ((rng.below(u32::MAX as usize) as u64) << 32) | rng.below(u32::MAX as usize) as u64,
        );
        assert_eq!(
            key_bits(a).cmp(&key_bits(b)),
            a.total_cmp(&b),
            "a={a:?} ({:#x}) b={b:?} ({:#x})",
            a.to_bits(),
            b.to_bits()
        );
    }
}

#[test]
fn prop_adaptive_run_threshold_boundary() {
    // Construct seeds with run counts straddling MAX_MERGE_RUNS so both
    // the merge path and the radix fallback are exercised on the same
    // keys, and agree.
    let n = 4 * MAX_MERGE_RUNS;
    let mut rng = Rng::new(8);
    let keys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let is_pos = labels(n, 0.2, &mut rng);
    let mut reference = Vec::new();
    SortEngine::new(SortStrategy::Comparison).order_by_keys(&keys, &is_pos, true, &mut reference);
    for runs_target in [2usize, MAX_MERGE_RUNS - 1, MAX_MERGE_RUNS + 8, n / 2] {
        // interleave `runs_target` ascending slices of the reference
        let mut seed = Vec::with_capacity(n);
        for r in 0..runs_target {
            seed.extend(reference.iter().skip(r).step_by(runs_target));
        }
        let mut engine = SortEngine::new(SortStrategy::Adaptive);
        engine.seed_prev(&seed);
        let mut order = Vec::new();
        engine.order_by_keys(&keys, &is_pos, true, &mut order);
        assert_eq!(order, reference, "seed with ~{runs_target} runs");
    }
}

#[test]
fn prop_multi_step_adaptive_training_is_bit_identical_to_comparison() {
    // The end-to-end property the engine relies on: K evolving steps
    // through the public kernel paths (squared hinge, linear hinge with
    // its negatives-first ordering, weighted hinge) where the adaptive
    // workspace carries its previous order from step to step, versus a
    // from-scratch comparison workspace at every step.  Loss and
    // gradient must agree bit for bit at each of the K steps.
    let mut rng = Rng::new(9);
    for case in 0..6 {
        let n = 50 + rng.below(500);
        let mut scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let is_pos = labels(n, [0.05, 0.3][case % 2], &mut rng);
        let weights: Vec<f32> = (0..n).map(|_| (rng.uniform() * 2.0) as f32).collect();
        let hinge = LossSpec::Hinge { margin: 1.0 }.build().unwrap();
        let lhinge = LossSpec::LinearHinge { margin: 1.0 }.build().unwrap();
        let whinge = WeightedSquaredHinge::new(1.0);
        let mut adaptive = LossWorkspace::with_sort_strategy(SortStrategy::Adaptive);
        let mut adaptive_w = LossWorkspace::with_sort_strategy(SortStrategy::Adaptive);
        for step in 0..5 {
            let batch = BatchView::new(&scores, &is_pos);
            let wbatch = BatchView::weighted(&scores, &is_pos, &weights);
            for (name, kernel) in [("hinge", &hinge), ("lhinge", &lhinge)] {
                let la = kernel.loss_and_grad(batch, &mut adaptive);
                let ga = adaptive.grad.clone();
                let mut fresh = LossWorkspace::with_sort_strategy(SortStrategy::Comparison);
                let lc = kernel.loss_and_grad(batch, &mut fresh);
                assert_eq!(
                    la.to_bits(),
                    lc.to_bits(),
                    "case {case} step {step}: {name} loss"
                );
                assert_eq!(ga, fresh.grad, "case {case} step {step}: {name} grad");
            }
            let la = LossFn::loss_and_grad(&whinge, wbatch, &mut adaptive_w);
            let ga = adaptive_w.grad.clone();
            let mut fresh = LossWorkspace::with_sort_strategy(SortStrategy::Comparison);
            let lc = LossFn::loss_and_grad(&whinge, wbatch, &mut fresh);
            assert_eq!(la.to_bits(), lc.to_bits(), "case {case} step {step}: whinge");
            assert_eq!(ga, fresh.grad, "case {case} step {step}: whinge grad");
            // evolve the scores a little: the next step's keys are
            // near-sorted relative to the carried adaptive order
            for s in scores.iter_mut() {
                *s += (rng.normal() * 0.02) as f32;
            }
        }
    }
}
