//! L-BFGS extension tests over the real `grad_*` artifacts.
//! Skipped (cleanly) until `make artifacts` has produced a manifest with
//! grad artifacts.

use allpairs::data::Rng;
use allpairs::metrics::auc;
use allpairs::runtime::Runtime;
use allpairs::train::lbfgs::{minimize, FullBatchObjective, LbfgsConfig};

fn artifacts_with_grad() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
    text.contains("\"grad\"").then_some(dir)
}

macro_rules! require_grad_artifacts {
    () => {
        match artifacts_with_grad() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: grad artifacts absent; run `make artifacts`");
                return;
            }
        }
    };
}

/// Separable 64-dim features (same construction as the runtime tests).
fn feature_batch(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(n * 64);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = rng.uniform() < 0.3;
        labels.push(if pos { 1.0 } else { 0.0 });
        for d in 0..64 {
            let shift = if pos && d < 8 { 1.5 } else { 0.0 };
            rows.push(rng.normal() as f32 + shift);
        }
    }
    (rows, labels)
}

#[test]
fn lbfgs_descends_and_separates() {
    let dir = require_grad_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let (rows, labels) = feature_batch(600, 1);
    let mut objective =
        FullBatchObjective::new(&runtime, "mlp", "hinge", &rows, &labels).unwrap();
    let theta0 = objective.init_params("mlp", "hinge", 0).unwrap();
    let (l0, _) = objective.eval(&theta0).unwrap();
    let config = LbfgsConfig {
        max_iters: 15,
        ..Default::default()
    };
    let (theta, trace) = minimize(&mut objective, theta0, &config).unwrap();
    assert!(!trace.is_empty());
    let final_loss = trace.last().unwrap().loss;
    assert!(final_loss.is_finite());
    assert!(final_loss < l0 * 0.5, "loss {l0} -> {final_loss}");
    // monotone non-increasing trace (Armijo guarantees decrease)
    let mut prev = l0;
    for r in &trace {
        assert!(r.loss <= prev * (1.0 + 1e-9), "iter {}: {} > {prev}", r.iter, r.loss);
        prev = r.loss;
    }
    assert_eq!(theta.len(), objective.dim());
}

#[test]
fn lbfgs_beats_few_epoch_sgd_on_full_batch_objective() {
    // The paper's §5 conjecture at reproduction scale: with the same
    // gradient-evaluation budget, deterministic full-batch L-BFGS reaches
    // a lower full-batch hinge loss than plain full-batch gradient
    // descent (momentum-free), because the problem is ill-conditioned.
    let dir = require_grad_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let (rows, labels) = feature_batch(600, 2);
    let mut objective =
        FullBatchObjective::new(&runtime, "mlp", "hinge", &rows, &labels).unwrap();
    let theta0 = objective.init_params("mlp", "hinge", 1).unwrap();

    // Budget: ~30 gradient evaluations each.
    let config = LbfgsConfig {
        max_iters: 12,
        max_ls: 4,
        ..Default::default()
    };
    let (_, trace) = minimize(&mut objective, theta0.clone(), &config).unwrap();
    let lbfgs_loss = trace.last().unwrap().loss;
    let lbfgs_evals = objective.evals;

    // Plain gradient descent with a tuned-ish fixed step, same evals.
    objective.evals = 0;
    let mut theta = theta0;
    let mut gd_loss = f64::INFINITY;
    for _ in 0..lbfgs_evals {
        let (l, g) = objective.eval(&theta).unwrap();
        gd_loss = l;
        for (t, gi) in theta.iter_mut().zip(&g) {
            *t -= 0.5 * gi;
        }
    }
    assert!(
        lbfgs_loss < gd_loss,
        "lbfgs {lbfgs_loss} (evals {lbfgs_evals}) vs gd {gd_loss}"
    );
}

#[test]
fn lbfgs_solution_ranks_well() {
    let dir = require_grad_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let (rows, labels) = feature_batch(500, 3);
    let mut objective =
        FullBatchObjective::new(&runtime, "mlp", "hinge", &rows, &labels).unwrap();
    let theta0 = objective.init_params("mlp", "hinge", 2).unwrap();
    let (theta, _) = minimize(
        &mut objective,
        theta0,
        &LbfgsConfig {
            max_iters: 20,
            ..Default::default()
        },
    )
    .unwrap();
    // score the training batch through the predict artifact by loading
    // theta back into a trainer state (params half; momentum zeros).
    let mut trainer = allpairs::train::Trainer::new(&runtime, "mlp", "hinge", 100).unwrap();
    trainer.init(0).unwrap();
    let mut state = trainer.state_to_host().unwrap();
    let mut offset = 0;
    let n_params = state.len() / 2;
    for t in state.iter_mut().take(n_params) {
        let len = t.data.len();
        t.data.copy_from_slice(&theta[offset..offset + len]);
        offset += len;
    }
    trainer.load_state(&state).unwrap();
    let data = allpairs::data::Dataset::new(rows, labels.clone(), 0, 64);
    let idx: Vec<u32> = (0..data.len() as u32).collect();
    let scores = trainer.predict(&data, &idx).unwrap();
    let a = auc(&scores, &labels).unwrap();
    assert!(a > 0.95, "train AUC after L-BFGS: {a}");
}
