//! L-BFGS extension tests over the native full-batch objective (every
//! build) and the PJRT `grad_*` artifacts (feature `pjrt` + artifacts).

use allpairs::data::Rng;
use allpairs::losses::LossSpec;
use allpairs::metrics::auc;
use allpairs::runtime::{NativeBackend, NativeSpec};
use allpairs::train::lbfgs::{minimize, LbfgsConfig, Objective};

/// Separable 64-dim features (same construction as the runtime tests).
fn feature_batch(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(n * 64);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = rng.uniform() < 0.3;
        labels.push(if pos { 1.0 } else { 0.0 });
        for d in 0..64 {
            let shift = if pos && d < 8 { 1.5 } else { 0.0 };
            rows.push(rng.normal() as f32 + shift);
        }
    }
    (rows, labels)
}

fn native_backend() -> NativeBackend {
    NativeBackend::new(NativeSpec {
        input_dim: 64,
        hidden: 16,
        threads: 1,
        ..NativeSpec::default()
    })
}

#[test]
fn lbfgs_descends_and_stays_monotone() {
    let backend = native_backend();
    let (rows, labels) = feature_batch(600, 1);
    let mut objective = backend.objective("mlp", &LossSpec::hinge(), &rows, &labels).unwrap();
    let theta0 = objective.init_params(0);
    let (l0, _) = objective.eval(&theta0).unwrap();
    let config = LbfgsConfig {
        max_iters: 15,
        ..Default::default()
    };
    let (theta, trace) = minimize(&mut objective, theta0, &config).unwrap();
    assert!(!trace.is_empty());
    let final_loss = trace.last().unwrap().loss;
    assert!(final_loss.is_finite());
    assert!(final_loss < l0, "loss {l0} -> {final_loss}");
    // monotone non-increasing trace (Armijo guarantees decrease)
    let mut prev = l0;
    for r in &trace {
        assert!(
            r.loss <= prev * (1.0 + 1e-9),
            "iter {}: {} > {prev}",
            r.iter,
            r.loss
        );
        prev = r.loss;
    }
    assert_eq!(theta.len(), objective.dim());
}

#[test]
fn lbfgs_matches_gd_budget_and_descends_further() {
    // The paper's §5 conjecture at reproduction scale: with the same
    // gradient-evaluation budget, L-BFGS should not lose to plain
    // momentum-free full-batch gradient descent with an untuned step.
    let backend = native_backend();
    let (rows, labels) = feature_batch(600, 2);
    let mut objective = backend.objective("mlp", &LossSpec::hinge(), &rows, &labels).unwrap();
    let theta0 = objective.init_params(1);

    let config = LbfgsConfig {
        max_iters: 12,
        max_ls: 4,
        ..Default::default()
    };
    let (_, trace) = minimize(&mut objective, theta0.clone(), &config).unwrap();
    let lbfgs_loss = trace.last().unwrap().loss;
    let lbfgs_evals = objective.evals;

    // Plain gradient descent with a fixed step, same eval budget.
    objective.evals = 0;
    let mut theta = theta0;
    let mut gd_loss = f64::INFINITY;
    for _ in 0..lbfgs_evals {
        let (l, g) = objective.eval(&theta).unwrap();
        gd_loss = l;
        for (t, gi) in theta.iter_mut().zip(&g) {
            *t -= 0.5 * gi;
        }
    }
    assert!(
        lbfgs_loss <= gd_loss,
        "lbfgs {lbfgs_loss} (evals {lbfgs_evals}) vs gd {gd_loss}"
    );
}

#[test]
fn lbfgs_solution_ranks_well() {
    let backend = native_backend();
    let (rows, labels) = feature_batch(500, 3);
    let mut objective = backend.objective("mlp", &LossSpec::hinge(), &rows, &labels).unwrap();
    let theta0 = objective.init_params(2);
    let (theta, _) = minimize(
        &mut objective,
        theta0,
        &LbfgsConfig {
            max_iters: 25,
            ..Default::default()
        },
    )
    .unwrap();
    let scores = objective.scores(&theta).unwrap();
    let a = auc(&scores, &labels).unwrap();
    assert!(a > 0.85, "train AUC after L-BFGS: {a}");
}

/// PJRT `grad_*`-artifact tests; need a real `xla` crate build plus
/// `make artifacts`.  Skipped cleanly otherwise.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use allpairs::runtime::Runtime;
    use allpairs::train::lbfgs::FullBatchObjective;

    fn artifacts_with_grad() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
        text.contains("\"grad\"").then_some(dir)
    }

    macro_rules! require_runtime {
        () => {
            match artifacts_with_grad().and_then(|dir| Runtime::new(&dir).ok()) {
                Some(rt) => rt,
                None => {
                    eprintln!("skipping: grad artifacts absent; run `make artifacts`");
                    return;
                }
            }
        };
    }

    #[test]
    fn pjrt_lbfgs_descends() {
        let runtime = require_runtime!();
        let (rows, labels) = feature_batch(600, 1);
        let mut objective =
            FullBatchObjective::new(&runtime, "mlp", &LossSpec::hinge(), &rows, &labels).unwrap();
        let theta0 = objective.init_params("mlp", &LossSpec::hinge(), 0).unwrap();
        let (l0, _) = objective.eval(&theta0).unwrap();
        let config = LbfgsConfig {
            max_iters: 15,
            ..Default::default()
        };
        let (theta, trace) = minimize(&mut objective, theta0, &config).unwrap();
        let final_loss = trace.last().unwrap().loss;
        assert!(final_loss.is_finite());
        assert!(final_loss < l0 * 0.5, "loss {l0} -> {final_loss}");
        assert_eq!(theta.len(), objective.dim());
    }
}
