//! Backend integration tests.
//!
//! The native-backend tests run in every build — they exercise the full
//! path the sweep uses: init → train steps → predict → checkpoint.  The
//! PJRT tests (feature `pjrt`, plus `make artifacts`) additionally
//! cross-check the Pallas hinge kernel against the native Algorithm 2 on
//! the same batch.

use allpairs::data::{Dataset, Rng};
use allpairs::losses::LossSpec;
use allpairs::runtime::{Backend, BackendSpec, NativeSpec};
use allpairs::train::Trainer;

fn native_backend() -> Box<dyn Backend> {
    BackendSpec::Native(NativeSpec {
        input_dim: 64,
        hidden: 16,
        threads: 1,
        ..NativeSpec::default()
    })
    .connect()
    .unwrap()
}

fn hinge() -> LossSpec {
    LossSpec::hinge()
}

fn feature_dataset(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 64);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = rng.uniform() < 0.3;
        y.push(if pos { 1.0 } else { 0.0 });
        for d in 0..64 {
            let shift = if pos && d < 8 { 1.5 } else { 0.0 };
            x.push(rng.normal() as f32 + shift);
        }
    }
    Dataset::new(x, y, 0, 64)
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let backend = native_backend();
    let mut a = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    let mut b = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    a.init(3).unwrap();
    b.init(3).unwrap();
    let cat = |t: &Trainer| -> Vec<f32> {
        t.state_to_host()
            .unwrap()
            .iter()
            .flat_map(|t| t.data.clone())
            .collect()
    };
    assert_eq!(cat(&a), cat(&b));
    b.init(4).unwrap();
    assert_ne!(cat(&a), cat(&b));
}

#[test]
fn single_train_step_runs_and_returns_finite_loss() {
    let backend = native_backend();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    trainer.init(0).unwrap();
    let data = feature_dataset(100, 1);
    let idx: Vec<u32> = (0..100).collect();
    let mut rng = Rng::new(2);
    let stats = trainer.train_epoch(&data, &idx, 0.05, &mut rng).unwrap();
    assert_eq!(stats.n_batches, 1);
    assert_eq!(stats.n_examples, 100);
    assert!(stats.mean_loss.is_finite());
    assert!(stats.mean_loss > 0.0);
}

#[test]
fn training_reduces_loss_and_improves_auc() {
    let backend = native_backend();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    let data = feature_dataset(400, 3);
    let idx: Vec<u32> = (0..400).collect();
    let mut rng = Rng::new(4);
    let history = trainer
        .fit(&data, &idx, &idx, 0.02, 10, 0, &mut rng)
        .unwrap();
    let first = &history.records[0];
    let last = history.records.last().unwrap();
    assert!(last.train_loss < first.train_loss, "{history:?}");
    assert!(last.val_auc.unwrap() > 0.75, "{history:?}");
}

#[test]
fn predict_is_chunking_invariant() {
    let backend = native_backend();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    trainer.init(1).unwrap();
    let data = feature_dataset(300, 5);
    let all: Vec<u32> = (0..300).collect();
    let scores = trainer.predict(&data, &all).unwrap();
    assert_eq!(scores.len(), 300);
    let head: Vec<u32> = (0..10).collect();
    let scores_head = trainer.predict(&data, &head).unwrap();
    for (a, b) in scores_head.iter().zip(&scores[..10]) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn checkpoint_roundtrip_preserves_predictions() {
    let backend = native_backend();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 100).unwrap();
    trainer.init(7).unwrap();
    let data = feature_dataset(120, 8);
    let idx: Vec<u32> = (0..120).collect();
    let mut rng = Rng::new(9);
    trainer.train_epoch(&data, &idx, 0.05, &mut rng).unwrap();
    let before = trainer.predict(&data, &idx).unwrap();

    let snapshot = trainer.state_to_host().unwrap();
    let path = std::env::temp_dir().join("allpairs_integration_ckpt.bin");
    allpairs::train::checkpoint::save(&path, &snapshot).unwrap();
    let restored = allpairs::train::checkpoint::load(&path).unwrap();

    // scramble the live state with another epoch, then restore
    trainer.train_epoch(&data, &idx, 0.05, &mut rng).unwrap();
    trainer.load_state(&restored).unwrap();
    let after = trainer.predict(&data, &idx).unwrap();
    for (a, b) in before.iter().zip(&after) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn backend_monitor_matches_direct_algorithm2() {
    use allpairs::coordinator::monitor;
    let backend = native_backend();
    let mut rng = Rng::new(10);
    let n = 2000;
    let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let is_pos: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.15 { 1.0 } else { 0.0 })
        .collect();
    let native = monitor::monitor_native(&scores, &is_pos, 1.0);
    let via_backend =
        monitor::monitor_backend(backend.as_ref(), &hinge(), &scores, &is_pos).unwrap();
    let rel = (native - via_backend).abs() / native.abs().max(1e-9);
    assert!(rel < 1e-9, "direct {native} vs backend {via_backend}");
}

/// PJRT-path tests: need a `--features pjrt` build (with the real `xla`
/// crate) and `make artifacts`; skipped cleanly otherwise.
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use allpairs::losses::functional::SquaredHinge;
    use allpairs::runtime::PjrtBackend;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    macro_rules! require_backend {
        () => {
            match artifacts_dir().and_then(|dir| PjrtBackend::new(&dir).ok()) {
                Some(backend) => backend,
                None => {
                    eprintln!("skipping: pjrt backend unavailable (run `make artifacts`)");
                    return;
                }
            }
        };
    }

    #[test]
    fn pjrt_training_reduces_loss_and_improves_auc() {
        let backend = require_backend!();
        let mut trainer = Trainer::new(&backend, "mlp", &hinge(), 100).unwrap();
        let data = feature_dataset(400, 3);
        let idx: Vec<u32> = (0..400).collect();
        let mut rng = Rng::new(4);
        let history = trainer.fit(&data, &idx, &idx, 0.1, 6, 0, &mut rng).unwrap();
        let first = &history.records[0];
        let last = history.records.last().unwrap();
        assert!(last.train_loss < first.train_loss, "{history:?}");
        assert!(last.val_auc.unwrap() > 0.85, "{history:?}");
    }

    #[test]
    fn pallas_loss_eval_matches_native_rust_algorithm2() {
        let backend = require_backend!();
        let mut rng = Rng::new(10);
        let n = 2000;
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let is_pos: Vec<f32> = (0..n)
            .map(|_| if rng.uniform() < 0.15 { 1.0 } else { 0.0 })
            .collect();
        let native = {
            let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
            let n_neg = n as f64 - n_pos;
            SquaredHinge::new(1.0).loss_only(&scores, &is_pos) / (n_pos * n_neg)
        };
        // eval_loss is pair-normalized (the L2 loss wrappers normalize
        // internally), matching monitor_native's convention.
        let pjrt = allpairs::coordinator::monitor::monitor_backend(
            &backend, &hinge(), &scores, &is_pos,
        )
        .unwrap();
        let rel = (native - pjrt).abs() / native.abs().max(1e-9);
        assert!(rel < 1e-4, "native {native} vs pallas {pjrt}");
    }
}
