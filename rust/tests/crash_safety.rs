//! Crash-safety end to end (DESIGN.md §10): torn-journal recovery at
//! every byte offset, failpoint-driven scheduler faults (panic
//! isolation, retry, exhaustion), and the resume invariant — an
//! interrupted-then-resumed sweep produces the identical record set as
//! an uninterrupted run of the same seed.

use std::collections::BTreeMap;

use allpairs::config::SweepConfig;
use allpairs::coordinator::cv;
use allpairs::data::synth::{generate, SynthSpec, SYNTH_DATASETS};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::sweep::results::{self, RunResult};
use allpairs::sweep::runner::{JobData, FP_RUN_JOB};
use allpairs::sweep::scheduler::{run_sweep_opts, RetryPolicy, SweepOptions};
use allpairs::sweep::Job;
use allpairs::util::failpoint;
use std::sync::Arc;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("allpairs_crash_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn fake_result(seed: u32, auc: f64) -> RunResult {
    RunResult {
        job: Job {
            dataset: "synth-pets".into(),
            imratio: 0.2,
            loss: "hinge".parse().unwrap(),
            batch: 50,
            lr: 0.01,
            seed,
            model: "resnet".into(),
            epochs: 1,
            patience: None,
            sampling: "preserve".into(),
        },
        best_val_auc: Some(auc),
        best_epoch: Some(0),
        test_auc: Some(auc - 0.02),
        final_train_loss: 0.4,
        diverged: false,
        seconds: 1.5,
        achieved_imratio: 0.199,
    }
}

// ---------------------------------------------------------------- journal

#[test]
fn torn_tail_recovers_at_every_byte_offset() {
    // Truncate the journal at EVERY byte offset inside the final record
    // (including the trailing newline): the lenient loader must recover
    // all complete lines, and after repair the journal must be strict-
    // loadable and appendable.
    let dir = tmp_dir("torn_every_offset");
    let path = dir.join("journal.jsonl");
    let originals = vec![fake_result(0, 0.9), fake_result(1, 0.8), fake_result(2, 0.7)];
    results::save_jsonl(&path, &originals).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // start of the final record = one past the second newline
    let second_nl = bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i)
        .nth(1)
        .unwrap();
    let last_start = second_nl + 1;
    assert!(last_start < bytes.len() - 1);

    for cut in last_start + 1..bytes.len() {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let replay = results::load_jsonl_lenient(&path)
            .unwrap_or_else(|e| panic!("lenient load failed at cut {cut}: {e}"));
        if cut == bytes.len() - 1 {
            // only the '\n' is missing: the record itself is intact
            assert_eq!(replay.results.len(), 3, "cut {cut}");
            assert!(replay.missing_newline);
        } else {
            assert_eq!(replay.results.len(), 2, "cut {cut}");
            assert!(replay.torn_bytes > 0, "cut {cut}");
            // the strict loader must reject the same file
            assert!(results::load_jsonl(&path).is_err(), "cut {cut}");
        }
        for (r, o) in replay.results.iter().zip(&originals) {
            assert_eq!(r.job.id(), o.job.id(), "cut {cut}");
        }
        // repair, then append — the journal must come back well-formed
        let recovered = results::repair_journal(&path).unwrap().results.len();
        let mut w = results::JsonlWriter::append_to(&path).unwrap();
        w.append(&fake_result(9, 0.5)).unwrap();
        drop(w);
        let all = results::load_jsonl(&path).unwrap();
        assert_eq!(all.len(), recovered + 1, "cut {cut}");
        assert_eq!(all.last().unwrap().job.seed, 9, "cut {cut}");
    }
}

// ------------------------------------------------------------- scheduler

fn sweep_data() -> JobData {
    let spec = SynthSpec {
        n_train: 300,
        n_test: 100,
        ..SYNTH_DATASETS[2] // synth-pets: 2 latent classes, learnable
    };
    let (train_pool, test) = generate(&spec, 99);
    JobData {
        train_pool: Arc::new(train_pool),
        test: Arc::new(test),
    }
}

fn sweep_job(seed: u32) -> Job {
    Job {
        dataset: "synth-pets".into(),
        imratio: 0.2,
        loss: "hinge".parse().unwrap(),
        batch: 50,
        lr: 0.01,
        seed,
        model: "mlp".into(),
        epochs: 1,
        patience: None,
        sampling: "preserve".into(),
    }
}

fn sweep_backend() -> BackendSpec {
    BackendSpec::Native(NativeSpec {
        input_dim: 16 * 16 * 3,
        hidden: 4,
        threads: 1,
        ..NativeSpec::default()
    })
}

#[test]
fn injected_panic_fails_one_job_and_the_rest_complete() {
    let _g = failpoint::serial_guard();
    // 6 jobs on 2 workers; the 3rd job *attempt* panics.  Panic
    // isolation must confine the damage to that one job while both
    // workers keep draining the queue.
    failpoint::arm_str(FP_RUN_JOB, "panic@3").unwrap();
    let mut datasets = BTreeMap::new();
    datasets.insert("synth-pets".to_string(), sweep_data());
    let jobs: Vec<Job> = (0..6).map(sweep_job).collect();
    let outcome = run_sweep_opts(
        &sweep_backend(),
        jobs,
        datasets,
        SweepOptions {
            workers: 2,
            retry: RetryPolicy {
                max_attempts: 3,
                base_backoff: std::time::Duration::from_millis(1),
            },
            ..SweepOptions::default()
        },
    );
    failpoint::disarm(FP_RUN_JOB);
    let outcome = outcome.unwrap();
    assert_eq!(outcome.results.len(), 5, "all non-panicking jobs must complete");
    assert_eq!(outcome.failures.len(), 1);
    let f = &outcome.failures[0];
    assert!(f.panicked);
    assert_eq!(f.attempts, 1, "panics are never retried");
    assert!(f.error.contains("failpoint"), "{}", f.error);
    // the failed job is one of the scheduled ids, exactly once
    let scheduled: Vec<String> = (0..6).map(|s| sweep_job(s).id()).collect();
    assert!(scheduled.contains(&f.job_id));
    assert!(!outcome.results.iter().any(|r| r.job.id() == f.job_id));
}

// ----------------------------------------------------------------- resume

fn micro_config() -> SweepConfig {
    SweepConfig {
        datasets: vec!["synth-pets".into()],
        imratios: vec![0.2],
        losses: vec![LossSpec::hinge()],
        batch_sizes: vec![50],
        seeds: vec![0, 1, 2],
        epochs: 1,
        max_train: Some(200),
        max_lrs: Some(1),
        workers: 1,
        backend: sweep_backend(),
        ..Default::default()
    }
}

/// Record set keyed by job id, with the only nondeterministic field
/// (wall time) zeroed — "bit-identical metrics" in comparable form.
fn record_set(results: &[RunResult]) -> std::collections::BTreeMap<String, String> {
    results
        .iter()
        .map(|r| {
            let mut r = r.clone();
            r.seconds = 0.0;
            (r.job.id(), r.to_json().dumps())
        })
        .collect()
}

#[test]
fn interrupted_then_resumed_sweep_matches_uninterrupted_run() {
    // serialize vs the panic-injection test: failpoint state is
    // process-global, and this test's sweeps hit the same site
    let _g = failpoint::serial_guard();
    let cfg = micro_config();
    assert_eq!(cfg.n_runs(), 3);

    // Uninterrupted reference run.
    let out_a = tmp_dir("resume_ref");
    cv::run(&cfg, &out_a, None).unwrap();
    let ref_results = results::load_jsonl(out_a.join("sweep_results.jsonl")).unwrap();
    assert_eq!(ref_results.len(), 3);

    // Simulate a crash: journal holds job 1 complete plus a torn slice
    // of job 2's record (a partially flushed line).
    let out_b = tmp_dir("resume_crash");
    let ref_bytes = std::fs::read(out_a.join("sweep_results.jsonl")).unwrap();
    let first_nl = ref_bytes.iter().position(|&b| b == b'\n').unwrap();
    let torn_end = (first_nl + 1 + 40).min(ref_bytes.len());
    std::fs::write(out_b.join("sweep_results.jsonl"), &ref_bytes[..torn_end]).unwrap();

    // Resume: replays the 1 intact record, repairs the tail, runs the
    // 2 missing jobs.
    let output = cv::run_with_options(&cfg, &out_b, None, &cv::RunOptions {
        resume: true,
        ..cv::RunOptions::default()
    })
    .unwrap();
    assert_eq!(output.replayed, 1);
    assert!(output.failures.is_empty());
    assert_eq!(output.results.len(), 3);

    // The journal is strict-loadable and its record set — keyed by
    // job.id(), metrics bit-identical — matches the uninterrupted run.
    let resumed = results::load_jsonl(out_b.join("sweep_results.jsonl")).unwrap();
    assert_eq!(resumed.len(), 3, "no duplicates, no gaps");
    assert_eq!(record_set(&resumed), record_set(&ref_results));

    // Resuming a *complete* journal replays everything and appends
    // nothing: the journal bytes are untouched.
    let before = std::fs::read(out_b.join("sweep_results.jsonl")).unwrap();
    let output = cv::run_with_options(&cfg, &out_b, None, &cv::RunOptions {
        resume: true,
        ..cv::RunOptions::default()
    })
    .unwrap();
    assert_eq!(output.replayed, 3);
    assert_eq!(output.results.len(), 3);
    let after = std::fs::read(out_b.join("sweep_results.jsonl")).unwrap();
    assert_eq!(before, after, "complete-journal resume must be a pure replay");
}

#[test]
fn resumed_journal_order_cannot_change_tied_selection() {
    // `sweep --resume` appends the previously-missing jobs at the
    // journal tail, so a resumed journal presents the same record SET
    // in a different ORDER than the uninterrupted run.  With exact
    // validation-AUC ties, an order-dependent tie-break would then
    // select (and report) a different model.  Write the same tied
    // records in uninterrupted order and in a resumed order, round-trip
    // both through the real journal, and require identical selection.
    let dir = tmp_dir("tied_selection");
    let mut a = fake_result(0, 0.9);
    a.job.batch = 10;
    a.test_auc = Some(0.83);
    let mut b = fake_result(0, 0.9);
    b.job.batch = 100;
    b.test_auc = Some(0.71);
    let mut c = fake_result(0, 0.9);
    c.job.lr = 0.1;
    c.test_auc = Some(0.64);
    let control = fake_result(1, 0.8);

    let uninterrupted = vec![a.clone(), b.clone(), c.clone(), control.clone()];
    // crash after b; resume replays {b} then appends the rest last
    let resumed = vec![b, control, a, c];

    let select_via_journal = |name: &str, records: &[RunResult]| {
        let path = dir.join(name);
        results::save_jsonl(&path, records).unwrap();
        let loaded = results::load_jsonl(&path).unwrap();
        allpairs::sweep::select::select_per_seed(&loaded)
            .into_iter()
            .map(|s| (s.seed, s.batch, s.lr, s.test_auc))
            .collect::<Vec<_>>()
    };
    let want = select_via_journal("uninterrupted.jsonl", &uninterrupted);
    assert_eq!(want.len(), 2);
    assert_eq!(
        (want[0].1, want[0].3),
        (10, Some(0.83)),
        "smallest grid key wins the tie"
    );
    assert_eq!(
        select_via_journal("resumed.jsonl", &resumed),
        want,
        "selection must be a pure function of the record set"
    );
}

#[test]
fn rerun_without_resume_rotates_never_truncates() {
    let _g = failpoint::serial_guard();
    let cfg = micro_config();
    let out = tmp_dir("rotate");
    cv::run(&cfg, &out, None).unwrap();
    let first = std::fs::read(out.join("sweep_results.jsonl")).unwrap();
    assert!(!first.is_empty());
    // second run, same dir, no --resume: the old journal must survive
    cv::run(&cfg, &out, None).unwrap();
    let rotated = std::fs::read(out.join("sweep_results.jsonl.1.bak")).unwrap();
    assert_eq!(rotated, first, "rotation must preserve the prior journal verbatim");
    let second = results::load_jsonl(out.join("sweep_results.jsonl")).unwrap();
    assert_eq!(second.len(), cfg.n_runs(), "fresh journal, not an append pile-up");
}
