//! Golden determinism: the same seed + config must reproduce the sweep
//! byte for byte — results, selection tables and per-epoch history —
//! across two independent runs.  This pins the streaming pipeline's
//! per-epoch reshuffle, the oversampling cycle and the early-stopping
//! logic to the seeded RNG (any hidden nondeterminism — map iteration,
//! time-based seeding, cross-thread reduction — breaks these).

use allpairs::config::SweepConfig;
use allpairs::coordinator::cv;
use allpairs::data::{features, FeatureSpec, Rng, SamplingMode, Split};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::sweep::results::{load_jsonl, RunResult};
use allpairs::train::{FitConfig, Trainer};

fn micro_config() -> SweepConfig {
    SweepConfig {
        datasets: vec!["synth-pets".into()],
        imratios: vec![0.1],
        losses: vec![LossSpec::hinge()],
        batch_sizes: vec![50, 100],
        sampling_modes: vec!["preserve".into(), "rebalance:0.5".into()],
        seeds: vec![0],
        epochs: 2,
        patience: Some(2),
        max_train: Some(300),
        max_lrs: Some(1),
        // one worker: completion order == queue order, so the JSONL
        // line order itself is part of the golden output
        workers: 1,
        backend: BackendSpec::Native(NativeSpec {
            input_dim: 16 * 16 * 3,
            hidden: 8,
            threads: 1,
            ..NativeSpec::default()
        }),
        ..Default::default()
    }
}

/// Canonical dump of results with the only nondeterministic field (wall
/// time) zeroed.
fn golden_dump(mut results: Vec<RunResult>) -> String {
    for r in &mut results {
        r.seconds = 0.0;
    }
    results
        .iter()
        .map(|r| r.to_json().dumps())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn sweep_outputs_are_identical_across_runs() {
    let cfg = micro_config();
    let out_a = std::env::temp_dir().join("allpairs_golden_a");
    let out_b = std::env::temp_dir().join("allpairs_golden_b");
    cv::run(&cfg, &out_a, None).unwrap();
    cv::run(&cfg, &out_b, None).unwrap();

    // results: identical modulo wall time (including line order)
    let ra = load_jsonl(out_a.join("sweep_results.jsonl")).unwrap();
    let rb = load_jsonl(out_b.join("sweep_results.jsonl")).unwrap();
    assert_eq!(ra.len(), cfg.n_runs());
    assert_eq!(golden_dump(ra), golden_dump(rb));

    // selection + report outputs carry no timing: byte-identical files
    for file in ["table2.md", "fig3.md", "fig3.csv"] {
        let a = std::fs::read(out_a.join(file)).unwrap();
        let b = std::fs::read(out_b.join(file)).unwrap();
        assert_eq!(a, b, "{file} differs between identical runs");
    }
}

#[test]
fn epoch_history_is_identical_across_runs() {
    // The streaming loop end to end — stratified reshuffle, rebalanced
    // oversampling, early stopping, best-checkpoint tracking — twice
    // from the same seed, compared bit for bit.
    let mut data_rng = Rng::new(41);
    let spec = FeatureSpec {
        pos_frac: 0.5,
        ..Default::default()
    };
    let pool = features::generate(&spec, 1200, &mut data_rng);
    let train = pool.imbalance(0.05, &mut data_rng);
    let split = Split::stratified(&train.y, 0.2, &mut data_rng);
    let backend = BackendSpec::Native(NativeSpec {
        input_dim: spec.dim,
        hidden: 16,
        threads: 1,
        ..NativeSpec::default()
    })
    .connect()
    .unwrap();
    let cfg = FitConfig {
        lr: 0.05,
        epochs: 6,
        patience: Some(2),
        sampling: SamplingMode::Rebalance { pos_fraction: 0.5 },
        seed: 3,
    };
    let run = || {
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &LossSpec::hinge(), 64).unwrap();
        trainer
            .fit_stream(
                &train,
                &split.subtrain,
                &split.validation,
                &cfg,
                &mut Rng::new(99),
            )
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.history.len(), b.history.len());
    assert_eq!(a.stopped_early, b.stopped_early);
    assert_eq!(a.diverged, b.diverged);
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.epoch, rb.epoch);
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
        assert_eq!(
            ra.val_auc.map(f64::to_bits),
            rb.val_auc.map(f64::to_bits)
        );
    }
    let (ba, bb) = (a.best.unwrap(), b.best.unwrap());
    assert_eq!(ba.epoch, bb.epoch);
    assert_eq!(ba.val_auc.to_bits(), bb.val_auc.to_bits());
    assert_eq!(ba.state, bb.state);
}
