//! Property tests for the native backend: its train step must equal a
//! reference step assembled by hand from `losses::functional` plus
//! explicit SGD-with-momentum algebra — many random cases, in-tree
//! generator (same style as `proptest_losses.rs`; the `proptest` crate
//! is unavailable offline).

use allpairs::data::Rng;
use allpairs::losses::functional::SquaredHinge;
use allpairs::losses::{LossSpec, PairwiseLoss};
use allpairs::runtime::{Backend, ModelExecutor, NativeBackend, NativeSpec};

const CASES: usize = 40;
const MOMENTUM: f32 = 0.9;

struct Case {
    dim: usize,
    batch: usize,
    x: Vec<f32>,
    is_pos: Vec<f32>,
    is_neg: Vec<f32>,
    lr: f32,
}

fn gen_case(rng: &mut Rng) -> Case {
    let dim = 2 + rng.below(10);
    let batch = 2 + rng.below(40);
    let pos_frac = [0.1, 0.3, 0.5][rng.below(3)];
    let pad_frac = [0.0, 0.2][rng.below(2)];
    let mut x = Vec::with_capacity(batch * dim);
    let mut is_pos = Vec::with_capacity(batch);
    let mut is_neg = Vec::with_capacity(batch);
    for _ in 0..batch {
        if rng.uniform() < pad_frac {
            // padding row: both masks zero, pixels zero
            is_pos.push(0.0);
            is_neg.push(0.0);
            x.resize(x.len() + dim, 0.0);
        } else {
            let pos = rng.uniform() < pos_frac;
            is_pos.push(if pos { 1.0 } else { 0.0 });
            is_neg.push(if pos { 0.0 } else { 1.0 });
            for _ in 0..dim {
                x.push(rng.normal() as f32);
            }
        }
    }
    Case {
        dim,
        batch,
        x,
        is_pos,
        is_neg,
        lr: [0.01, 0.1][rng.below(2)] as f32,
    }
}

/// Reference linear train step: forward, pairwise hinge on real rows,
/// normalized gradient, manual heavy-ball update.
fn reference_linear_step(
    w: &[f32],
    b: f32,
    vw: &[f32],
    vb: f32,
    case: &Case,
) -> (f64, Vec<f32>, f32, Vec<f32>, f32) {
    let dim = case.dim;
    // forward
    let scores: Vec<f32> = (0..case.batch)
        .map(|r| {
            let row = &case.x[r * dim..(r + 1) * dim];
            b + row.iter().zip(w).map(|(a, c)| a * c).sum::<f32>()
        })
        .collect();
    // compact real rows
    let mut c_scores = Vec::new();
    let mut c_pos = Vec::new();
    let mut c_rows = Vec::new();
    for r in 0..case.batch {
        if case.is_pos[r] != 0.0 || case.is_neg[r] != 0.0 {
            c_scores.push(scores[r]);
            c_pos.push(case.is_pos[r]);
            c_rows.push(r);
        }
    }
    let n_pos = c_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = c_pos.len() as f64 - n_pos;
    let norm = (n_pos * n_neg).max(1.0);
    let (raw, g_scores) = SquaredHinge::new(1.0).loss_and_grad(&c_scores, &c_pos);
    // parameter gradient
    let mut gw = vec![0.0_f32; dim];
    let mut gb = 0.0_f32;
    for (slot, &r) in c_rows.iter().enumerate() {
        let ds = (g_scores[slot] as f64 / norm) as f32;
        let row = &case.x[r * dim..(r + 1) * dim];
        for (g, &v) in gw.iter_mut().zip(row) {
            *g += ds * v;
        }
        gb += ds;
    }
    // heavy-ball
    let new_vw: Vec<f32> = vw.iter().zip(&gw).map(|(&v, &g)| MOMENTUM * v + g).collect();
    let new_vb = MOMENTUM * vb + gb;
    let new_w: Vec<f32> = w
        .iter()
        .zip(&new_vw)
        .map(|(&p, &v)| p - case.lr * v)
        .collect();
    let new_b = b - case.lr * new_vb;
    (raw / norm, new_w, new_b, new_vw, new_vb)
}

#[test]
fn prop_native_train_step_equals_functional_plus_manual_sgd() {
    let mut rng = Rng::new(42);
    for case_idx in 0..CASES {
        let case = gen_case(&mut rng);
        let backend = NativeBackend::new(NativeSpec {
            input_dim: case.dim,
            hidden: 0, // linear: the reference is exactly re-derivable
            threads: 1,
            ..NativeSpec::default()
        });
        let mut exec = backend.open("linear", &LossSpec::hinge(), case.batch).unwrap();
        exec.init(case_idx as u32).unwrap();

        // two steps: the second exercises non-zero momentum state
        for step in 0..2 {
            let state = exec.state_to_host().unwrap();
            let (w, b) = (state[0].data.clone(), state[1].data[0]);
            let (vw, vb) = (state[2].data.clone(), state[3].data[0]);
            let (want_loss, want_w, want_b, want_vw, want_vb) =
                reference_linear_step(&w, b, &vw, vb, &case);
            let got_loss = exec
                .train_step(&case.x, &case.is_pos, &case.is_neg, case.lr)
                .unwrap();
            let rel = (got_loss - want_loss).abs() / want_loss.abs().max(1.0);
            assert!(
                rel < 1e-9,
                "case {case_idx} step {step}: loss {got_loss} vs {want_loss}"
            );
            let after = exec.state_to_host().unwrap();
            let close = |a: &[f32], b: &[f32], what: &str| {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-5 * x.abs().max(1.0),
                        "case {case_idx} step {step} {what}: {x} vs {y}"
                    );
                }
            };
            close(&after[0].data, &want_w, "w");
            close(&after[1].data, &[want_b], "b");
            close(&after[2].data, &want_vw, "vw");
            close(&after[3].data, &[want_vb], "vb");
        }
    }
}

#[test]
fn prop_native_loss_matches_functional_loss_value() {
    // The reported batch loss equals the functional loss over the real
    // rows, normalized per pair — across losses.
    let mut rng = Rng::new(7);
    for _ in 0..CASES {
        let case = gen_case(&mut rng);
        let backend = NativeBackend::new(NativeSpec {
            input_dim: case.dim,
            hidden: 4,
            threads: 1,
            ..NativeSpec::default()
        });
        let mut exec = backend.open("mlp", &LossSpec::hinge(), case.batch).unwrap();
        exec.init(0).unwrap();
        let scores = exec.predict(&case.x, case.batch).unwrap();
        let mut c_scores = Vec::new();
        let mut c_pos = Vec::new();
        for r in 0..case.batch {
            if case.is_pos[r] != 0.0 || case.is_neg[r] != 0.0 {
                c_scores.push(scores[r]);
                c_pos.push(case.is_pos[r]);
            }
        }
        let n_pos = c_pos.iter().filter(|&&p| p != 0.0).count() as f64;
        let n_neg = c_pos.len() as f64 - n_pos;
        let want = SquaredHinge::new(1.0).loss_and_grad(&c_scores, &c_pos).0
            / (n_pos * n_neg).max(1.0);
        let got = exec
            .train_step(&case.x, &case.is_pos, &case.is_neg, 0.0)
            .unwrap();
        assert!(
            (got - want).abs() <= 1e-9 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }
}

#[test]
fn prop_predict_is_deterministic_across_thread_counts() {
    let mut rng = Rng::new(11);
    for _ in 0..10 {
        let dim = 8;
        let rows = 600; // above the rows-per-thread cutoff → parallel path
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.normal() as f32).collect();
        let mk = |threads: usize| {
            NativeBackend::new(NativeSpec {
                input_dim: dim,
                hidden: 8,
                threads,
                ..NativeSpec::default()
            })
        };
        let b1 = mk(1);
        let b4 = mk(4);
        let mut e1 = b1.open("mlp", &LossSpec::hinge(), 8).unwrap();
        let mut e4 = b4.open("mlp", &LossSpec::hinge(), 8).unwrap();
        e1.init(5).unwrap();
        e4.init(5).unwrap();
        // forward is row-independent: bit-identical across thread counts
        assert_eq!(e1.predict(&x, rows).unwrap(), e4.predict(&x, rows).unwrap());
    }
}
