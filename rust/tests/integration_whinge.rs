//! End-to-end training of the class-balanced weighted squared hinge
//! (`--loss whinge`) — the imbalance scenario the typed loss API turned
//! from dead code into a schedulable loss.
//!
//! A rebalanced synthetic imbalance run through [`Trainer::fit_stream`]
//! must (a) learn the signal (validation AUC >= 0.9) and (b) be
//! bit-deterministic across worker-thread counts {1, 8}: batches of 600
//! rows exceed twice the engine's 256-row chunk so the parallel data
//! path genuinely runs (DESIGN.md §7), while the weighted sweep itself
//! stays serial.

use allpairs::data::{features, FeatureSpec, Rng, SamplingMode, Split};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::train::{FitConfig, FitOutcome, Trainer};

const BATCH: usize = 600; // > 2 * engine::CHUNK_ROWS: parallel path engaged

fn fit_whinge(threads: usize) -> FitOutcome {
    // Strong 16-dim signal (the large_batch example's construction),
    // imbalanced to ~8% positive, rebalanced per batch.
    let mut rng = Rng::new(7);
    let spec = FeatureSpec {
        pos_frac: 0.5,
        signal_dims: 16,
        shift: 2.0,
        ..Default::default()
    };
    let pool = features::generate(&spec, 2000, &mut rng);
    let rows: Vec<u32> = (0..1600).collect();
    let train = pool.subset(&rows).imbalance(0.08, &mut rng);
    let split = Split::stratified(&train.y, 0.2, &mut rng);

    let backend = BackendSpec::Native(NativeSpec {
        input_dim: spec.dim,
        hidden: 16,
        threads,
        ..NativeSpec::default()
    })
    .connect()
    .unwrap();
    let loss: LossSpec = "whinge".parse().unwrap();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &loss, BATCH).unwrap();
    let cfg = FitConfig {
        lr: 0.05,
        epochs: 25, // ~2 batches/epoch: 50 steps, plenty for the strong signal
        patience: None, // fixed epochs: both thread counts do identical work
        sampling: SamplingMode::Rebalance { pos_fraction: 0.5 },
        seed: 0,
    };
    trainer
        .fit_stream(
            &train,
            &split.subtrain,
            &split.validation,
            &cfg,
            &mut Rng::new(0x57EA4),
        )
        .unwrap()
}

#[test]
fn whinge_trains_to_high_auc_and_is_thread_deterministic() {
    let serial = fit_whinge(1);
    let best = serial
        .best
        .as_ref()
        .expect("validation AUC defined on mixed-class data");
    assert!(!serial.diverged);
    assert!(
        best.val_auc >= 0.9,
        "whinge should learn the rebalanced scenario: best val AUC {:.4}",
        best.val_auc
    );

    // Same run at 8 worker threads: the thread count is a speed knob,
    // never a result knob — the whole history is bit-identical.
    let parallel = fit_whinge(8);
    assert_eq!(serial.history.len(), parallel.history.len());
    for (a, b) in serial
        .history
        .records
        .iter()
        .zip(&parallel.history.records)
    {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "epoch {} loss differs across thread counts",
            a.epoch
        );
        assert_eq!(
            a.val_auc.map(f64::to_bits),
            b.val_auc.map(f64::to_bits),
            "epoch {} val AUC differs across thread counts",
            a.epoch
        );
    }
    let pbest = parallel.best.as_ref().unwrap();
    assert_eq!(best.epoch, pbest.epoch);
    assert_eq!(best.val_auc.to_bits(), pbest.val_auc.to_bits());
    assert_eq!(best.state, pbest.state);
}
