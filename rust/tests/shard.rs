//! Out-of-core shard store (DESIGN.md §13): format round-trip
//! properties across shapes, exhaustive corruption rejection, and the
//! headline differential — training from a shard store is
//! bit-identical to resident training at every thread count and every
//! shard count, including counts that do not divide n.

use std::path::{Path, PathBuf};

use allpairs::data::dataset::Dataset;
use allpairs::data::shard::{validate_store, write_store, ShardFile, ShardedDataset};
use allpairs::data::{features, DatasetSource, FeatureSpec, Rng, SamplingMode, Split};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, HostTensor, NativeSpec};
use allpairs::train::{FitConfig, FitOutcome, Trainer};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("allpairs_shard_it_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn random_dataset(n: usize, hw: usize, channels: usize, seed: u64) -> Dataset {
    let row = if hw == 0 { channels } else { hw * hw * channels };
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * row).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.3 { 1.0 } else { 0.0 })
        .collect();
    Dataset::new(x, y, hw, channels)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

// --- round-trip properties ---------------------------------------------

#[test]
fn store_round_trip_is_bit_exact_across_shapes() {
    // Flat feature vectors (hw = 0) and image-shaped rows (hw != 0),
    // shard counts that do and do not divide n, k == n singleton
    // shards, and a single-shard store.
    for (case, (n, hw, channels, k)) in [
        (23usize, 0usize, 4usize, 3usize),
        (16, 2, 3, 5),
        (7, 0, 2, 7),
        (101, 0, 3, 7),
        (12, 0, 5, 1),
    ]
    .into_iter()
    .enumerate()
    {
        let d = random_dataset(n, hw, channels, 0xF00D + case as u64);
        let dir = tmp(&format!("roundtrip_{case}"));
        let manifest = write_store(&dir, &d, k).unwrap();
        assert_eq!(manifest.n_rows, n);
        assert_eq!(manifest.shards.len(), k);
        assert_eq!(manifest.n_pos(), d.n_pos());

        let s = ShardedDataset::open(&dir).unwrap();
        assert_eq!((s.len(), s.row_len()), (d.len(), d.row_len()));
        assert_eq!((s.hw(), s.channels()), (hw, channels));
        assert_eq!(bits(s.labels()), bits(&d.y), "labels, case {case}");

        // Every row, fetched in one call: bit-exact feature recovery.
        let indices: Vec<u32> = (0..n as u32).collect();
        let mut got = vec![0.0f32; n * d.row_len()];
        s.fetch_rows(&indices, &mut got).unwrap();
        assert_eq!(bits(&got), bits(&d.x), "features, case {case}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

// --- corruption rejection ----------------------------------------------

#[test]
fn every_flipped_byte_is_rejected_before_the_header_is_trusted() {
    // Flip each byte of a shard file in turn — header, body and footer
    // alike — and require both the direct open and the store validation
    // to fail.  The CRC streams over header + body *before* any header
    // field is parsed, so even a flip that fabricates a plausible
    // n_rows never reaches the allocation it tries to inflate.
    let d = random_dataset(5, 0, 3, 0xC0FFEE);
    let dir = tmp("corruption");
    write_store(&dir, &d, 1).unwrap();
    let victim = dir.join("shard-00000.bin");
    let pristine = std::fs::read(&victim).unwrap();
    // 20-byte header + 5×3 features + 5 labels (4 bytes each) + CRC
    assert_eq!(pristine.len(), 20 + 5 * 3 * 4 + 5 * 4 + 4);

    for i in 0..pristine.len() {
        let mut doctored = pristine.clone();
        doctored[i] ^= 0x01;
        std::fs::write(&victim, &doctored).unwrap();
        assert!(
            ShardFile::open(&victim).is_err(),
            "byte {i}: flipped shard must not open"
        );
        assert!(
            validate_store(&dir).is_err(),
            "byte {i}: flipped store must not validate"
        );
    }

    // Restored, the store loads again and the data is intact.
    std::fs::write(&victim, &pristine).unwrap();
    validate_store(&dir).unwrap();
    let s = ShardedDataset::open(&dir).unwrap();
    let indices: Vec<u32> = (0..5).collect();
    let mut got = vec![0.0f32; 15];
    s.fetch_rows(&indices, &mut got).unwrap();
    assert_eq!(bits(&got), bits(&d.x));
    std::fs::remove_dir_all(&dir).ok();
}

// --- the headline differential -----------------------------------------

fn fit_once(
    source: &dyn DatasetSource,
    dim: usize,
    threads: usize,
    sampling: SamplingMode,
) -> (FitOutcome, Vec<HostTensor>) {
    // Split and epoch RNG are seeded identically per call; only the
    // data source (and the thread count) varies between runs.
    let split = Split::stratified(source.labels(), 0.2, &mut Rng::new(5));
    let backend = BackendSpec::Native(NativeSpec {
        input_dim: dim,
        hidden: 8,
        threads,
        ..NativeSpec::default()
    })
    .connect()
    .unwrap();
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &LossSpec::hinge(), 32).unwrap();
    let cfg = FitConfig {
        lr: 0.05,
        epochs: 3,
        patience: None,
        sampling,
        seed: 3,
    };
    let outcome = trainer
        .fit_stream(
            source,
            &split.subtrain,
            &split.validation,
            &cfg,
            &mut Rng::new(99),
        )
        .unwrap();
    let state = trainer.state_to_host().unwrap();
    (outcome, state)
}

fn assert_identical(
    (a, sa): &(FitOutcome, Vec<HostTensor>),
    (b, sb): &(FitOutcome, Vec<HostTensor>),
    label: &str,
) {
    assert_eq!(a.history.len(), b.history.len(), "{label}: epoch count");
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.epoch, rb.epoch, "{label}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{label}: epoch {} train loss",
            ra.epoch
        );
        assert_eq!(
            ra.val_auc.map(f64::to_bits),
            rb.val_auc.map(f64::to_bits),
            "{label}: epoch {} val AUC",
            ra.epoch
        );
    }
    match (&a.best, &b.best) {
        (Some(ba), Some(bb)) => {
            assert_eq!(ba.epoch, bb.epoch, "{label}: best epoch");
            assert_eq!(
                ba.val_auc.to_bits(),
                bb.val_auc.to_bits(),
                "{label}: best val AUC"
            );
            assert_eq!(ba.state, bb.state, "{label}: best state tensors");
        }
        (None, None) => {}
        _ => panic!("{label}: one run has a best checkpoint, the other does not"),
    }
    assert_eq!(sa, sb, "{label}: final state tensors");
}

#[test]
fn sharded_training_is_bit_identical_to_resident() {
    // n = 101 is deliberately coprime with every shard count tested, so
    // both the ragged final shard and ragged final batches are in play.
    let spec = FeatureSpec {
        pos_frac: 0.3,
        ..Default::default()
    };
    let d = features::generate(&spec, 101, &mut Rng::new(11));
    assert_eq!(d.len(), 101);
    let baseline = fit_once(&d, spec.dim, 1, SamplingMode::Preserve);
    assert!(!baseline.0.history.records.is_empty());

    for threads in [1usize, 8] {
        // Thread count is a pure speed knob on resident data too.
        let resident = fit_once(&d, spec.dim, threads, SamplingMode::Preserve);
        assert_identical(&resident, &baseline, &format!("resident t{threads}"));

        for n_shards in [1usize, 3, 7] {
            let dir = tmp(&format!("diff_t{threads}_k{n_shards}"));
            write_store(&dir, &d, n_shards).unwrap();
            let sharded_source = ShardedDataset::open(&dir).unwrap();
            let sharded = fit_once(&sharded_source, spec.dim, threads, SamplingMode::Preserve);
            assert_identical(
                &sharded,
                &baseline,
                &format!("sharded t{threads} k{n_shards}"),
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

#[test]
fn sharded_training_matches_resident_under_rebalance() {
    // The oversampling path stresses repeated indices inside one epoch
    // order (the same row fetched from disk more than once per epoch).
    let spec = FeatureSpec {
        pos_frac: 0.3,
        ..Default::default()
    };
    let d = features::generate(&spec, 101, &mut Rng::new(12));
    let mode = SamplingMode::Rebalance { pos_fraction: 0.5 };
    let resident = fit_once(&d, spec.dim, 1, mode);

    let dir = tmp("diff_rebalance");
    write_store(&dir, &d, 3).unwrap();
    let source = ShardedDataset::open(&dir).unwrap();
    let sharded = fit_once(&source, spec.dim, 1, mode);
    assert_identical(&sharded, &resident, "rebalance k3");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn validate_store_reports_the_manifest_totals() {
    let d = random_dataset(31, 0, 2, 0xBEEF);
    let dir = tmp("totals");
    write_store(&dir, &d, 4).unwrap();
    let check = validate_store(&dir).unwrap();
    assert_eq!(check.n_rows, 31);
    assert_eq!(check.n_shards, 4);
    assert_eq!(check.n_pos, d.n_pos());
    assert_eq!(check.n_pos + check.n_neg, 31);
    // A store is self-describing: no manifest, no store.
    std::fs::remove_file(dir.join("manifest.json")).unwrap();
    assert!(validate_store(&dir).is_err());
    assert!(ShardedDataset::open(Path::new(&dir)).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
