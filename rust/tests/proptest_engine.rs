//! Property tests for the deterministic parallel train-step engine
//! (DESIGN.md §7): at every thread count the native backend must
//! produce **bit-identical** results — batch loss, score path, full
//! parameter/momentum state, and the L-BFGS oracle's gradient — to the
//! serial path, including non-chunk-aligned batch sizes.  In-tree
//! generator, same style as `proptest_losses.rs` (the `proptest` crate
//! is unavailable offline).

use allpairs::data::Rng;
use allpairs::losses::LossSpec;
use allpairs::runtime::{NativeBackend, NativeSpec};
use allpairs::train::lbfgs::Objective;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Batch sizes straddling the engine's chunk granularity (256 rows):
/// sub-chunk, exactly aligned, one-off-aligned, and ragged multiples.
const SIZES: [usize; 9] = [1, 7, 100, 255, 256, 257, 600, 777, 1023];

struct Case {
    n: usize,
    dim: usize,
    hidden: usize,
    model: &'static str,
    loss: LossSpec,
    x: Vec<f32>,
    is_pos: Vec<f32>,
    is_neg: Vec<f32>,
}

fn gen_case(n: usize, case_idx: usize, rng: &mut Rng) -> Case {
    let dim = 2 + rng.below(8);
    let (model, hidden) = if rng.below(2) == 0 {
        ("linear", 0)
    } else {
        ("mlp", 2 + rng.below(6))
    };
    // every native kernel, the weighted hinge included, must be
    // bit-identical across thread counts
    let loss = [
        LossSpec::hinge(),
        LossSpec::square(),
        LossSpec::logistic(),
        LossSpec::weighted_hinge(),
        LossSpec::linear_hinge(),
    ][case_idx % 5];
    let pad_frac = [0.0, 0.15][rng.below(2)];
    let mut x = Vec::with_capacity(n * dim);
    let mut is_pos = Vec::with_capacity(n);
    let mut is_neg = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.uniform() < pad_frac {
            is_pos.push(0.0);
            is_neg.push(0.0);
            x.resize(x.len() + dim, 0.0);
        } else {
            let pos = rng.uniform() < 0.3;
            is_pos.push(if pos { 1.0 } else { 0.0 });
            is_neg.push(if pos { 0.0 } else { 1.0 });
            for _ in 0..dim {
                x.push(rng.normal() as f32);
            }
        }
    }
    Case {
        n,
        dim,
        hidden,
        model,
        loss,
        x,
        is_pos,
        is_neg,
    }
}

fn backend(case: &Case, threads: usize) -> NativeBackend {
    NativeBackend::new(NativeSpec {
        input_dim: case.dim,
        hidden: case.hidden,
        threads,
    })
}

#[test]
fn prop_train_step_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0xE9617E);
    for (case_idx, &n) in SIZES.iter().enumerate() {
        for round in 0..3 {
            let case = gen_case(n, case_idx + round, &mut rng);
            // Reference: the serial path (threads = 1), two steps so
            // momentum state is exercised.
            let mut outputs = Vec::new();
            for &threads in &THREAD_COUNTS {
                let b = backend(&case, threads);
                let mut exec = b.open(case.model, &case.loss, case.n).unwrap();
                exec.init(round as u32).unwrap();
                let mut losses = Vec::new();
                for _ in 0..2 {
                    let l = exec.train_step(&case.x, &case.is_pos, &case.is_neg, 0.05).unwrap();
                    losses.push(l);
                }
                let scores = exec.predict(&case.x, case.n).unwrap();
                outputs.push((losses, exec.state_to_host().unwrap(), scores));
            }
            let (ref_losses, ref_state, ref_scores) = &outputs[0];
            for (t_idx, (losses, state, scores)) in outputs.iter().enumerate().skip(1) {
                let ctx = format!(
                    "n={n} model={} loss={} threads={}",
                    case.model, case.loss, THREAD_COUNTS[t_idx]
                );
                for (a, b) in ref_losses.iter().zip(losses) {
                    assert_eq!(a.to_bits(), b.to_bits(), "loss differs: {ctx}");
                }
                assert_eq!(ref_state, state, "state differs: {ctx}");
                assert_eq!(ref_scores, scores, "scores differ: {ctx}");
            }
        }
    }
}

#[test]
fn prop_objective_gradient_is_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(0x0B1EC7);
    for (case_idx, &n) in [100usize, 257, 600, 1023].iter().enumerate() {
        let case = gen_case(n, case_idx, &mut rng);
        let theta = backend(&case, 1)
            .objective(case.model, &case.loss, &case.x, &case.is_pos)
            .unwrap()
            .init_params(7);
        let mut outputs = Vec::new();
        for &threads in &THREAD_COUNTS {
            let b = backend(&case, threads);
            let mut obj = b.objective(case.model, &case.loss, &case.x, &case.is_pos).unwrap();
            outputs.push(obj.eval(&theta).unwrap());
        }
        let (ref_loss, ref_grad) = &outputs[0];
        for (t_idx, (loss, grad)) in outputs.iter().enumerate().skip(1) {
            let ctx = format!(
                "n={n} model={} loss={} threads={}",
                case.model, case.loss, THREAD_COUNTS[t_idx]
            );
            assert_eq!(ref_loss.to_bits(), loss.to_bits(), "loss differs: {ctx}");
            assert_eq!(ref_grad, grad, "gradient differs: {ctx}");
        }
    }
}
