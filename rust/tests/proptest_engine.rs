//! Property tests for the deterministic parallel train-step engine
//! (DESIGN.md §7): at every (thread count × sort strategy) combination
//! the native backend must produce **bit-identical** results — batch
//! loss, score path, full parameter/momentum state, and the L-BFGS
//! oracle's gradient — to the serial comparison-sort path, including
//! non-chunk-aligned batch sizes.  The sort axis leans on the canonical
//! permutation invariant pinned by `proptest_sort.rs`: identical
//! permutation ⇒ identical f64 sweep order ⇒ identical bits.  In-tree
//! generator, same style as `proptest_losses.rs` (the `proptest` crate
//! is unavailable offline).

use allpairs::data::Rng;
use allpairs::losses::{LossSpec, SortStrategy};
use allpairs::runtime::{NativeBackend, NativeSpec};
use allpairs::train::lbfgs::Objective;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Batch sizes straddling the engine's chunk granularity (256 rows):
/// sub-chunk, exactly aligned, one-off-aligned, and ragged multiples.
const SIZES: [usize; 9] = [1, 7, 100, 255, 256, 257, 600, 777, 1023];

struct Case {
    n: usize,
    dim: usize,
    hidden: usize,
    model: &'static str,
    loss: LossSpec,
    x: Vec<f32>,
    is_pos: Vec<f32>,
    is_neg: Vec<f32>,
}

fn gen_case(n: usize, case_idx: usize, rng: &mut Rng) -> Case {
    let dim = 2 + rng.below(8);
    let (model, hidden) = if rng.below(2) == 0 {
        ("linear", 0)
    } else {
        ("mlp", 2 + rng.below(6))
    };
    // every native kernel, the weighted hinge included, must be
    // bit-identical across thread counts and sort strategies
    let loss = [
        LossSpec::hinge(),
        LossSpec::square(),
        LossSpec::logistic(),
        LossSpec::weighted_hinge(),
        LossSpec::linear_hinge(),
    ][case_idx % 5];
    let pad_frac = [0.0, 0.15][rng.below(2)];
    let mut x = Vec::with_capacity(n * dim);
    let mut is_pos = Vec::with_capacity(n);
    let mut is_neg = Vec::with_capacity(n);
    for _ in 0..n {
        if rng.uniform() < pad_frac {
            is_pos.push(0.0);
            is_neg.push(0.0);
            x.resize(x.len() + dim, 0.0);
        } else {
            let pos = rng.uniform() < 0.3;
            is_pos.push(if pos { 1.0 } else { 0.0 });
            is_neg.push(if pos { 0.0 } else { 1.0 });
            for _ in 0..dim {
                x.push(rng.normal() as f32);
            }
        }
    }
    Case {
        n,
        dim,
        hidden,
        model,
        loss,
        x,
        is_pos,
        is_neg,
    }
}

fn backend(case: &Case, threads: usize, sort: SortStrategy) -> NativeBackend {
    NativeBackend::new(NativeSpec {
        input_dim: case.dim,
        hidden: case.hidden,
        threads,
        sort,
    })
}

#[test]
fn prop_train_step_is_bit_identical_across_threads_and_sort_strategies() {
    let mut rng = Rng::new(0xE9617E);
    for (case_idx, &n) in SIZES.iter().enumerate() {
        for round in 0..3 {
            let case = gen_case(n, case_idx + round, &mut rng);
            // Reference: outputs[0] is the serial comparison-sort path
            // (THREAD_COUNTS[0] = 1, SortStrategy::ALL[0] = Comparison).
            // Three steps so momentum state is exercised AND the
            // adaptive strategy re-sorts from a genuinely stale
            // previous-step order more than once.
            let mut outputs = Vec::new();
            let mut labels = Vec::new();
            for &threads in &THREAD_COUNTS {
                for sort in SortStrategy::ALL {
                    let b = backend(&case, threads, sort);
                    let mut exec = b.open(case.model, &case.loss, case.n).unwrap();
                    exec.init(round as u32).unwrap();
                    let mut losses = Vec::new();
                    for _ in 0..3 {
                        let l = exec
                            .train_step(&case.x, &case.is_pos, &case.is_neg, 0.05)
                            .unwrap();
                        losses.push(l.to_bits());
                    }
                    let scores = exec.predict(&case.x, case.n).unwrap();
                    outputs.push((losses, exec.state_to_host().unwrap(), scores));
                    labels.push(format!("threads={threads} sort={sort}"));
                }
            }
            for (label, out) in labels.iter().zip(&outputs) {
                assert_eq!(
                    *out, outputs[0],
                    "n={n} model={} loss={} {label} diverged from the serial \
                     comparison reference",
                    case.model, case.loss
                );
            }
        }
    }
}

#[test]
fn prop_objective_gradient_is_bit_identical_across_threads_and_sorts() {
    let mut rng = Rng::new(0x0B1EC7);
    for (case_idx, &n) in [100usize, 257, 600, 1023].iter().enumerate() {
        let case = gen_case(n, case_idx, &mut rng);
        let theta = backend(&case, 1, SortStrategy::Comparison)
            .objective(case.model, &case.loss, &case.x, &case.is_pos)
            .unwrap()
            .init_params(7);
        let mut outputs = Vec::new();
        let mut labels = Vec::new();
        for &threads in &THREAD_COUNTS {
            for sort in SortStrategy::ALL {
                let b = backend(&case, threads, sort);
                let mut obj = b
                    .objective(case.model, &case.loss, &case.x, &case.is_pos)
                    .unwrap();
                // two evals: the second reuses the workspace, so the
                // adaptive engine starts from the previous permutation
                let first = obj.eval(&theta).unwrap();
                let second = obj.eval(&theta).unwrap();
                outputs.push((first, second));
                labels.push(format!("threads={threads} sort={sort}"));
            }
        }
        for (label, out) in labels.iter().zip(&outputs) {
            let ctx = format!("n={n} model={} loss={} {label}", case.model, case.loss);
            let passes = [(1, &out.0, &outputs[0].0), (2, &out.1, &outputs[0].1)];
            for (pass, got, want) in passes {
                assert_eq!(got.0.to_bits(), want.0.to_bits(), "loss differs: {ctx} pass {pass}");
                assert_eq!(got.1, want.1, "gradient differs: {ctx} pass {pass}");
            }
        }
    }
}
