//! Serve-path integration tests (DESIGN.md §11): concurrent TCP
//! clients get bit-identical micro-batched scores, every complete
//! request line gets exactly one ordered response (malformed input
//! included), mid-line disconnects are harmless, and hot reload under
//! load swaps whole models only.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use allpairs::data::Rng;
use allpairs::losses::LossSpec;
use allpairs::runtime::{Backend, HostTensor, ModelExecutor, NativeBackend, NativeSpec};
use allpairs::serve::{
    run_stdin, spawn_reload_watcher, Scorer, ScorerOptions, Server, ServerOptions, FP_RELOAD,
};
use allpairs::train::checkpoint;
use allpairs::util::failpoint;
use allpairs::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("allpairs_serve_{}_{name}", std::process::id()))
}

/// Init an executor at `seed` and publish its state as a checkpoint.
fn make_checkpoint(path: &Path, seed: u32, dim: usize, hidden: usize) -> Vec<HostTensor> {
    let backend = NativeBackend::new(NativeSpec {
        input_dim: dim,
        hidden,
        threads: 1,
        ..NativeSpec::default()
    });
    let model = if hidden == 0 { "linear" } else { "mlp" };
    let mut exec = backend.open(model, &LossSpec::hinge(), 1).unwrap();
    exec.init(seed).unwrap();
    let state = exec.state_to_host().unwrap();
    checkpoint::save(path, &state).unwrap();
    state
}

/// Offline single-row scores for `rows` under `state` — the reference
/// the served scores must match bit for bit.
fn offline_scores(state: &[HostTensor], dim: usize, hidden: usize, rows: &[Vec<f32>]) -> Vec<f32> {
    let backend = NativeBackend::new(NativeSpec {
        input_dim: dim,
        hidden,
        threads: 1,
        ..NativeSpec::default()
    });
    let model = if hidden == 0 { "linear" } else { "mlp" };
    let mut exec = backend.open(model, &LossSpec::hinge(), 1).unwrap();
    exec.load_state(state).unwrap();
    rows.iter().map(|r| exec.predict(r, 1).unwrap()[0]).collect()
}

fn request_line(id: usize, row: &[f32]) -> String {
    let feats: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
    format!("{{\"id\": {id}, \"features\": [{}]}}", feats.join(", "))
}

/// `(id, Ok(score) | Err(message))` from a response line.
fn parse_response(line: &str) -> (Json, Result<f64, String>) {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"));
    let id = j.get("id").cloned().expect("response carries an id");
    match j.get("score").and_then(Json::as_f64) {
        Some(s) => (id, Ok(s)),
        None => {
            let msg = j.get("error").and_then(Json::as_str).expect("score or error");
            (id, Err(msg.to_string()))
        }
    }
}

fn rand_row(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.normal() as f32).collect()
}

#[test]
fn stdin_mode_answers_every_complete_line_in_order() {
    let p = tmp("stdin.bin");
    let state = make_checkpoint(&p, 3, 4, 2);
    let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();

    let row = vec![0.5_f32, -1.25, 2.0, 0.75];
    let want = offline_scores(&state, 4, 2, std::slice::from_ref(&row))[0];
    let input = format!(
        "{}\n{}\n{}\n{}\n{}\n\n{}\n",
        request_line(1, &row),
        "{\"id\": 2, \"features\": [1,", // malformed JSON
        "{\"id\": 3, \"features\": [1.0]}", // wrong arity
        "{\"id\": 4, \"features\": [1e999]}", // non-finite literal
        "{\"id\": 5, \"features\": [1e300, 0, 0, 0]}", // overflows f32
        request_line(6, &row),
    );
    let mut output = Vec::new();
    let n = run_stdin(&scorer.handle, input.as_bytes(), &mut output, 1 << 16).unwrap();
    assert_eq!(n, 6, "one response per complete line, blank skipped");

    let lines: Vec<String> = String::from_utf8(output)
        .unwrap()
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(lines.len(), 6);
    let responses: Vec<(Json, Result<f64, String>)> =
        lines.iter().map(|l| parse_response(l.as_str())).collect();

    assert_eq!(responses[0].0, Json::num(1.0));
    assert_eq!(responses[0].1, Ok(want as f64), "bit-faithful score");
    // Malformed JSON: no id to echo, structured error, no skipped line.
    assert_eq!(responses[1].0, Json::Null);
    assert!(responses[1].1.as_ref().unwrap_err().contains("invalid JSON"));
    assert_eq!(responses[2].0, Json::num(3.0));
    assert!(responses[2].1.as_ref().unwrap_err().contains("expected 4 features"));
    // 1e999 dies in the JSON parser itself (finiteness is a parse
    // error), so its id is unreachable — but the response still comes.
    assert_eq!(responses[3].0, Json::Null);
    assert!(responses[3].1.as_ref().unwrap_err().contains("invalid JSON"));
    assert_eq!(responses[4].0, Json::num(5.0));
    assert!(responses[4].1.as_ref().unwrap_err().contains("finite f32"));
    assert_eq!(responses[5].0, Json::num(6.0));
    assert_eq!(responses[5].1, Ok(want as f64), "still serving after the garbage");

    let stats = scorer.handle.stats().unwrap();
    assert_eq!(stats.rows, 2, "only the two valid requests reached the model");
    scorer.shutdown();
}

#[test]
fn concurrent_tcp_clients_get_bit_identical_micro_batched_scores() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 25;
    const DIM: usize = 16;
    let p = tmp("tcp.bin");
    let state = make_checkpoint(&p, 5, DIM, 4);
    let scorer = Scorer::spawn(ScorerOptions {
        max_batch: 64,
        threads: 1,
        ..ScorerOptions::new(&p)
    })
    .unwrap();
    let server =
        Server::start("127.0.0.1:0", scorer.handle.clone(), ServerOptions::default()).unwrap();
    let addr = server.addr();

    // Deterministic per-thread request rows + their offline reference.
    let rows: Vec<Vec<Vec<f32>>> = (0..THREADS)
        .map(|t| {
            let mut rng = Rng::new(0xC0FFEE ^ t as u64);
            (0..PER_THREAD).map(|_| rand_row(&mut rng, DIM)).collect()
        })
        .collect();
    let want: Vec<Vec<f32>> = rows
        .iter()
        .map(|rs| offline_scores(&state, DIM, 4, rs))
        .collect();

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let rows = rows[t].clone();
            std::thread::spawn(move || -> Vec<(Json, Result<f64, String>)> {
                let mut conn = TcpStream::connect(addr).unwrap();
                // Pipeline every request before reading a single reply:
                // responses must come back in submission order anyway.
                for (i, row) in rows.iter().enumerate() {
                    writeln!(conn, "{}", request_line(t * 1000 + i, row)).unwrap();
                }
                let mut reader = BufReader::new(conn);
                (0..rows.len())
                    .map(|_| {
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        parse_response(line.trim_end())
                    })
                    .collect()
            })
        })
        .collect();

    for (t, w) in workers.into_iter().enumerate() {
        let responses = w.join().unwrap();
        for (i, (id, outcome)) in responses.into_iter().enumerate() {
            assert_eq!(id, Json::num((t * 1000 + i) as f64), "order within connection");
            let got = outcome.unwrap_or_else(|e| panic!("thread {t} req {i}: {e}"));
            assert_eq!(
                (got as f32).to_bits(),
                want[t][i].to_bits(),
                "micro-batched score must be bit-identical to the offline pass"
            );
        }
    }
    let stats = scorer.handle.stats().unwrap();
    assert_eq!(stats.rows, (THREADS * PER_THREAD) as u64);
    assert_eq!(stats.errors, 0);
    assert!(stats.batches >= 1 && stats.max_batch_rows >= 1, "{stats:?}");
    server.stop();
    scorer.shutdown();
}

#[test]
fn malformed_lines_and_midline_disconnects_leave_the_server_serving() {
    let p = tmp("robust.bin");
    let state = make_checkpoint(&p, 9, 3, 0);
    let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();
    let server = Server::start(
        "127.0.0.1:0",
        scorer.handle.clone(),
        ServerOptions { max_line: 128 },
    )
    .unwrap();
    let addr = server.addr();
    let row = vec![1.0_f32, -2.0, 0.5];
    let want = offline_scores(&state, 3, 0, std::slice::from_ref(&row))[0] as f64;

    // Connection A: a mix of garbage and valid lines — one ordered
    // response each, the connection stays up throughout.
    let mut conn = TcpStream::connect(addr).unwrap();
    let burst = format!(
        "not json at all\n{}\n{{\"id\": 2, \"features\": \"x\"}}\n{}\n{}\n",
        request_line(1, &row),
        "x".repeat(300), // over the 128-byte line cap
        request_line(3, &row),
    );
    conn.write_all(burst.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut read_one = || {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse_response(line.trim_end())
    };
    let r = read_one();
    assert!(r.1.unwrap_err().contains("invalid JSON"));
    let r = read_one();
    assert_eq!((r.0, r.1), (Json::num(1.0), Ok(want)));
    let r = read_one();
    assert_eq!(r.0, Json::num(2.0), "id echoed on a validation error");
    assert!(r.1.unwrap_err().contains("must be an array"));
    let r = read_one();
    assert!(r.1.unwrap_err().contains("exceeds 128 bytes"));
    let r = read_one();
    assert_eq!((r.0, r.1), (Json::num(3.0), Ok(want)));

    // Connection B: dies mid-line.  No response owed, nobody else hurt.
    let mut dead = TcpStream::connect(addr).unwrap();
    write!(dead, "{{\"id\": 99, \"features\": [0.1, ").unwrap();
    drop(dead);

    // Connection A (still open) and a fresh connection C both serve.
    writeln!(conn, "{}", request_line(4, &row)).unwrap();
    let r = read_one();
    assert_eq!((r.0, r.1), (Json::num(4.0), Ok(want)));
    let mut fresh = TcpStream::connect(addr).unwrap();
    writeln!(fresh, "{}", request_line(5, &row)).unwrap();
    let mut fresh_reader = BufReader::new(fresh);
    let mut line = String::new();
    fresh_reader.read_line(&mut line).unwrap();
    let r = parse_response(line.trim_end());
    assert_eq!((r.0, r.1), (Json::num(5.0), Ok(want)));

    // Close every client before shutdown: the per-connection threads
    // hold ScoreHandle clones until their sockets reach EOF.
    drop(read_one);
    drop(reader);
    drop(conn);
    drop(fresh_reader);
    server.stop();
    scorer.shutdown();
}

#[test]
fn hot_reload_under_load_swaps_whole_models_only() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 50;
    const DIM: usize = 8;
    let p = tmp("reload_load.bin");
    let state_a = make_checkpoint(&p, 1, DIM, 2);
    let scorer = Scorer::spawn(ScorerOptions {
        max_batch: 32,
        threads: 1,
        ..ScorerOptions::new(&p)
    })
    .unwrap();
    let watch = spawn_reload_watcher(&p, Duration::from_millis(2), scorer.handle.clone()).unwrap();
    let server =
        Server::start("127.0.0.1:0", scorer.handle.clone(), ServerOptions::default()).unwrap();
    let addr = server.addr();

    // One fixed row per thread; precompute its score under both models.
    let rows: Vec<Vec<f32>> = (0..THREADS)
        .map(|t| {
            let mut rng = Rng::new(0xAB ^ t as u64);
            rand_row(&mut rng, DIM)
        })
        .collect();
    let want_a = offline_scores(&state_a, DIM, 2, &rows);

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let row = rows[t].clone();
            std::thread::spawn(move || -> Vec<f64> {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                (0..PER_THREAD)
                    .map(|i| {
                        writeln!(conn, "{}", request_line(i, &row)).unwrap();
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        let (id, outcome) = parse_response(line.trim_end());
                        assert_eq!(id, Json::num(i as f64));
                        outcome.unwrap()
                    })
                    .collect()
            })
        })
        .collect();

    // Republish the checkpoint mid-stream; the watcher hot-swaps it.
    std::thread::sleep(Duration::from_millis(10));
    let state_b = make_checkpoint(&p, 2, DIM, 2);
    let want_b = offline_scores(&state_b, DIM, 2, &rows);

    for (t, w) in workers.into_iter().enumerate() {
        let scores = w.join().unwrap();
        assert_eq!(scores.len(), PER_THREAD, "no dropped responses across the swap");
        let (a, b) = (want_a[t] as f64, want_b[t] as f64);
        for (i, s) in scores.iter().enumerate() {
            assert!(
                *s == a || *s == b,
                "thread {t} response {i}: {s} is neither model A ({a}) nor model B ({b}) — \
                 a torn parameter mix"
            );
        }
    }
    // The swap itself must have happened (and only cleanly).
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let stats = scorer.handle.stats().unwrap();
        if stats.reloads_ok >= 1 {
            assert_eq!(stats.reloads_failed, 0);
            break;
        }
        assert!(std::time::Instant::now() < deadline, "reload never observed");
        std::thread::sleep(Duration::from_millis(5));
    }
    server.stop();
    drop(watch);
    scorer.shutdown();
}

#[test]
fn injected_reload_failure_keeps_the_old_model_on_the_wire() {
    let _guard = failpoint::serial_guard();
    const DIM: usize = 5;
    let p = tmp("reload_fail.bin");
    let state_a = make_checkpoint(&p, 30, DIM, 0);
    let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();
    let server =
        Server::start("127.0.0.1:0", scorer.handle.clone(), ServerOptions::default()).unwrap();
    let row = vec![0.25_f32; DIM];
    let want_a = offline_scores(&state_a, DIM, 0, std::slice::from_ref(&row))[0] as f64;

    let mut conn = TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut score_once = |id: usize| {
        writeln!(conn, "{}", request_line(id, &row)).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        parse_response(line.trim_end()).1.unwrap()
    };
    assert_eq!(score_once(0), want_a);

    // A failed reload (injected) must leave model A serving.
    failpoint::arm_str(FP_RELOAD, "error").unwrap();
    assert!(scorer.handle.reload());
    let stats = scorer.handle.stats().unwrap();
    assert_eq!((stats.reloads_ok, stats.reloads_failed), (0, 1));
    assert_eq!(score_once(1), want_a, "old model still on the wire");
    failpoint::disarm(FP_RELOAD);

    // With the failpoint gone the same republish goes through.
    let state_b = make_checkpoint(&p, 31, DIM, 0);
    let want_b = offline_scores(&state_b, DIM, 0, std::slice::from_ref(&row))[0] as f64;
    assert!(scorer.handle.reload());
    scorer.handle.stats().unwrap(); // barrier: reload applied
    assert_eq!(score_once(2), want_b);

    drop(score_once);
    drop(reader);
    drop(conn);
    server.stop();
    scorer.shutdown();
}
