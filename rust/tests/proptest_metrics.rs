//! Property tests for the evaluation metrics: AUC against its literal
//! pair-counting definition, ROC curve shape invariants, and partial
//! AUC bounds.  Same in-tree generator style as `proptest_losses.rs`
//! (no proptest crate in the offline build).

use allpairs::data::Rng;
use allpairs::metrics::{auc, partial_auc, roc_curve};

/// The Bamber (1975) definition, literally: over every (positive,
/// negative) pair, count 1 for a correctly ordered pair, ½ for a tie,
/// normalized by the pair count.  This is the specification `auc`'s
/// O(n log n) midrank formulation must reproduce.
fn pair_counting_auc(scores: &[f32], is_pos: &[f32]) -> Option<f64> {
    let pos: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .filter(|(_, &p)| p != 0.0)
        .map(|(&s, _)| s)
        .collect();
    let neg: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .filter(|(_, &p)| p == 0.0)
        .map(|(&s, _)| s)
        .collect();
    if pos.is_empty() || neg.is_empty() {
        return None;
    }
    let mut u = 0.0_f64;
    for &a in &pos {
        for &b in &neg {
            if a > b {
                u += 1.0;
            } else if a == b {
                u += 0.5;
            }
        }
    }
    Some(u / (pos.len() as f64 * neg.len() as f64))
}

/// Random case: sizes 0..400, tie-prone quantized scores, positive
/// fractions down to "usually zero or one positive".
fn random_case(rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
    let n = rng.below(400);
    let pos_frac = [0.0, 0.005, 0.1, 0.5, 0.95, 1.0][rng.below(6)];
    let quantize = rng.uniform() < 0.5;
    let scores: Vec<f32> = (0..n)
        .map(|_| {
            let v = (rng.normal() * 2.0) as f32;
            if quantize {
                (v * 4.0).round() / 4.0
            } else {
                v
            }
        })
        .collect();
    let is_pos: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < pos_frac { 1.0 } else { 0.0 })
        .collect();
    (scores, is_pos)
}

#[test]
fn prop_auc_equals_pair_counting_definition() {
    let mut rng = Rng::new(1);
    for case in 0..300 {
        let (scores, is_pos) = random_case(&mut rng);
        match (auc(&scores, &is_pos), pair_counting_auc(&scores, &is_pos)) {
            // Both pure-f64 computations over < 2^20 exact half-integer
            // counts: agreement to 1e-12 relative is the f64 round-off
            // of the two different normalization orders.
            (Some(a), Some(b)) => {
                assert!((a - b).abs() < 1e-12, "case {case}: {a} vs {b}")
            }
            (None, None) => {}
            other => panic!("case {case}: definedness mismatch {other:?}"),
        }
    }
}

#[test]
fn prop_roc_curve_monotone_anchored_and_consistent() {
    let mut rng = Rng::new(2);
    for case in 0..200 {
        let (scores, is_pos) = random_case(&mut rng);
        let curve = roc_curve(&scores, &is_pos);
        let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count();
        if n_pos == 0 || n_pos == is_pos.len() || is_pos.is_empty() {
            assert!(curve.is_empty(), "case {case}: curve on single class");
            continue;
        }
        // anchored at (0,0) and (1,1)
        assert_eq!((curve[0].fpr, curve[0].tpr), (0.0, 0.0), "case {case}");
        let last = curve.last().unwrap();
        assert_eq!((last.fpr, last.tpr), (1.0, 1.0), "case {case}");
        // monotone non-decreasing in both coordinates, rates in [0,1]
        for w in curve.windows(2) {
            assert!(w[1].fpr >= w[0].fpr, "case {case}: fpr decreased");
            assert!(w[1].tpr >= w[0].tpr, "case {case}: tpr decreased");
        }
        for p in &curve {
            assert!((0.0..=1.0).contains(&p.fpr) && (0.0..=1.0).contains(&p.tpr));
        }
        // thresholds strictly decrease (one point per distinct score)
        for w in curve.windows(2) {
            assert!(w[1].threshold < w[0].threshold, "case {case}: thresholds");
        }
    }
}

#[test]
fn prop_partial_auc_bounded_and_consistent_with_auc() {
    let mut rng = Rng::new(3);
    let mut defined = 0;
    for case in 0..200 {
        let (scores, is_pos) = random_case(&mut rng);
        // random non-degenerate FPR interval
        let a = rng.uniform() * 0.8;
        let b = a + 0.01 + rng.uniform() * (0.99 - a);
        let full = auc(&scores, &is_pos);
        let partial = partial_auc(&scores, &is_pos, a, b.min(1.0));
        assert_eq!(
            full.is_some(),
            partial.is_some(),
            "case {case}: definedness must match"
        );
        let (Some(full), Some(partial)) = (full, partial) else {
            continue;
        };
        defined += 1;
        // normalized pAUC is an average TPR over the interval: in [0,1]
        assert!((0.0..=1.0 + 1e-12).contains(&partial), "case {case}: {partial}");
        // the full interval recovers the ordinary AUC
        let whole = partial_auc(&scores, &is_pos, 0.0, 1.0).unwrap();
        assert!((whole - full).abs() < 1e-12, "case {case}: {whole} vs {full}");
    }
    assert!(defined > 50, "generator produced too few two-class cases");
}
