// Fixture: `as f32` on a kernel computation path must fire
// float-narrowing-in-kernel when linted under src/losses/.
pub fn sweep_key(score: f64, margin: f64) -> f32 {
    let key = margin - score;
    key as f32
}
