// Fixture: kernel-style code written to the house rules — f64 math
// end to end, ordered containers, recovered locks.  Zero findings
// expected even under the strictest scope (src/losses/).
use std::collections::BTreeMap;
use std::sync::Mutex;

pub fn sweep(scores: &[f64], margin: f64) -> f64 {
    let mut acc = 0.0_f64;
    for &y in scores {
        acc += (margin - y).max(0.0);
    }
    acc
}

pub fn ordered_tally(ids: &[u32]) -> BTreeMap<u32, usize> {
    let mut seen = BTreeMap::new();
    for &id in ids {
        *seen.entry(id).or_insert(0) += 1;
    }
    seen
}

pub fn drain(queue: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut guard = queue.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    std::mem::take(&mut *guard)
}
