// Fixture: everything inside a #[cfg(test)] item is exempt, including
// nested attributes and multiple would-be findings.
pub fn production(x: f64) -> f64 {
    x * 2.0
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn helper_may_do_anything() {
        let narrowed = 1.25_f64 as f32;
        let mut m = HashMap::new();
        m.insert("k", narrowed);
        std::fs::write("/tmp/scratch", b"test scratch").unwrap();
    }
}
