// Fixture: the same narrow is silent when the suppression carries a
// reason — and only on the line it covers.
pub fn final_store(grad: f64) -> f32 {
    // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; grad store is f32
    grad as f32
}
