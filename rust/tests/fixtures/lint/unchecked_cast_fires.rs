// Fixture: bare integer casts while parsing untrusted input must fire
// unchecked-cast-in-parse when linted under a parse-path file name.
pub fn read_len(header: &[u8]) -> usize {
    let raw = i64::from_le_bytes(header[..8].try_into().unwrap());
    raw as usize
}
