// Fixture: wall-clock reads in deterministic engine code must fire
// wallclock-in-kernel (both patterns).
use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    (Instant::now(), SystemTime::now())
}
