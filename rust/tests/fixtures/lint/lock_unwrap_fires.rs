// Fixture: .lock().unwrap() must fire lock-unwrap anywhere in the tree.
use std::sync::Mutex;

pub fn drain(queue: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut guard = queue.lock().unwrap();
    std::mem::take(&mut *guard)
}
