// Fixture: HashMap/HashSet on a deterministic path must fire
// nondeterministic-iteration.
use std::collections::HashMap;

pub fn tally(ids: &[u32]) -> usize {
    let mut seen: HashMap<u32, usize> = HashMap::new();
    for &id in ids {
        *seen.entry(id).or_insert(0) += 1;
    }
    seen.len()
}
