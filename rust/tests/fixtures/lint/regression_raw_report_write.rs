// Regression fixture: the PR 7 bug pattern.  Sweep reports were
// written with a single std::fs::write; a crash mid-write left a torn
// half-report that a resume then trusted.  The linter must flag the
// raw write so it is routed through util::fsio::write_atomic.
pub fn save_report(path: &std::path::Path, json: &str) -> std::io::Result<()> {
    std::fs::write(path, json)
}
