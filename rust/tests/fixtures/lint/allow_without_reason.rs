// Fixture: suppressions that do not carry a reason, or that name an
// unknown rule, are themselves findings (lint-allow-needs-reason).
pub fn bad_allows(grad: f64) -> f32 {
    // lint:allow(float-narrowing-in-kernel)
    let a = grad as f32;
    // lint:allow(float-narrowing-in-kernel):
    let b = grad as f32;
    // lint:allow(no-such-rule): confidently wrong
    let c = grad as f32;
    a + b + c
}
