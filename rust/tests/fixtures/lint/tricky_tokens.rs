// Fixture: lexer stress — none of the rule-pattern text below is real
// code until the last function, which must be the only finding.
pub fn decoys<'a>(tag: &'a str) -> String {
    // as f32 in a line comment is not code
    /* HashMap inside /* a nested */ block comment */
    let plain = "string mentioning Instant::now and as f32";
    let raw = r#"raw string: std::fs::write("x") and .lock().unwrap()"#;
    let byte_str = b"as f32 in a byte string";
    let ch = 'a'; // char literal, not a lifetime
    let escaped = '\''; // escaped char, still not a lifetime
    let unicode = "π ≈ 3.14159; naïve café"; // multi-byte before the finding
    format!("{tag}{plain}{raw}{ch}{escaped}{unicode}{:?}", byte_str)
}

pub fn real_finding(score: f64) -> f32 {
    score as f32
}
