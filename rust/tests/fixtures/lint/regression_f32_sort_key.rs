// Regression fixture: the PR 4 bug pattern.  Hinge sort keys were
// narrowed to f32 before sorting; near-margin pairs whose f64 keys
// differed only below f32 precision collapsed to equal keys and the
// sweep silently dropped their contribution.  The linter must flag the
// narrowing on the key path.
pub fn build_keys(scores: &[f64], margin: f64, keys: &mut Vec<f32>) {
    keys.clear();
    for &y in scores {
        keys.push((margin - y) as f32);
    }
    keys.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
