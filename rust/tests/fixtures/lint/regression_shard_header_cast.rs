// Regression fixture: the shard-header bug pattern.  ShardFile header
// fields (n_rows, hw, channels) are untrusted little-endian bytes off
// disk; multiplying bare-cast values can wrap the declared body size
// past the length check that follows (see data/shard/format.rs).
pub fn body_len(header: &[u8]) -> usize {
    let n_rows = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let row_len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    n_rows * row_len * 4
}
