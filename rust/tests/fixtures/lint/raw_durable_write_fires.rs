// Fixture: raw std::fs durable writes must fire raw-durable-write.
pub fn persist(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)?;
    let _sidecar = std::fs::File::create(path.with_extension("meta"))?;
    Ok(())
}
