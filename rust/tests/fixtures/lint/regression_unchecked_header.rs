// Regression fixture: the PR 7 bug pattern.  Checkpoint-header tensor
// dims were multiplied as usize without overflow checks; a crafted
// header could wrap the byte count past a bounds check and trigger a
// huge allocation.  The linter must flag the bare casts in parse code.
pub fn payload_len(dims: &[i64]) -> usize {
    let mut elems = 1usize;
    for &d in dims {
        elems *= d as usize;
    }
    elems * 4
}
