//! Sweep-engine integration: a tiny but complete cross-validation sweep
//! through the native backend (multi-worker scheduler, imbalance,
//! stratified splits, max-val-AUC selection, aggregation, persistence).
//! No artifacts needed — this runs in every build.

use std::sync::Arc;

use allpairs::config::SweepConfig;
use allpairs::coordinator::cv;
use allpairs::data::synth::{generate, SynthSpec, SYNTH_DATASETS};
use allpairs::losses::LossSpec;
use allpairs::runtime::{Backend, BackendSpec, NativeSpec};
use allpairs::sweep::runner::{run_job, JobData};
use allpairs::sweep::scheduler::run_sweep;
use allpairs::sweep::select::{aggregate, select_per_seed};
use allpairs::sweep::{results, Job};

/// Native spec matching the synthetic image datasets (16 x 16 x 3).
fn native_spec() -> BackendSpec {
    BackendSpec::Native(NativeSpec {
        input_dim: 16 * 16 * 3,
        hidden: 8,
        threads: 1,
        ..NativeSpec::default()
    })
}

fn tiny_data() -> JobData {
    let spec = SynthSpec {
        n_train: 400,
        n_test: 200,
        ..SYNTH_DATASETS[2] // synth-pets: 2 latent classes, learnable
    };
    let (train_pool, test) = generate(&spec, 99);
    JobData {
        train_pool: Arc::new(train_pool),
        test: Arc::new(test),
    }
}

fn tiny_job(loss: &str, batch: usize, seed: u32) -> Job {
    Job {
        dataset: "synth-pets".into(),
        imratio: 0.2,
        loss: loss.parse().unwrap(),
        batch,
        lr: 0.01,
        seed,
        model: "resnet".into(),
        epochs: 2,
        patience: None,
        sampling: "preserve".into(),
    }
}

#[test]
fn single_job_end_to_end() {
    let backend = native_spec().connect().unwrap();
    let data = tiny_data();
    let result = run_job(backend.as_ref(), &tiny_job("hinge", 50, 0), &data).unwrap();
    assert!(!result.diverged);
    assert!(result.best_val_auc.is_some());
    assert!(result.test_auc.is_some());
    let t = result.test_auc.unwrap();
    assert!((0.0..=1.0).contains(&t));
    assert!((result.achieved_imratio - 0.2).abs() < 0.1);
    assert!(result.seconds > 0.0);
}

#[test]
fn job_results_are_reproducible() {
    let backend = native_spec().connect().unwrap();
    let data = tiny_data();
    let job = tiny_job("logistic", 100, 1);
    let a = run_job(backend.as_ref(), &job, &data).unwrap();
    let b = run_job(backend.as_ref(), &job, &data).unwrap();
    assert_eq!(a.best_val_auc, b.best_val_auc);
    assert_eq!(a.test_auc, b.test_auc);
    assert_eq!(a.best_epoch, b.best_epoch);
}

#[test]
fn jobs_in_one_selection_group_share_data() {
    // Jobs differing only in training knobs (batch, sampling, patience)
    // must see the identical imbalanced subset and validation split.
    // With lr = 0 the model never moves, so validation AUC depends only
    // on the init seed and the validation subset — bit-equality across
    // the two jobs pins the shared-data seeding (Job::data_key).
    let backend = native_spec().connect().unwrap();
    let data = tiny_data();
    let mut a = tiny_job("hinge", 50, 0);
    a.lr = 0.0;
    let mut b = tiny_job("hinge", 100, 0);
    b.lr = 0.0;
    b.sampling = "rebalance:0.5".into();
    b.patience = Some(3);
    let ra = run_job(backend.as_ref(), &a, &data).unwrap();
    let rb = run_job(backend.as_ref(), &b, &data).unwrap();
    assert_eq!(ra.achieved_imratio, rb.achieved_imratio);
    assert_eq!(ra.best_val_auc, rb.best_val_auc);
}

#[test]
fn multiworker_sweep_selection_and_persistence() {
    let jobs = vec![
        tiny_job("hinge", 50, 0),
        tiny_job("hinge", 100, 0),
        tiny_job("hinge", 50, 1),
        tiny_job("hinge", 100, 1),
        tiny_job("logistic", 50, 0),
        tiny_job("logistic", 100, 0),
    ];
    let n_jobs = jobs.len();
    let mut datasets = std::collections::BTreeMap::new();
    datasets.insert("synth-pets".to_string(), tiny_data());
    let outcome = run_sweep(&native_spec(), jobs, datasets, 3, None).unwrap();
    assert!(outcome.failures.is_empty(), "{:?}", outcome.failures);
    let results_vec = outcome.results;
    assert_eq!(results_vec.len(), n_jobs);

    // selection: one winner per (loss, seed)
    let selections = select_per_seed(&results_vec);
    assert_eq!(selections.len(), 3); // hinge x {0,1}, logistic x {0}
    let cells = aggregate(&selections);
    assert_eq!(cells.len(), 2); // hinge cell + logistic cell
    for c in &cells {
        assert!(c.median_batch == 50.0 || c.median_batch == 75.0 || c.median_batch == 100.0);
        assert!(!c.test_auc.is_empty());
    }

    // persistence roundtrip
    let path = std::env::temp_dir().join("allpairs_sweep_test.jsonl");
    results::save_jsonl(&path, &results_vec).unwrap();
    let loaded = results::load_jsonl(&path).unwrap();
    assert_eq!(loaded.len(), n_jobs);
    let again = aggregate(&select_per_seed(&loaded));
    assert_eq!(again.len(), cells.len());
    for (a, b) in cells.iter().zip(&again) {
        assert_eq!(a.loss, b.loss);
        assert!((a.test_auc.mean() - b.test_auc.mean()).abs() < 1e-9);
    }
}

#[test]
fn whinge_job_runs_end_to_end_through_the_sweep() {
    // The weighted hinge is a schedulable scenario, not dead code: a
    // whinge job runs the full imbalance → split → fit → select path.
    let backend = native_spec().connect().unwrap();
    let data = tiny_data();
    let result = run_job(backend.as_ref(), &tiny_job("whinge", 50, 0), &data).unwrap();
    assert!(!result.diverged);
    assert!(result.best_val_auc.is_some());
    assert!(result.test_auc.is_some());
}

#[test]
fn pre_redesign_jsonl_fixture_still_parses() {
    // Verbatim lines captured from pre-LossSpec writers.  The first is
    // a PR-3-era line (streaming fields present); the second predates
    // the streaming pipeline (no patience/sampling keys).  Both must
    // keep parsing, with the loss string landing in a typed spec and
    // the job id unchanged.
    let fixture = concat!(
        r#"{"best_epoch":1,"best_val_auc":0.9125,"diverged":false,"final_train_loss":0.412,"#,
        r#""achieved_imratio":0.1,"job":{"batch":50,"dataset":"synth-cifar","epochs":2,"#,
        r#""imratio":0.1,"loss":"hinge","lr":0.01,"model":"resnet","patience":null,"#,
        r#""sampling":"preserve","seed":0},"seconds":1.5,"test_auc":0.88}"#,
        "
",
        r#"{"best_epoch":0,"best_val_auc":0.8,"diverged":false,"final_train_loss":0.6,"#,
        r#""achieved_imratio":0.01,"job":{"batch":100,"dataset":"synth-pets","epochs":3,"#,
        r#""imratio":0.01,"loss":"logistic","lr":0.1,"model":"resnet","seed":2},"#,
        r#""seconds":2.0,"test_auc":0.79}"#,
        "
"
    );
    let path = std::env::temp_dir().join("allpairs_pre_redesign.jsonl");
    std::fs::write(&path, fixture).unwrap();
    let loaded = results::load_jsonl(&path).unwrap();
    assert_eq!(loaded.len(), 2);
    assert_eq!(loaded[0].job.loss, LossSpec::hinge());
    assert_eq!(loaded[0].job.id(), "synth-cifar_im0.1_hinge_bs50_lr1e-2_s0");
    assert_eq!(loaded[1].job.loss, LossSpec::logistic());
    assert_eq!(loaded[1].job.sampling, "preserve"); // pre-streaming default
    assert_eq!(loaded[1].job.patience, None);
    // a bad loss in a job line is rejected at parse time, naming the specs
    std::fs::write(
        &path,
        r#"{"job":{"batch":50,"dataset":"d","epochs":2,"imratio":0.1,"loss":"typo","lr":0.01,"model":"resnet","seed":0},"final_train_loss":0.1,"diverged":false,"seconds":1.0,"achieved_imratio":0.1}"#,
    )
    .unwrap();
    let err = results::load_jsonl(&path).unwrap_err().to_string();
    assert!(err.contains("hinge"), "{err}");
}

#[test]
fn cv_summarize_writes_reports() {
    let backend = native_spec().connect().unwrap();
    let data = tiny_data();
    let results_vec = vec![
        run_job(backend.as_ref(), &tiny_job("hinge", 50, 0), &data).unwrap(),
        run_job(backend.as_ref(), &tiny_job("logistic", 50, 0), &data).unwrap(),
    ];
    let out = std::env::temp_dir().join("allpairs_cv_reports");
    std::fs::create_dir_all(&out).unwrap();
    let output = cv::summarize(results_vec, &out).unwrap();
    assert_eq!(output.cells.len(), 2);
    for file in ["table2.md", "fig3.md", "fig3.csv"] {
        let text = std::fs::read_to_string(out.join(file)).unwrap();
        assert!(text.contains("hinge"), "{file} missing hinge row");
    }
}

#[test]
fn cv_run_executes_a_micro_sweep_end_to_end() {
    // The full coordinator path — config → datasets → scheduler →
    // selection → reports — on a deliberately tiny grid.
    let cfg = SweepConfig {
        datasets: vec!["synth-pets".into()],
        imratios: vec![0.2],
        losses: vec![LossSpec::hinge()],
        batch_sizes: vec![50],
        seeds: vec![0],
        epochs: 1,
        max_train: Some(200),
        max_lrs: Some(1),
        workers: 2,
        backend: native_spec(),
        ..Default::default()
    };
    let out = std::env::temp_dir().join("allpairs_cv_run_micro");
    let output = cv::run(&cfg, &out, None).unwrap();
    assert_eq!(output.results.len(), cfg.n_runs());
    assert!(out.join("sweep_results.jsonl").exists());
    assert!(out.join("table2.md").exists());
}

#[test]
fn native_backend_opens_every_scheduled_combination() {
    // Every (model, loss, batch) the default-config grid schedules must
    // open on the native backend — except aucm, which documents its
    // pjrt-only status by erroring with a clear message.
    let backend = native_spec().connect().unwrap();
    let cfg = SweepConfig::default();
    let jobs = allpairs::sweep::grid::expand(&cfg);
    let mut checked = std::collections::BTreeSet::new();
    for job in jobs {
        let key = (job.model.clone(), job.loss.to_string(), job.batch);
        if !checked.insert(key) {
            continue;
        }
        let opened = backend.open(&job.model, &job.loss, job.batch);
        if job.loss == LossSpec::aucm() {
            let msg = opened.err().unwrap().to_string();
            assert!(msg.contains("aucm"), "unhelpful error: {msg}");
        } else {
            assert!(opened.is_ok(), "cannot open {}", job.id());
        }
    }
}

#[test]
fn scheduled_grid_has_matching_artifacts_when_present() {
    // Config/manifest drift guard for the AOT path: every (model, loss,
    // batch) the default config schedules must exist in the manifest.
    // Manifest parsing needs no PJRT, so this runs in every build —
    // skipped cleanly when `make artifacts` has not been run.
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts directory (run `make artifacts`)");
        return;
    }
    let manifest = allpairs::runtime::Manifest::load(&dir).unwrap();
    let cfg = SweepConfig::default();
    let mut checked = std::collections::BTreeSet::new();
    for job in allpairs::sweep::grid::expand(&cfg) {
        let key = (job.model.clone(), job.loss.to_string(), job.batch);
        if !checked.insert(key) {
            continue;
        }
        manifest
            .get(&allpairs::runtime::Manifest::train_name(
                &job.model,
                job.loss.base_name(),
                job.batch,
            ))
            .unwrap_or_else(|e| panic!("missing artifact for {}: {e}", job.id()));
    }
}

#[test]
fn build_datasets_generates_all_synth_sets() {
    let cfg = SweepConfig {
        max_train: Some(50),
        ..Default::default()
    };
    let data = cv::build_datasets(&cfg).unwrap();
    assert_eq!(data.len(), 3);
    for name in ["synth-cifar", "synth-stl", "synth-pets"] {
        let d = &data[name];
        assert_eq!(d.train_pool.len(), 50);
        // balanced test pool
        let pos = d.test.y.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(pos * 2, d.test.len());
    }
}
