//! Sweep-engine integration: a tiny but complete cross-validation sweep
//! through real PJRT artifacts (multi-worker scheduler, imbalance,
//! stratified splits, max-val-AUC selection, aggregation, persistence).
//!
//! Skipped cleanly when `make artifacts` has not been run.

use std::sync::Arc;

use allpairs::config::SweepConfig;
use allpairs::coordinator::cv;
use allpairs::data::synth::{generate, SynthSpec, SYNTH_DATASETS};
use allpairs::sweep::runner::{run_job, JobData};
use allpairs::sweep::scheduler::run_sweep;
use allpairs::sweep::select::{aggregate, select_per_seed};
use allpairs::sweep::{grid, results, Job};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => dir,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

fn tiny_data() -> JobData {
    let spec = SynthSpec {
        n_train: 400,
        n_test: 200,
        ..SYNTH_DATASETS[2] // synth-pets: 2 latent classes, learnable
    };
    let (train_pool, test) = generate(&spec, 99);
    JobData {
        train_pool: Arc::new(train_pool),
        test: Arc::new(test),
    }
}

fn tiny_job(loss: &str, batch: usize, seed: u32) -> Job {
    Job {
        dataset: "synth-pets".into(),
        imratio: 0.2,
        loss: loss.into(),
        batch,
        lr: 0.01,
        seed,
        model: "resnet".into(),
        epochs: 2,
    }
}

#[test]
fn single_job_end_to_end() {
    let dir = require_artifacts!();
    let runtime = allpairs::runtime::Runtime::new(&dir).unwrap();
    let data = tiny_data();
    let result = run_job(&runtime, &tiny_job("hinge", 50, 0), &data).unwrap();
    assert!(!result.diverged);
    assert!(result.best_val_auc.is_some());
    assert!(result.test_auc.is_some());
    let t = result.test_auc.unwrap();
    assert!((0.0..=1.0).contains(&t));
    assert!((result.achieved_imratio - 0.2).abs() < 0.1);
    assert!(result.seconds > 0.0);
}

#[test]
fn job_results_are_reproducible() {
    let dir = require_artifacts!();
    let runtime = allpairs::runtime::Runtime::new(&dir).unwrap();
    let data = tiny_data();
    let job = tiny_job("logistic", 100, 1);
    let a = run_job(&runtime, &job, &data).unwrap();
    let b = run_job(&runtime, &job, &data).unwrap();
    assert_eq!(a.best_val_auc, b.best_val_auc);
    assert_eq!(a.test_auc, b.test_auc);
    assert_eq!(a.best_epoch, b.best_epoch);
}

#[test]
fn multiworker_sweep_selection_and_persistence() {
    let dir = require_artifacts!();
    let jobs = vec![
        tiny_job("hinge", 50, 0),
        tiny_job("hinge", 100, 0),
        tiny_job("hinge", 50, 1),
        tiny_job("hinge", 100, 1),
        tiny_job("logistic", 50, 0),
        tiny_job("logistic", 100, 0),
    ];
    let n_jobs = jobs.len();
    let mut datasets = std::collections::HashMap::new();
    datasets.insert("synth-pets".to_string(), tiny_data());
    let results_vec = run_sweep(&dir, jobs, datasets, 3, None).unwrap();
    assert_eq!(results_vec.len(), n_jobs);

    // selection: one winner per (loss, seed)
    let selections = select_per_seed(&results_vec);
    assert_eq!(selections.len(), 3); // hinge x {0,1}, logistic x {0}
    let cells = aggregate(&selections);
    assert_eq!(cells.len(), 2); // hinge cell + logistic cell
    for c in &cells {
        assert!(c.median_batch == 50.0 || c.median_batch == 75.0 || c.median_batch == 100.0);
        assert!(!c.test_auc.is_empty());
    }

    // persistence roundtrip
    let path = std::env::temp_dir().join("allpairs_sweep_test.jsonl");
    results::save_jsonl(&path, &results_vec).unwrap();
    let loaded = results::load_jsonl(&path).unwrap();
    assert_eq!(loaded.len(), n_jobs);
    let again = aggregate(&select_per_seed(&loaded));
    assert_eq!(again.len(), cells.len());
    for (a, b) in cells.iter().zip(&again) {
        assert_eq!(a.loss, b.loss);
        assert!((a.test_auc.mean() - b.test_auc.mean()).abs() < 1e-9);
    }
}

#[test]
fn cv_summarize_writes_reports() {
    let dir = require_artifacts!();
    let runtime = allpairs::runtime::Runtime::new(&dir).unwrap();
    let data = tiny_data();
    let results_vec = vec![
        run_job(&runtime, &tiny_job("hinge", 50, 0), &data).unwrap(),
        run_job(&runtime, &tiny_job("logistic", 50, 0), &data).unwrap(),
    ];
    let out = std::env::temp_dir().join("allpairs_cv_reports");
    std::fs::create_dir_all(&out).unwrap();
    let output = cv::summarize(results_vec, &out).unwrap();
    assert_eq!(output.cells.len(), 2);
    for file in ["table2.md", "fig3.md", "fig3.csv"] {
        let text = std::fs::read_to_string(out.join(file)).unwrap();
        assert!(text.contains("hinge"), "{file} missing hinge row");
    }
}

#[test]
fn grid_jobs_have_matching_artifacts() {
    // Every (model, loss, batch) the default config would schedule must
    // exist in the manifest — catches config/manifest drift.
    let dir = require_artifacts!();
    let runtime = allpairs::runtime::Runtime::new(&dir).unwrap();
    let cfg = SweepConfig::default();
    let jobs = grid::expand(&cfg);
    let manifest = runtime.manifest();
    let mut checked = std::collections::BTreeSet::new();
    for job in jobs {
        let key = (job.model.clone(), job.loss.clone(), job.batch);
        if !checked.insert(key) {
            continue;
        }
        manifest
            .get(&allpairs::runtime::Manifest::train_name(
                &job.model, &job.loss, job.batch,
            ))
            .unwrap_or_else(|e| panic!("missing artifact for {}: {e}", job.id()));
    }
}

#[test]
fn build_datasets_generates_all_synth_sets() {
    let cfg = SweepConfig {
        max_train: Some(50),
        ..Default::default()
    };
    let data = cv::build_datasets(&cfg).unwrap();
    assert_eq!(data.len(), 3);
    for name in ["synth-cifar", "synth-stl", "synth-pets"] {
        let d = &data[name];
        assert_eq!(d.train_pool.len(), 50);
        // balanced test pool
        let pos = d.test.y.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(pos * 2, d.test.len());
    }
}
