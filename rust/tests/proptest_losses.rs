//! Property tests for the core algorithm invariants.
//!
//! The `proptest` crate is unavailable in this offline build, so these
//! use the same structure with an in-tree generator: many random cases
//! per property, deterministic seeds, shrink-free but wide coverage
//! (sizes 0..2000, margins 0..4, imbalance down to one example, heavy
//! ties, extreme magnitudes).

use allpairs::data::Rng;
use allpairs::losses::functional::{Square, SquaredHinge};
use allpairs::losses::logistic::Logistic;
use allpairs::losses::naive::{NaiveSquare, NaiveSquaredHinge};
use allpairs::losses::weighted::WeightedSquaredHinge;
// NOTE: `LossFn` is imported per-test below — importing it at file scope
// alongside `PairwiseLoss` would make `loss_and_grad` method calls on the
// functional losses (which implement both traits) ambiguous.
use allpairs::losses::{BatchView, LossSpec, LossWorkspace, PairwiseLoss, SortStrategy};
use allpairs::metrics::auc::auc;

const CASES: usize = 120;

/// Random test case generator: (scores, is_pos) with assorted pathologies.
struct CaseGen {
    rng: Rng,
}

impl CaseGen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    fn next_case(&mut self) -> (Vec<f32>, Vec<f32>, f32) {
        let n = self.rng.below(2000);
        let pos_frac = [0.001, 0.01, 0.1, 0.3, 0.5, 0.9][self.rng.below(6)];
        let scale = [0.01_f64, 1.0, 10.0, 1000.0][self.rng.below(4)];
        let quantize = self.rng.uniform() < 0.3;
        let margin = [0.0_f32, 0.5, 1.0, 4.0][self.rng.below(4)];
        let scores: Vec<f32> = (0..n)
            .map(|_| {
                let v = (self.rng.normal() * scale) as f32;
                if quantize {
                    (v * 2.0).round() / 2.0
                } else {
                    v
                }
            })
            .collect();
        let is_pos: Vec<f32> = (0..n)
            .map(|_| if self.rng.uniform() < pos_frac { 1.0 } else { 0.0 })
            .collect();
        (scores, is_pos, margin)
    }
}

fn assert_rel(a: f64, b: f64, tol: f64, ctx: &str) {
    let scale = a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() <= tol * scale, "{ctx}: {a} vs {b}");
}

#[test]
fn prop_functional_hinge_equals_naive() {
    use allpairs::losses::LossFn;
    let mut gen = CaseGen::new(1);
    // One persistent workspace per sort strategy, reused across every
    // case: the adaptive engine then sees stale previous orders of the
    // wrong length each time sizes change, which must not matter.
    let mut workspaces: Vec<LossWorkspace> = SortStrategy::ALL
        .iter()
        .map(|&s| LossWorkspace::with_sort_strategy(s))
        .collect();
    for case in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        if scores.len() > 400 {
            continue; // naive is quadratic; keep the oracle cheap
        }
        // UFCS for the trait calls: `LossFn` is in scope here, and
        // `SquaredHinge` implements both traits' `loss_and_grad`.
        let (ln, gn) =
            PairwiseLoss::loss_and_grad(&NaiveSquaredHinge::new(margin), &scores, &is_pos);
        let (lf, gf) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(margin), &scores, &is_pos);
        assert_rel(ln, lf, 1e-6, &format!("case {case} loss"));
        let gscale = gn.iter().fold(1.0_f32, |m, g| m.max(g.abs()));
        for (i, (a, b)) in gn.iter().zip(&gf).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * gscale,
                "case {case} grad[{i}]: {a} vs {b}"
            );
        }
        // Every sort strategy must reproduce the same kernel output bit
        // for bit (identical permutation => identical sweep order).
        let kernel = LossSpec::Hinge { margin }.build().unwrap();
        let mut outputs = Vec::new();
        for ws in &mut workspaces {
            let l = kernel.loss_and_grad(BatchView::new(&scores, &is_pos), ws);
            outputs.push((l.to_bits(), ws.grad.clone()));
        }
        for (strategy, out) in SortStrategy::ALL.iter().zip(&outputs) {
            assert_eq!(
                *out, outputs[0],
                "case {case}: {strategy} diverged from comparison"
            );
        }
    }
}

#[test]
fn prop_functional_square_equals_naive() {
    let mut gen = CaseGen::new(2);
    for case in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        if scores.len() > 400 {
            continue;
        }
        let (ln, gn) = NaiveSquare::new(margin).loss_and_grad(&scores, &is_pos);
        let (lf, gf) = Square::new(margin).loss_and_grad(&scores, &is_pos);
        assert_rel(ln, lf, 1e-6, &format!("case {case} loss"));
        let gscale = gn.iter().fold(1.0_f32, |m, g| m.max(g.abs()));
        for (i, (a, b)) in gn.iter().zip(&gf).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * gscale,
                "case {case} grad[{i}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_hinge_le_square() {
    // (m - z)_+^2 <= (m - z)^2 pairwise, so the totals must order.
    let mut gen = CaseGen::new(3);
    for _ in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        let lh = SquaredHinge::new(margin).loss_only(&scores, &is_pos);
        let (ls, _) = Square::new(margin).loss_and_grad(&scores, &is_pos);
        assert!(lh <= ls * (1.0 + 1e-9) + 1e-9, "{lh} > {ls}");
    }
}

#[test]
fn prop_loss_nonnegative_and_finite() {
    let mut gen = CaseGen::new(4);
    for _ in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        let hinge = SquaredHinge::new(margin);
        let square = Square::new(margin);
        let losses: [&dyn PairwiseLoss; 3] = [&hinge, &square, &Logistic];
        for loss in losses {
            let (l, g) = loss.loss_and_grad(&scores, &is_pos);
            assert!(l >= 0.0 && l.is_finite(), "{} loss {l}", loss.name());
            assert!(g.iter().all(|x| x.is_finite()), "{} grad", loss.name());
        }
    }
}

#[test]
fn prop_shift_invariance_of_hinge() {
    // Adding a constant to every score preserves all pairwise differences.
    let mut gen = CaseGen::new(5);
    for _ in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        if scores.iter().any(|s| s.abs() > 100.0) {
            continue; // keep the shift numerically meaningful in f32
        }
        let l0 = SquaredHinge::new(margin).loss_only(&scores, &is_pos);
        let shifted: Vec<f32> = scores.iter().map(|s| s + 3.25).collect();
        let l1 = SquaredHinge::new(margin).loss_only(&shifted, &is_pos);
        assert_rel(l0, l1, 1e-3, "shift invariance");
    }
}

#[test]
fn prop_gradient_descent_direction_reduces_loss() {
    // A small step against the gradient must not increase the loss
    // (convexity + smoothness of the squared hinge).
    let mut gen = CaseGen::new(6);
    for _ in 0..40 {
        let (mut scores, is_pos, margin) = gen.next_case();
        if scores.len() < 2 || scores.iter().any(|s| s.abs() > 50.0) {
            continue;
        }
        let hinge = SquaredHinge::new(margin);
        let (l0, g) = hinge.loss_and_grad(&scores, &is_pos);
        if l0 == 0.0 {
            continue;
        }
        let gnorm2: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if gnorm2 < 1e-12 {
            continue;
        }
        let step = (1e-4 * l0 / gnorm2) as f32;
        for (s, gi) in scores.iter_mut().zip(&g) {
            *s -= step * gi;
        }
        let l1 = hinge.loss_only(&scores, &is_pos);
        assert!(l1 <= l0 * (1.0 + 1e-6), "{l1} > {l0}");
    }
}

#[test]
fn prop_workspace_reuse_equals_fresh() {
    // One LossWorkspace reused across every case must reproduce the
    // allocating Figure-2 path bit for bit — for each LossFn kernel and
    // each sort strategy (LinearHinge covers the negatives-first-on-ties
    // ordering the squared-hinge path never takes).
    use allpairs::losses::LossFn;
    for strategy in SortStrategy::ALL {
        let mut gen = CaseGen::new(7);
        let mut ws = LossWorkspace::with_sort_strategy(strategy);
        for _ in 0..CASES {
            let (scores, is_pos, margin) = gen.next_case();
            for spec in [
                LossSpec::Hinge { margin },
                LossSpec::Square { margin },
                LossSpec::Logistic,
                LossSpec::LinearHinge { margin },
            ] {
                let kernel = spec.build().unwrap();
                let reused = kernel.loss_and_grad(BatchView::new(&scores, &is_pos), &mut ws);
                let fresh = kernel.loss_and_grad(
                    BatchView::new(&scores, &is_pos),
                    &mut LossWorkspace::with_sort_strategy(strategy),
                );
                assert_eq!(reused, fresh, "{spec} under {strategy}");
                assert_eq!(
                    kernel.loss_only(BatchView::new(&scores, &is_pos), &mut ws),
                    reused,
                    "{spec}: loss_only under {strategy}"
                );
            }
        }
    }
}

#[test]
fn prop_loss_spec_display_from_str_roundtrip() {
    // Property over all variants x a wide margin set: Display output
    // parses back to the identical spec, and the bare names hit the
    // default margin.
    let margins = [
        0.0_f32, 1e-3, 0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 3.25, 10.0, 123.456, 1e6,
    ];
    let mk: [fn(f32) -> LossSpec; 4] = [
        |margin| LossSpec::Hinge { margin },
        |margin| LossSpec::Square { margin },
        |margin| LossSpec::LinearHinge { margin },
        |margin| LossSpec::WeightedHinge { margin },
    ];
    for make in mk {
        for &m in &margins {
            let spec = make(m);
            let text = spec.to_string();
            let back: LossSpec = text.parse().unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, spec, "{text}");
        }
    }
    for spec in [LossSpec::Logistic, LossSpec::Aucm] {
        assert_eq!(spec.to_string().parse::<LossSpec>().unwrap(), spec);
    }
    // and randomized f32 margins round-trip through the shortest-float
    // Display formatting
    let mut rng = Rng::new(0x5bec);
    for _ in 0..200 {
        let m = (rng.uniform() * 8.0) as f32;
        let spec = LossSpec::Hinge { margin: m };
        assert_eq!(spec.to_string().parse::<LossSpec>().unwrap(), spec, "m={m}");
    }
}

#[test]
fn prop_weighted_hinge_matches_naive_weighted_reference() {
    // Differential property for the weighted kernel: loss AND gradient
    // against the O(n²) weighted double sum, under random weights,
    // margins and imbalance (previously only the loss value was
    // cross-checked).
    use allpairs::losses::LossFn;
    let mut gen = CaseGen::new(11);
    let mut rng = Rng::new(0x3e16);
    let mut workspaces: Vec<LossWorkspace> = SortStrategy::ALL
        .iter()
        .map(|&s| LossWorkspace::with_sort_strategy(s))
        .collect();
    for case in 0..CASES {
        let (scores, is_pos, margin) = gen.next_case();
        if scores.len() > 400 {
            continue; // naive is quadratic; keep the oracle cheap
        }
        let weights: Vec<f32> = scores
            .iter()
            .map(|_| {
                // mixture: mostly O(1) weights, some zeros, some large
                match rng.below(10) {
                    0 => 0.0,
                    1 => (rng.uniform() * 20.0) as f32,
                    _ => (rng.uniform() * 2.0) as f32,
                }
            })
            .collect();
        let wh = WeightedSquaredHinge::new(margin);
        let (ln, gn) = wh.loss_and_grad_naive(&scores, &is_pos, &weights);
        let mut outputs = Vec::new();
        for ws in &mut workspaces {
            let lf = LossFn::loss_and_grad(
                &wh,
                BatchView::weighted(&scores, &is_pos, &weights),
                ws,
            );
            outputs.push((lf, ws.grad.clone()));
        }
        // bit-identical across sort strategies, tolerance vs the oracle
        for (strategy, out) in SortStrategy::ALL.iter().zip(&outputs) {
            assert_eq!(
                (out.0.to_bits(), &out.1),
                (outputs[0].0.to_bits(), &outputs[0].1),
                "case {case}: weighted {strategy} diverged from comparison"
            );
        }
        let (lf, gf) = (outputs[0].0, &outputs[0].1);
        assert_rel(ln, lf, 1e-6, &format!("case {case} weighted loss"));
        let gscale = gn.iter().fold(1.0_f32, |m, g| m.max(g.abs()));
        for (i, (a, b)) in gn.iter().zip(gf.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * gscale,
                "case {case} weighted grad[{i}]: {a} vs {b} (scale {gscale})"
            );
        }
    }
}

#[test]
fn diff_large_n_weighted_hinge() {
    // Paper-scale differential check for the weighted kernel (release
    // runs at n = 10^4; debug shrinks like the unweighted suite).
    use allpairs::losses::LossFn;
    let n = differential_n();
    let mut rng = Rng::new(0x9e1d);
    for (case, pos_frac) in [0.5, 0.05].into_iter().enumerate() {
        let scores: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
        let is_pos = labels_with(n, (((n as f64) * pos_frac) as usize).max(1), &mut rng);
        let weights: Vec<f32> = (0..n).map(|_| (rng.uniform() * 2.0) as f32).collect();
        let wh = WeightedSquaredHinge::new(1.0);
        let (ln, gn) = wh.loss_and_grad_naive(&scores, &is_pos, &weights);
        let mut ws = LossWorkspace::default();
        let lf = LossFn::loss_and_grad(
            &wh,
            BatchView::weighted(&scores, &is_pos, &weights),
            &mut ws,
        );
        assert_rel(ln, lf, 1e-8, &format!("weighted case {case} loss"));
        let gscale = gn.iter().fold(1.0_f32, |m, g| m.max(g.abs()));
        for (i, (a, b)) in gn.iter().zip(&ws.grad).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * gscale,
                "weighted case {case} grad[{i}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn prop_auc_bounds_and_complement() {
    // AUC in [0,1]; negating scores gives 1 - AUC (ties preserved at 0.5).
    let mut gen = CaseGen::new(8);
    for _ in 0..CASES {
        let (scores, is_pos, _) = gen.next_case();
        let Some(a) = auc(&scores, &is_pos) else { continue };
        assert!((0.0..=1.0).contains(&a), "{a}");
        let neg: Vec<f32> = scores.iter().map(|s| -s).collect();
        let an = auc(&neg, &is_pos).unwrap();
        assert!((a + an - 1.0).abs() < 1e-9, "{a} + {an} != 1");
    }
}

// ---------------------------------------------------------------------------
// Differential tests at paper scale: the functional algorithms vs the
// O(n²) references at n >= 10^4 (the size regime the paper's Figure 2
// claims wins in).  The naive oracle is quadratic — 2.5·10⁷ pair ops
// per balanced case in release — so debug builds (tier-1 `cargo test
// -q`) shrink n; release CI (`cargo test --release`) runs full size.
// ---------------------------------------------------------------------------

/// 10⁴ in release; small enough to keep the quadratic oracle fast in
/// unoptimized tier-1 runs.
fn differential_n() -> usize {
    if cfg!(debug_assertions) {
        1_500
    } else {
        10_000
    }
}

/// Class-indicator vector with exactly `n_pos` positives, shuffled.
fn labels_with(n: usize, n_pos: usize, rng: &mut Rng) -> Vec<f32> {
    let mut is_pos = vec![0.0_f32; n];
    for p in is_pos.iter_mut().take(n_pos) {
        *p = 1.0;
    }
    rng.shuffle(&mut is_pos);
    is_pos
}

/// Compare functional vs naive on one case.
///
/// Tolerances: both implementations accumulate the loss in f64, where
/// the summation error over ~n² terms of similar magnitude is below
/// 1e-12 relative — 1e-8 leaves two orders of headroom for the
/// different algebraic groupings (pair-by-pair vs the coefficient
/// sweep).  Gradients are returned as f32: each side computes an exact
/// f64 value and rounds once (~6e-8 relative), so entries can differ by
/// a couple of f32 ulps at the gradient scale — 1e-4 of the max
/// absolute gradient covers that with a wide margin while still
/// catching any real indexing/sweep error (which shows up at O(scale)).
fn assert_differential(scores: &[f32], is_pos: &[f32], margin: f32, ctx: &str) {
    let (lnh, gnh) = NaiveSquaredHinge::new(margin).loss_and_grad(scores, is_pos);
    let (lfh, gfh) = SquaredHinge::new(margin).loss_and_grad(scores, is_pos);
    assert_rel(lnh, lfh, 1e-8, &format!("{ctx}: hinge loss"));
    // Every sort strategy reproduces the hinge loss and gradient bit for
    // bit at paper scale (the canonical permutation fixes the f64
    // accumulation order, so this is exact equality, not a tolerance).
    {
        use allpairs::losses::LossFn;
        let kernel = LossSpec::Hinge { margin }.build().unwrap();
        let mut reference: Option<(u64, Vec<f32>)> = None;
        for strategy in SortStrategy::ALL {
            let mut ws = LossWorkspace::with_sort_strategy(strategy);
            let l = kernel.loss_and_grad(BatchView::new(scores, is_pos), &mut ws);
            let out = (l.to_bits(), ws.grad.clone());
            match &reference {
                None => reference = Some(out),
                Some(want) => {
                    assert_eq!(&out, want, "{ctx}: hinge under {strategy} diverged");
                }
            }
        }
    }
    let (lns, gns) = NaiveSquare::new(margin).loss_and_grad(scores, is_pos);
    let (lfs, gfs) = Square::new(margin).loss_and_grad(scores, is_pos);
    assert_rel(lns, lfs, 1e-8, &format!("{ctx}: square loss"));
    for (family, gn, gf) in [("hinge", &gnh, &gfh), ("square", &gns, &gfs)] {
        let gscale = gn.iter().fold(1.0_f32, |m, g| m.max(g.abs()));
        for (i, (a, b)) in gn.iter().zip(gf.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 * gscale,
                "{ctx}: {family} grad[{i}]: {a} vs {b} (scale {gscale})"
            );
        }
    }
}

#[test]
fn diff_large_n_random_scores() {
    let n = differential_n();
    let mut rng = Rng::new(0xD1FF);
    for (case, pos_frac) in [0.5, 0.1, 0.01].into_iter().enumerate() {
        let scores: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
        let is_pos = labels_with(n, ((n as f64) * pos_frac) as usize, &mut rng);
        assert_differential(&scores, &is_pos, 1.0, &format!("random case {case}"));
    }
}

#[test]
fn diff_large_n_tie_heavy_scores() {
    // Quantized scores: long runs of exactly-equal sort keys exercise
    // the tie-handling argument of the ascending sweep (any tie order
    // is valid because tied (pos, neg) pairs contribute zero).
    let n = differential_n();
    let mut rng = Rng::new(0x7135);
    for margin in [0.0_f32, 0.5, 1.0] {
        let scores: Vec<f32> = (0..n)
            .map(|_| ((rng.normal() * 4.0).round() / 2.0) as f32)
            .collect();
        let is_pos = labels_with(n, n / 5, &mut rng);
        assert_differential(&scores, &is_pos, margin, &format!("ties margin {margin}"));
    }
}

#[test]
fn diff_large_n_extreme_imbalance() {
    // The paper's regime: a single positive among thousands of
    // negatives (the naive oracle is only O(n) pairs here, so this
    // runs at full 10^4 even in debug).
    let n = 10_000;
    let mut rng = Rng::new(0x1BAD);
    for n_pos in [1usize, 3, 10] {
        let scores: Vec<f32> = (0..n).map(|_| (rng.normal() * 3.0) as f32).collect();
        let is_pos = labels_with(n, n_pos, &mut rng);
        assert_differential(&scores, &is_pos, 1.0, &format!("{n_pos} positives"));
    }
}

#[test]
fn diff_large_n_varied_sizes_and_margins() {
    // Random (size, margin, imbalance) combinations around the large-n
    // scale so the agreement is not an artifact of one fixed shape.
    let mut rng = Rng::new(0x517E);
    let cap = differential_n();
    for case in 0..4 {
        let n = cap / 2 + rng.below(cap / 2);
        let margin = [0.0_f32, 0.5, 1.0, 4.0][rng.below(4)];
        let pos_frac = [0.5, 0.1, 0.003][rng.below(3)];
        let scores: Vec<f32> = (0..n).map(|_| (rng.normal() * 2.0) as f32).collect();
        let n_pos = (((n as f64) * pos_frac) as usize).max(1);
        let is_pos = labels_with(n, n_pos, &mut rng);
        assert_differential(
            &scores,
            &is_pos,
            margin,
            &format!("varied case {case} (n={n}, m={margin})"),
        );
    }
}

#[test]
fn prop_zero_hinge_loss_implies_perfect_auc() {
    // If the squared hinge loss is exactly zero, every positive outranks
    // every negative by >= m; with m > 0 that forces AUC = 1.
    let mut rng = Rng::new(9);
    for _ in 0..60 {
        let n = 2 + rng.below(300);
        let mut scores = Vec::with_capacity(n);
        let mut is_pos = Vec::with_capacity(n);
        for _ in 0..n {
            let pos = rng.uniform() < 0.4;
            is_pos.push(if pos { 1.0 } else { 0.0 });
            // positives in [2, 3], negatives in [-3, -2]: margin-1 safe
            let base = rng.uniform() as f32;
            scores.push(if pos { 2.0 + base } else { -3.0 + base });
        }
        let l = SquaredHinge::new(1.0).loss_only(&scores, &is_pos);
        assert_eq!(l, 0.0);
        if let Some(a) = auc(&scores, &is_pos) {
            assert_eq!(a, 1.0);
        }
    }
}
