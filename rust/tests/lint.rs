//! Integration tests for `allpairs lint` (the in-repo invariant
//! linter, DESIGN.md §12): every rule fires on its fixture, the escape
//! hatches behave, the tricky-token lexer cases hold, the historical
//! bug patterns are caught, and the repo itself lints clean.
//!
//! Fixtures live in `tests/fixtures/lint/` and are never compiled;
//! each is linted under a *synthetic* in-scope path, because rule
//! scoping keys on the relative path, not the file's real location.

use std::path::Path;

use allpairs::analysis::{all_rules, lint_source, run_lint, Finding};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/lint")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn lint_fixture(name: &str, as_path: &str) -> Vec<Finding> {
    lint_source(as_path, &fixture(name))
}

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// --- each rule fires on its fixture -----------------------------------

#[test]
fn float_narrowing_fires_in_losses() {
    let got = lint_fixture("float_narrowing_fires.rs", "src/losses/fixture.rs");
    assert_eq!(rules_of(&got), vec!["float-narrowing-in-kernel"]);
    assert_eq!((got[0].line, got[0].col), (5, 9));
}

#[test]
fn float_narrowing_is_path_scoped() {
    let got = lint_fixture("float_narrowing_fires.rs", "src/metrics/fixture.rs");
    assert!(got.is_empty(), "out of scope, must not fire: {got:?}");
}

#[test]
fn nondeterministic_iteration_fires() {
    for path in ["src/losses/f.rs", "src/runtime/f.rs", "src/coordinator/f.rs"] {
        let got = lint_fixture("nondeterministic_iteration_fires.rs", path);
        assert_eq!(got.len(), 3, "use + two ctor mentions at {path}: {got:?}");
        assert!(got.iter().all(|f| f.rule == "nondeterministic-iteration"));
    }
}

#[test]
fn raw_durable_write_fires() {
    let got = lint_fixture("raw_durable_write_fires.rs", "src/report/fixture.rs");
    assert_eq!(
        rules_of(&got),
        vec!["raw-durable-write", "raw-durable-write"],
        "fs::write and File::create: {got:?}"
    );
}

#[test]
fn raw_durable_write_exempts_fsio() {
    let got = lint_fixture("raw_durable_write_fires.rs", "src/util/fsio.rs");
    assert!(got.is_empty(), "fsio is the one place raw writes live: {got:?}");
}

#[test]
fn lock_unwrap_fires_anywhere() {
    let got = lint_fixture("lock_unwrap_fires.rs", "src/made/up/path.rs");
    assert_eq!(rules_of(&got), vec!["lock-unwrap"]);
    assert_eq!((got[0].line, got[0].col), (5, 26));
}

#[test]
fn wallclock_fires_in_engine_paths() {
    let got = lint_fixture("wallclock_fires.rs", "src/runtime/fixture.rs");
    assert_eq!(got.len(), 3, "SystemTime import + Instant::now + SystemTime::now: {got:?}");
    assert!(got.iter().all(|f| f.rule == "wallclock-in-kernel"));
    // ...but timing the coordinator/bench layer is fine.
    assert!(lint_fixture("wallclock_fires.rs", "src/util/bench.rs").is_empty());
}

#[test]
fn unchecked_cast_fires_in_parse_paths() {
    let got = lint_fixture("unchecked_cast_fires.rs", "src/serve/protocol.rs");
    assert_eq!(rules_of(&got), vec!["unchecked-cast-in-parse"]);
    assert_eq!((got[0].line, got[0].col), (5, 9));
}

// --- escape hatches ----------------------------------------------------

#[test]
fn reasoned_allow_suppresses() {
    let got = lint_fixture("float_narrowing_allowed.rs", "src/losses/fixture.rs");
    assert!(got.is_empty(), "reasoned allow must silence the narrow: {got:?}");
}

#[test]
fn cfg_test_module_is_exempt() {
    let got = lint_fixture("cfg_test_exempt.rs", "src/losses/fixture.rs");
    assert!(got.is_empty(), "#[cfg(test)] content is exempt: {got:?}");
}

#[test]
fn clean_kernel_code_has_no_findings() {
    let got = lint_fixture("clean.rs", "src/losses/clean.rs");
    assert!(got.is_empty(), "house-style code must lint clean: {got:?}");
}

#[test]
fn reasonless_and_unknown_allows_are_findings() {
    // Under a neutral path only the meta-rule fires: one finding per
    // bad suppression (no reason, empty reason, unknown rule).
    let got = lint_fixture("allow_without_reason.rs", "src/util/other.rs");
    assert_eq!(
        rules_of(&got),
        vec!["lint-allow-needs-reason"; 3],
        "three bad suppressions: {got:?}"
    );
    assert_eq!(
        got.iter().map(|f| f.line).collect::<Vec<_>>(),
        vec![4, 6, 8]
    );
}

#[test]
fn bad_allows_do_not_suppress() {
    // Under a kernel path the same fixture also reports the narrows the
    // bad suppressions failed to cover — nothing grandfathers silently.
    let got = lint_fixture("allow_without_reason.rs", "src/losses/fixture.rs");
    let narrows = got
        .iter()
        .filter(|f| f.rule == "float-narrowing-in-kernel")
        .count();
    assert_eq!(narrows, 3, "each bad allow leaves its cast exposed: {got:?}");
    assert_eq!(got.len(), 6);
}

// --- tricky tokens ------------------------------------------------------

#[test]
fn tricky_tokens_produce_exactly_one_finding() {
    let got = lint_fixture("tricky_tokens.rs", "src/losses/tricky.rs");
    assert_eq!(
        rules_of(&got),
        vec!["float-narrowing-in-kernel"],
        "decoys in strings/comments/chars must not fire: {got:?}"
    );
    assert_eq!((got[0].line, got[0].col), (16, 11), "span after multi-byte text: {got:?}");
}

// --- historical bug regressions (the patterns that motivated the rules) -

#[test]
fn regression_f32_sort_key_is_caught() {
    let got = lint_fixture("regression_f32_sort_key.rs", "src/losses/sort_keys.rs");
    assert_eq!(rules_of(&got), vec!["float-narrowing-in-kernel"]);
    assert_eq!((got[0].line, got[0].col), (9, 32));
}

#[test]
fn regression_unchecked_header_is_caught() {
    let got = lint_fixture("regression_unchecked_header.rs", "src/train/checkpoint.rs");
    assert_eq!(rules_of(&got), vec!["unchecked-cast-in-parse"]);
    assert_eq!((got[0].line, got[0].col), (8, 20));
}

#[test]
fn regression_shard_header_cast_is_caught() {
    // The shard-format analogue of the PR 7 checkpoint-header bug:
    // bare casts on header fields read straight off disk.  The rule is
    // path-scoped over src/data/shard/, so the same fixture under a
    // neighbouring data/ path must stay silent.
    let got = lint_fixture("regression_shard_header_cast.rs", "src/data/shard/format.rs");
    assert_eq!(
        rules_of(&got),
        vec!["unchecked-cast-in-parse", "unchecked-cast-in-parse"]
    );
    assert_eq!((got[0].line, got[0].col), (6, 70));
    assert_eq!((got[1].line, got[1].col), (7, 72));
    let out = lint_fixture("regression_shard_header_cast.rs", "src/data/stream.rs");
    assert!(out.is_empty(), "out of scope, must not fire: {out:?}");
}

#[test]
fn regression_raw_report_write_is_caught() {
    let got = lint_fixture("regression_raw_report_write.rs", "src/report/summary.rs");
    assert_eq!(rules_of(&got), vec!["raw-durable-write"]);
    assert_eq!((got[0].line, got[0].col), (6, 10));
}

// --- the repo itself ----------------------------------------------------

#[test]
fn repo_lints_clean() {
    let findings = run_lint(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    assert!(
        findings.is_empty(),
        "the tree must lint clean (no silent baseline):\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn finding_display_format_is_stable() {
    let got = lint_fixture("lock_unwrap_fires.rs", "src/sweep/queue.rs");
    assert_eq!(
        got[0].to_string(),
        "src/sweep/queue.rs:5:26 [lock-unwrap] ".to_string() + got[0].message.as_str()
    );
}

#[test]
fn rule_catalog_is_complete() {
    let names: Vec<&str> = all_rules().iter().map(|r| r.name).collect();
    for expected in [
        "float-narrowing-in-kernel",
        "nondeterministic-iteration",
        "raw-durable-write",
        "lock-unwrap",
        "wallclock-in-kernel",
        "unchecked-cast-in-parse",
        "lint-allow-needs-reason",
    ] {
        assert!(names.contains(&expected), "missing rule {expected}");
    }
}
