//! Paper §5 extension: deterministic full-batch training with L-BFGS.
//!
//! "We expect that for problems where there exists a bad condition
//! number, LBFGS with full batch size should out-perform Stochastic
//! Gradient Descent with small batch sizes."  The log-linear loss makes
//! the full-batch gradient affordable, so this example runs both on the
//! same imbalanced feature problem with an equal gradient-evaluation
//! budget and reports full-batch loss + training AUC.  Runs on the
//! native backend's full-batch objective — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example lbfgs_fullbatch
//! ```

use allpairs::data::{features, FeatureSpec, Rng};
use allpairs::losses::LossSpec;
use allpairs::metrics::auc;
use allpairs::runtime::{NativeBackend, NativeSpec};
use allpairs::train::lbfgs::{minimize, LbfgsConfig, Objective};
use allpairs::util::cli::Args;

fn feature_batch(n: usize, pos_frac: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
    // Moderate conditioning: with the MLP's squashing head, strongly
    // anisotropic inputs saturate the activations and stall *every*
    // first-order method; the interesting regime for the §5 comparison
    // is curvature variation the quasi-Newton update can exploit while
    // gradients still flow.
    let spec = FeatureSpec {
        pos_frac,
        ..Default::default()
    };
    let d = features::generate(&spec, n, &mut Rng::new(seed));
    (d.x, d.y)
}

fn main() -> allpairs::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.expect_known(&["iters", "n", "pos-frac", "hidden"])?;
    let iters: usize = args.get("iters", 15)?;
    let n: usize = args.get("n", 800)?;
    let pos_frac: f64 = args.get("pos-frac", 0.1)?;
    let hidden: usize = args.get("hidden", 16)?;

    let backend = NativeBackend::new(NativeSpec {
        input_dim: 64,
        hidden,
        threads: 0,
        ..NativeSpec::default()
    });
    let (rows, labels) = feature_batch(n, pos_frac, 7);
    println!(
        "full-batch problem: {n} examples, {:.1}% positive",
        100.0 * labels.iter().sum::<f32>() as f64 / n as f64
    );

    let mut objective = backend.objective("mlp", &LossSpec::hinge(), &rows, &labels)?;
    let theta0 = objective.init_params(0);
    let (l0, _) = objective.eval(&theta0)?;
    println!("initial full-batch hinge loss: {l0:.6}\n== L-BFGS ==");

    let config = LbfgsConfig {
        max_iters: iters,
        ..Default::default()
    };
    let (theta, trace) = minimize(&mut objective, theta0.clone(), &config)?;
    for r in &trace {
        println!(
            "iter {:3}  loss {:10.6}  |grad|inf {:9.2e}  step {:7.4}  ls {}",
            r.iter, r.loss, r.grad_norm, r.step, r.ls_trials
        );
    }
    let lbfgs_evals = objective.evals;
    let lbfgs_loss = trace.last().map(|r| r.loss).unwrap_or(l0);

    // Equal-budget plain full-batch gradient descent baseline.
    println!("\n== full-batch gradient descent (same {lbfgs_evals} grad evals) ==");
    objective.evals = 0;
    let mut theta_gd = theta0;
    let mut gd_loss = l0;
    for i in 0..lbfgs_evals {
        let (l, g) = objective.eval(&theta_gd)?;
        gd_loss = l;
        if i % 5 == 0 {
            println!("eval {i:3}  loss {l:10.6}");
        }
        for (t, gi) in theta_gd.iter_mut().zip(&g) {
            *t -= 0.5 * gi;
        }
    }

    // AUC of both solutions on the training batch.
    let lbfgs_auc = auc(&objective.scores(&theta)?, &labels).unwrap_or(f64::NAN);
    let gd_auc = auc(&objective.scores(&theta_gd)?, &labels).unwrap_or(f64::NAN);
    println!("\n== summary (equal gradient-evaluation budget) ==");
    println!("L-BFGS : loss {lbfgs_loss:10.6}  AUC {lbfgs_auc:.4}");
    println!("GD     : loss {gd_loss:10.6}  AUC {gd_auc:.4}");
    anyhow::ensure!(lbfgs_loss <= gd_loss, "expected L-BFGS <= GD on this problem");
    println!("\nlbfgs_fullbatch OK");
    Ok(())
}
