//! Table 2 + Figure 3 reproduction driver: the full cross-validation
//! sweep over datasets × imratios × losses × batch sizes × learning rates
//! × seeds, through any backend, with max-validation-AUC selection.
//!
//! The default configuration is the full paper protocol on the native
//! backend; `--smoke` runs a reduced grid in a couple of minutes, and
//! `--medium` is the EXPERIMENTS.md configuration (reduced but still
//! covering every cell of Table 2 / Figure 3).  Pass `--backend pjrt`
//! (on a `--features pjrt` build with `make artifacts`) to drive the
//! AOT kernels instead — that path also enables the `aucm` baseline.
//!
//! ```bash
//! cargo run --release --example imbalance_sweep -- --medium
//! ```

use allpairs::config::SweepConfig;
use allpairs::coordinator::cv;
use allpairs::data::SamplingMode;
use allpairs::losses::LossSpec;
use allpairs::runtime::BackendSpec;
use allpairs::util::cli::Args;

fn main() -> allpairs::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.expect_known(&[
        "smoke", "medium", "artifacts", "backend", "out", "workers", "epochs", "config",
        "patience", "sampling",
    ])?;
    let out = std::path::PathBuf::from(args.get_str("out", "results"));

    let user_config = args.get_opt("config").is_some();
    let mut cfg = match args.get_opt("config") {
        Some(path) => SweepConfig::load(path)?,
        None => SweepConfig::default(),
    };
    match args.get_opt("backend").as_deref() {
        Some("pjrt") => cfg.backend = BackendSpec::pjrt(args.get_str("artifacts", "artifacts")),
        Some("native") => cfg.backend = BackendSpec::native(),
        None => {} // keep the config file's backend (native by default)
        Some(other) => anyhow::bail!("unknown backend {other:?} (native | pjrt)"),
    }
    let native = matches!(cfg.backend, BackendSpec::Native(_));
    if cfg.adapt_losses_to_backend(!user_config) {
        eprintln!(
            "note: aucm requires the pjrt backend; sweeping losses {:?}",
            cfg.losses.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        );
    }
    if args.flag("smoke") {
        cfg.datasets = vec!["synth-pets".into()];
        cfg.imratios = vec![0.1, 0.01];
        cfg.losses = vec![LossSpec::hinge(), LossSpec::logistic()];
        cfg.batch_sizes = vec![50, 500];
        cfg.seeds = vec![0, 1];
        cfg.epochs = 4;
        cfg.max_train = Some(1000);
    } else if args.flag("medium") {
        // The EXPERIMENTS.md configuration: every Table-2/Fig-3 cell
        // covered (3 datasets x 3 imratios x 3 losses), grid thinned —
        // batch {10, 1000}, top-2 learning rates, 2 seeds, 3 epochs —
        // to finish in well under an hour on a single-core testbed.
        cfg.imratios = vec![0.1, 0.01, 0.001];
        cfg.batch_sizes = vec![10, 1000];
        cfg.seeds = vec![0, 1];
        cfg.epochs = 3;
        cfg.max_train = Some(4000);
        cfg.max_lrs = Some(2);
        if !native {
            cfg.workers = 1; // one PJRT runtime: compile each variant once
        }
    }
    cfg.workers = args.get("workers", cfg.workers)?;
    cfg.epochs = args.get("epochs", cfg.epochs)?;
    if let Some(p) = args.get_opt("patience") {
        cfg.patience = Some(p.parse()?);
    }
    if let Some(modes) = args.get_opt("sampling") {
        cfg.sampling_modes = modes.split(',').map(|m| m.trim().to_string()).collect();
        for name in &cfg.sampling_modes {
            SamplingMode::parse(name)?;
        }
    }

    eprintln!(
        "sweep: {} runs ({} datasets x {} imratios x {} losses x {} batches x lr-grid x {} seeds) on {} workers ({} backend)",
        cfg.n_runs(),
        cfg.datasets.len(),
        cfg.imratios.len(),
        cfg.losses.len(),
        cfg.batch_sizes.len(),
        cfg.seeds.len(),
        cfg.workers,
        cfg.backend.kind(),
    );
    let t0 = std::time::Instant::now();
    let progress: allpairs::sweep::scheduler::ProgressFn = Box::new(|done, total, msg| {
        eprintln!("[{done}/{total}] {msg}");
    });
    let output = cv::run(&cfg, &out, Some(progress))?;

    println!(
        "\nsweep finished: {} runs in {:.1} min",
        output.results.len(),
        t0.elapsed().as_secs_f64() / 60.0
    );
    if !output.failures.is_empty() {
        eprintln!(
            "warning: {} job(s) failed and are missing from the reports; \
             `allpairs sweep --resume --out {}` retries only those",
            output.failures.len(),
            out.display()
        );
    }
    println!("\n== Table 2: median selected hyper-parameters ==\n");
    print!("{}", std::fs::read_to_string(out.join("table2.md"))?);
    println!("\n== Figure 3: test AUC (mean ± sd over seeds) ==\n");
    print!("{}", std::fs::read_to_string(out.join("fig3.md"))?);
    println!(
        "\nraw results: {}",
        out.join("sweep_results.jsonl").display()
    );
    Ok(())
}
