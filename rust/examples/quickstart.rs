//! Quickstart: train a small MLP with the all-pairs squared hinge loss on
//! a synthetic imbalanced feature dataset, entirely through the public
//! API on the self-contained native backend — no artifacts, no Python.
//! Finishes in seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use allpairs::data::{features, FeatureSpec, Rng, Split};
use allpairs::losses::{functional, LossSpec, PairwiseLoss};
use allpairs::metrics::{auc, roc_curve};
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::train::Trainer;

fn main() -> allpairs::Result<()> {
    let mut rng = Rng::new(42);

    // --- 1. The paper's algorithm, natively: loss + gradient in O(n log n)
    println!("== Algorithm 2 (native Rust): all-pairs squared hinge");
    let scores: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
    let is_pos = vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0];
    let hinge = functional::SquaredHinge::new(1.0);
    let (loss, grad) = hinge.loss_and_grad(&scores, &is_pos);
    println!("   loss = {loss:.4}");
    println!(
        "   grad = {:?}\n",
        grad.iter().map(|g| (g * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    // --- 2. End-to-end training through the backend layer (mlp + hinge)
    println!("== Training MLP + all-pairs hinge via the native backend");
    // one pool, one signal process; first 2000 rows train, rest test
    let spec = FeatureSpec {
        pos_frac: 0.5,
        ..Default::default()
    };
    let pool = features::generate(&spec, 3000, &mut rng);
    let train_idx: Vec<u32> = (0..2000).collect();
    let test_idx_pool: Vec<u32> = (2000..3000).collect();
    let train = pool.subset(&train_idx).imbalance(0.05, &mut rng); // 5% positive
    let test = pool.subset(&test_idx_pool);
    let split = Split::stratified(&train.y, 0.2, &mut rng);
    println!(
        "   train: {} examples, {:.1}% positive; subtrain {} / val {}",
        train.len(),
        100.0 * train.pos_fraction(),
        split.subtrain.len(),
        split.validation.len()
    );

    let backend = BackendSpec::Native(NativeSpec {
        input_dim: spec.dim,
        hidden: 32,
        threads: 0, // one per core
        ..NativeSpec::default()
    })
    .connect()?;
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &LossSpec::hinge(), 100)?;
    let history = trainer.fit(
        &train,
        &split.subtrain,
        &split.validation,
        0.05,
        8,
        0,
        &mut rng,
    )?;
    for r in &history.records {
        println!(
            "   epoch {:2}  train_loss {:8.5}  val_auc {}",
            r.epoch,
            r.train_loss,
            r.val_auc
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "n/a".into())
        );
    }

    // --- 3. Evaluate on the balanced test set: AUC + a few ROC points
    let test_idx: Vec<u32> = (0..test.len() as u32).collect();
    let scores = trainer.predict(&test, &test_idx)?;
    let labels: Vec<f32> = test.y.clone();
    let test_auc = auc(&scores, &labels).expect("balanced test set");
    println!("\n== Test AUC: {test_auc:.4}");
    let curve = roc_curve(&scores, &labels);
    println!(
        "   ROC curve ({} points), selected operating points:",
        curve.len()
    );
    for p in curve.iter().step_by(curve.len() / 5 + 1) {
        println!("   thr {:7.4}  FPR {:.3}  TPR {:.3}", p.threshold, p.fpr, p.tpr);
    }
    anyhow::ensure!(test_auc > 0.7, "quickstart should reach AUC > 0.7");
    println!("\nquickstart OK");
    Ok(())
}
