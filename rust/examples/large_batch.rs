//! Large-batch streaming training — the regime the paper's log-linear
//! gradient makes practical.  Trains an MLP with the all-pairs squared
//! hinge loss on a synthetic imbalanced feature dataset through
//! [`Trainer::fit_stream`]: stratified rebalanced batches of 1000,
//! validation-AUC early stopping, best-checkpoint tracking — then
//! re-runs the fit to assert the whole pipeline is bit-deterministic
//! under the fixed seed, and requires validation AUC >= 0.95.
//!
//! ```bash
//! cargo run --release --example large_batch
//! cargo run --release --example large_batch -- --batch 2000 --sampling preserve
//! ```

use allpairs::data::{features, FeatureSpec, Rng, SamplingMode, Split};
use allpairs::losses::LossSpec;
use allpairs::runtime::{BackendSpec, NativeSpec};
use allpairs::train::{FitConfig, Trainer};
use allpairs::util::cli::Args;

fn main() -> allpairs::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.expect_known(&[
        "batch", "epochs", "patience", "lr", "imratio", "sampling", "seed", "loss",
    ])?;
    // e.g. --loss whinge trains the class-balanced weighted hinge
    let loss: LossSpec = args.get_str("loss", "hinge").parse()?;
    let batch: usize = args.get("batch", 1000)?;
    let epochs: usize = args.get("epochs", 40)?;
    let patience: usize = args.get("patience", 5)?;
    let lr: f64 = args.get("lr", 0.05)?;
    let imratio: f64 = args.get("imratio", 0.05)?;
    let sampling = SamplingMode::parse(&args.get_str("sampling", "rebalance:0.5"))?;
    let seed: u32 = args.get("seed", 0)?;

    // The default synthetic imbalanced dataset: balanced pool with a
    // strong class signal, then positives removed to `imratio`.
    let mut rng = Rng::new(7);
    let spec = FeatureSpec {
        pos_frac: 0.5,
        signal_dims: 16,
        shift: 2.0,
        ..Default::default()
    };
    let pool = features::generate(&spec, 8000, &mut rng);
    let train_rows: Vec<u32> = (0..6000).collect();
    let test_rows: Vec<u32> = (6000..8000).collect();
    let train = pool.subset(&train_rows).imbalance(imratio, &mut rng);
    let test = pool.subset(&test_rows);
    let split = Split::stratified(&train.y, 0.2, &mut rng);
    println!(
        "train: {} examples ({:.2}% positive), subtrain {} / validation {}, batch {batch} ({})",
        train.len(),
        100.0 * train.pos_fraction(),
        split.subtrain.len(),
        split.validation.len(),
        sampling.name(),
    );

    let backend = BackendSpec::Native(NativeSpec {
        input_dim: spec.dim,
        hidden: 32,
        threads: 0, // one per core: large batches parallelize well
        ..NativeSpec::default()
    })
    .connect()?;
    let cfg = FitConfig {
        lr: lr as f32,
        epochs,
        patience: Some(patience),
        sampling,
        seed,
    };
    let fit_seed = seed as u64 + 0x57EA4;
    let mut trainer = Trainer::new(backend.as_ref(), "mlp", &loss, batch)?;
    let outcome = trainer.fit_stream(
        &train,
        &split.subtrain,
        &split.validation,
        &cfg,
        &mut Rng::new(fit_seed),
    )?;
    for r in &outcome.history.records {
        println!(
            "epoch {:3}  loss {:10.6}  val_auc {}  ({:.2}s)",
            r.epoch,
            r.train_loss,
            r.val_auc
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "  n/a ".into()),
            r.seconds
        );
    }
    let best = outcome
        .best
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("validation AUC was never defined"))?;
    println!(
        "best val AUC {:.4} at epoch {} ({})",
        best.val_auc,
        best.epoch,
        if outcome.stopped_early {
            "stopped early"
        } else {
            "full epoch budget"
        }
    );

    // Same seed, fresh trainer: the streaming pipeline (reshuffle,
    // oversampling cycle, early stop) must reproduce bit-identically.
    let mut rerun_trainer = Trainer::new(backend.as_ref(), "mlp", &loss, batch)?;
    let rerun = rerun_trainer.fit_stream(
        &train,
        &split.subtrain,
        &split.validation,
        &cfg,
        &mut Rng::new(fit_seed),
    )?;
    anyhow::ensure!(
        rerun.history.len() == outcome.history.len()
            && rerun
                .history
                .records
                .iter()
                .zip(&outcome.history.records)
                .all(|(a, b)| {
                    a.train_loss.to_bits() == b.train_loss.to_bits() && a.val_auc == b.val_auc
                }),
        "streaming fit must be deterministic under a fixed seed"
    );
    println!("determinism check OK (re-run history is bit-identical)");

    // Restore the best checkpoint and evaluate the balanced test set.
    trainer.load_state(&best.state)?;
    let test_all: Vec<u32> = (0..test.len() as u32).collect();
    let test_auc = trainer
        .eval_auc(&test, &test_all)?
        .ok_or_else(|| anyhow::anyhow!("test AUC undefined"))?;
    println!("test AUC at best checkpoint: {test_auc:.4}");
    anyhow::ensure!(
        best.val_auc >= 0.95,
        "expected validation AUC >= 0.95, got {:.4}",
        best.val_auc
    );
    println!("large_batch OK");
    Ok(())
}
