//! Figure 2 reproduction driver: loss + gradient computation time vs data
//! size for Naive O(n²), Functional O(n)/O(n log n) and Logistic O(n).
//!
//! Writes `results/fig2.csv`, prints the ASCII log-log plot, the fitted
//! asymptotic slopes, and the paper's "largest n within one second"
//! comparison (§4.1: naive ≈ 10³ vs functional ≈ 10⁶).
//!
//! ```bash
//! cargo run --release --example timing_comparison            # full 10^7
//! cargo run --release --example timing_comparison -- --max-exp 5   # quick
//! ```

use allpairs::coordinator::timing;
use allpairs::report::figures::{ascii_loglog, write_csv};
use allpairs::util::cli::Args;

fn main() -> allpairs::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.expect_known(&["max-exp", "repeats", "naive-cap", "out"])?;
    let max_exp: u32 = args.get("max-exp", 7)?;
    let out = std::path::PathBuf::from(args.get_str("out", "results"));
    let config = timing::TimingConfig {
        sizes: (1..=max_exp).map(|e| 10usize.pow(e)).collect(),
        repeats: args.get("repeats", 3)?,
        naive_cap: args.get("naive-cap", 30_000)?,
        margin: 1.0,
    };
    eprintln!(
        "Figure 2: timing {} algorithms at sizes {:?} ...",
        5, config.sizes
    );
    let points = timing::run(&config);

    // CSV (the canonical output EXPERIMENTS.md references)
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.algorithm.to_string(),
                p.complexity.to_string(),
                p.n.to_string(),
                format!("{:.6e}", p.seconds),
            ]
        })
        .collect();
    write_csv(
        out.join("fig2.csv"),
        &["algorithm", "complexity", "n", "seconds"],
        &rows,
    )?;

    println!("{}", ascii_loglog(&timing::to_series(&points), 72, 22));

    println!("fitted log-log slopes over the largest sizes:");
    println!("  (theory: naive = 2, functional/logistic = 1 + o(1))");
    for (name, slope) in timing::slopes(&points, 3) {
        println!("  {name:28} slope {slope:5.2}");
    }

    println!("\nlargest n with loss+gradient under 1 second (paper §4.1):");
    for (name, n) in timing::max_n_within(&points, 1.0) {
        println!("  {name:28} n = {n}");
    }

    println!("\nwrote {}", out.join("fig2.csv").display());
    Ok(())
}
