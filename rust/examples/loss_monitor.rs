//! Section-5 use case: monitor the *full-set* all-pairs squared hinge
//! loss every epoch, in the same O(n log n) as AUC — the paper's
//! interpretability argument for the functional representation.
//!
//! Trains a model while computing, per epoch, on the whole subtrain set:
//! (a) the all-pairs hinge loss via the **native Rust** Algorithm 2
//! directly, (b) the same loss via the **backend's** monitoring entry
//! point (cross-checking the plumbing; on a pjrt build with artifacts
//! this is the Pallas loss_eval kernel), and (c) AUC.
//!
//! ```bash
//! cargo run --release --example loss_monitor
//! ```

use allpairs::config::SweepConfig;
use allpairs::coordinator::{cv, monitor};
use allpairs::data::{Rng, Split};
use allpairs::losses::LossSpec;
use allpairs::metrics::auc;
use allpairs::runtime::BackendSpec;
use allpairs::train::Trainer;
use allpairs::util::cli::Args;

fn main() -> allpairs::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.expect_known(&["artifacts", "backend", "epochs", "imratio", "max-train"])?;
    let epochs: usize = args.get("epochs", 6)?;
    let imratio: f64 = args.get("imratio", 0.05)?;
    let max_train: usize = args.get("max-train", 2000)?;

    let cfg = SweepConfig {
        datasets: vec!["synth-cifar".into()],
        max_train: Some(max_train),
        ..Default::default()
    };
    let data = cv::build_datasets(&cfg)?;
    let pool = &data["synth-cifar"];
    let mut rng = Rng::new(11);
    let train = pool.train_pool.imbalance(imratio, &mut rng);
    let split = Split::stratified(&train.y, 0.2, &mut rng);
    println!(
        "monitoring run: {} train examples ({:.2}% positive)",
        train.len(),
        100.0 * train.pos_fraction()
    );

    let spec = match args.get_opt("backend").as_deref() {
        Some("pjrt") => BackendSpec::pjrt(args.get_str("artifacts", "artifacts")),
        Some("native") | None => BackendSpec::native(),
        Some(other) => anyhow::bail!("unknown backend {other:?} (native | pjrt)"),
    };
    let backend = spec.connect()?;
    let hinge = LossSpec::hinge();
    let mut trainer = Trainer::new(backend.as_ref(), "resnet", &hinge, 100)?;
    trainer.init(0)?;

    println!(
        "{:>5} {:>12} {:>14} {:>14} {:>10} {:>10}",
        "epoch", "batch_loss", "full_loss_rust", "full_loss_bknd", "sub_auc", "val_auc"
    );
    for epoch in 0..epochs {
        let stats = trainer.train_epoch(&train, &split.subtrain, 0.01, &mut rng)?;

        // Full-subtrain monitoring: predict once, evaluate both paths.
        let scores = trainer.predict(&train, &split.subtrain)?;
        let labels: Vec<f32> = split
            .subtrain
            .iter()
            .map(|&i| train.y[i as usize])
            .collect();
        let full_rust = monitor::monitor_native(&scores, &labels, 1.0);
        // both monitors are pair-normalized; they must agree to fp tolerance
        let full_backend = monitor::monitor_backend(backend.as_ref(), &hinge, &scores, &labels)?;
        let sub_auc = auc(&scores, &labels).unwrap_or(f64::NAN);
        let val_auc = trainer
            .eval_auc(&train, &split.validation)?
            .unwrap_or(f64::NAN);
        println!(
            "{epoch:>5} {:>12.6} {full_rust:>14.6} {full_backend:>14.6} {sub_auc:>10.4} {val_auc:>10.4}",
            stats.mean_loss
        );
        anyhow::ensure!(
            (full_rust - full_backend).abs() <= 1e-3 * full_rust.abs().max(1e-6),
            "native and backend monitors disagree: {full_rust} vs {full_backend}"
        );
    }
    println!("\ndirect Algorithm 2 and the backend loss monitor agree; loss_monitor OK");
    Ok(())
}
