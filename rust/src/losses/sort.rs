//! The `SortEngine` seam: interchangeable strategies for producing the
//! hinge-family sort permutation (DESIGN.md §9).
//!
//! The log-linear hinge sweeps (Algorithm 2) are dominated by the sort
//! over augmented-value keys, so "beat the sort" (ROADMAP item 2) is a
//! kernel-speed priority.  This module pins one **canonical
//! permutation** — ascending by `f64::total_cmp` on the key, then
//! negatives before positives when requested, then index ascending —
//! and provides three strategies that all produce it exactly:
//!
//! * [`SortStrategy::Comparison`] — `slice::sort_unstable_by` over the
//!   composite comparator.  The reference implementation: obviously
//!   correct, O(n log n) with a data-dependent constant.
//! * [`SortStrategy::Radix`] — LSD radix sort over the order-preserving
//!   monotone u64 transform of the f64 keys ([`key_bits`]), 8 bits per
//!   pass with constant-byte passes skipped, followed by an O(n)
//!   negatives-first tie pass.  O(n), branch-free inner loop.
//! * [`SortStrategy::Adaptive`] — seeds from the previous call's
//!   permutation (SGD moves scores little between steps, so the old
//!   order is near-sorted), detects maximal ascending runs, and merges
//!   them bottom-up in `ceil(log2 runs)` linear passes; falls back to
//!   radix when disorder exceeds [`MAX_MERGE_RUNS`].
//!
//! Because the permutation is identical across strategies, the f64
//! sweep accumulation order is identical, so losses, gradients and
//! optimizer state are **bit-identical** regardless of strategy — the
//! determinism guarantees of DESIGN.md §7 survive strategy selection.
//! The differential layer in `tests/proptest_sort.rs` pins this.

use std::fmt;
use std::str::FromStr;

/// How many ascending runs the adaptive strategy will merge before
/// falling back to radix.  A bottom-up merge of `k` runs costs
/// `n · ceil(log2 k)` comparisons; radix costs at most 9 linear passes
/// (1 histogram + 8 scatter) with no comparisons.  At 256 runs the
/// merge does 8 passes — about radix parity — and beyond that radix
/// only gets relatively cheaper, so the threshold errs toward radix.
/// Tune against the `sort/*` records of `allpairs bench`.
pub const MAX_MERGE_RUNS: usize = 256;

/// Strategy selecting how the hinge-family sort permutation is
/// produced.  All strategies yield the identical permutation; only
/// speed differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SortStrategy {
    /// Reference: `sort_unstable_by` over the composite comparator.
    Comparison,
    /// LSD radix over the monotone u64 key transform.
    Radix,
    /// Run-merge from the previous permutation; radix fallback.
    #[default]
    Adaptive,
}

impl SortStrategy {
    /// Every strategy, comparison (the reference) first.
    pub const ALL: [SortStrategy; 3] = [
        SortStrategy::Comparison,
        SortStrategy::Radix,
        SortStrategy::Adaptive,
    ];

    /// Stable lower-case name (CLI flags, JSON specs, bench records).
    pub fn name(self) -> &'static str {
        match self {
            SortStrategy::Comparison => "comparison",
            SortStrategy::Radix => "radix",
            SortStrategy::Adaptive => "adaptive",
        }
    }
}

impl fmt::Display for SortStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for SortStrategy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "comparison" => Ok(SortStrategy::Comparison),
            "radix" => Ok(SortStrategy::Radix),
            "adaptive" => Ok(SortStrategy::Adaptive),
            other => Err(anyhow::anyhow!(
                "unknown sort strategy '{other}' (expected comparison | radix | adaptive)"
            )),
        }
    }
}

/// Order-preserving monotone transform from f64 to u64: `a` sorts
/// before `b` under [`f64::total_cmp`] iff `key_bits(a) < key_bits(b)`.
///
/// IEEE-754 doubles compare like sign-magnitude integers: for
/// non-negative values the raw bit pattern already ascends with the
/// value, and setting the sign bit lifts them above every negative;
/// for negative values the pattern ascends as the value *descends*, and
/// complementing reverses that while mapping them below the
/// non-negatives.  This is exactly the flip `total_cmp` performs
/// internally, so the transform agrees with it bit-for-bit on every
/// input — -0.0 < +0.0, subnormals in order, and NaNs at the extremes
/// by sign and payload.
#[inline]
pub fn key_bits(key: f64) -> u64 {
    let b = key.to_bits();
    if b & SIGN_BIT != 0 {
        !b
    } else {
        b | SIGN_BIT
    }
}

const SIGN_BIT: u64 = 1 << 63;

/// Reusable state for one sort stream: the strategy, the previous
/// permutation (the adaptive seed), and the scratch buffers of the
/// radix and merge passes.  Lives inside
/// [`super::kernel::LossWorkspace`] so the training hot loop stays
/// allocation-free after warm-up and the adaptive path sees the prior
/// step's order.
#[derive(Debug, Default, Clone)]
pub struct SortEngine {
    strategy: SortStrategy,
    /// Permutation produced by the previous [`Self::order_by_keys`]
    /// call (or injected via [`Self::seed_prev`]); the adaptive seed.
    prev: Vec<u32>,
    /// Monotone u64 transform of the current keys, indexed by example.
    bits: Vec<u64>,
    /// Radix ping/pong key buffers, aligned with the order being built.
    key_a: Vec<u64>,
    key_b: Vec<u64>,
    /// Order pong buffer (radix) / merge target buffer (adaptive).
    ord_b: Vec<u32>,
    /// Stable-partition scratch of the negatives-first tie pass.
    ties: Vec<u32>,
    /// Run boundaries of the adaptive merge (ping/pong).
    runs: Vec<u32>,
    runs_next: Vec<u32>,
}

impl SortEngine {
    /// An engine with the given strategy and no previous permutation.
    pub fn new(strategy: SortStrategy) -> Self {
        Self {
            strategy,
            ..Self::default()
        }
    }

    /// The active strategy.
    pub fn strategy(&self) -> SortStrategy {
        self.strategy
    }

    /// Switch strategy in place.  Safe mid-stream: every strategy
    /// produces the identical permutation, and the previous-order seed
    /// is kept (a stale or wrong-length seed only costs speed, never
    /// correctness).
    pub fn set_strategy(&mut self, strategy: SortStrategy) {
        self.strategy = strategy;
    }

    /// Inject a previous permutation for the adaptive strategy (bench /
    /// test entry point; training paths seed implicitly from the prior
    /// step).  Must be a permutation of `0..order.len()` — validated in
    /// debug builds, a plain copy in release so benches can seed
    /// per-iteration without distorting the measurement.
    pub fn seed_prev(&mut self, order: &[u32]) {
        debug_assert!(is_permutation(order), "seed_prev: not a permutation");
        self.prev.clear();
        self.prev.extend_from_slice(order);
    }

    /// Fill `order` with the canonical permutation of `keys`: ascending
    /// by `total_cmp`, then (when `negatives_first_on_ties`) negatives
    /// — `is_pos[i] == 0.0` — before positives within an exact-key tie
    /// group, then index ascending.  The index tie-break makes the
    /// permutation unique, which is what lets every strategy match the
    /// reference bit-for-bit.
    pub fn order_by_keys(
        &mut self,
        keys: &[f64],
        is_pos: &[f32],
        negatives_first_on_ties: bool,
        order: &mut Vec<u32>,
    ) {
        let n = keys.len();
        assert_eq!(is_pos.len(), n, "keys/is_pos length mismatch");
        assert!(n <= u32::MAX as usize, "batch too large for u32 order indices");
        let Self {
            strategy,
            prev,
            bits,
            key_a,
            key_b,
            ord_b,
            ties,
            runs,
            runs_next,
        } = self;
        match *strategy {
            SortStrategy::Comparison => {
                fill_identity(order, n);
                comparison_sort(keys, is_pos, negatives_first_on_ties, order);
            }
            SortStrategy::Radix => {
                fill_bits(bits, keys);
                fill_identity(order, n);
                lsd_radix(bits, order, key_a, key_b, ord_b);
                if negatives_first_on_ties {
                    negatives_first_pass(bits, is_pos, order, ties);
                }
            }
            SortStrategy::Adaptive => {
                fill_bits(bits, keys);
                // Seed from the previous permutation when the length
                // matches (it is a permutation by construction);
                // identity otherwise.  The seed only affects speed: any
                // permutation input merges to the unique canonical one.
                if prev.len() == n {
                    order.clear();
                    order.extend_from_slice(prev);
                } else {
                    fill_identity(order, n);
                }
                // Maximal ascending runs under the canonical order.
                runs.clear();
                runs.push(0);
                for j in 1..n {
                    if lt(bits, is_pos, negatives_first_on_ties, order[j], order[j - 1]) {
                        runs.push(j as u32);
                    }
                }
                if runs.len() > 1 {
                    if runs.len() > MAX_MERGE_RUNS {
                        // Too disordered for the merge to beat radix.
                        fill_identity(order, n);
                        lsd_radix(bits, order, key_a, key_b, ord_b);
                        if negatives_first_on_ties {
                            negatives_first_pass(bits, is_pos, order, ties);
                        }
                    } else {
                        runs.push(n as u32);
                        merge_runs(
                            bits,
                            is_pos,
                            negatives_first_on_ties,
                            order,
                            ord_b,
                            runs,
                            runs_next,
                        );
                    }
                }
            }
        }
        // Persist for the next adaptive call on this engine.
        prev.clear();
        prev.extend_from_slice(order);
    }
}

fn is_permutation(order: &[u32]) -> bool {
    let n = order.len();
    let mut seen = vec![false; n];
    order
        .iter()
        .all(|&i| (i as usize) < n && !std::mem::replace(&mut seen[i as usize], true))
}

fn fill_identity(order: &mut Vec<u32>, n: usize) {
    order.clear();
    order.extend(0..n as u32);
}

fn fill_bits(bits: &mut Vec<u64>, keys: &[f64]) {
    bits.clear();
    bits.extend(keys.iter().map(|&k| key_bits(k)));
}

/// The canonical strict order as a `<` predicate over example indices:
/// key bits, then class (negatives first, when enabled), then index.
/// Strict and total, so the sorted permutation is unique.
#[inline]
fn lt(bits: &[u64], is_pos: &[f32], neg_first: bool, a: u32, b: u32) -> bool {
    let (ka, kb) = (bits[a as usize], bits[b as usize]);
    if ka != kb {
        return ka < kb;
    }
    if neg_first {
        let (pa, pb) = (is_pos[a as usize] != 0.0, is_pos[b as usize] != 0.0);
        if pa != pb {
            return !pa;
        }
    }
    a < b
}

/// Reference: comparison sort under the canonical composite order,
/// phrased over the raw f64 keys via `total_cmp` (the definition the
/// bit-transform strategies must match).
fn comparison_sort(keys: &[f64], is_pos: &[f32], neg_first: bool, order: &mut [u32]) {
    order.sort_unstable_by(|&a, &b| {
        let by_key = keys[a as usize].total_cmp(&keys[b as usize]);
        let by_class = if neg_first {
            by_key.then_with(|| {
                let pa = (is_pos[a as usize] != 0.0) as u8;
                let pb = (is_pos[b as usize] != 0.0) as u8;
                pa.cmp(&pb)
            })
        } else {
            by_key
        };
        by_class.then_with(|| a.cmp(&b))
    });
}

/// LSD radix sort of `order` by `bits[order[j]]`, 8 bits per pass.
/// All 8 histograms are gathered in one pass; a pass whose digit is
/// constant across the batch is skipped (a stable pass over a constant
/// digit is the identity).  Stability plus the identity start makes the
/// result ordered by (bits, index) — the canonical order minus the
/// class tie-break, which [`negatives_first_pass`] restores.
fn lsd_radix(
    bits: &[u64],
    order: &mut Vec<u32>,
    key_a: &mut Vec<u64>,
    key_b: &mut Vec<u64>,
    ord_b: &mut Vec<u32>,
) {
    let n = order.len();
    key_a.clear();
    key_a.extend(order.iter().map(|&i| bits[i as usize]));
    key_b.clear();
    key_b.resize(n, 0);
    ord_b.clear();
    ord_b.resize(n, 0);
    let mut hist = [[0u32; 256]; 8];
    for &k in key_a.iter() {
        for (level, h) in hist.iter_mut().enumerate() {
            h[((k >> (level * 8)) & 0xFF) as usize] += 1;
        }
    }
    for (level, h) in hist.iter().enumerate() {
        if h.iter().any(|&c| c as usize == n) {
            continue;
        }
        let mut offsets = [0u32; 256];
        let mut sum = 0u32;
        for (o, &c) in offsets.iter_mut().zip(h.iter()) {
            *o = sum;
            sum += c;
        }
        let shift = level * 8;
        for (&k, &o) in key_a.iter().zip(order.iter()) {
            let digit = ((k >> shift) & 0xFF) as usize;
            let pos = offsets[digit] as usize;
            offsets[digit] += 1;
            key_b[pos] = k;
            ord_b[pos] = o;
        }
        std::mem::swap(key_a, key_b);
        std::mem::swap(order, ord_b);
    }
}

/// Restore the negatives-first tie-break after a (bits, index) radix
/// sort: within each maximal equal-bits group, stable-partition
/// negatives before positives.  O(n) total; single-class groups (the
/// common case under quantized ties) are left untouched.
fn negatives_first_pass(bits: &[u64], is_pos: &[f32], order: &mut [u32], ties: &mut Vec<u32>) {
    let n = order.len();
    let mut i = 0;
    while i < n {
        let k = bits[order[i] as usize];
        let mut j = i + 1;
        while j < n && bits[order[j] as usize] == k {
            j += 1;
        }
        let group = &order[i..j];
        if group.len() > 1
            && group.iter().any(|&e| is_pos[e as usize] != 0.0)
            && group.iter().any(|&e| is_pos[e as usize] == 0.0)
        {
            ties.clear();
            ties.extend(group.iter().filter(|&&e| is_pos[e as usize] == 0.0));
            ties.extend(group.iter().filter(|&&e| is_pos[e as usize] != 0.0));
            order[i..j].copy_from_slice(ties);
        }
        i = j;
    }
}

/// Bottom-up natural merge of the ascending runs delimited by `runs`
/// (which must end with the sentinel `n`), under the canonical
/// composite order.  `ceil(log2 runs)` linear passes, ping-ponging
/// between `order` and `tmp`.
fn merge_runs(
    bits: &[u64],
    is_pos: &[f32],
    neg_first: bool,
    order: &mut Vec<u32>,
    tmp: &mut Vec<u32>,
    runs: &mut Vec<u32>,
    runs_next: &mut Vec<u32>,
) {
    let n = order.len();
    tmp.clear();
    tmp.resize(n, 0);
    while runs.len() > 2 {
        runs_next.clear();
        runs_next.push(0);
        let mut p = 0;
        while p + 2 < runs.len() {
            let (lo, mid, hi) = (runs[p] as usize, runs[p + 1] as usize, runs[p + 2] as usize);
            let (mut i, mut j) = (lo, mid);
            for slot in tmp[lo..hi].iter_mut() {
                let take_left =
                    j >= hi || (i < mid && !lt(bits, is_pos, neg_first, order[j], order[i]));
                *slot = if take_left {
                    let v = order[i];
                    i += 1;
                    v
                } else {
                    let v = order[j];
                    j += 1;
                    v
                };
            }
            runs_next.push(hi as u32);
            p += 2;
        }
        if p + 1 < runs.len() {
            // trailing lone run: carry it into the next round
            let (lo, hi) = (runs[p] as usize, runs[p + 1] as usize);
            tmp[lo..hi].copy_from_slice(&order[lo..hi]);
            runs_next.push(hi as u32);
        }
        std::mem::swap(order, tmp);
        std::mem::swap(runs, runs_next);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adversarial f64 values: signed zeros, subnormals, infinities,
    /// NaNs of both signs and different payloads, powers of two around
    /// the f32 precision cliff, and ordinary values.
    fn adversarial_keys() -> Vec<f64> {
        let mut ks = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            f64::MIN_POSITIVE,
            -f64::MIN_POSITIVE,
            f64::from_bits(1),             // smallest positive subnormal
            f64::from_bits(SIGN_BIT | 1),  // smallest-magnitude negative subnormal
            f64::from_bits(0x000F_FFFF_FFFF_FFFF), // largest subnormal
            f32::MIN_POSITIVE as f64,
            -(f32::MIN_POSITIVE as f64),
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MAX,
            f64::MIN,
            f64::NAN,
            -f64::NAN,
            f64::from_bits(0x7FF8_0000_0000_0001), // NaN, different payload
            16_777_216.0, // 2^24: the f32-key regression family
            16_777_217.0,
            16_777_218.0,
            16_777_215.0,
            1e-300,
            -1e-300,
            0.1,
            -0.1,
        ];
        // plus every value nudged one ulp in each direction
        for k in ks.clone() {
            if k.is_finite() {
                ks.push(f64::from_bits(k.to_bits().wrapping_add(1)));
                ks.push(f64::from_bits(k.to_bits().wrapping_sub(1)));
            }
        }
        ks
    }

    #[test]
    fn key_bits_agrees_with_total_cmp_on_adversarial_pairs() {
        let ks = adversarial_keys();
        for &a in &ks {
            for &b in &ks {
                assert_eq!(
                    key_bits(a).cmp(&key_bits(b)),
                    a.total_cmp(&b),
                    "a={a:?} ({:#018x})  b={b:?} ({:#018x})",
                    a.to_bits(),
                    b.to_bits()
                );
            }
        }
    }

    #[test]
    fn key_bits_orders_negative_zero_before_positive_zero() {
        assert!(key_bits(-0.0) < key_bits(0.0));
        assert_eq!((-0.0_f64).total_cmp(&0.0), std::cmp::Ordering::Less);
    }

    fn canonical(keys: &[f64], is_pos: &[f32], neg_first: bool) -> Vec<u32> {
        let mut order = Vec::new();
        let mut engine = SortEngine::new(SortStrategy::Comparison);
        engine.order_by_keys(keys, is_pos, neg_first, &mut order);
        order
    }

    #[test]
    fn all_strategies_agree_on_adversarial_keys() {
        let keys = adversarial_keys();
        let is_pos: Vec<f32> = (0..keys.len()).map(|i| (i % 3 == 0) as u32 as f32).collect();
        for neg_first in [false, true] {
            let want = canonical(&keys, &is_pos, neg_first);
            for strategy in [SortStrategy::Radix, SortStrategy::Adaptive] {
                let mut engine = SortEngine::new(strategy);
                let mut order = Vec::new();
                engine.order_by_keys(&keys, &is_pos, neg_first, &mut order);
                assert_eq!(order, want, "{strategy} neg_first={neg_first}");
                // warm second call (adaptive now seeds from its own output)
                engine.order_by_keys(&keys, &is_pos, neg_first, &mut order);
                assert_eq!(order, want, "{strategy} warm neg_first={neg_first}");
            }
        }
    }

    #[test]
    fn comparison_result_is_sorted_under_lt() {
        let keys = adversarial_keys();
        let is_pos: Vec<f32> = (0..keys.len()).map(|i| (i % 2) as f32).collect();
        let mut bits = Vec::new();
        fill_bits(&mut bits, &keys);
        for neg_first in [false, true] {
            let order = canonical(&keys, &is_pos, neg_first);
            for w in order.windows(2) {
                assert!(lt(&bits, &is_pos, neg_first, w[0], w[1]));
            }
        }
    }

    #[test]
    fn adaptive_is_exact_from_any_seed() {
        let keys: Vec<f64> = (0..1000).map(|i| ((i * 37) % 100) as f64 * 0.25).collect();
        let is_pos: Vec<f32> = (0..1000).map(|i| (i % 4 == 0) as u32 as f32).collect();
        let want = canonical(&keys, &is_pos, true);
        let n = keys.len() as u32;
        let reversed: Vec<u32> = (0..n).rev().collect();
        let rotated: Vec<u32> = (0..n).map(|i| (i + 917) % n).collect();
        let sorted = want.clone();
        for seed in [reversed, rotated, sorted] {
            let mut engine = SortEngine::new(SortStrategy::Adaptive);
            engine.seed_prev(&seed);
            let mut order = Vec::new();
            engine.order_by_keys(&keys, &is_pos, true, &mut order);
            assert_eq!(order, want);
        }
        // wrong-length seed: falls back to the identity start, still exact
        let mut engine = SortEngine::new(SortStrategy::Adaptive);
        engine.seed_prev(&[0, 1, 2]);
        let mut order = Vec::new();
        engine.order_by_keys(&keys, &is_pos, true, &mut order);
        assert_eq!(order, want);
    }

    #[test]
    fn radix_skips_constant_byte_passes_correctly() {
        // keys differing only in the low mantissa byte: 7 of 8 passes
        // are constant and skipped
        let keys: Vec<f64> = (0..200)
            .map(|i| f64::from_bits(0x3FF0_0000_0000_0000 | ((199 - i) as u64 & 0xFF)))
            .collect();
        let is_pos = vec![0.0f32; 200];
        let want = canonical(&keys, &is_pos, false);
        let mut engine = SortEngine::new(SortStrategy::Radix);
        let mut order = Vec::new();
        engine.order_by_keys(&keys, &is_pos, false, &mut order);
        assert_eq!(order, want);
        // and the keys really are descending, so the permutation reverses
        assert_eq!(order, (0..200u32).rev().collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_singleton_batches() {
        for strategy in SortStrategy::ALL {
            let mut engine = SortEngine::new(strategy);
            let mut order = vec![9, 9, 9];
            engine.order_by_keys(&[], &[], true, &mut order);
            assert!(order.is_empty(), "{strategy}");
            engine.order_by_keys(&[4.2], &[1.0], true, &mut order);
            assert_eq!(order, vec![0], "{strategy}");
        }
    }

    #[test]
    fn strategy_round_trips_through_strings() {
        for strategy in SortStrategy::ALL {
            assert_eq!(strategy.name().parse::<SortStrategy>().unwrap(), strategy);
        }
        assert!("quantum".parse::<SortStrategy>().is_err());
        assert_eq!(SortStrategy::default(), SortStrategy::Adaptive);
    }

    #[test]
    fn set_strategy_mid_stream_keeps_the_permutation() {
        let keys: Vec<f64> = (0..500).map(|i| ((i * 7919) % 233) as f64).collect();
        let is_pos: Vec<f32> = (0..500).map(|i| (i % 5 == 0) as u32 as f32).collect();
        let want = canonical(&keys, &is_pos, true);
        let mut engine = SortEngine::new(SortStrategy::Radix);
        let mut order = Vec::new();
        engine.order_by_keys(&keys, &is_pos, true, &mut order);
        assert_eq!(order, want);
        engine.set_strategy(SortStrategy::Adaptive);
        assert_eq!(engine.strategy(), SortStrategy::Adaptive);
        engine.order_by_keys(&keys, &is_pos, true, &mut order);
        assert_eq!(order, want);
    }
}
