//! Extension (paper §5 future work): the all-pairs *linear* hinge loss
//! `ℓ(z) = (m − z)₊` in O(n log n) via the same functional representation.
//!
//! The paper's conclusion proposes investigating "how our functional
//! representation could be used when computing the linear hinge loss,
//! which has non-differentiable points, so we could make use of
//! sub-differential analysis".  The representation carries over directly:
//! for the linear hinge, a degree-1 polynomial suffices —
//!
//! ```text
//! L⁺(x) = Σ_{j: ŷⱼ − x < m} (m − ŷⱼ + x) = A(x)·x + C(x)
//!   A(x) = #{active j}        C(x) = Σ_{active j} (m − ŷⱼ)
//! ```
//!
//! so the ascending sweep carries **two** coefficients instead of three.
//! The subgradient is piecewise constant:
//!
//! ```text
//! ∂L/∂ŷₖ ∋  #{j: ŷⱼ < vₖ}          (count of active positives)
//! ∂L/∂ŷⱼ ∋ −#{k: vₖ > ŷⱼ}          (count of active negatives)
//! ```
//!
//! where we take the one-sided choice that pairs *exactly at* the margin
//! (ŷⱼ − ŷₖ = m) contribute zero — the minimal-norm element at those
//! non-differentiable points, consistent with the squared-hinge limit.
//! Ties in the sort are then benign exactly as in Algorithm 2 (a pair at
//! equality adds 0 to the loss and 0 to the chosen subgradient).

use super::kernel::{fill_hinge_order, pair_norm, BatchView, LossFn, LossWorkspace};
use super::PairwiseLoss;

/// O(n log n) all-pairs linear hinge loss with subgradient.
#[derive(Debug, Clone, Copy)]
pub struct LinearHinge {
    margin: f32,
}

impl LinearHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }
}

/// O(n²) reference for the linear hinge (tests + Figure 2 extension).
#[derive(Debug, Clone, Copy)]
pub struct NaiveLinearHinge {
    margin: f32,
}

impl NaiveLinearHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }
}

impl PairwiseLoss for NaiveLinearHinge {
    fn name(&self) -> &'static str {
        "naive_linear_hinge"
    }

    fn complexity(&self) -> &'static str {
        "O(n^2)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        assert_eq!(scores.len(), is_pos.len());
        let m = self.margin as f64;
        let mut loss = 0.0_f64;
        let mut grad = vec![0.0_f64; scores.len()];
        for (j, (&yj, &pj)) in scores.iter().zip(is_pos).enumerate() {
            if pj == 0.0 {
                continue;
            }
            for (k, (&yk, &pk)) in scores.iter().zip(is_pos).enumerate() {
                if pk != 0.0 {
                    continue;
                }
                let d = m - yj as f64 + yk as f64;
                if d > 0.0 {
                    loss += d;
                    grad[j] -= 1.0;
                    grad[k] += 1.0;
                }
            }
        }
        // lint:allow(float-narrowing-in-kernel): pairs accumulated in f64; final grad store is f32
        (loss, grad.into_iter().map(|g| g as f32).collect())
    }
}

impl LossFn for LinearHinge {
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let n = batch.len();
        let m = self.margin as f64;
        ws.grad.clear();
        ws.grad.resize(n, 0.0);
        if n == 0 {
            return 0.0;
        }
        // Augmented sort keys, as in Algorithm 2 (paper eq. 20), on
        // exact f64 keys.  The strictness choice (pairs exactly at the
        // margin are inactive) requires breaking ties so that an
        // equal-key *negative* precedes an equal-key *positive*: the
        // negative's evaluation then excludes that positive.  For the
        // loss this is immaterial (the term is 0); for the subgradient
        // it selects the minimal-norm element.
        fill_hinge_order(batch, m, &mut ws.keys, &mut ws.order, &mut ws.sort, true);

        // Ascending sweep: degree-1 coefficients over active positives.
        let (mut a_cnt, mut c_sum) = (0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &ws.order {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            if batch.is_pos[i] != 0.0 {
                a_cnt += 1.0;
                c_sum += m - y;
            } else {
                loss += a_cnt * y + c_sum;
                // lint:allow(float-narrowing-in-kernel): pair counts are exact in f32 up to 2^24
                ws.grad[i] = a_cnt as f32; // subgradient: count of active positives
            }
        }
        // Descending sweep: counts of active negatives for positives.
        let mut n_cnt = 0.0_f64;
        for &i in ws.order.iter().rev() {
            let i = i as usize;
            if batch.is_pos[i] != 0.0 {
                // lint:allow(float-narrowing-in-kernel): pair counts are exact in f32 up to 2^24
                ws.grad[i] = -(n_cnt as f32);
            } else {
                n_cnt += 1.0;
            }
        }
        loss
    }

    fn loss_only(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let m = self.margin as f64;
        if batch.is_empty() {
            return 0.0;
        }
        fill_hinge_order(batch, m, &mut ws.keys, &mut ws.order, &mut ws.sort, true);
        let (mut a_cnt, mut c_sum) = (0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &ws.order {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            if batch.is_pos[i] != 0.0 {
                a_cnt += 1.0;
                c_sum += m - y;
            } else {
                loss += a_cnt * y + c_sum;
            }
        }
        loss
    }

    fn norm(&self, batch: BatchView<'_>) -> f64 {
        pair_norm(batch)
    }
}

impl PairwiseLoss for LinearHinge {
    fn name(&self) -> &'static str {
        "functional_linear_hinge"
    }

    fn complexity(&self) -> &'static str {
        "O(n log n)"
    }

    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        LossFn::loss_only(self, BatchView::new(scores, is_pos), &mut LossWorkspace::default())
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut ws = LossWorkspace::default();
        let loss = LossFn::loss_and_grad(self, BatchView::new(scores, is_pos), &mut ws);
        (loss, std::mem::take(&mut ws.grad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_case(seed: u64, n: usize, pos_frac: f64) -> (Vec<f32>, Vec<f32>) {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let s: Vec<f32> = (0..n).map(|_| (next() * 6.0 - 3.0) as f32).collect();
        let p: Vec<f32> = (0..n)
            .map(|_| if next() < pos_frac { 1.0 } else { 0.0 })
            .collect();
        (s, p)
    }

    #[test]
    fn matches_naive_loss_exactly() {
        for seed in 0..25 {
            let (s, p) = random_case(seed, 80, 0.3);
            let (ln, _) = NaiveLinearHinge::new(1.0).loss_and_grad(&s, &p);
            let (lf, _) = PairwiseLoss::loss_and_grad(&LinearHinge::new(1.0), &s, &p);
            let scale = ln.abs().max(1.0);
            assert!((ln - lf).abs() < 1e-9 * scale, "{ln} vs {lf}");
        }
    }

    #[test]
    fn matches_naive_subgradient_off_kinks() {
        // Away from the non-differentiable points the subgradient is the
        // gradient; random continuous scores hit kinks with prob. 0.
        for seed in 0..25 {
            let (s, p) = random_case(seed + 100, 60, 0.4);
            let (_, gn) = NaiveLinearHinge::new(1.0).loss_and_grad(&s, &p);
            let (_, gf) = PairwiseLoss::loss_and_grad(&LinearHinge::new(1.0), &s, &p);
            assert_eq!(gn, gf);
        }
    }

    #[test]
    fn margin_boundary_pairs_are_inactive() {
        // pos at exactly neg + m: loss 0, subgradient 0 (minimal norm).
        let s = vec![1.0, 0.0];
        let p = vec![1.0, 0.0];
        let (l, g) = PairwiseLoss::loss_and_grad(&LinearHinge::new(1.0), &s, &p);
        assert_eq!(l, 0.0);
        assert_eq!(g, vec![0.0, 0.0]);
    }

    #[test]
    fn single_violating_pair_hand_computed() {
        // pos 0.2, neg 0.5, m=1: d = 1 - 0.2 + 0.5 = 1.3; grad ±1.
        let s = vec![0.2, 0.5];
        let p = vec![1.0, 0.0];
        let (l, g) = PairwiseLoss::loss_and_grad(&LinearHinge::new(1.0), &s, &p);
        assert!((l - 1.3).abs() < 1e-6);
        assert_eq!(g, vec![-1.0, 1.0]);
    }

    #[test]
    fn subgradient_counts_are_integers() {
        let (s, p) = random_case(7, 200, 0.2);
        let (_, g) = PairwiseLoss::loss_and_grad(&LinearHinge::new(1.0), &s, &p);
        for v in g {
            assert_eq!(v, v.round());
        }
    }

    #[test]
    fn tie_heavy_inputs_match_naive_loss() {
        let (mut s, p) = random_case(13, 150, 0.35);
        for y in &mut s {
            *y = (*y * 2.0).round() / 2.0;
        }
        let (ln, _) = NaiveLinearHinge::new(0.5).loss_and_grad(&s, &p);
        let (lf, _) = PairwiseLoss::loss_and_grad(&LinearHinge::new(0.5), &s, &p);
        assert!((ln - lf).abs() < 1e-9 * ln.abs().max(1.0));
    }

    #[test]
    fn loss_only_matches_full() {
        let (s, p) = random_case(19, 120, 0.3);
        let lh = LinearHinge::new(1.0);
        let (full, _) = PairwiseLoss::loss_and_grad(&lh, &s, &p);
        let only = PairwiseLoss::loss(&lh, &s, &p);
        assert!((full - only).abs() < 1e-12 * full.abs().max(1.0));
    }
}
