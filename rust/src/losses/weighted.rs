//! Extension (related work: Airola et al. 2011): all-pairs squared hinge
//! loss with **real-valued example weights** in O(n log n).
//!
//! Airola et al. train ranking SVMs in linearithmic time with utility
//! scores; the functional representation generalizes the same way.  Give
//! every example a weight `wᵢ ≥ 0` and define
//!
//! ```text
//! L = Σ_{j∈I⁺} Σ_{k∈I⁻} wⱼ wₖ (m − ŷⱼ + ŷₖ)₊²
//! ```
//!
//! The Algorithm-2 sweep carries *weighted* coefficients —
//! `a = Σ wⱼ`, `b = Σ wⱼ·2(m−ŷⱼ)`, `c = Σ wⱼ(m−ŷⱼ)²`, `t = Σ wⱼŷⱼ` —
//! and every negative evaluation is scaled by `wₖ`.  Setting all weights
//! to 1 recovers the unweighted loss exactly (tested).  This is also the
//! building block for cost-sensitive / class-balanced reweighting
//! (Cui et al. 2019) on top of the pairwise objective.

/// Weighted all-pairs squared hinge loss, O(n log n).
#[derive(Debug, Clone, Copy)]
pub struct WeightedSquaredHinge {
    margin: f32,
}

impl WeightedSquaredHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// Loss + gradient w.r.t. scores.  `weights[i] >= 0`; an example with
    /// weight 0 is ignored entirely.
    pub fn loss_and_grad(
        &self,
        scores: &[f32],
        is_pos: &[f32],
        weights: &[f32],
    ) -> (f64, Vec<f32>) {
        assert_eq!(scores.len(), is_pos.len());
        assert_eq!(scores.len(), weights.len());
        let n = scores.len();
        let m = self.margin as f64;
        let mut grad = vec![0.0_f32; n];
        if n == 0 {
            return (0.0, grad);
        }
        // f64 keys so key order matches the f64 sweep exactly (see
        // `functional::HingeScratch` for the rounding failure mode).
        let mut order: Vec<u32> = (0..n as u32).collect();
        let keys: Vec<f64> = scores
            .iter()
            .zip(is_pos)
            .map(|(&y, &p)| if p != 0.0 { y as f64 } else { y as f64 + m })
            .collect();
        order.sort_unstable_by(|&a, &b| keys[a as usize].total_cmp(&keys[b as usize]));

        // Ascending sweep with weighted coefficients.
        let (mut a, mut b, mut c, mut t) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &order {
            let i = i as usize;
            let y = scores[i] as f64;
            let w = weights[i] as f64;
            if is_pos[i] != 0.0 {
                let z = m - y;
                a += w;
                b += w * 2.0 * z;
                c += w * z * z;
                t += w * y;
            } else {
                loss += w * (a * y * y + b * y + c);
                grad[i] = (w * 2.0 * (a * (m + y) - t)) as f32;
            }
        }
        // Descending sweep: weighted negative mass for positive gradients.
        let (mut n_w, mut t_w) = (0.0_f64, 0.0_f64);
        for &i in order.iter().rev() {
            let i = i as usize;
            let y = scores[i] as f64;
            let w = weights[i] as f64;
            if is_pos[i] != 0.0 {
                grad[i] = (-w * 2.0 * (n_w * (m - y) + t_w)) as f32;
            } else {
                n_w += w;
                t_w += w * y;
            }
        }
        (loss, grad)
    }

    /// O(n²) reference (tests only).
    pub fn loss_naive(&self, scores: &[f32], is_pos: &[f32], weights: &[f32]) -> f64 {
        let m = self.margin as f64;
        let mut loss = 0.0_f64;
        for (j, (&yj, &pj)) in scores.iter().zip(is_pos).enumerate() {
            if pj == 0.0 {
                continue;
            }
            for (k, (&yk, &pk)) in scores.iter().zip(is_pos).enumerate() {
                if pk != 0.0 {
                    continue;
                }
                let d = (m - yj as f64 + yk as f64).max(0.0);
                loss += weights[j] as f64 * weights[k] as f64 * d * d;
            }
        }
        loss
    }
}

/// Class-balanced weights (inverse class frequency, Cui et al. 2019
/// flavor): every example of a class gets `n / (2 * n_class)`.
pub fn class_balanced_weights(is_pos: &[f32]) -> Vec<f32> {
    let n = is_pos.len() as f64;
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = n - n_pos;
    is_pos
        .iter()
        .map(|&p| {
            if p != 0.0 {
                (n / (2.0 * n_pos.max(1.0))) as f32
            } else {
                (n / (2.0 * n_neg.max(1.0))) as f32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::functional::SquaredHinge;
    use crate::losses::PairwiseLoss;

    fn random_case(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let s: Vec<f32> = (0..n).map(|_| (next() * 4.0 - 2.0) as f32).collect();
        let p: Vec<f32> = (0..n)
            .map(|_| if next() < 0.3 { 1.0 } else { 0.0 })
            .collect();
        let w: Vec<f32> = (0..n).map(|_| (next() * 2.0) as f32).collect();
        (s, p, w)
    }

    #[test]
    fn unit_weights_recover_unweighted() {
        for seed in 0..10 {
            let (s, p, _) = random_case(seed, 120);
            let ones = vec![1.0; s.len()];
            let (lw, gw) = WeightedSquaredHinge::new(1.0).loss_and_grad(&s, &p, &ones);
            let (lu, gu) = SquaredHinge::new(1.0).loss_and_grad(&s, &p);
            assert!((lw - lu).abs() < 1e-9 * lu.abs().max(1.0));
            for (a, b) in gw.iter().zip(&gu) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matches_naive_weighted() {
        for seed in 0..15 {
            let (s, p, w) = random_case(seed + 50, 90);
            let wh = WeightedSquaredHinge::new(1.0);
            let (lf, _) = wh.loss_and_grad(&s, &p, &w);
            let ln = wh.loss_naive(&s, &p, &w);
            assert!((lf - ln).abs() < 1e-8 * ln.abs().max(1.0), "{lf} vs {ln}");
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (s, p, w) = random_case(3, 40);
        let wh = WeightedSquaredHinge::new(1.0);
        let (_, g) = wh.loss_and_grad(&s, &p, &w);
        let eps = 1e-3_f32;
        for i in (0..s.len()).step_by(7) {
            let mut sp = s.clone();
            sp[i] += eps;
            let mut sm = s.clone();
            sm[i] -= eps;
            let fd = (wh.loss_naive(&sp, &p, &w) - wh.loss_naive(&sm, &p, &w))
                / (2.0 * eps as f64);
            assert!(
                (fd - g[i] as f64).abs() < 1e-2 * fd.abs().max(1.0),
                "i={i}: {fd} vs {}",
                g[i]
            );
        }
    }

    #[test]
    fn zero_weight_examples_are_ignored() {
        let (s, p, _) = random_case(9, 60);
        let mut w = vec![1.0; 60];
        // zero out some examples; must equal dropping them
        for i in (0..60).step_by(3) {
            w[i] = 0.0;
        }
        let wh = WeightedSquaredHinge::new(1.0);
        let (lw, gw) = wh.loss_and_grad(&s, &p, &w);
        let keep: Vec<usize> = (0..60).filter(|i| i % 3 != 0).collect();
        let s2: Vec<f32> = keep.iter().map(|&i| s[i]).collect();
        let p2: Vec<f32> = keep.iter().map(|&i| p[i]).collect();
        let (lu, gu) = SquaredHinge::new(1.0).loss_and_grad(&s2, &p2);
        assert!((lw - lu).abs() < 1e-9 * lu.abs().max(1.0));
        for (slot, &i) in keep.iter().enumerate() {
            assert!((gw[i] - gu[slot]).abs() < 1e-4);
        }
        for i in (0..60).step_by(3) {
            assert_eq!(gw[i], 0.0);
        }
    }

    #[test]
    fn class_balanced_weights_sum_to_n() {
        let p = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        let w = class_balanced_weights(&p);
        let total: f32 = w.iter().sum();
        assert!((total - 8.0).abs() < 1e-5);
        assert!(w[0] > w[1]); // minority class upweighted
    }
}
