//! Extension (related work: Airola et al. 2011): all-pairs squared hinge
//! loss with **real-valued example weights** in O(n log n).
//!
//! Airola et al. train ranking SVMs in linearithmic time with utility
//! scores; the functional representation generalizes the same way.  Give
//! every example a weight `wᵢ ≥ 0` and define
//!
//! ```text
//! L = Σ_{j∈I⁺} Σ_{k∈I⁻} wⱼ wₖ (m − ŷⱼ + ŷₖ)₊²
//! ```
//!
//! The Algorithm-2 sweep carries *weighted* coefficients —
//! `a = Σ wⱼ`, `b = Σ wⱼ·2(m−ŷⱼ)`, `c = Σ wⱼ(m−ŷⱼ)²`, `t = Σ wⱼŷⱼ` —
//! and every negative evaluation is scaled by `wₖ`.  Setting all weights
//! to 1 recovers the unweighted loss exactly (tested).
//!
//! As a [`LossFn`] (spec string `"whinge"`) this is the **class-balanced**
//! scenario (Cui et al. 2019 flavor): when the [`BatchView`] carries no
//! explicit weights, every example of a class gets `n / (2·n_class)` —
//! derived per batch into the workspace, allocation-free — so the
//! minority class contributes half the total pair mass regardless of the
//! imbalance ratio.  The normalizer is the weighted pair mass
//! `(Σ_pos w)(Σ_neg w)`, which reduces to the plain pair count at unit
//! weights.  This makes cost-sensitive reweighting trainable end to end
//! (`--loss whinge`) rather than a standalone kernel.

use super::kernel::{fill_hinge_order, BatchView, LossFn, LossWorkspace};

/// Weighted all-pairs squared hinge loss, O(n log n).
#[derive(Debug, Clone, Copy)]
pub struct WeightedSquaredHinge {
    margin: f32,
}

impl WeightedSquaredHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// Loss + gradient w.r.t. scores with explicit weights
    /// (`weights[i] >= 0`; a weight-0 example is ignored entirely).
    /// Allocating convenience form of the [`LossFn`] entry point.
    pub fn loss_and_grad(
        &self,
        scores: &[f32],
        is_pos: &[f32],
        weights: &[f32],
    ) -> (f64, Vec<f32>) {
        let mut ws = LossWorkspace::default();
        let loss =
            LossFn::loss_and_grad(self, BatchView::weighted(scores, is_pos, weights), &mut ws);
        (loss, std::mem::take(&mut ws.grad))
    }

    /// O(n²) loss reference (tests only).
    pub fn loss_naive(&self, scores: &[f32], is_pos: &[f32], weights: &[f32]) -> f64 {
        self.loss_and_grad_naive(scores, is_pos, weights).0
    }

    /// O(n²) loss *and gradient* reference (tests only): the double sum
    /// taken literally, differentiated pair by pair.
    pub fn loss_and_grad_naive(
        &self,
        scores: &[f32],
        is_pos: &[f32],
        weights: &[f32],
    ) -> (f64, Vec<f32>) {
        let m = self.margin as f64;
        let mut loss = 0.0_f64;
        let mut grad = vec![0.0_f64; scores.len()];
        for (j, (&yj, &pj)) in scores.iter().zip(is_pos).enumerate() {
            if pj == 0.0 {
                continue;
            }
            for (k, (&yk, &pk)) in scores.iter().zip(is_pos).enumerate() {
                if pk != 0.0 {
                    continue;
                }
                let d = (m - yj as f64 + yk as f64).max(0.0);
                let w = weights[j] as f64 * weights[k] as f64;
                loss += w * d * d;
                grad[j] -= w * 2.0 * d;
                grad[k] += w * 2.0 * d;
            }
        }
        // lint:allow(float-narrowing-in-kernel): pairs accumulated in f64; final grad store is f32
        (loss, grad.into_iter().map(|g| g as f32).collect())
    }
}

impl LossFn for WeightedSquaredHinge {
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let n = batch.len();
        let m = self.margin as f64;
        let LossWorkspace {
            grad,
            order,
            keys,
            weights: derived,
            sort,
        } = ws;
        grad.clear();
        grad.resize(n, 0.0);
        if n == 0 {
            return 0.0;
        }
        let weights: &[f32] = match batch.weights {
            Some(w) => w,
            None => {
                fill_class_balanced(batch.is_pos, derived);
                &derived[..]
            }
        };
        fill_hinge_order(batch, m, keys, order, sort, false);

        // Ascending sweep with weighted coefficients.
        let (mut a, mut b, mut c, mut t) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in order.iter() {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            let w = weights[i] as f64;
            if batch.is_pos[i] != 0.0 {
                let z = m - y;
                a += w;
                b += w * 2.0 * z;
                c += w * z * z;
                t += w * y;
            } else {
                loss += w * (a * y * y + b * y + c);
                // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; grad store is f32
                grad[i] = (w * 2.0 * (a * (m + y) - t)) as f32;
            }
        }
        // Descending sweep: weighted negative mass for positive gradients.
        let (mut n_w, mut t_w) = (0.0_f64, 0.0_f64);
        for &i in order.iter().rev() {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            let w = weights[i] as f64;
            if batch.is_pos[i] != 0.0 {
                // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; grad store is f32
                grad[i] = (-w * 2.0 * (n_w * (m - y) + t_w)) as f32;
            } else {
                n_w += w;
                t_w += w * y;
            }
        }
        loss
    }

    fn loss_only(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let m = self.margin as f64;
        if batch.is_empty() {
            return 0.0;
        }
        let LossWorkspace {
            order,
            keys,
            weights: derived,
            sort,
            ..
        } = ws;
        let weights: &[f32] = match batch.weights {
            Some(w) => w,
            None => {
                fill_class_balanced(batch.is_pos, derived);
                &derived[..]
            }
        };
        fill_hinge_order(batch, m, keys, order, sort, false);
        let (mut a, mut b, mut c) = (0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in order.iter() {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            let w = weights[i] as f64;
            if batch.is_pos[i] != 0.0 {
                let z = m - y;
                a += w;
                b += w * 2.0 * z;
                c += w * z * z;
            } else {
                loss += w * (a * y * y + b * y + c);
            }
        }
        loss
    }

    /// Weighted pair mass `(Σ_pos w)(Σ_neg w)`, floored at 1.  At unit
    /// weights this is the plain pair count; with the derived
    /// class-balanced weights it is `(n/2)²` whenever both classes are
    /// present.
    fn norm(&self, batch: BatchView<'_>) -> f64 {
        let (pos_mass, neg_mass) = match batch.weights {
            Some(w) => {
                let (mut pos, mut neg) = (0.0_f64, 0.0_f64);
                for (&wi, &p) in w.iter().zip(batch.is_pos) {
                    if p != 0.0 {
                        pos += wi as f64;
                    } else {
                        neg += wi as f64;
                    }
                }
                (pos, neg)
            }
            None => {
                // Closed form of the class-balanced masses: each class
                // present contributes exactly n/2.
                let n = batch.len() as f64;
                let n_pos = batch.is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
                let n_neg = n - n_pos;
                (
                    if n_pos > 0.0 { n / 2.0 } else { 0.0 },
                    if n_neg > 0.0 { n / 2.0 } else { 0.0 },
                )
            }
        };
        (pos_mass * neg_mass).max(1.0)
    }
}

/// Fill `out` with class-balanced weights (inverse class frequency,
/// Cui et al. 2019 flavor): every example of a class gets
/// `n / (2 * n_class)`.  Allocation-free when `out` has capacity.
pub fn fill_class_balanced(is_pos: &[f32], out: &mut Vec<f32>) {
    let n = is_pos.len() as f64;
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = n - n_pos;
    // lint:allow(float-narrowing-in-kernel): class weights are f32 model inputs, derived in f64
    let w_pos = (n / (2.0 * n_pos.max(1.0))) as f32;
    // lint:allow(float-narrowing-in-kernel): class weights are f32 model inputs, derived in f64
    let w_neg = (n / (2.0 * n_neg.max(1.0))) as f32;
    out.clear();
    out.extend(is_pos.iter().map(|&p| if p != 0.0 { w_pos } else { w_neg }));
}

/// Class-balanced weights as a fresh vector (see [`fill_class_balanced`]).
pub fn class_balanced_weights(is_pos: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    fill_class_balanced(is_pos, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::functional::SquaredHinge;
    use crate::losses::PairwiseLoss;

    fn random_case(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let s: Vec<f32> = (0..n).map(|_| (next() * 4.0 - 2.0) as f32).collect();
        let p: Vec<f32> = (0..n)
            .map(|_| if next() < 0.3 { 1.0 } else { 0.0 })
            .collect();
        let w: Vec<f32> = (0..n).map(|_| (next() * 2.0) as f32).collect();
        (s, p, w)
    }

    #[test]
    fn unit_weights_recover_unweighted() {
        for seed in 0..10 {
            let (s, p, _) = random_case(seed, 120);
            let ones = vec![1.0; s.len()];
            let (lw, gw) = WeightedSquaredHinge::new(1.0).loss_and_grad(&s, &p, &ones);
            let (lu, gu) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(1.0), &s, &p);
            assert!((lw - lu).abs() < 1e-9 * lu.abs().max(1.0));
            for (a, b) in gw.iter().zip(&gu) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matches_naive_weighted() {
        for seed in 0..15 {
            let (s, p, w) = random_case(seed + 50, 90);
            let wh = WeightedSquaredHinge::new(1.0);
            let (lf, _) = wh.loss_and_grad(&s, &p, &w);
            let ln = wh.loss_naive(&s, &p, &w);
            assert!((lf - ln).abs() < 1e-8 * ln.abs().max(1.0), "{lf} vs {ln}");
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (s, p, w) = random_case(3, 40);
        let wh = WeightedSquaredHinge::new(1.0);
        let (_, g) = wh.loss_and_grad(&s, &p, &w);
        let eps = 1e-3_f32;
        for i in (0..s.len()).step_by(7) {
            let mut sp = s.clone();
            sp[i] += eps;
            let mut sm = s.clone();
            sm[i] -= eps;
            let fd = (wh.loss_naive(&sp, &p, &w) - wh.loss_naive(&sm, &p, &w))
                / (2.0 * eps as f64);
            assert!(
                (fd - g[i] as f64).abs() < 1e-2 * fd.abs().max(1.0),
                "i={i}: {fd} vs {}",
                g[i]
            );
        }
    }

    #[test]
    fn zero_weight_examples_are_ignored() {
        let (s, p, _) = random_case(9, 60);
        let mut w = vec![1.0; 60];
        // zero out some examples; must equal dropping them
        for i in (0..60).step_by(3) {
            w[i] = 0.0;
        }
        let wh = WeightedSquaredHinge::new(1.0);
        let (lw, gw) = wh.loss_and_grad(&s, &p, &w);
        let keep: Vec<usize> = (0..60).filter(|i| i % 3 != 0).collect();
        let s2: Vec<f32> = keep.iter().map(|&i| s[i]).collect();
        let p2: Vec<f32> = keep.iter().map(|&i| p[i]).collect();
        let (lu, gu) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(1.0), &s2, &p2);
        assert!((lw - lu).abs() < 1e-9 * lu.abs().max(1.0));
        for (slot, &i) in keep.iter().enumerate() {
            assert!((gw[i] - gu[slot]).abs() < 1e-4);
        }
        for i in (0..60).step_by(3) {
            assert_eq!(gw[i], 0.0);
        }
    }

    #[test]
    fn class_balanced_weights_sum_to_n() {
        let p = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        let w = class_balanced_weights(&p);
        let total: f32 = w.iter().sum();
        assert!((total - 8.0).abs() < 1e-5);
        assert!(w[0] > w[1]); // minority class upweighted
    }

    #[test]
    fn derived_weights_equal_explicit_class_balanced() {
        // The `whinge` scenario: a weight-free BatchView must behave
        // exactly as if class-balanced weights were passed explicitly.
        let (s, p, _) = random_case(21, 150);
        let wh = WeightedSquaredHinge::new(1.0);
        let w = class_balanced_weights(&p);
        let mut ws = LossWorkspace::default();
        let implicit = LossFn::loss_and_grad(&wh, BatchView::new(&s, &p), &mut ws);
        let g_implicit = ws.grad.clone();
        let (explicit, g_explicit) = wh.loss_and_grad(&s, &p, &w);
        assert_eq!(implicit, explicit);
        assert_eq!(g_implicit, g_explicit);
        // and the normalizers agree to rounding
        let n_implicit = LossFn::norm(&wh, BatchView::new(&s, &p));
        let n_explicit = LossFn::norm(&wh, BatchView::weighted(&s, &p, &w));
        assert!((n_implicit - n_explicit).abs() < 1e-6 * n_implicit);
    }

    #[test]
    fn loss_only_matches_full_weighted() {
        let (s, p, w) = random_case(33, 200);
        let wh = WeightedSquaredHinge::new(1.0);
        let mut ws = LossWorkspace::default();
        let full = LossFn::loss_and_grad(&wh, BatchView::weighted(&s, &p, &w), &mut ws);
        let only = LossFn::loss_only(&wh, BatchView::weighted(&s, &p, &w), &mut ws);
        assert_eq!(full, only);
    }

    #[test]
    fn norm_is_weighted_pair_mass() {
        let s = [0.0_f32; 4];
        let p = [1.0_f32, 0.0, 0.0, 0.0];
        let w = [2.0_f32, 1.0, 1.0, 1.0];
        let wh = WeightedSquaredHinge::new(1.0);
        assert_eq!(LossFn::norm(&wh, BatchView::weighted(&s, &p, &w)), 6.0);
        // derived class-balanced masses: (4/2) * (4/2)
        assert_eq!(LossFn::norm(&wh, BatchView::new(&s, &p)), 4.0);
        // single-class batches floor at 1
        let all_neg = [0.0_f32; 4];
        assert_eq!(LossFn::norm(&wh, BatchView::new(&s, &all_neg)), 1.0);
    }
}
