//! The allocation-free loss kernel layer: [`LossFn`], [`BatchView`] and
//! [`LossWorkspace`].
//!
//! Every native training loss implements [`LossFn`] — one entry point,
//! one workspace — so the backend, trainer, L-BFGS oracle, sweep and
//! bench layers all call losses the same way.  This replaces the four
//! historical call shapes (`loss_and_grad` allocating a `Vec<f32>` per
//! step, `loss_and_grad_into`, `loss_and_grad_with` + `HingeScratch`,
//! and the weighted 4-argument form): the workspace owns the gradient
//! buffer *and* the sort scratch, so the training hot loop performs no
//! per-batch allocation after warm-up regardless of the loss.
//!
//! Loss *identity* lives one level up in [`super::spec::LossSpec`],
//! which maps a validated spec onto a boxed [`LossFn`]; nothing above
//! the losses module matches on loss-name strings.

use super::sort::{SortEngine, SortStrategy};

/// One batch of predictions as the loss kernels see it: predicted
/// scores, {0,1} positive-class indicators, and optional per-example
/// weights.
///
/// `is_pos[i] == 1.0` marks example *i* positive; `0.0` negative.
/// `weights` is consumed only by the weighted losses
/// ([`super::weighted::WeightedSquaredHinge`]); when `None`, a weighted
/// loss derives class-balanced weights internally and the unweighted
/// losses are unaffected.
#[derive(Debug, Clone, Copy)]
pub struct BatchView<'a> {
    /// Predicted scores, one per example.
    pub scores: &'a [f32],
    /// {0,1} positive-class indicators, same length as `scores`.
    pub is_pos: &'a [f32],
    /// Optional per-example weights (`>= 0`), same length as `scores`.
    pub weights: Option<&'a [f32]>,
}

impl<'a> BatchView<'a> {
    /// An unweighted batch view.  Panics on length mismatch.
    pub fn new(scores: &'a [f32], is_pos: &'a [f32]) -> Self {
        assert_eq!(scores.len(), is_pos.len(), "scores/is_pos length mismatch");
        Self {
            scores,
            is_pos,
            weights: None,
        }
    }

    /// A weighted batch view.  Panics on length mismatch.
    pub fn weighted(scores: &'a [f32], is_pos: &'a [f32], weights: &'a [f32]) -> Self {
        assert_eq!(scores.len(), is_pos.len(), "scores/is_pos length mismatch");
        assert_eq!(scores.len(), weights.len(), "scores/weights length mismatch");
        Self {
            scores,
            is_pos,
            weights: Some(weights),
        }
    }

    /// Number of examples in the batch.
    pub fn len(&self) -> usize {
        self.scores.len()
    }

    /// Whether the batch is empty.
    pub fn is_empty(&self) -> bool {
        self.scores.is_empty()
    }
}

/// Reusable buffers for [`LossFn`] calls: the per-score gradient output
/// plus the sort scratch of the hinge-family sweeps.  Reusing one
/// workspace across calls keeps the training hot loop allocation-free
/// after warm-up; a fresh `LossWorkspace::default()` is always valid.
#[derive(Debug, Default, Clone)]
pub struct LossWorkspace {
    /// Gradient w.r.t. every score, written by
    /// [`LossFn::loss_and_grad`] (cleared and resized to the batch
    /// length each call).  Contents are unspecified after
    /// [`LossFn::loss_only`].
    pub grad: Vec<f32>,
    /// Sort permutation of the hinge-family sweeps.
    pub(crate) order: Vec<u32>,
    /// f64 sort keys of the hinge-family sweeps (see
    /// [`fill_hinge_order`] for why they must be f64).
    pub(crate) keys: Vec<f64>,
    /// Derived per-example weights (class-balanced reweighting).
    pub(crate) weights: Vec<f32>,
    /// Sort engine of the hinge-family sweeps (DESIGN.md §9): holds the
    /// strategy, its scratch, and the previous step's permutation (the
    /// adaptive seed) — which is why hot loops should reuse one
    /// workspace instead of rebuilding it per step.
    pub(crate) sort: SortEngine,
}

impl LossWorkspace {
    /// An empty workspace (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty workspace whose hinge sorts use the given strategy.
    /// Every strategy produces the identical permutation (and therefore
    /// bit-identical losses and gradients); the choice is purely about
    /// speed — see DESIGN.md §9.
    pub fn with_sort_strategy(strategy: SortStrategy) -> Self {
        Self {
            sort: SortEngine::new(strategy),
            ..Self::default()
        }
    }

    /// The active hinge-sort strategy.
    pub fn sort_strategy(&self) -> SortStrategy {
        self.sort.strategy()
    }

    /// Switch the hinge-sort strategy in place (safe mid-training: the
    /// permutation, and hence every result bit, is strategy-invariant).
    pub fn set_sort_strategy(&mut self, strategy: SortStrategy) {
        self.sort.set_strategy(strategy);
    }

    /// Direct access to the sort engine (bench / test seam).
    pub fn sort_engine_mut(&mut self) -> &mut SortEngine {
        &mut self.sort
    }
}

/// A training loss over a [`BatchView`]: the single seam between loss
/// kernels and everything that calls them (native executor, L-BFGS
/// oracle, `Backend::eval_loss`, benches).
///
/// All entry points are allocation-free after workspace warm-up, and
/// return the **unnormalized** loss — callers divide by [`LossFn::norm`]
/// (pair count for pairwise losses, example count for pointwise ones),
/// matching the L2 loss wrappers so learning rates transfer between
/// backends.
pub trait LossFn: Send + Sync {
    /// Loss value; gradient w.r.t. every score written into `ws.grad`
    /// (cleared and resized to `batch.len()`).
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64;

    /// Loss value only — implementations override this with their
    /// cheaper gradient-free path (e.g. the single ascending sweep of
    /// the squared hinge); `ws.grad` is left unspecified.
    fn loss_only(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        self.loss_and_grad(batch, ws)
    }

    /// Normalizer for this loss on this batch, floored at 1: the
    /// (pos, neg) pair count for pairwise losses, the example count for
    /// pointwise ones, the weighted pair mass for weighted losses.
    fn norm(&self, batch: BatchView<'_>) -> f64;
}

/// Pair-count normalizer shared by the unweighted pairwise losses.
pub(crate) fn pair_norm(batch: BatchView<'_>) -> f64 {
    let n_pos = batch.is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = batch.is_pos.len() as f64 - n_pos;
    (n_pos * n_neg).max(1.0)
}

/// Fill `keys`/`order` with the augmented-value sort of the hinge-family
/// sweeps: `vᵢ = ŷᵢ + m·I[yᵢ = −1]` (paper eq. 20), ascending.
///
/// Keys are f64: the sweeps accumulate in f64, so the sort order must be
/// decided by the *exact* augmented values.  Building the key as an f32
/// sum rounds it (at |ŷ| = 2²⁴ the f32 ulp is 2.0, so `ŷₖ + 1` collapses
/// onto `ŷₖ`), and a near-margin pair whose rounded key flips or ties
/// out of order is silently dropped from (or added to) the loss and
/// gradient.  f32 → f64 conversion and the f64 sum of two f32-valued
/// operands are exact, so the f64 key order always matches the f64
/// sweep (regression tests: `losses::functional`).
///
/// With `negatives_first_on_ties`, equal-key ties are broken so that a
/// negative precedes a positive — required by the linear hinge's
/// minimal-norm subgradient choice at exact-margin pairs.  The squared
/// hinges pass `false`: their exact-tie pairs contribute zero loss and
/// zero gradient in any order.
///
/// The actual ordering is delegated to [`SortEngine::order_by_keys`],
/// which pins the canonical permutation (key ascending under
/// `total_cmp` — so a -0.0 score sorts before +0.0 — then the class
/// tie-break, then index ascending) and produces it with whichever
/// strategy the workspace carries.  The trailing index tie-break makes
/// the permutation unique, so the f64 sweep accumulation order — and
/// therefore every loss/gradient bit — is independent of the strategy.
pub(crate) fn fill_hinge_order(
    batch: BatchView<'_>,
    margin: f64,
    keys: &mut Vec<f64>,
    order: &mut Vec<u32>,
    sort: &mut SortEngine,
    negatives_first_on_ties: bool,
) {
    keys.clear();
    keys.extend(batch.scores.iter().zip(batch.is_pos).map(|(&y, &p)| {
        if p != 0.0 {
            y as f64
        } else {
            y as f64 + margin
        }
    }));
    sort.order_by_keys(keys, batch.is_pos, negatives_first_on_ties, order);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_view_lengths_checked() {
        let s = [0.1_f32, 0.2];
        let p = [1.0_f32, 0.0];
        let v = BatchView::new(&s, &p);
        assert_eq!(v.len(), 2);
        assert!(!v.is_empty());
        assert!(v.weights.is_none());
        let w = [1.0_f32, 2.0];
        assert!(BatchView::weighted(&s, &p, &w).weights.is_some());
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn batch_view_rejects_mismatch() {
        let _ = BatchView::new(&[0.0], &[1.0, 0.0]);
    }

    #[test]
    fn pair_norm_floors_at_one() {
        let s = [0.0_f32; 3];
        assert_eq!(pair_norm(BatchView::new(&s, &[1.0, 1.0, 1.0])), 1.0);
        assert_eq!(pair_norm(BatchView::new(&s, &[1.0, 0.0, 0.0])), 2.0);
        assert_eq!(pair_norm(BatchView::new(&[], &[])), 1.0);
    }

    fn hinge_order(
        s: &[f32],
        p: &[f32],
        margin: f64,
        strategy: SortStrategy,
        neg_first: bool,
    ) -> (Vec<f64>, Vec<u32>) {
        let mut keys = Vec::new();
        let mut order = Vec::new();
        let mut sort = SortEngine::new(strategy);
        fill_hinge_order(
            BatchView::new(s, p),
            margin,
            &mut keys,
            &mut order,
            &mut sort,
            neg_first,
        );
        (keys, order)
    }

    #[test]
    fn hinge_order_sorts_augmented_values() {
        // pos 0.5 (key 0.5), neg 0.0 (key 1.0), neg -2.0 (key -1.0)
        let s = [0.5_f32, 0.0, -2.0];
        let p = [1.0_f32, 0.0, 0.0];
        for strategy in SortStrategy::ALL {
            let (keys, order) = hinge_order(&s, &p, 1.0, strategy, false);
            assert_eq!(order, vec![2, 0, 1], "{strategy}");
            assert_eq!(keys, vec![0.5, 1.0, -1.0], "{strategy}");
        }
    }

    #[test]
    fn tie_break_puts_negatives_first() {
        // pos 1.0 (key 1.0) ties with neg 0.0 (key 1.0) at margin 1
        let s = [1.0_f32, 0.0];
        let p = [1.0_f32, 0.0];
        for strategy in SortStrategy::ALL {
            let (_, order) = hinge_order(&s, &p, 1.0, strategy, true);
            assert_eq!(order, vec![1, 0], "negative first within ties: {strategy}");
        }
    }

    #[test]
    fn equal_key_ties_fall_back_to_index_order() {
        // three identical positives and two identical negatives at the
        // same augmented value: the canonical order within each class is
        // ascending index, for every strategy — the uniqueness property
        // that makes strategies interchangeable bit-for-bit.
        let s = [1.0_f32, 0.0, 1.0, 0.0, 1.0];
        let p = [1.0_f32, 0.0, 1.0, 0.0, 1.0];
        for strategy in SortStrategy::ALL {
            let (_, order) = hinge_order(&s, &p, 1.0, strategy, true);
            assert_eq!(order, vec![1, 3, 0, 2, 4], "{strategy}");
            let (_, order) = hinge_order(&s, &p, 1.0, strategy, false);
            assert_eq!(order, vec![0, 1, 2, 3, 4], "{strategy}");
        }
    }

    #[test]
    fn negative_zero_scores_sort_before_positive_zero_in_every_strategy() {
        // Pinned ±0.0 semantics: `total_cmp` orders -0.0 before +0.0,
        // and the radix u64 transform agrees bit-for-bit, so a score of
        // -0.0 can never reorder pairs between strategies.
        assert_eq!((-0.0_f64).total_cmp(&0.0), std::cmp::Ordering::Less);
        assert!(super::super::sort::key_bits(-0.0) < super::super::sort::key_bits(0.0));
        // two positives scoring +0.0 and -0.0 (keys are the raw scores)
        let s = [0.0_f32, -0.0];
        let p = [1.0_f32, 1.0];
        for strategy in SortStrategy::ALL {
            let (keys, order) = hinge_order(&s, &p, 1.0, strategy, false);
            assert_eq!(order, vec![1, 0], "-0.0 key sorts first: {strategy}");
            assert_eq!(keys[1].to_bits(), (-0.0_f64).to_bits(), "{strategy}");
        }
        // margin 0: the neg's key is -0.0 + 0.0 = +0.0 (IEEE addition
        // normalizes the zero sign), an exact tie with the pos at +0.0
        // — resolved by the class tie-break, identically everywhere
        let s = [0.0_f32, -0.0];
        let p = [1.0_f32, 0.0];
        for strategy in SortStrategy::ALL {
            let (keys, order) = hinge_order(&s, &p, 0.0, strategy, true);
            assert_eq!(keys[1].to_bits(), 0.0_f64.to_bits(), "{strategy}");
            assert_eq!(order, vec![1, 0], "negative first on the tie: {strategy}");
        }
    }

    #[test]
    fn workspace_sort_strategy_accessors() {
        let mut ws = LossWorkspace::with_sort_strategy(SortStrategy::Radix);
        assert_eq!(ws.sort_strategy(), SortStrategy::Radix);
        ws.set_sort_strategy(SortStrategy::Comparison);
        assert_eq!(ws.sort_strategy(), SortStrategy::Comparison);
        assert_eq!(
            LossWorkspace::default().sort_strategy(),
            SortStrategy::Adaptive,
            "hot paths default to the adaptive strategy"
        );
        ws.sort_engine_mut().seed_prev(&[0]);
    }
}
