//! Naive O(n²) all-pairs losses: the paper's equation (2), literally.
//!
//! For every positive example *j* and negative example *k* the pair
//! contributes `ℓ(ŷⱼ − ŷₖ)` with `ℓ(z) = (m − z)²` (square) or
//! `(m − z)²₊` (squared hinge).  Gradients are accumulated pair by pair:
//!
//! ```text
//! ∂L/∂ŷⱼ += −2 (m − ŷⱼ + ŷₖ)[₊]      ∂L/∂ŷₖ += 2 (m − ŷⱼ + ŷₖ)[₊]
//! ```
//!
//! This is the "Naive" baseline of Figure 2: correct, simple, quadratic.
//! Accumulation is in f64 so that the property tests comparing against the
//! functional algorithms are not dominated by summation error at n ≥ 10⁴.

use super::PairwiseLoss;

/// O(n²) all-pairs squared hinge loss.
#[derive(Debug, Clone, Copy)]
pub struct NaiveSquaredHinge {
    margin: f32,
}

impl NaiveSquaredHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }
}

impl PairwiseLoss for NaiveSquaredHinge {
    fn name(&self) -> &'static str {
        "naive_squared_hinge"
    }

    fn complexity(&self) -> &'static str {
        "O(n^2)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        assert_eq!(scores.len(), is_pos.len());
        let m = self.margin as f64;
        let mut loss = 0.0_f64;
        let mut grad = vec![0.0_f64; scores.len()];
        for (j, (&yj, &pj)) in scores.iter().zip(is_pos).enumerate() {
            if pj == 0.0 {
                continue;
            }
            for (k, (&yk, &pk)) in scores.iter().zip(is_pos).enumerate() {
                if pk != 0.0 {
                    continue;
                }
                let d = m - yj as f64 + yk as f64;
                if d > 0.0 {
                    loss += d * d;
                    grad[j] -= 2.0 * d;
                    grad[k] += 2.0 * d;
                }
            }
        }
        // lint:allow(float-narrowing-in-kernel): pairs accumulated in f64; final grad store is f32
        (loss, grad.into_iter().map(|g| g as f32).collect())
    }
}

/// O(n²) all-pairs square loss (no hinge).
#[derive(Debug, Clone, Copy)]
pub struct NaiveSquare {
    margin: f32,
}

impl NaiveSquare {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }
}

impl PairwiseLoss for NaiveSquare {
    fn name(&self) -> &'static str {
        "naive_square"
    }

    fn complexity(&self) -> &'static str {
        "O(n^2)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        assert_eq!(scores.len(), is_pos.len());
        let m = self.margin as f64;
        let mut loss = 0.0_f64;
        let mut grad = vec![0.0_f64; scores.len()];
        for (j, (&yj, &pj)) in scores.iter().zip(is_pos).enumerate() {
            if pj == 0.0 {
                continue;
            }
            for (k, (&yk, &pk)) in scores.iter().zip(is_pos).enumerate() {
                if pk != 0.0 {
                    continue;
                }
                let d = m - yj as f64 + yk as f64;
                loss += d * d;
                grad[j] -= 2.0 * d;
                grad[k] += 2.0 * d;
            }
        }
        // lint:allow(float-narrowing-in-kernel): pairs accumulated in f64; final grad store is f32
        (loss, grad.into_iter().map(|g| g as f32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_pair_hand_computed() {
        // pos at 0.3, neg at 0.8, m = 1: d = 1 - 0.3 + 0.8 = 1.5
        let scores = vec![0.3, 0.8];
        let is_pos = vec![1.0, 0.0];
        let (l, g) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert!((l - 2.25).abs() < 1e-6);
        assert!((g[0] + 3.0).abs() < 1e-6);
        assert!((g[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn hinge_clamps_inactive_pairs() {
        // pos well above neg by more than the margin: zero loss, zero grad.
        let scores = vec![3.0, -3.0];
        let is_pos = vec![1.0, 0.0];
        let (l, g) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert_eq!(l, 0.0);
        assert_eq!(g, vec![0.0, 0.0]);
        // ...but the square loss still counts it.
        let (l, _) = NaiveSquare::new(1.0).loss_and_grad(&scores, &is_pos);
        assert!((l - 25.0).abs() < 1e-6); // (1 - 3 - 3)^2
    }

    #[test]
    fn all_one_class_is_zero() {
        let scores = vec![0.1, 0.2, 0.3];
        for is_pos in [vec![1.0, 1.0, 1.0], vec![0.0, 0.0, 0.0]] {
            let (l, g) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
            assert_eq!(l, 0.0);
            assert!(g.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn loss_counts_pairs() {
        // 2 pos, 3 neg, all scores equal 0, m=1: every pair contributes 1.
        let scores = vec![0.0; 5];
        let is_pos = vec![1.0, 1.0, 0.0, 0.0, 0.0];
        let (l, _) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert!((l - 6.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "margin must be non-negative")]
    fn negative_margin_rejected() {
        NaiveSquaredHinge::new(-1.0);
    }
}
