//! Linear-time logistic loss baseline (sums over examples, not pairs).
//!
//! The paper's Figure 2 includes the logistic loss as the O(n) reference
//! slope: the functional algorithms should track it up to the `log n`
//! sort factor.  We use the numerically-stable logits formulation
//! `log(1 + exp(-y f))` with `y ∈ {−1, +1}` on raw scores.

use super::PairwiseLoss;

/// Per-example logistic loss on raw (unbounded) scores.
#[derive(Debug, Clone, Copy)]
pub struct Logistic;

/// `log(1 + exp(-z))` computed without overflow for any `z`.
#[inline]
pub fn log1p_exp_neg(z: f64) -> f64 {
    if z > 0.0 {
        (-z).exp().ln_1p()
    } else {
        -z + z.exp().ln_1p()
    }
}

impl Logistic {
    /// Loss + gradient written into `grad` (cleared and refilled) — the
    /// allocation-free hot path.
    pub fn loss_and_grad_into(&self, scores: &[f32], is_pos: &[f32], grad: &mut Vec<f32>) -> f64 {
        assert_eq!(scores.len(), is_pos.len());
        let mut loss = 0.0_f64;
        grad.clear();
        grad.extend(scores.iter().zip(is_pos).map(|(&s, &p)| {
            let y = if p != 0.0 { 1.0 } else { -1.0 };
            let z = y * s as f64;
            loss += log1p_exp_neg(z);
            // d/ds log(1+exp(-ys)) = -y sigmoid(-ys)
            let sig = 1.0 / (1.0 + z.exp());
            (-y * sig) as f32
        }));
        loss
    }
}

impl PairwiseLoss for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn complexity(&self) -> &'static str {
        "O(n)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut grad = Vec::new();
        let loss = self.loss_and_grad_into(scores, is_pos, &mut grad);
        (loss, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_scores_give_log2() {
        let s = vec![0.0; 10];
        let p = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let (l, g) = Logistic.loss_and_grad(&s, &p);
        assert!((l - 10.0 * (2.0_f64).ln()).abs() < 1e-9);
        for (gi, pi) in g.iter().zip(&p) {
            let expect = if *pi != 0.0 { -0.5 } else { 0.5 };
            assert!((gi - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn stable_for_extreme_scores() {
        let s = vec![1e4, -1e4];
        let p = vec![1.0, 0.0];
        let (l, g) = Logistic.loss_and_grad(&s, &p);
        assert!(l.is_finite() && l < 1e-6);
        assert!(g.iter().all(|x| x.is_finite()));
        // Misclassified extremes: loss ~ |z|, grad saturates at ±1.
        let (l, g) = Logistic.loss_and_grad(&s, &[0.0, 1.0]);
        assert!(l.is_finite() && (l - 2e4).abs() / 2e4 < 1e-6);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let s = vec![0.3_f32, -0.7, 1.2];
        let p = vec![1.0, 0.0, 0.0];
        let (_, g) = Logistic.loss_and_grad(&s, &p);
        let eps = 1e-3_f32;
        for i in 0..s.len() {
            let mut sp = s.clone();
            sp[i] += eps;
            let mut sm = s.clone();
            sm[i] -= eps;
            let fd = (Logistic.loss_and_grad(&sp, &p).0 - Logistic.loss_and_grad(&sm, &p).0)
                / (2.0 * eps as f64);
            assert!((fd - g[i] as f64).abs() < 1e-3, "i={i}: {fd} vs {}", g[i]);
        }
    }
}
