//! Linear-time logistic loss baseline (sums over examples, not pairs).
//!
//! The paper's Figure 2 includes the logistic loss as the O(n) reference
//! slope: the functional algorithms should track it up to the `log n`
//! sort factor.  We use the numerically-stable logits formulation
//! `log(1 + exp(-y f))` with `y ∈ {−1, +1}` on raw scores.

use super::kernel::{BatchView, LossFn, LossWorkspace};
use super::PairwiseLoss;

/// Per-example logistic loss on raw (unbounded) scores.
#[derive(Debug, Clone, Copy)]
pub struct Logistic;

/// `log(1 + exp(-z))` computed without overflow for any `z`.
#[inline]
pub fn log1p_exp_neg(z: f64) -> f64 {
    if z > 0.0 {
        (-z).exp().ln_1p()
    } else {
        -z + z.exp().ln_1p()
    }
}

impl LossFn for Logistic {
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let mut loss = 0.0_f64;
        ws.grad.clear();
        ws.grad
            .extend(batch.scores.iter().zip(batch.is_pos).map(|(&s, &p)| {
                let y = if p != 0.0 { 1.0 } else { -1.0 };
                let z = y * s as f64;
                loss += log1p_exp_neg(z);
                // d/ds log(1+exp(-ys)) = -y sigmoid(-ys)
                let sig = 1.0 / (1.0 + z.exp());
                // lint:allow(float-narrowing-in-kernel): f64 math ends here; grad is f32
                (-y * sig) as f32
            }));
        loss
    }

    fn loss_only(&self, batch: BatchView<'_>, _ws: &mut LossWorkspace) -> f64 {
        batch
            .scores
            .iter()
            .zip(batch.is_pos)
            .map(|(&s, &p)| {
                let y = if p != 0.0 { 1.0 } else { -1.0 };
                log1p_exp_neg(y * s as f64)
            })
            .sum()
    }

    /// Pointwise loss: normalized per example, not per pair.
    fn norm(&self, batch: BatchView<'_>) -> f64 {
        (batch.len() as f64).max(1.0)
    }
}

impl PairwiseLoss for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn complexity(&self) -> &'static str {
        "O(n)"
    }

    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        LossFn::loss_only(self, BatchView::new(scores, is_pos), &mut LossWorkspace::default())
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut ws = LossWorkspace::default();
        let loss = LossFn::loss_and_grad(self, BatchView::new(scores, is_pos), &mut ws);
        (loss, std::mem::take(&mut ws.grad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_scores_give_log2() {
        let s = vec![0.0; 10];
        let p = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        let (l, g) = PairwiseLoss::loss_and_grad(&Logistic, &s, &p);
        assert!((l - 10.0 * (2.0_f64).ln()).abs() < 1e-9);
        for (gi, pi) in g.iter().zip(&p) {
            let expect = if *pi != 0.0 { -0.5 } else { 0.5 };
            assert!((gi - expect).abs() < 1e-6);
        }
        // the gradient-free path agrees
        assert!((PairwiseLoss::loss(&Logistic, &s, &p) - l).abs() < 1e-12);
    }

    #[test]
    fn stable_for_extreme_scores() {
        let s = vec![1e4, -1e4];
        let p = vec![1.0, 0.0];
        let (l, g) = PairwiseLoss::loss_and_grad(&Logistic, &s, &p);
        assert!(l.is_finite() && l < 1e-6);
        assert!(g.iter().all(|x| x.is_finite()));
        // Misclassified extremes: loss ~ |z|, grad saturates at ±1.
        let (l, g) = PairwiseLoss::loss_and_grad(&Logistic, &s, &[0.0, 1.0]);
        assert!(l.is_finite() && (l - 2e4).abs() / 2e4 < 1e-6);
        assert!((g[0] - 1.0).abs() < 1e-6 && (g[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let s = vec![0.3_f32, -0.7, 1.2];
        let p = vec![1.0, 0.0, 0.0];
        let (_, g) = PairwiseLoss::loss_and_grad(&Logistic, &s, &p);
        let eps = 1e-3_f32;
        for i in 0..s.len() {
            let mut sp = s.clone();
            sp[i] += eps;
            let mut sm = s.clone();
            sm[i] -= eps;
            let fd = (PairwiseLoss::loss_and_grad(&Logistic, &sp, &p).0
                - PairwiseLoss::loss_and_grad(&Logistic, &sm, &p).0)
                / (2.0 * eps as f64);
            assert!((fd - g[i] as f64).abs() < 1e-3, "i={i}: {fd} vs {}", g[i]);
        }
    }
}
