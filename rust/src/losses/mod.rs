//! Native Rust implementations of the paper's loss algorithms, behind
//! the typed loss API.
//!
//! Two seams, layered:
//!
//! * [`spec::LossSpec`] — the typed loss *identity*: what crosses every
//!   API boundary (CLI, configs, Job JSON, `Backend::open`).  Parsed
//!   and validated once, at the edge (`"hinge"`, `"hinge@margin=2"`,
//!   ...); see the spec-grammar docs in [`spec`].
//! * [`kernel::LossFn`] — the allocation-free loss *kernel*: one entry
//!   point (`loss_and_grad(BatchView, &mut LossWorkspace)`) plus a
//!   gradient-free `loss_only` path, implemented by every native loss
//!   and consumed by the backend, trainer, L-BFGS oracle and benches.
//!
//! The loss families, all computing the same mathematical objects:
//!
//! * [`naive`] — the O(n²) brute-force double sum over all (positive,
//!   negative) pairs, the paper's equation (2) taken literally.  This is
//!   the "Naive" baseline of Figure 2 and the ground truth for property
//!   tests.
//! * [`functional`] — the paper's contribution: Algorithm 1 (all-pairs
//!   square loss, O(n)) and Algorithm 2 (all-pairs squared hinge loss,
//!   O(n log n)) plus the closed-form gradients derived in DESIGN.md §3.
//! * [`linear_hinge`] — the §5 linear-hinge extension with subgradients.
//! * [`weighted`] — the weighted squared hinge (class-balanced
//!   reweighting, spec `"whinge"`).
//! * [`logistic`] — the linear-time per-example logistic loss, the
//!   paper's "Logistic" timing baseline.
//!
//! The [`PairwiseLoss`] trait unifies them for the Figure 2 harness.

pub mod functional;
pub mod kernel;
pub mod linear_hinge;
pub mod logistic;
pub mod naive;
pub mod sort;
pub mod spec;
pub mod weighted;

pub use kernel::{BatchView, LossFn, LossWorkspace};
pub use sort::{SortEngine, SortStrategy};
pub use spec::LossSpec;

/// A loss over predicted scores with {0,1} positive-class indicators —
/// the *allocating* comparison interface of the Figure 2 timing harness
/// (training paths use [`LossFn`] instead).
///
/// `is_pos[i] == 1.0` marks example *i* positive; `0.0` marks it negative.
/// (The Rust layer never needs the padding convention of the AOT kernels —
/// batches here are always exact.)
pub trait PairwiseLoss {
    /// Human-readable name used in reports and benches.
    fn name(&self) -> &'static str;

    /// Loss value only.  The default computes (and discards) a full
    /// gradient; every functional implementation overrides it with its
    /// gradient-free [`LossFn::loss_only`] path.
    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        self.loss_and_grad(scores, is_pos).0
    }

    /// Loss value and gradient w.r.t. every score.
    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>);

    /// Asymptotic complexity label (for report tables), e.g. `"O(n log n)"`.
    fn complexity(&self) -> &'static str;
}

/// All loss implementations compared in the Figure 2 timing study.
pub fn figure2_losses(margin: f32) -> Vec<Box<dyn PairwiseLoss + Send + Sync>> {
    vec![
        Box::new(naive::NaiveSquaredHinge::new(margin)),
        Box::new(naive::NaiveSquare::new(margin)),
        Box::new(functional::SquaredHinge::new(margin)),
        Box::new(functional::Square::new(margin)),
        Box::new(logistic::Logistic),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_set_is_complete() {
        let names: Vec<_> = figure2_losses(1.0).iter().map(|l| l.name()).collect();
        assert_eq!(
            names,
            vec![
                "naive_squared_hinge",
                "naive_square",
                "functional_squared_hinge",
                "functional_square",
                "logistic",
            ]
        );
    }

    #[test]
    fn default_loss_matches_loss_and_grad() {
        let l = functional::SquaredHinge::new(1.0);
        let s = vec![0.3, -0.2, 0.8, 0.1];
        let p = vec![1.0, 0.0, 1.0, 0.0];
        let (v, _) = PairwiseLoss::loss_and_grad(&l, &s, &p);
        assert!((PairwiseLoss::loss(&l, &s, &p) - v).abs() < 1e-12);
    }

    #[test]
    fn every_spec_kernel_agrees_with_pairwise_trait() {
        // The LossFn seam and the Figure-2 trait compute the same values
        // for every spec that has both.
        let s = vec![0.9_f32, -0.3, 0.4, 0.1, -0.8];
        let p = vec![1.0_f32, 0.0, 1.0, 0.0, 0.0];
        for (spec, reference) in [
            (
                LossSpec::hinge(),
                PairwiseLoss::loss_and_grad(&functional::SquaredHinge::new(1.0), &s, &p),
            ),
            (
                LossSpec::square(),
                PairwiseLoss::loss_and_grad(&functional::Square::new(1.0), &s, &p),
            ),
            (
                LossSpec::logistic(),
                PairwiseLoss::loss_and_grad(&logistic::Logistic, &s, &p),
            ),
            (
                LossSpec::linear_hinge(),
                PairwiseLoss::loss_and_grad(&linear_hinge::LinearHinge::new(1.0), &s, &p),
            ),
        ] {
            let kernel = spec.build().unwrap();
            let mut ws = LossWorkspace::default();
            let view = BatchView::new(&s, &p);
            let loss = kernel.loss_and_grad(view, &mut ws);
            assert_eq!(loss, reference.0, "{spec}");
            assert_eq!(ws.grad, reference.1, "{spec}");
            assert_eq!(kernel.loss_only(view, &mut ws), loss, "{spec}");
        }
    }
}
