//! Native Rust implementations of the paper's loss algorithms.
//!
//! Three families, all computing the same mathematical objects:
//!
//! * [`naive`] — the O(n²) brute-force double sum over all (positive,
//!   negative) pairs, the paper's equation (2) taken literally.  This is
//!   the "Naive" baseline of Figure 2 and the ground truth for property
//!   tests.
//! * [`functional`] — the paper's contribution: Algorithm 1 (all-pairs
//!   square loss, O(n)) and Algorithm 2 (all-pairs squared hinge loss,
//!   O(n log n)) plus the closed-form gradients derived in DESIGN.md §3.
//! * [`logistic`] — the linear-time per-example logistic loss, the
//!   paper's "Logistic" timing baseline.
//!
//! The [`PairwiseLoss`] trait unifies them for the Figure 2 harness; every
//! implementation returns both the loss value and the full gradient
//! vector, because that is what one gradient-descent step needs.

pub mod functional;
pub mod linear_hinge;
pub mod logistic;
pub mod naive;
pub mod weighted;

/// A loss over predicted scores with {0,1} positive-class indicators.
///
/// `is_pos[i] == 1.0` marks example *i* positive; `0.0` marks it negative.
/// (The Rust layer never needs the padding convention of the AOT kernels —
/// batches here are always exact.)
pub trait PairwiseLoss {
    /// Human-readable name used in reports and benches.
    fn name(&self) -> &'static str;

    /// Loss value only.
    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        self.loss_and_grad(scores, is_pos).0
    }

    /// Loss value and gradient w.r.t. every score.
    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>);

    /// Asymptotic complexity label (for report tables), e.g. `"O(n log n)"`.
    fn complexity(&self) -> &'static str;
}

/// All loss implementations compared in the Figure 2 timing study.
pub fn figure2_losses(margin: f32) -> Vec<Box<dyn PairwiseLoss + Send + Sync>> {
    vec![
        Box::new(naive::NaiveSquaredHinge::new(margin)),
        Box::new(naive::NaiveSquare::new(margin)),
        Box::new(functional::SquaredHinge::new(margin)),
        Box::new(functional::Square::new(margin)),
        Box::new(logistic::Logistic),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_set_is_complete() {
        let names: Vec<_> = figure2_losses(1.0).iter().map(|l| l.name()).collect();
        assert_eq!(
            names,
            vec![
                "naive_squared_hinge",
                "naive_square",
                "functional_squared_hinge",
                "functional_square",
                "logistic",
            ]
        );
    }

    #[test]
    fn default_loss_matches_loss_and_grad() {
        let l = functional::SquaredHinge::new(1.0);
        let s = vec![0.3, -0.2, 0.8, 0.1];
        let p = vec![1.0, 0.0, 1.0, 0.0];
        let (v, _) = l.loss_and_grad(&s, &p);
        assert!((l.loss(&s, &p) - v).abs() < 1e-12);
    }
}
