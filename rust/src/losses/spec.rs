//! Typed loss specification: the [`LossSpec`] enum and its spec-string
//! grammar.
//!
//! A `LossSpec` is the *only* form in which a loss crosses an API
//! boundary — CLI flags, sweep configs, Job JSON, `Backend::open`,
//! `Backend::eval_loss` — replacing the stringly-typed `"hinge"`-style
//! dispatch that used to be re-matched (and re-validated) inside every
//! layer.  Strings exist only at the edges, via the [`FromStr`] /
//! [`fmt::Display`] round-trip:
//!
//! ```text
//! spec   := name | name "@margin=" FLOAT
//! name   := "hinge" | "square" | "logistic" | "lhinge" | "whinge" | "aucm"
//! ```
//!
//! `"hinge"` parses to the default margin (1.0); `"hinge@margin=2"`
//! carries an explicit one — which makes the per-loss margin a sweepable
//! axis (`"losses": ["hinge", "hinge@margin=2"]` in a sweep config).
//! `logistic` and `aucm` take no margin and reject one at parse time.
//! Parsing validates everything (unknown names, malformed or negative
//! margins) immediately, so a typo'd `--loss` or config entry fails
//! before any data is generated, not inside `Backend::open`.
//!
//! `Aucm` (the LIBAUC PESG baseline) is pjrt-gated at *execution* time:
//! the variant always parses — mirroring how [`crate::runtime::BackendSpec::Pjrt`]
//! exists without the `pjrt` cargo feature — but it has no native
//! kernel, so [`LossSpec::build`] (and therefore the native backend)
//! rejects it with a pointer to `--backend pjrt`.

use std::fmt;
use std::str::FromStr;

use super::functional::{Square, SquaredHinge};
use super::kernel::LossFn;
use super::linear_hinge::LinearHinge;
use super::logistic::Logistic;
use super::weighted::WeightedSquaredHinge;

/// Margin applied when a spec string carries no explicit `@margin=`.
pub const DEFAULT_MARGIN: f32 = 1.0;

/// The grammar summary used in parse-error messages.
pub const VALID_SPECS: &str = "hinge | square | logistic | lhinge | whinge | aucm \
                               (pairwise losses accept an optional margin, e.g. \"hinge@margin=2\"; \
                               aucm requires the pjrt backend)";

/// A fully-validated training-loss specification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LossSpec {
    /// All-pairs squared hinge (paper Algorithm 2, O(n log n)).
    Hinge { margin: f32 },
    /// All-pairs square loss (paper Algorithm 1, O(n)).
    Square { margin: f32 },
    /// Per-example logistic loss (pointwise O(n) baseline).
    Logistic,
    /// All-pairs *linear* hinge with subgradient (paper §5 extension).
    LinearHinge { margin: f32 },
    /// Class-balanced weighted all-pairs squared hinge (Airola et al.
    /// 2011 / Cui et al. 2019 flavor): per-batch inverse-class-frequency
    /// weights on top of the pairwise objective.
    WeightedHinge { margin: f32 },
    /// The LIBAUC PESG baseline — exists only as an AOT artifact, so it
    /// runs through the pjrt backend only.
    Aucm,
}

impl LossSpec {
    /// `hinge` at the default margin.
    pub fn hinge() -> Self {
        LossSpec::Hinge {
            margin: DEFAULT_MARGIN,
        }
    }

    /// `square` at the default margin.
    pub fn square() -> Self {
        LossSpec::Square {
            margin: DEFAULT_MARGIN,
        }
    }

    /// `logistic`.
    pub fn logistic() -> Self {
        LossSpec::Logistic
    }

    /// `lhinge` at the default margin.
    pub fn linear_hinge() -> Self {
        LossSpec::LinearHinge {
            margin: DEFAULT_MARGIN,
        }
    }

    /// `whinge` at the default margin.
    pub fn weighted_hinge() -> Self {
        LossSpec::WeightedHinge {
            margin: DEFAULT_MARGIN,
        }
    }

    /// `aucm` (pjrt backend only).
    pub fn aucm() -> Self {
        LossSpec::Aucm
    }

    /// The bare loss name — the AOT artifact-name component and the
    /// report/lr-grid key (`"hinge"`, `"whinge"`, ...).
    pub fn base_name(&self) -> &'static str {
        match self {
            LossSpec::Hinge { .. } => "hinge",
            LossSpec::Square { .. } => "square",
            LossSpec::Logistic => "logistic",
            LossSpec::LinearHinge { .. } => "lhinge",
            LossSpec::WeightedHinge { .. } => "whinge",
            LossSpec::Aucm => "aucm",
        }
    }

    /// Margin of the pairwise hinge-family losses (`None` for the
    /// margin-free `logistic` / `aucm`).
    pub fn margin(&self) -> Option<f32> {
        match *self {
            LossSpec::Hinge { margin }
            | LossSpec::Square { margin }
            | LossSpec::LinearHinge { margin }
            | LossSpec::WeightedHinge { margin } => Some(margin),
            LossSpec::Logistic | LossSpec::Aucm => None,
        }
    }

    /// Whether the loss sums over (positive, negative) pairs (vs per
    /// example).
    pub fn is_pairwise(&self) -> bool {
        !matches!(self, LossSpec::Logistic)
    }

    /// Instantiate the native kernel for this spec.  Errors for `aucm`,
    /// which exists only as a pjrt artifact — the one spec with no
    /// native [`LossFn`].
    pub fn build(&self) -> crate::Result<Box<dyn LossFn>> {
        match *self {
            LossSpec::Hinge { margin } => Ok(Box::new(SquaredHinge::new(margin))),
            LossSpec::Square { margin } => Ok(Box::new(Square::new(margin))),
            LossSpec::Logistic => Ok(Box::new(Logistic)),
            LossSpec::LinearHinge { margin } => Ok(Box::new(LinearHinge::new(margin))),
            LossSpec::WeightedHinge { margin } => Ok(Box::new(WeightedSquaredHinge::new(margin))),
            LossSpec::Aucm => anyhow::bail!(
                "loss \"aucm\" has no native kernel (the LIBAUC baseline exists only as \
                 an AOT artifact); use the pjrt backend"
            ),
        }
    }
}

impl fmt::Display for LossSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.margin() {
            Some(m) if m != DEFAULT_MARGIN => write!(f, "{}@margin={m}", self.base_name()),
            _ => f.write_str(self.base_name()),
        }
    }
}

impl FromStr for LossSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (name, args) = match s.split_once('@') {
            None => (s, None),
            Some((name, args)) => (name, Some(args)),
        };
        let margin = match args {
            None => None,
            Some(args) => {
                let value = args.strip_prefix("margin=").ok_or_else(|| {
                    anyhow::anyhow!(
                        "bad loss spec {s:?}: expected \"{name}@margin=M\" (valid specs: {VALID_SPECS})"
                    )
                })?;
                let m: f32 = value
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad margin in loss spec {s:?}: {e}"))?;
                anyhow::ensure!(
                    m.is_finite() && m >= 0.0,
                    "bad loss spec {s:?}: margin must be a finite non-negative number"
                );
                Some(m)
            }
        };
        let with_margin = |mk: fn(f32) -> LossSpec| Ok(mk(margin.unwrap_or(DEFAULT_MARGIN)));
        let margin_free = |spec: LossSpec| {
            anyhow::ensure!(
                margin.is_none(),
                "loss {name:?} takes no margin (got {s:?}); valid specs: {VALID_SPECS}"
            );
            Ok(spec)
        };
        match name {
            "hinge" => with_margin(|margin| LossSpec::Hinge { margin }),
            "square" => with_margin(|margin| LossSpec::Square { margin }),
            "lhinge" => with_margin(|margin| LossSpec::LinearHinge { margin }),
            "whinge" => with_margin(|margin| LossSpec::WeightedHinge { margin }),
            "logistic" => margin_free(LossSpec::Logistic),
            "aucm" => margin_free(LossSpec::Aucm),
            other => anyhow::bail!("unknown loss {other:?}; valid specs: {VALID_SPECS}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_parse_to_default_margin() {
        assert_eq!("hinge".parse::<LossSpec>().unwrap(), LossSpec::hinge());
        assert_eq!("square".parse::<LossSpec>().unwrap(), LossSpec::square());
        assert_eq!("logistic".parse::<LossSpec>().unwrap(), LossSpec::Logistic);
        assert_eq!(
            "lhinge".parse::<LossSpec>().unwrap(),
            LossSpec::linear_hinge()
        );
        assert_eq!(
            "whinge".parse::<LossSpec>().unwrap(),
            LossSpec::weighted_hinge()
        );
        assert_eq!("aucm".parse::<LossSpec>().unwrap(), LossSpec::Aucm);
    }

    #[test]
    fn explicit_margin_parses() {
        assert_eq!(
            "hinge@margin=2".parse::<LossSpec>().unwrap(),
            LossSpec::Hinge { margin: 2.0 }
        );
        assert_eq!(
            "whinge@margin=0.5".parse::<LossSpec>().unwrap(),
            LossSpec::WeightedHinge { margin: 0.5 }
        );
        // margin equal to the default round-trips to the bare name
        assert_eq!("square@margin=1".parse::<LossSpec>().unwrap(), LossSpec::square());
    }

    #[test]
    fn display_round_trips() {
        for spec in [
            LossSpec::hinge(),
            LossSpec::Hinge { margin: 2.0 },
            LossSpec::Square { margin: 0.25 },
            LossSpec::Logistic,
            LossSpec::LinearHinge { margin: 0.0 },
            LossSpec::WeightedHinge { margin: 3.5 },
            LossSpec::Aucm,
        ] {
            let text = spec.to_string();
            assert_eq!(text.parse::<LossSpec>().unwrap(), spec, "{text}");
        }
        assert_eq!(LossSpec::hinge().to_string(), "hinge");
        assert_eq!(LossSpec::Hinge { margin: 2.0 }.to_string(), "hinge@margin=2");
    }

    #[test]
    fn invalid_specs_fail_listing_the_grammar() {
        for bad in [
            "typo",
            "hinge@m=2",
            "hinge@margin=",
            "hinge@margin=-1",
            "hinge@margin=nope",
            "hinge@margin=inf",
            "logistic@margin=2",
            "aucm@margin=1",
            "",
        ] {
            let err = bad.parse::<LossSpec>().unwrap_err().to_string();
            assert!(
                err.contains("hinge") || err.contains("margin"),
                "{bad:?}: error should name the valid specs, got: {err}"
            );
        }
    }

    #[test]
    fn build_covers_every_native_loss_and_rejects_aucm() {
        for spec in [
            LossSpec::hinge(),
            LossSpec::square(),
            LossSpec::logistic(),
            LossSpec::linear_hinge(),
            LossSpec::weighted_hinge(),
        ] {
            assert!(spec.build().is_ok(), "{spec}");
        }
        let err = LossSpec::Aucm.build().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn accessors() {
        assert_eq!(LossSpec::hinge().base_name(), "hinge");
        assert_eq!(LossSpec::weighted_hinge().base_name(), "whinge");
        assert_eq!(LossSpec::Hinge { margin: 2.0 }.margin(), Some(2.0));
        assert_eq!(LossSpec::Logistic.margin(), None);
        assert!(LossSpec::Aucm.is_pairwise());
        assert!(!LossSpec::Logistic.is_pairwise());
    }
}
