//! The paper's contribution: functional-representation all-pairs losses.
//!
//! * [`Square`] — Algorithm 1: three coefficients over the positives plus
//!   three mirrored sums over the negatives give loss *and* gradient in
//!   O(n) with no sort.
//! * [`SquaredHinge`] — Algorithm 2: sort by the augmented value
//!   `vᵢ = ŷᵢ + m·I[yᵢ = −1]` (eq. 20), then one ascending sweep carrying
//!   the coefficients `(a, b, c)` (eqs. 22–24) evaluates the loss at every
//!   negative (eq. 25).  We extend the sweep with a running sum `t` of
//!   positive predictions — that makes the same pass emit the closed-form
//!   gradient for negatives — and run a mirrored descending sweep for the
//!   positive gradients.  Total O(n log n), dominated by the sort.
//!
//! Both implement the allocation-free [`LossFn`] kernel API — gradients
//! and the hinge sort scratch live in the caller's [`LossWorkspace`], so
//! the training hot loop allocates nothing after warm-up (see
//! EXPERIMENTS.md §Perf) — plus the allocating [`PairwiseLoss`] trait
//! for the Figure 2 harness.
//!
//! Accumulators are f64: at n = 10⁷ the loss is a sum of ~10¹³-scale
//! products and f32 accumulation would lose the low-order digits that the
//! property tests (functional ≡ naive) check.  The hinge sort keys are
//! f64 for the same reason — an f32-rounded key can order a near-margin
//! pair differently than the f64 sweep evaluates it (see
//! `kernel::fill_hinge_order` and the regression tests below).

use super::kernel::{fill_hinge_order, pair_norm, BatchView, LossFn, LossWorkspace};
use super::PairwiseLoss;

/// Algorithm 1: all-pairs square loss in O(n).
#[derive(Debug, Clone, Copy)]
pub struct Square {
    margin: f32,
}

impl Square {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// The six global sums of pass 1 (paper eqs. 11-13 + mirrors):
    /// `(n_pos, b_pos, c_pos, n_neg, s_neg, q_neg)`.
    fn coefficients(&self, batch: BatchView<'_>) -> (f64, f64, f64, f64, f64, f64) {
        let m = self.margin as f64;
        let (mut n_pos, mut b_pos, mut c_pos) = (0.0_f64, 0.0_f64, 0.0_f64);
        let (mut n_neg, mut s_neg, mut q_neg) = (0.0_f64, 0.0_f64, 0.0_f64);
        for (&y, &p) in batch.scores.iter().zip(batch.is_pos) {
            let y = y as f64;
            if p != 0.0 {
                let z = m - y;
                n_pos += 1.0;
                b_pos += 2.0 * z;
                c_pos += z * z;
            } else {
                n_neg += 1.0;
                s_neg += y;
                q_neg += y * y;
            }
        }
        (n_pos, b_pos, c_pos, n_neg, s_neg, q_neg)
    }
}

impl LossFn for Square {
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let m = self.margin as f64;
        let (n_pos, b_pos, c_pos, n_neg, s_neg, q_neg) = self.coefficients(batch);
        // Loss (eq. 15): sum_k a+ yk^2 + b+ yk + c+.
        let loss = n_pos * q_neg + b_pos * s_neg + c_pos * n_neg;
        // Pass 2: closed-form per-element gradient.
        ws.grad.clear();
        ws.grad
            .extend(batch.scores.iter().zip(batch.is_pos).map(|(&y, &p)| {
                let y = y as f64;
                if p != 0.0 {
                    // lint:allow(float-narrowing-in-kernel): f64 math ends here; grad is f32
                    (-2.0 * (n_neg * (m - y) + s_neg)) as f32
                } else {
                    // lint:allow(float-narrowing-in-kernel): f64 math ends here; grad is f32
                    (2.0 * n_pos * y + b_pos) as f32
                }
            }));
        loss
    }

    fn loss_only(&self, batch: BatchView<'_>, _ws: &mut LossWorkspace) -> f64 {
        let (n_pos, b_pos, c_pos, n_neg, s_neg, q_neg) = self.coefficients(batch);
        n_pos * q_neg + b_pos * s_neg + c_pos * n_neg
    }

    fn norm(&self, batch: BatchView<'_>) -> f64 {
        pair_norm(batch)
    }
}

impl PairwiseLoss for Square {
    fn name(&self) -> &'static str {
        "functional_square"
    }

    fn complexity(&self) -> &'static str {
        "O(n)"
    }

    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        // Gradient-free path: pass 1 only, no buffer touched.
        LossFn::loss_only(self, BatchView::new(scores, is_pos), &mut LossWorkspace::default())
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut ws = LossWorkspace::default();
        let loss = LossFn::loss_and_grad(self, BatchView::new(scores, is_pos), &mut ws);
        (loss, std::mem::take(&mut ws.grad))
    }
}

/// Algorithm 2: all-pairs squared hinge loss in O(n log n).
#[derive(Debug, Clone, Copy)]
pub struct SquaredHinge {
    margin: f32,
}

impl SquaredHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// Loss only — single ascending sweep, no gradient buffers.  The
    /// allocating convenience form of [`LossFn::loss_only`] (monitoring
    /// and tests; the hot paths hold a [`LossWorkspace`]).
    pub fn loss_only(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        LossFn::loss_only(self, BatchView::new(scores, is_pos), &mut LossWorkspace::default())
    }
}

impl LossFn for SquaredHinge {
    fn loss_and_grad(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let n = batch.len();
        let m = self.margin as f64;
        ws.grad.clear();
        ws.grad.resize(n, 0.0);
        if n == 0 {
            return 0.0;
        }

        // Sort indices by augmented value (eq. 20) on exact f64 keys
        // (see `kernel::fill_hinge_order`).  Exact-tie order is benign:
        // a (pos, neg) pair at equal v contributes zero loss and zero
        // gradient.
        fill_hinge_order(batch, m, &mut ws.keys, &mut ws.order, &mut ws.sort, false);

        // Ascending sweep (paper eqs. 22-25) + negative gradients.
        let (mut a, mut b, mut c, mut t) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &ws.order {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            if batch.is_pos[i] != 0.0 {
                let z = m - y;
                a += 1.0;
                b += 2.0 * z;
                c += z * z;
                t += y;
            } else {
                loss += a * y * y + b * y + c;
                // dL/dyk = 2 [ a_k (m + yk) - t_k ]
                // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; grad store is f32
                ws.grad[i] = (2.0 * (a * (m + y) - t)) as f32;
            }
        }

        // Descending sweep: positive gradients.
        let (mut n_cnt, mut t_sum) = (0.0_f64, 0.0_f64);
        for &i in ws.order.iter().rev() {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            if batch.is_pos[i] != 0.0 {
                // dL/dyj = -2 [ N_j (m - yj) + T_j ]
                // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; grad store is f32
                ws.grad[i] = (-2.0 * (n_cnt * (m - y) + t_sum)) as f32;
            } else {
                n_cnt += 1.0;
                t_sum += y;
            }
        }
        loss
    }

    fn loss_only(&self, batch: BatchView<'_>, ws: &mut LossWorkspace) -> f64 {
        let m = self.margin as f64;
        if batch.is_empty() {
            return 0.0;
        }
        fill_hinge_order(batch, m, &mut ws.keys, &mut ws.order, &mut ws.sort, false);
        let (mut a, mut b, mut c) = (0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &ws.order {
            let i = i as usize;
            let y = batch.scores[i] as f64;
            if batch.is_pos[i] != 0.0 {
                let z = m - y;
                a += 1.0;
                b += 2.0 * z;
                c += z * z;
            } else {
                loss += a * y * y + b * y + c;
            }
        }
        loss
    }

    fn norm(&self, batch: BatchView<'_>) -> f64 {
        pair_norm(batch)
    }
}

impl PairwiseLoss for SquaredHinge {
    fn name(&self) -> &'static str {
        "functional_squared_hinge"
    }

    fn complexity(&self) -> &'static str {
        "O(n log n)"
    }

    fn loss(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        // Route the trait's loss-only evaluation through the sweep-only
        // path instead of the default "compute and discard a gradient".
        self.loss_only(scores, is_pos)
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut ws = LossWorkspace::default();
        let loss = LossFn::loss_and_grad(self, BatchView::new(scores, is_pos), &mut ws);
        (loss, std::mem::take(&mut ws.grad))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::naive::{NaiveSquare, NaiveSquaredHinge};

    fn random_case(seed: u64, n: usize, pos_frac: f64) -> (Vec<f32>, Vec<f32>) {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let scores: Vec<f32> = (0..n).map(|_| (next() * 6.0 - 3.0) as f32).collect();
        let is_pos: Vec<f32> = (0..n)
            .map(|_| if next() < pos_frac { 1.0 } else { 0.0 })
            .collect();
        (scores, is_pos)
    }

    fn assert_close(a: f64, b: f64, tol: f64) {
        let scale = 1.0_f64.max(a.abs()).max(b.abs());
        assert!((a - b).abs() <= tol * scale, "{a} vs {b}");
    }

    #[test]
    fn hinge_matches_naive_small() {
        for seed in 0..20 {
            let (s, p) = random_case(seed, 50, 0.3);
            let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&s, &p);
            let (lf, gf) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(1.0), &s, &p);
            assert_close(ln, lf, 1e-9);
            for (a, b) in gn.iter().zip(&gf) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn square_matches_naive_small() {
        for seed in 0..20 {
            let (s, p) = random_case(seed + 100, 64, 0.2);
            let (ln, gn) = NaiveSquare::new(1.0).loss_and_grad(&s, &p);
            let (lf, gf) = PairwiseLoss::loss_and_grad(&Square::new(1.0), &s, &p);
            assert_close(ln, lf, 1e-9);
            for (a, b) in gn.iter().zip(&gf) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn hinge_zero_margin() {
        let (s, p) = random_case(7, 40, 0.5);
        let (ln, _) = NaiveSquaredHinge::new(0.0).loss_and_grad(&s, &p);
        let (lf, _) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(0.0), &s, &p);
        assert_close(ln, lf, 1e-9);
    }

    #[test]
    fn hinge_tie_heavy_inputs() {
        // Quantized scores force many exact ties in the sort keys.
        let (mut s, p) = random_case(13, 200, 0.3);
        for y in &mut s {
            *y = (*y * 2.0).round() / 2.0;
        }
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&s, &p);
        let (lf, gf) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(1.0), &s, &p);
        assert_close(ln, lf, 1e-9);
        for (a, b) in gn.iter().zip(&gf) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn loss_only_matches_full() {
        let (s, p) = random_case(3, 333, 0.1);
        let h = SquaredHinge::new(1.0);
        let (full, _) = PairwiseLoss::loss_and_grad(&h, &s, &p);
        assert_close(h.loss_only(&s, &p), full, 1e-12);
        // and the trait's loss-only entry point takes the same path
        assert_close(PairwiseLoss::loss(&h, &s, &p), full, 1e-12);
    }

    #[test]
    fn square_loss_only_matches_full() {
        let (s, p) = random_case(4, 222, 0.3);
        let sq = Square::new(1.0);
        let (full, _) = PairwiseLoss::loss_and_grad(&sq, &s, &p);
        assert_close(PairwiseLoss::loss(&sq, &s, &p), full, 1e-12);
    }

    #[test]
    fn workspace_reuse_is_identical() {
        let h = SquaredHinge::new(1.0);
        let mut ws = LossWorkspace::default();
        let (s1, p1) = random_case(1, 100, 0.4);
        let (s2, p2) = random_case(2, 77, 0.2);
        let l1 = LossFn::loss_and_grad(&h, BatchView::new(&s1, &p1), &mut ws);
        let g1 = ws.grad.clone();
        let _ = LossFn::loss_and_grad(&h, BatchView::new(&s2, &p2), &mut ws);
        let l1b = LossFn::loss_and_grad(&h, BatchView::new(&s1, &p1), &mut ws);
        assert_eq!(l1, l1b);
        assert_eq!(g1, ws.grad);
    }

    #[test]
    fn empty_and_degenerate() {
        let h = SquaredHinge::new(1.0);
        assert_eq!(PairwiseLoss::loss_and_grad(&h, &[], &[]).0, 0.0);
        assert_eq!(PairwiseLoss::loss_and_grad(&h, &[0.5], &[1.0]).0, 0.0);
        assert_eq!(PairwiseLoss::loss_and_grad(&h, &[0.5], &[0.0]).0, 0.0);
    }

    #[test]
    fn regression_f32_keys_drop_near_boundary_pairs() {
        // Scores within one f32 ulp of the sort-key boundary.  At
        // |score| = 2^24 the f32 ulp is 2.0, so the f32 sum
        // `y_neg + m = 2^24 + 1` rounds back onto 2^24 and ties with
        // every positive key — the ascending sweep then sees the
        // negative *before* the positives (unstable sort keeps the
        // input order of exact ties at this size) and drops all five
        // active pairs, each of which contributes (m - yj + yk)^2 = 1.
        // The exact f64 key 2^24 + 1 sorts strictly after the
        // positives, matching the f64 sweep.  This test fails if the
        // keys are computed in f32.
        let big = 16_777_216.0_f32; // 2^24
        let mut scores = vec![big]; // the negative first, so a tie order
        let mut is_pos = vec![0.0]; // that keeps input order is wrong
        for _ in 0..5 {
            scores.push(big);
            is_pos.push(1.0);
        }
        let h = SquaredHinge::new(1.0);
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert_eq!(ln, 5.0); // five active pairs, each exactly 1 (f64-exact)
        let (lf, gf) = PairwiseLoss::loss_and_grad(&h, &scores, &is_pos);
        assert_close(ln, lf, 1e-12);
        assert_close(h.loss_only(&scores, &is_pos), ln, 1e-12);
        // grad[neg] = 2 * 5 pairs * (m - yj + yk) = 10; grad[pos] = -2
        for (a, b) in gn.iter().zip(&gf) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(gf[0], 10.0);
        assert!(gf[1..].iter().all(|&g| g == -2.0));
    }

    #[test]
    fn regression_f32_keys_add_phantom_pairs_on_round_up() {
        // The mirror of the test above: here the f32 key sum rounds
        // *up* onto the positive keys.  y_neg = 2^24 + 2 has an odd
        // f32 mantissa, so `y_neg + 1 = 2^24 + 3` is an exact halfway
        // case and round-to-even lands on 2^24 + 4 — tying with the
        // positives at 2^24 + 4 even though the exact key sorts
        // strictly *before* them.  Every pair has yj - yk = 2 > m, so
        // the correct loss and gradients are exactly zero; an f32-key
        // sweep that breaks the tie with the negative last adds a
        // phantom (m - yj + yk)^2 = 1 per pair.  Together with the
        // round-down test above (which needs the negative *last* in
        // its tie group, while this one needs it *first*), no single
        // tie-break policy can make f32 keys pass both.
        let pos = 16_777_220.0_f32; // 2^24 + 4
        let neg = 16_777_218.0_f32; // 2^24 + 2
        let scores = vec![pos, pos, pos, neg];
        let is_pos = vec![1.0, 1.0, 1.0, 0.0];
        let h = SquaredHinge::new(1.0);
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert_eq!(ln, 0.0);
        assert!(gn.iter().all(|&g| g == 0.0));
        let (lf, gf) = PairwiseLoss::loss_and_grad(&h, &scores, &is_pos);
        assert_eq!(lf, 0.0);
        assert!(gf.iter().all(|&g| g == 0.0));
        assert_eq!(h.loss_only(&scores, &is_pos), 0.0);
    }

    #[test]
    fn perfect_separation_beyond_margin_is_zero() {
        let s = vec![-2.0, -1.9, 2.0, 2.1];
        let p = vec![0.0, 0.0, 1.0, 1.0];
        let (l, g) = PairwiseLoss::loss_and_grad(&SquaredHinge::new(1.0), &s, &p);
        assert_eq!(l, 0.0);
        assert!(g.iter().all(|&x| x == 0.0));
    }
}
