//! The paper's contribution: functional-representation all-pairs losses.
//!
//! * [`Square`] — Algorithm 1: three coefficients over the positives plus
//!   three mirrored sums over the negatives give loss *and* gradient in
//!   O(n) with no sort.
//! * [`SquaredHinge`] — Algorithm 2: sort by the augmented value
//!   `vᵢ = ŷᵢ + m·I[yᵢ = −1]` (eq. 20), then one ascending sweep carrying
//!   the coefficients `(a, b, c)` (eqs. 22–24) evaluates the loss at every
//!   negative (eq. 25).  We extend the sweep with a running sum `t` of
//!   positive predictions — that makes the same pass emit the closed-form
//!   gradient for negatives — and run a mirrored descending sweep for the
//!   positive gradients.  Total O(n log n), dominated by the sort.
//!
//! The scratch buffers used by the hinge sweep can be reused across calls
//! via [`SquaredHinge::loss_and_grad_with`] + [`HingeScratch`], which keeps
//! the training hot loop allocation-free (see EXPERIMENTS.md §Perf).
//!
//! Accumulators are f64: at n = 10⁷ the loss is a sum of ~10¹³-scale
//! products and f32 accumulation would lose the low-order digits that the
//! property tests (functional ≡ naive) check.  The hinge sort keys are
//! f64 for the same reason — an f32-rounded key can order a near-margin
//! pair differently than the f64 sweep evaluates it (see
//! [`HingeScratch`]).

use super::PairwiseLoss;

/// Algorithm 1: all-pairs square loss in O(n).
#[derive(Debug, Clone, Copy)]
pub struct Square {
    margin: f32,
}

impl Square {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// Loss + gradient written into `grad` (cleared and refilled) — the
    /// allocation-free hot path.  Algorithm 1 needs no sort and hence
    /// no scratch beyond the gradient buffer itself.
    pub fn loss_and_grad_into(&self, scores: &[f32], is_pos: &[f32], grad: &mut Vec<f32>) -> f64 {
        assert_eq!(scores.len(), is_pos.len());
        let m = self.margin as f64;
        // Pass 1: the six global sums (paper eqs. 11-13 + mirrors).
        let (mut n_pos, mut b_pos, mut c_pos) = (0.0_f64, 0.0_f64, 0.0_f64);
        let (mut n_neg, mut s_neg, mut q_neg) = (0.0_f64, 0.0_f64, 0.0_f64);
        for (&y, &p) in scores.iter().zip(is_pos) {
            let y = y as f64;
            if p != 0.0 {
                let z = m - y;
                n_pos += 1.0;
                b_pos += 2.0 * z;
                c_pos += z * z;
            } else {
                n_neg += 1.0;
                s_neg += y;
                q_neg += y * y;
            }
        }
        // Loss (eq. 15): sum_k a+ yk^2 + b+ yk + c+.
        let loss = n_pos * q_neg + b_pos * s_neg + c_pos * n_neg;
        // Pass 2: closed-form per-element gradient.
        grad.clear();
        grad.extend(scores.iter().zip(is_pos).map(|(&y, &p)| {
            let y = y as f64;
            if p != 0.0 {
                (-2.0 * (n_neg * (m - y) + s_neg)) as f32
            } else {
                (2.0 * n_pos * y + b_pos) as f32
            }
        }));
        loss
    }
}

impl PairwiseLoss for Square {
    fn name(&self) -> &'static str {
        "functional_square"
    }

    fn complexity(&self) -> &'static str {
        "O(n)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut grad = Vec::new();
        let loss = self.loss_and_grad_into(scores, is_pos, &mut grad);
        (loss, grad)
    }
}

/// Reusable scratch for [`SquaredHinge::loss_and_grad_with`]: the sort
/// permutation and sorted copies.  Reusing it across calls makes the sweep
/// allocation-free after warm-up.
///
/// Keys are f64: the sweep accumulates in f64, so the sort order must be
/// decided by the *exact* augmented values `ŷᵢ + m·I[neg]`.  Building the
/// key as an f32 sum rounds it (at |ŷ| = 2²⁴ the f32 ulp is 2.0, so
/// `ŷₖ + 1` collapses onto `ŷₖ`), and a near-margin pair whose rounded
/// key flips or ties out of order is silently dropped from (or added to)
/// the loss and gradient.  f32 → f64 conversion and the f64 sum of two
/// f32-valued operands are exact, so the f64 key order always matches
/// the f64 sweep.
#[derive(Debug, Default, Clone)]
pub struct HingeScratch {
    order: Vec<u32>,
    keys: Vec<f64>,
}

/// Algorithm 2: all-pairs squared hinge loss in O(n log n).
#[derive(Debug, Clone, Copy)]
pub struct SquaredHinge {
    margin: f32,
}

impl SquaredHinge {
    pub fn new(margin: f32) -> Self {
        assert!(margin >= 0.0, "margin must be non-negative");
        Self { margin }
    }

    /// Loss + gradient, writing the gradient into `grad` (resized to fit)
    /// and reusing `scratch` buffers.  The allocation-free hot path.
    pub fn loss_and_grad_with(
        &self,
        scores: &[f32],
        is_pos: &[f32],
        grad: &mut Vec<f32>,
        scratch: &mut HingeScratch,
    ) -> f64 {
        assert_eq!(scores.len(), is_pos.len());
        let n = scores.len();
        let m = self.margin as f64;
        grad.clear();
        grad.resize(n, 0.0);
        if n == 0 {
            return 0.0;
        }

        // Sort indices by augmented value v_i = yhat_i + m * I[neg] (eq. 20),
        // computed in f64 so key order matches the f64 sweep (see
        // [`HingeScratch`]).  Exact-tie order is benign: a (pos, neg) pair
        // at equal v contributes zero loss and zero gradient.
        scratch.keys.clear();
        scratch
            .keys
            .extend(scores.iter().zip(is_pos).map(|(&y, &p)| {
                if p != 0.0 {
                    y as f64
                } else {
                    y as f64 + m
                }
            }));
        scratch.order.clear();
        scratch.order.extend(0..n as u32);
        let keys = &scratch.keys;
        scratch
            .order
            .sort_unstable_by(|&a, &b| keys[a as usize].total_cmp(&keys[b as usize]));

        // Ascending sweep (paper eqs. 22-25) + negative gradients.
        let (mut a, mut b, mut c, mut t) = (0.0_f64, 0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &scratch.order {
            let i = i as usize;
            let y = scores[i] as f64;
            if is_pos[i] != 0.0 {
                let z = m - y;
                a += 1.0;
                b += 2.0 * z;
                c += z * z;
                t += y;
            } else {
                loss += a * y * y + b * y + c;
                // dL/dyk = 2 [ a_k (m + yk) - t_k ]
                grad[i] = (2.0 * (a * (m + y) - t)) as f32;
            }
        }

        // Descending sweep: positive gradients.
        let (mut n_cnt, mut t_sum) = (0.0_f64, 0.0_f64);
        for &i in scratch.order.iter().rev() {
            let i = i as usize;
            let y = scores[i] as f64;
            if is_pos[i] != 0.0 {
                // dL/dyj = -2 [ N_j (m - yj) + T_j ]
                grad[i] = (-2.0 * (n_cnt * (m - y) + t_sum)) as f32;
            } else {
                n_cnt += 1.0;
                t_sum += y;
            }
        }
        loss
    }

    /// Loss only — single ascending sweep, no gradient buffers.
    pub fn loss_only(&self, scores: &[f32], is_pos: &[f32]) -> f64 {
        assert_eq!(scores.len(), is_pos.len());
        let n = scores.len();
        let m = self.margin as f64;
        let mut order: Vec<u32> = (0..n as u32).collect();
        // f64 keys for the same reason as `loss_and_grad_with` (see
        // [`HingeScratch`]): key order must match the f64 sweep.
        let keys: Vec<f64> = scores
            .iter()
            .zip(is_pos)
            .map(|(&y, &p)| if p != 0.0 { y as f64 } else { y as f64 + m })
            .collect();
        order.sort_unstable_by(|&a, &b| keys[a as usize].total_cmp(&keys[b as usize]));
        let (mut a, mut b, mut c) = (0.0_f64, 0.0_f64, 0.0_f64);
        let mut loss = 0.0_f64;
        for &i in &order {
            let i = i as usize;
            let y = scores[i] as f64;
            if is_pos[i] != 0.0 {
                let z = m - y;
                a += 1.0;
                b += 2.0 * z;
                c += z * z;
            } else {
                loss += a * y * y + b * y + c;
            }
        }
        loss
    }
}

impl PairwiseLoss for SquaredHinge {
    fn name(&self) -> &'static str {
        "functional_squared_hinge"
    }

    fn complexity(&self) -> &'static str {
        "O(n log n)"
    }

    fn loss_and_grad(&self, scores: &[f32], is_pos: &[f32]) -> (f64, Vec<f32>) {
        let mut grad = Vec::new();
        let mut scratch = HingeScratch::default();
        let loss = self.loss_and_grad_with(scores, is_pos, &mut grad, &mut scratch);
        (loss, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::naive::{NaiveSquare, NaiveSquaredHinge};

    fn random_case(seed: u64, n: usize, pos_frac: f64) -> (Vec<f32>, Vec<f32>) {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let scores: Vec<f32> = (0..n).map(|_| (next() * 6.0 - 3.0) as f32).collect();
        let is_pos: Vec<f32> = (0..n)
            .map(|_| if next() < pos_frac { 1.0 } else { 0.0 })
            .collect();
        (scores, is_pos)
    }

    fn assert_close(a: f64, b: f64, tol: f64) {
        let scale = 1.0_f64.max(a.abs()).max(b.abs());
        assert!((a - b).abs() <= tol * scale, "{a} vs {b}");
    }

    #[test]
    fn hinge_matches_naive_small() {
        for seed in 0..20 {
            let (s, p) = random_case(seed, 50, 0.3);
            let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&s, &p);
            let (lf, gf) = SquaredHinge::new(1.0).loss_and_grad(&s, &p);
            assert_close(ln, lf, 1e-9);
            for (a, b) in gn.iter().zip(&gf) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn square_matches_naive_small() {
        for seed in 0..20 {
            let (s, p) = random_case(seed + 100, 64, 0.2);
            let (ln, gn) = NaiveSquare::new(1.0).loss_and_grad(&s, &p);
            let (lf, gf) = Square::new(1.0).loss_and_grad(&s, &p);
            assert_close(ln, lf, 1e-9);
            for (a, b) in gn.iter().zip(&gf) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn hinge_zero_margin() {
        let (s, p) = random_case(7, 40, 0.5);
        let (ln, _) = NaiveSquaredHinge::new(0.0).loss_and_grad(&s, &p);
        let (lf, _) = SquaredHinge::new(0.0).loss_and_grad(&s, &p);
        assert_close(ln, lf, 1e-9);
    }

    #[test]
    fn hinge_tie_heavy_inputs() {
        // Quantized scores force many exact ties in the sort keys.
        let (mut s, p) = random_case(13, 200, 0.3);
        for y in &mut s {
            *y = (*y * 2.0).round() / 2.0;
        }
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&s, &p);
        let (lf, gf) = SquaredHinge::new(1.0).loss_and_grad(&s, &p);
        assert_close(ln, lf, 1e-9);
        for (a, b) in gn.iter().zip(&gf) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn loss_only_matches_full() {
        let (s, p) = random_case(3, 333, 0.1);
        let h = SquaredHinge::new(1.0);
        let (full, _) = h.loss_and_grad(&s, &p);
        assert_close(h.loss_only(&s, &p), full, 1e-12);
    }

    #[test]
    fn scratch_reuse_is_identical() {
        let h = SquaredHinge::new(1.0);
        let mut grad = Vec::new();
        let mut scratch = HingeScratch::default();
        let (s1, p1) = random_case(1, 100, 0.4);
        let (s2, p2) = random_case(2, 77, 0.2);
        let l1 = h.loss_and_grad_with(&s1, &p1, &mut grad, &mut scratch);
        let g1 = grad.clone();
        let _ = h.loss_and_grad_with(&s2, &p2, &mut grad, &mut scratch);
        let l1b = h.loss_and_grad_with(&s1, &p1, &mut grad, &mut scratch);
        assert_eq!(l1, l1b);
        assert_eq!(g1, grad);
    }

    #[test]
    fn empty_and_degenerate() {
        let h = SquaredHinge::new(1.0);
        assert_eq!(h.loss_and_grad(&[], &[]).0, 0.0);
        assert_eq!(h.loss_and_grad(&[0.5], &[1.0]).0, 0.0);
        assert_eq!(h.loss_and_grad(&[0.5], &[0.0]).0, 0.0);
    }

    #[test]
    fn regression_f32_keys_drop_near_boundary_pairs() {
        // Scores within one f32 ulp of the sort-key boundary.  At
        // |score| = 2^24 the f32 ulp is 2.0, so the f32 sum
        // `y_neg + m = 2^24 + 1` rounds back onto 2^24 and ties with
        // every positive key — the ascending sweep then sees the
        // negative *before* the positives (unstable sort keeps the
        // input order of exact ties at this size) and drops all five
        // active pairs, each of which contributes (m - yj + yk)^2 = 1.
        // The exact f64 key 2^24 + 1 sorts strictly after the
        // positives, matching the f64 sweep.  This test fails if the
        // keys are computed in f32.
        let big = 16_777_216.0_f32; // 2^24
        let mut scores = vec![big]; // the negative first, so a tie order
        let mut is_pos = vec![0.0]; // that keeps input order is wrong
        for _ in 0..5 {
            scores.push(big);
            is_pos.push(1.0);
        }
        let h = SquaredHinge::new(1.0);
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert_eq!(ln, 5.0); // five active pairs, each exactly 1 (f64-exact)
        let (lf, gf) = h.loss_and_grad(&scores, &is_pos);
        assert_close(ln, lf, 1e-12);
        assert_close(h.loss_only(&scores, &is_pos), ln, 1e-12);
        // grad[neg] = 2 * 5 pairs * (m - yj + yk) = 10; grad[pos] = -2
        for (a, b) in gn.iter().zip(&gf) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(gf[0], 10.0);
        assert!(gf[1..].iter().all(|&g| g == -2.0));
    }

    #[test]
    fn regression_f32_keys_add_phantom_pairs_on_round_up() {
        // The mirror of the test above: here the f32 key sum rounds
        // *up* onto the positive keys.  y_neg = 2^24 + 2 has an odd
        // f32 mantissa, so `y_neg + 1 = 2^24 + 3` is an exact halfway
        // case and round-to-even lands on 2^24 + 4 — tying with the
        // positives at 2^24 + 4 even though the exact key sorts
        // strictly *before* them.  Every pair has yj - yk = 2 > m, so
        // the correct loss and gradients are exactly zero; an f32-key
        // sweep that breaks the tie with the negative last adds a
        // phantom (m - yj + yk)^2 = 1 per pair.  Together with the
        // round-down test above (which needs the negative *last* in
        // its tie group, while this one needs it *first*), no single
        // tie-break policy can make f32 keys pass both.
        let pos = 16_777_220.0_f32; // 2^24 + 4
        let neg = 16_777_218.0_f32; // 2^24 + 2
        let scores = vec![pos, pos, pos, neg];
        let is_pos = vec![1.0, 1.0, 1.0, 0.0];
        let h = SquaredHinge::new(1.0);
        let (ln, gn) = NaiveSquaredHinge::new(1.0).loss_and_grad(&scores, &is_pos);
        assert_eq!(ln, 0.0);
        assert!(gn.iter().all(|&g| g == 0.0));
        let (lf, gf) = h.loss_and_grad(&scores, &is_pos);
        assert_eq!(lf, 0.0);
        assert!(gf.iter().all(|&g| g == 0.0));
        assert_eq!(h.loss_only(&scores, &is_pos), 0.0);
    }

    #[test]
    fn perfect_separation_beyond_margin_is_zero() {
        let s = vec![-2.0, -1.9, 2.0, 2.1];
        let p = vec![0.0, 0.0, 1.0, 1.0];
        let (l, g) = SquaredHinge::new(1.0).loss_and_grad(&s, &p);
        assert_eq!(l, 0.0);
        assert!(g.iter().all(|&x| x == 0.0));
    }
}
