//! Extension (paper §5 future work): full-batch deterministic training
//! with L-BFGS over a pluggable objective oracle.
//!
//! The paper: *"We would like to explore how our method could be used
//! with full batch sizes and deterministic optimization algorithms such
//! as the Limited Memory Broyden–Fletcher–Goldfarb–Shanno (LBFGS)
//! optimizer.  We expect that for problems where there exists a bad
//! condition number, LBFGS with full batch size should out-perform
//! Stochastic Gradient Descent with small batch sizes."*  The functional
//! loss makes full-batch gradients affordable (O(n log n) per epoch),
//! which is precisely what a deterministic quasi-Newton method needs.
//!
//! The optimizer is written against the [`Objective`] trait; two oracles
//! exist: [`crate::runtime::native::NativeObjective`] (default build,
//! via [`crate::runtime::NativeBackend::objective`]) and the PJRT
//! `FullBatchObjective` over `grad_*` artifacts (feature `pjrt`).
//!
//! Implementation: standard two-loop recursion with history `m`, an
//! Armijo backtracking line search, and gamma-scaled initial Hessian;
//! all quasi-Newton algebra runs on flat host vectors.

use std::collections::VecDeque;

#[cfg(feature = "pjrt")]
use xla::Literal;

#[cfg(feature = "pjrt")]
use crate::runtime::pjrt::{tensor_from_literal, Runtime};
#[cfg(feature = "pjrt")]
use crate::runtime::ArtifactKind;

/// A full-batch (loss, gradient) oracle over flat parameters.
pub trait Objective {
    /// Total number of scalar parameters.
    fn dim(&self) -> usize;

    /// Evaluate (loss, gradient) at flat parameters `theta`.
    fn eval(&mut self, theta: &[f32]) -> crate::Result<(f64, Vec<f32>)>;

    /// Number of evaluations performed so far (budget accounting).
    fn evals(&self) -> usize;
}

/// L-BFGS hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct LbfgsConfig {
    /// History length (pairs of (s, y) kept).
    pub history: usize,
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Armijo sufficient-decrease constant.
    pub c1: f64,
    /// Line-search backtracking factor.
    pub backtrack: f64,
    /// Maximum line-search trials per iteration.
    pub max_ls: usize,
    /// Stop when the gradient inf-norm falls below this.
    pub grad_tol: f64,
}

impl Default for LbfgsConfig {
    fn default() -> Self {
        Self {
            history: 10,
            max_iters: 50,
            c1: 1e-4,
            backtrack: 0.5,
            max_ls: 20,
            grad_tol: 1e-6,
        }
    }
}

/// One record of the optimization trace.
#[derive(Debug, Clone, Copy)]
pub struct LbfgsRecord {
    pub iter: usize,
    pub loss: f64,
    pub grad_norm: f64,
    pub step: f64,
    pub ls_trials: usize,
}

/// The PJRT full-batch objective bound to a `grad_*` artifact and a
/// dataset (feature `pjrt`).
#[cfg(feature = "pjrt")]
pub struct FullBatchObjective<'rt> {
    runtime: &'rt Runtime,
    grad_name: String,
    n_params: usize,
    /// Fixed full-batch inputs (x, is_pos, is_neg), padded to the
    /// artifact's static size.
    x: Literal,
    pos: Literal,
    neg: Literal,
    /// Shapes of the parameter tensors (for packing/unpacking).
    param_shapes: Vec<Vec<i64>>,
    /// Number of objective evaluations performed (diagnostics).
    pub evals: usize,
}

#[cfg(feature = "pjrt")]
impl<'rt> FullBatchObjective<'rt> {
    /// Bind the `grad_<model>_<loss>_n<N>` artifact to a dataset slice.
    ///
    /// `rows` is row-major example data (`n_examples * row_len`) and
    /// `labels` the {0,1} positive indicators; both are zero-padded to
    /// the artifact's static batch.
    pub fn new(
        runtime: &'rt Runtime,
        model: &str,
        spec: &crate::losses::LossSpec,
        rows: &[f32],
        labels: &[f32],
    ) -> crate::Result<Self> {
        crate::runtime::pjrt::check_artifact_margin(runtime, spec)?;
        let loss = spec.base_name();
        let art = runtime
            .manifest()
            .artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Grad && a.model == model && a.loss == loss)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no grad artifact for {model}/{loss}"))?;
        let n_params = art.n_state;
        let cap = art.batch;
        anyhow::ensure!(
            labels.len() <= cap,
            "grad artifact holds {cap} examples, got {}",
            labels.len()
        );
        let row_len: usize = art.inputs[n_params].shape[1..].iter().product();
        anyhow::ensure!(rows.len() == labels.len() * row_len, "rows/labels mismatch");
        let mut x = rows.to_vec();
        x.resize(cap * row_len, 0.0);
        let mut pos = labels.to_vec();
        pos.resize(cap, 0.0);
        let neg: Vec<f32> = labels
            .iter()
            .map(|&p| if p != 0.0 { 0.0 } else { 1.0 })
            .chain(std::iter::repeat(0.0))
            .take(cap)
            .collect();
        let x_shape: Vec<i64> = art.inputs[n_params].shape.iter().map(|&d| d as i64).collect();
        let param_shapes: Vec<Vec<i64>> = art.inputs[..n_params]
            .iter()
            .map(|sig| sig.shape.iter().map(|&d| d as i64).collect())
            .collect();
        Ok(Self {
            runtime,
            grad_name: art.name.clone(),
            n_params,
            x: Literal::vec1(&x).reshape(&x_shape)?,
            pos: Literal::vec1(&pos),
            neg: Literal::vec1(&neg),
            param_shapes,
            evals: 0,
        })
    }

    /// Initial parameters from the matching init artifact, flattened.
    pub fn init_params(
        &self,
        model: &str,
        spec: &crate::losses::LossSpec,
        seed: u32,
    ) -> crate::Result<Vec<f32>> {
        let init_name = crate::runtime::Manifest::init_name(model, spec.base_name());
        let outs = self.runtime.execute(&init_name, &[Literal::scalar(seed)])?;
        // init returns the full state (params + optimizer slots); the
        // params are the leading tensors whose shapes match ours.
        let mut flat = Vec::with_capacity(Objective::dim(self));
        for (lit, shape) in outs.iter().zip(&self.param_shapes) {
            let t = tensor_from_literal(lit)?;
            anyhow::ensure!(&t.shape == shape, "init/grad param shape mismatch");
            flat.extend_from_slice(&t.data);
        }
        Ok(flat)
    }
}

#[cfg(feature = "pjrt")]
impl Objective for FullBatchObjective<'_> {
    fn dim(&self) -> usize {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product::<i64>() as usize)
            .sum()
    }

    fn eval(&mut self, theta: &[f32]) -> crate::Result<(f64, Vec<f32>)> {
        anyhow::ensure!(theta.len() == self.dim(), "theta dim");
        self.evals += 1;
        let mut params: Vec<Literal> = Vec::with_capacity(self.n_params);
        let mut offset = 0;
        for shape in &self.param_shapes {
            let len: i64 = shape.iter().product();
            let chunk = &theta[offset..offset + len as usize];
            offset += len as usize;
            params.push(Literal::vec1(chunk).reshape(shape)?);
        }
        // borrow the fixed batch literals; only the params are rebuilt
        let args: Vec<&Literal> = params
            .iter()
            .chain([&self.x, &self.pos, &self.neg])
            .collect();
        let outs = self.runtime.execute(&self.grad_name, &args)?;
        let loss = outs[0].to_vec::<f32>()?[0] as f64;
        let mut grad = Vec::with_capacity(self.dim());
        for lit in &outs[1..] {
            grad.extend_from_slice(&tensor_from_literal(lit)?.data);
        }
        Ok((loss, grad))
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

fn inf_norm(a: &[f32]) -> f64 {
    a.iter().fold(0.0_f64, |m, &x| m.max(x.abs() as f64))
}

/// Minimize the objective with L-BFGS; returns (theta*, trace).
pub fn minimize(
    objective: &mut dyn Objective,
    theta0: Vec<f32>,
    config: &LbfgsConfig,
) -> crate::Result<(Vec<f32>, Vec<LbfgsRecord>)> {
    let mut theta = theta0;
    let (mut loss, mut grad) = objective.eval(&theta)?;
    let mut trace = Vec::new();
    let mut s_hist: VecDeque<Vec<f32>> = VecDeque::new();
    let mut y_hist: VecDeque<Vec<f32>> = VecDeque::new();
    let mut rho_hist: VecDeque<f64> = VecDeque::new();

    for iter in 0..config.max_iters {
        let gnorm = inf_norm(&grad);
        if gnorm < config.grad_tol {
            trace.push(LbfgsRecord {
                iter,
                loss,
                grad_norm: gnorm,
                step: 0.0,
                ls_trials: 0,
            });
            break;
        }
        // Two-loop recursion: d = -H g.
        let mut q: Vec<f64> = grad.iter().map(|&g| g as f64).collect();
        let mut alphas = Vec::with_capacity(s_hist.len());
        for i in (0..s_hist.len()).rev() {
            let alpha = rho_hist[i]
                * s_hist[i]
                    .iter()
                    .zip(&q)
                    .map(|(&s, &qv)| s as f64 * qv)
                    .sum::<f64>();
            for (qv, &y) in q.iter_mut().zip(&y_hist[i]) {
                *qv -= alpha * y as f64;
            }
            alphas.push(alpha);
        }
        // Initial Hessian scaling gamma = s·y / y·y from the newest pair.
        let gamma = match s_hist.back() {
            Some(s) => {
                let y = y_hist.back().unwrap();
                let sy = dot(s, y);
                let yy = dot(y, y);
                if yy > 0.0 {
                    sy / yy
                } else {
                    1.0
                }
            }
            None => 1.0,
        };
        for qv in q.iter_mut() {
            *qv *= gamma;
        }
        for (idx, i) in (0..s_hist.len()).enumerate() {
            let beta = rho_hist[i]
                * y_hist[i]
                    .iter()
                    .zip(&q)
                    .map(|(&y, &qv)| y as f64 * qv)
                    .sum::<f64>();
            let alpha = alphas[s_hist.len() - 1 - idx];
            for (qv, &s) in q.iter_mut().zip(&s_hist[i]) {
                *qv += (alpha - beta) * s as f64;
            }
        }
        let direction: Vec<f32> = q.iter().map(|&v| -v as f32).collect();
        let dir_deriv = dot(&direction, &grad);
        // Fall back to steepest descent on a non-descent direction.
        let (direction, dir_deriv) = if dir_deriv < 0.0 {
            (direction, dir_deriv)
        } else {
            let d: Vec<f32> = grad.iter().map(|&g| -g).collect();
            let dd = dot(&d, &grad);
            (d, dd)
        };

        // Armijo backtracking line search.
        let mut step = 1.0_f64;
        let mut trials = 0;
        let (new_theta, new_loss, new_grad) = loop {
            trials += 1;
            let candidate: Vec<f32> = theta
                .iter()
                .zip(&direction)
                .map(|(&t, &d)| t + (step * d as f64) as f32)
                .collect();
            let (cl, cg) = objective.eval(&candidate)?;
            if cl <= loss + config.c1 * step * dir_deriv || trials >= config.max_ls {
                break (candidate, cl, cg);
            }
            step *= config.backtrack;
        };

        // Curvature update.
        let s: Vec<f32> = new_theta
            .iter()
            .zip(&theta)
            .map(|(&a, &b)| a - b)
            .collect();
        let y: Vec<f32> = new_grad.iter().zip(&grad).map(|(&a, &b)| a - b).collect();
        let sy = dot(&s, &y);
        if sy > 1e-10 {
            if s_hist.len() == config.history {
                s_hist.pop_front();
                y_hist.pop_front();
                rho_hist.pop_front();
            }
            rho_hist.push_back(1.0 / sy);
            s_hist.push_back(s);
            y_hist.push_back(y);
        }
        trace.push(LbfgsRecord {
            iter,
            loss: new_loss,
            grad_norm: inf_norm(&new_grad),
            step,
            ls_trials: trials,
        });
        theta = new_theta;
        loss = new_loss;
        grad = new_grad;
        if !loss.is_finite() {
            break;
        }
    }
    Ok((theta, trace))
}

#[cfg(test)]
mod tests {
    // Backend-driven tests live in rust/tests/integration_lbfgs.rs; here
    // we cover the pure vector helpers and a tiny analytic objective.
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(inf_norm(&[-3.0, 2.0]), 3.0);
        assert_eq!(inf_norm(&[]), 0.0);
    }

    #[test]
    fn default_config_sane() {
        let c = LbfgsConfig::default();
        assert!(c.history > 0 && c.c1 < 1.0 && c.backtrack < 1.0);
    }

    /// f(x) = Σ cᵢ xᵢ² — an ill-conditioned quadratic bowl.
    struct Quadratic {
        coeffs: Vec<f64>,
        evals: usize,
    }

    impl Objective for Quadratic {
        fn dim(&self) -> usize {
            self.coeffs.len()
        }
        fn eval(&mut self, theta: &[f32]) -> crate::Result<(f64, Vec<f32>)> {
            self.evals += 1;
            let mut loss = 0.0;
            let grad = theta
                .iter()
                .zip(&self.coeffs)
                .map(|(&x, &c)| {
                    loss += c * (x as f64) * (x as f64);
                    (2.0 * c * x as f64) as f32
                })
                .collect();
            Ok((loss, grad))
        }
        fn evals(&self) -> usize {
            self.evals
        }
    }

    #[test]
    fn minimizes_ill_conditioned_quadratic() {
        let mut obj = Quadratic {
            coeffs: vec![1.0, 10.0, 100.0, 1000.0],
            evals: 0,
        };
        let theta0 = vec![1.0_f32; 4];
        let (theta, trace) = minimize(&mut obj, theta0, &LbfgsConfig::default()).unwrap();
        assert!(!trace.is_empty());
        let final_loss = trace.last().unwrap().loss;
        assert!(final_loss < 1e-6, "final loss {final_loss}");
        assert!(theta.iter().all(|x| x.abs() < 1e-2));
        assert!(obj.evals() > 0);
    }
}
