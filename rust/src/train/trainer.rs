//! The backend-agnostic training loop.
//!
//! A [`Trainer`] opens a [`ModelExecutor`] for (model, loss, batch) on
//! any [`Backend`] and drives it:
//!
//! ```text
//! init(seed) ──► state ──► train_step(x, p, q, lr) ──► state' ─┐
//!                 ▲                                            │
//!                 └───────────────── every batch ◄─────────────┘
//! ```
//!
//! Where the state lives is the executor's business: host vectors on the
//! native backend, device-resident `PjRtBuffer`s on PJRT.  The trainer
//! owns the parts every backend shares — streaming epoch batching via
//! [`EpochSampler`] (stratified, deterministically reshuffled per
//! epoch), per-epoch validation AUC, validation-AUC early stopping,
//! best-checkpoint tracking, divergence cutoff, and host-side state
//! snapshots.  The batch buffers live on the trainer, so the epoch hot
//! loop performs no per-batch allocation after warm-up.
//!
//! On the native backend every `train_step`/`predict` call below runs
//! through the deterministic parallel engine (`runtime/engine.rs`,
//! DESIGN.md §7), so a [`Trainer::fit_stream`] run is bit-reproducible
//! from its seed at *any* thread count — the worker count is a pure
//! speed knob, never a result knob (`tests/proptest_engine.rs`).

use crate::data::{BatchPlan, DatasetSource, EpochSampler, Rng, SamplingMode};
use crate::losses::LossSpec;
use crate::metrics::auc;
use crate::runtime::{Backend, HostTensor, ModelExecutor};

use super::history::{EpochRecord, History};

/// Statistics from one training epoch.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub mean_loss: f64,
    pub n_batches: usize,
    pub n_examples: usize,
}

/// Options for the streaming epoch loop ([`Trainer::fit_stream`]).
#[derive(Debug, Clone, Copy)]
pub struct FitConfig {
    /// Learning rate.
    pub lr: f32,
    /// Maximum number of epochs.
    pub epochs: usize,
    /// Early stopping: stop once validation AUC has not improved for
    /// this many consecutive epochs (`None` = the paper's fixed-epoch
    /// protocol; best-checkpoint tracking runs either way).
    pub patience: Option<usize>,
    /// Mini-batch class-composition policy.
    pub sampling: SamplingMode,
    /// Model-init seed.
    pub seed: u32,
}

impl Default for FitConfig {
    fn default() -> Self {
        Self {
            lr: 0.01,
            epochs: 10,
            patience: None,
            sampling: SamplingMode::Preserve,
            seed: 0,
        }
    }
}

/// The max-validation-AUC checkpoint of a run.
#[derive(Debug, Clone)]
pub struct BestState {
    pub val_auc: f64,
    pub epoch: usize,
    /// Host snapshot, restorable via [`Trainer::load_state`] (or
    /// persistable via [`crate::train::checkpoint`]).
    pub state: Vec<HostTensor>,
}

/// Outcome of a streaming fit.
#[derive(Debug, Clone)]
pub struct FitOutcome {
    /// Per-epoch records (loss, validation AUC, wall time).
    pub history: History,
    /// Best checkpoint, `None` when validation AUC was never defined.
    pub best: Option<BestState>,
    /// Early stopping fired before the epoch budget was spent.
    pub stopped_early: bool,
    /// A non-finite training loss ended the run (paper: large learning
    /// rates overflow the pair sum).
    pub diverged: bool,
}

/// Drives one (model, loss, batch) run on an open backend.
pub struct Trainer<'b> {
    exec: Box<dyn ModelExecutor + 'b>,
    batch: usize,
    row_len: usize,
    // Reusable fixed-shape batch buffers (see module docs).
    buf_x: Vec<f32>,
    buf_pos: Vec<f32>,
    buf_neg: Vec<f32>,
}

impl<'b> Trainer<'b> {
    /// Open the (model, loss, batch) executor on `backend`.
    pub fn new(
        backend: &'b dyn Backend,
        model: &str,
        loss: &LossSpec,
        batch: usize,
    ) -> crate::Result<Self> {
        let exec = backend.open(model, loss, batch)?;
        let batch = exec.batch_size();
        let row_len = exec.row_len();
        Ok(Self {
            exec,
            batch,
            row_len,
            buf_x: vec![0.0; batch * row_len],
            buf_pos: vec![0.0; batch],
            buf_neg: vec![0.0; batch],
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn n_state(&self) -> usize {
        self.exec.n_state()
    }

    /// Initialize the training state from a seed.
    pub fn init(&mut self, seed: u32) -> crate::Result<()> {
        self.exec.init(seed)
    }

    /// One pass over a prepared epoch plan.  `source` may be resident
    /// or out-of-core ([`DatasetSource`]); the loss/gradient bits are
    /// identical either way (DESIGN.md §13).
    pub fn train_plan(
        &mut self,
        source: &dyn DatasetSource,
        plan: &BatchPlan,
        lr: f32,
    ) -> crate::Result<EpochStats> {
        anyhow::ensure!(
            source.row_len() == self.row_len,
            "dataset row length {} != executor {}",
            source.row_len(),
            self.row_len
        );
        anyhow::ensure!(
            plan.batch_size() == self.batch,
            "plan batch size {} != executor {}",
            plan.batch_size(),
            self.batch
        );
        let mut iter = source.batches(plan)?;
        let mut total_loss = 0.0;
        let mut n_batches = 0;
        let mut n_examples = 0;
        while let Some(count) =
            iter.fill_next(&mut self.buf_x, &mut self.buf_pos, &mut self.buf_neg)?
        {
            total_loss += self
                .exec
                .train_step(&self.buf_x, &self.buf_pos, &self.buf_neg, lr)?;
            n_batches += 1;
            n_examples += count;
        }
        Ok(EpochStats {
            mean_loss: if n_batches > 0 {
                total_loss / n_batches as f64
            } else {
                0.0
            },
            n_batches,
            n_examples,
        })
    }

    /// One plainly-shuffled epoch over `indices` of `source`.
    pub fn train_epoch(
        &mut self,
        source: &dyn DatasetSource,
        indices: &[u32],
        lr: f32,
        rng: &mut Rng,
    ) -> crate::Result<EpochStats> {
        let plan = BatchPlan::new(indices, self.batch, rng)?;
        self.train_plan(source, &plan, lr)
    }

    /// Predict scores for `indices` of `source`.
    ///
    /// The gather is chunked so host memory stays bounded regardless of
    /// the evaluation-set size (the executor handles any further
    /// chunking/padding its substrate needs); an out-of-core source
    /// reads each chunk straight from its shards.
    pub fn predict(
        &mut self,
        source: &dyn DatasetSource,
        indices: &[u32],
    ) -> crate::Result<Vec<f32>> {
        const GATHER_ROWS: usize = 1024;
        let row = source.row_len();
        anyhow::ensure!(row == self.row_len, "row length mismatch");
        let mut scores = Vec::with_capacity(indices.len());
        let mut x = vec![0.0f32; indices.len().min(GATHER_ROWS) * row];
        for chunk in indices.chunks(GATHER_ROWS) {
            let buf = &mut x[..chunk.len() * row];
            source.fetch_rows(chunk, buf)?;
            scores.extend(self.exec.predict(buf, chunk.len())?);
        }
        Ok(scores)
    }

    /// AUC of predictions over `indices` against the source labels.
    pub fn eval_auc(
        &mut self,
        source: &dyn DatasetSource,
        indices: &[u32],
    ) -> crate::Result<Option<f64>> {
        let scores = self.predict(source, indices)?;
        let all = source.labels();
        let labels: Vec<f32> = indices.iter().map(|&i| all[i as usize]).collect();
        Ok(auc(&scores, &labels))
    }

    /// The streaming epoch loop: stratified batches with a deterministic
    /// per-epoch reshuffle, per-epoch validation AUC, best-checkpoint
    /// tracking and (optional) validation-AUC early stopping.
    ///
    /// The trainer is left at its *final* state; restore the best
    /// checkpoint explicitly via `load_state(&outcome.best...state)`
    /// when evaluating test metrics (the paper's protocol).
    pub fn fit_stream(
        &mut self,
        source: &dyn DatasetSource,
        subtrain: &[u32],
        validation: &[u32],
        cfg: &FitConfig,
        rng: &mut Rng,
    ) -> crate::Result<FitOutcome> {
        anyhow::ensure!(
            source.row_len() == self.row_len,
            "dataset row length {} != executor {}",
            source.row_len(),
            self.row_len
        );
        self.init(cfg.seed)?;
        let mut sampler = EpochSampler::new(source.labels(), subtrain, self.batch, cfg.sampling)?;
        let mut history = History::new();
        let mut best: Option<BestState> = None;
        let mut stopped_early = false;
        let mut diverged = false;
        for epoch in 0..cfg.epochs {
            let t0 = std::time::Instant::now();
            let plan = sampler.epoch_plan(rng);
            let stats = self.train_plan(source, &plan, cfg.lr)?;
            if !stats.mean_loss.is_finite() {
                diverged = true;
                history.push(EpochRecord {
                    epoch,
                    train_loss: stats.mean_loss,
                    val_auc: None,
                    seconds: t0.elapsed().as_secs_f64(),
                });
                break;
            }
            let val_auc = if validation.is_empty() {
                None
            } else {
                self.eval_auc(source, validation)?
            };
            if let Some(v) = val_auc {
                let improved = best.as_ref().map(|b| v > b.val_auc).unwrap_or(true);
                if improved {
                    best = Some(BestState {
                        val_auc: v,
                        epoch,
                        state: self.state_to_host()?,
                    });
                }
            }
            history.push(EpochRecord {
                epoch,
                train_loss: stats.mean_loss,
                val_auc,
                seconds: t0.elapsed().as_secs_f64(),
            });
            if let Some(patience) = cfg.patience {
                if history.plateaued(patience) {
                    stopped_early = true;
                    break;
                }
            }
        }
        Ok(FitOutcome {
            history,
            best,
            stopped_early,
            diverged,
        })
    }

    /// Fixed-epoch run with per-epoch validation AUC (the pre-streaming
    /// entry point, kept for ad-hoc runs; [`Self::fit_stream`] exposes
    /// early stopping and checkpoint tracking).
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        &mut self,
        source: &dyn DatasetSource,
        subtrain: &[u32],
        validation: &[u32],
        lr: f32,
        epochs: usize,
        seed: u32,
        rng: &mut Rng,
    ) -> crate::Result<History> {
        let cfg = FitConfig {
            lr,
            epochs,
            patience: None,
            sampling: SamplingMode::Preserve,
            seed,
        };
        Ok(self
            .fit_stream(source, subtrain, validation, &cfg, rng)?
            .history)
    }

    /// Download the training state for checkpointing.
    pub fn state_to_host(&self) -> crate::Result<Vec<HostTensor>> {
        self.exec.state_to_host()
    }

    /// Restore a previously downloaded state.
    pub fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()> {
        self.exec.load_state(tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::runtime::{BackendSpec, NativeSpec};

    fn hinge() -> LossSpec {
        LossSpec::hinge()
    }

    fn toy_dataset(n: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let pos = rng.uniform() < 0.3;
            y.push(if pos { 1.0 } else { 0.0 });
            for d in 0..dim {
                let shift = if pos && d < 2 { 1.5 } else { 0.0 };
                x.push(rng.normal() as f32 + shift);
            }
        }
        Dataset::new(x, y, 0, dim)
    }

    fn native_backend(dim: usize) -> Box<dyn Backend> {
        BackendSpec::Native(NativeSpec {
            input_dim: dim,
            hidden: 8,
            threads: 1,
            ..NativeSpec::default()
        })
        .connect()
        .unwrap()
    }

    #[test]
    fn epoch_counts_batches_and_examples() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 8).unwrap();
        trainer.init(0).unwrap();
        let data = toy_dataset(25, 6, 1);
        let idx: Vec<u32> = (0..25).collect();
        let stats = trainer
            .train_epoch(&data, &idx, 0.01, &mut Rng::new(2))
            .unwrap();
        assert_eq!(stats.n_batches, 4); // 8 + 8 + 8 + 1
        assert_eq!(stats.n_examples, 25);
        assert!(stats.mean_loss.is_finite());
    }

    #[test]
    fn row_length_mismatch_is_error() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 8).unwrap();
        trainer.init(0).unwrap();
        let data = toy_dataset(10, 4, 3);
        let idx: Vec<u32> = (0..10).collect();
        assert!(trainer
            .train_epoch(&data, &idx, 0.01, &mut Rng::new(4))
            .is_err());
        assert!(trainer.predict(&data, &idx).is_err());
        assert!(trainer
            .fit_stream(&data, &idx, &idx, &FitConfig::default(), &mut Rng::new(4))
            .is_err());
    }

    #[test]
    fn fit_records_epochs_and_val_auc() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 16).unwrap();
        let data = toy_dataset(80, 6, 5);
        let idx: Vec<u32> = (0..80).collect();
        let history = trainer
            .fit(&data, &idx, &idx, 0.05, 3, 0, &mut Rng::new(6))
            .unwrap();
        assert_eq!(history.len(), 3);
        assert!(history.records.iter().all(|r| r.val_auc.is_some()));
    }

    #[test]
    fn fit_stream_tracks_best_checkpoint() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 16).unwrap();
        let data = toy_dataset(120, 6, 7);
        let idx: Vec<u32> = (0..120).collect();
        let cfg = FitConfig {
            lr: 0.05,
            epochs: 4,
            sampling: SamplingMode::Rebalance { pos_fraction: 0.5 },
            ..Default::default()
        };
        let outcome = trainer
            .fit_stream(&data, &idx, &idx, &cfg, &mut Rng::new(8))
            .unwrap();
        assert_eq!(outcome.history.len(), 4);
        assert!(!outcome.stopped_early);
        assert!(!outcome.diverged);
        let best = outcome.best.expect("val AUC defined on mixed-class data");
        assert_eq!(Some(best.val_auc), outcome.history.best_val_auc());
        assert_eq!(best.epoch, outcome.history.best_epoch().unwrap().epoch);
        // restoring the snapshot reproduces the best-epoch validation AUC
        trainer.load_state(&best.state).unwrap();
        let auc_restored = trainer.eval_auc(&data, &idx).unwrap().unwrap();
        assert_eq!(auc_restored, best.val_auc);
    }

    #[test]
    fn fit_stream_early_stops_on_plateau() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 16).unwrap();
        let data = toy_dataset(80, 6, 9);
        let idx: Vec<u32> = (0..80).collect();
        // lr = 0: the model never changes, so validation AUC never
        // improves after epoch 0 and patience-1 stopping fires at epoch 1.
        let cfg = FitConfig {
            lr: 0.0,
            epochs: 50,
            patience: Some(1),
            ..Default::default()
        };
        let outcome = trainer
            .fit_stream(&data, &idx, &idx, &cfg, &mut Rng::new(10))
            .unwrap();
        assert!(outcome.stopped_early);
        assert!(outcome.history.len() <= 3, "ran {} epochs", outcome.history.len());
    }

    #[test]
    fn fit_stream_is_deterministic_per_seed() {
        let backend = native_backend(6);
        let data = toy_dataset(100, 6, 11);
        let idx: Vec<u32> = (0..100).collect();
        let cfg = FitConfig {
            lr: 0.02,
            epochs: 3,
            sampling: SamplingMode::Rebalance { pos_fraction: 0.5 },
            ..Default::default()
        };
        let run = || {
            let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 16).unwrap();
            trainer
                .fit_stream(&data, &idx, &idx, &cfg, &mut Rng::new(12))
                .unwrap()
        };
        let a = run();
        let b = run();
        for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
            assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
            assert_eq!(ra.val_auc, rb.val_auc);
        }
    }

    #[test]
    fn predict_order_matches_indices() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", &hinge(), 8).unwrap();
        trainer.init(1).unwrap();
        let data = toy_dataset(30, 6, 7);
        let all: Vec<u32> = (0..30).collect();
        let scores = trainer.predict(&data, &all).unwrap();
        let head: Vec<u32> = vec![3, 7, 11];
        let subset = trainer.predict(&data, &head).unwrap();
        for (s, &i) in subset.iter().zip(&head) {
            assert_eq!(*s, scores[i as usize]);
        }
    }
}
