//! The training loop: one PJRT execution per step, state device-resident.
//!
//! A [`Trainer`] binds (runtime, model, loss, batch size) to the three
//! artifacts `init_*`, `train_*_bs<B>`, `predict_*_bs<P>` and drives them:
//!
//! ```text
//! init(seed) ──► state ──► train(state, x, p, q, lr) ──► state' ─┐
//!                 ▲                                              │
//!                 └──────────────── every batch ◄────────────────┘
//! ```
//!
//! The state tensors stay on the device as `PjRtBuffer`s between steps and
//! are passed to each execution *by reference* (PJRT borrows inputs; no
//! donation is configured, so they remain valid).  Only the scalar loss is
//! read back per batch, and scores per evaluation pass.

use xla::{Literal, PjRtBuffer};

use crate::data::{BatchPlan, Dataset, Rng};
use crate::metrics::auc;
use crate::runtime::{ArtifactKind, HostTensor, Manifest, Runtime};

use super::history::{EpochRecord, History};

/// Statistics from one training epoch.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub mean_loss: f64,
    pub n_batches: usize,
    pub n_examples: usize,
}

/// Drives init/train/predict artifacts for one (model, loss, batch) run.
pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    train_name: String,
    predict_name: String,
    init_name: String,
    batch: usize,
    predict_batch: usize,
    n_state: usize,
    row_len: usize,
    x_shape: Vec<i64>,
    /// Device-resident training state (params + optimizer slots).
    state: Option<Vec<PjRtBuffer>>,
}

impl<'rt> Trainer<'rt> {
    /// Resolve artifacts for (model, loss, batch) and validate signatures.
    pub fn new(
        runtime: &'rt Runtime,
        model: &str,
        loss: &str,
        batch: usize,
    ) -> crate::Result<Self> {
        let manifest = runtime.manifest();
        let train_name = Manifest::train_name(model, loss, batch);
        let train_art = manifest.get(&train_name)?.clone();
        anyhow::ensure!(train_art.kind == ArtifactKind::Train, "{train_name} kind");
        let predict_batch = manifest.predict_batch(model, loss)?;
        let predict_name = Manifest::predict_name(model, loss, predict_batch);
        let init_name = Manifest::init_name(model, loss);
        manifest.get(&init_name)?;
        manifest.get(&predict_name)?;

        let n_state = train_art.n_state;
        // x is the tensor right after the state block; its trailing dims
        // give the per-example row length.
        let x_sig = &train_art.inputs[n_state];
        anyhow::ensure!(x_sig.shape[0] == batch, "batch dim mismatch");
        let row_len: usize = x_sig.shape[1..].iter().product();
        let x_shape: Vec<i64> = x_sig.shape.iter().map(|&d| d as i64).collect();
        Ok(Self {
            runtime,
            train_name,
            predict_name,
            init_name,
            batch,
            predict_batch,
            n_state,
            row_len,
            x_shape,
            state: None,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn n_state(&self) -> usize {
        self.n_state
    }

    /// Initialize the training state from a seed (runs the init artifact).
    pub fn init(&mut self, seed: u32) -> crate::Result<()> {
        let seed_lit = Literal::scalar(seed);
        let outs = self.runtime.execute(&self.init_name, &[seed_lit])?;
        anyhow::ensure!(outs.len() == self.n_state, "init arity");
        // to_device_sync: the source literals are dropped at the end of
        // this function, so the async host→device copies must be forced.
        let buffers = outs
            .iter()
            .map(|lit| self.runtime.to_device_sync(lit))
            .collect::<crate::Result<Vec<_>>>()?;
        self.state = Some(buffers);
        Ok(())
    }

    fn state_ref(&self) -> crate::Result<&Vec<PjRtBuffer>> {
        self.state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("trainer not initialized; call init()"))
    }

    /// One gradient step on a filled batch.  Returns the batch loss.
    fn step(&mut self, x: &[f32], pos: &[f32], neg: &[f32], lr: f32) -> crate::Result<f64> {
        debug_assert_eq!(x.len(), self.batch * self.row_len);
        // The input literals MUST outlive the loss read-back below: the
        // host→device copies run asynchronously and are only guaranteed
        // complete once an output of the execution has been synchronized.
        let x_lit = Literal::vec1(x).reshape(&self.x_shape)?;
        let pos_lit = Literal::vec1(pos);
        let neg_lit = Literal::vec1(neg);
        let lr_lit = Literal::scalar(lr);
        let inputs = [
            self.runtime.to_device(&x_lit)?,
            self.runtime.to_device(&pos_lit)?,
            self.runtime.to_device(&neg_lit)?,
            self.runtime.to_device(&lr_lit)?,
        ];
        let mut outs = {
            let state = self.state_ref()?;
            let args: Vec<&PjRtBuffer> = state.iter().chain(inputs.iter()).collect();
            self.runtime.execute_buffers(&self.train_name, &args)?
        };
        anyhow::ensure!(outs.len() == self.n_state + 2, "train arity");
        let _scores = outs.pop().unwrap(); // per-batch scores unused here
        let loss_buf = outs.pop().unwrap();
        self.state = Some(outs);
        // Synchronizes the whole step (and thus the input copies).
        let loss = loss_buf.to_literal_sync()?.to_vec::<f32>()?[0] as f64;
        Ok(loss)
    }

    /// One shuffled epoch over `indices` of `dataset`.
    pub fn train_epoch(
        &mut self,
        dataset: &Dataset,
        indices: &[u32],
        lr: f32,
        rng: &mut Rng,
    ) -> crate::Result<EpochStats> {
        anyhow::ensure!(
            dataset.row_len() == self.row_len,
            "dataset row length {} != artifact {}",
            dataset.row_len(),
            self.row_len
        );
        let plan = BatchPlan::new(indices, self.batch, rng);
        let mut iter = plan.iter(dataset);
        let mut x = vec![0.0_f32; self.batch * self.row_len];
        let mut p = vec![0.0_f32; self.batch];
        let mut q = vec![0.0_f32; self.batch];
        let mut total_loss = 0.0;
        let mut n_batches = 0;
        let mut n_examples = 0;
        while let Some(count) = iter.fill_next(&mut x, &mut p, &mut q) {
            total_loss += self.step(&x, &p, &q, lr)?;
            n_batches += 1;
            n_examples += count;
        }
        Ok(EpochStats {
            mean_loss: if n_batches > 0 {
                total_loss / n_batches as f64
            } else {
                0.0
            },
            n_batches,
            n_examples,
        })
    }

    /// Predict scores for `indices` of `dataset` (chunked + padded).
    ///
    /// The predict artifact consumes only the model-parameter slots of
    /// the training state (`state_indices` in the manifest); optimizer
    /// slots are not uploaded.
    pub fn predict(&self, dataset: &Dataset, indices: &[u32]) -> crate::Result<Vec<f32>> {
        let state = self.state_ref()?;
        let row = dataset.row_len();
        anyhow::ensure!(row == self.row_len, "row length mismatch");
        let predict_art = self.runtime.manifest().get(&self.predict_name)?.clone();
        let selected: Vec<&PjRtBuffer> = predict_art.select_state(state);
        let pb = self.predict_batch;
        let mut x_shape = self.x_shape.clone();
        x_shape[0] = pb as i64;
        let mut scores = Vec::with_capacity(indices.len());
        let mut x_buf = vec![0.0_f32; pb * row];
        for chunk in indices.chunks(pb) {
            for (slot, &idx) in chunk.iter().enumerate() {
                x_buf[slot * row..(slot + 1) * row].copy_from_slice(dataset.row(idx as usize));
            }
            x_buf[chunk.len() * row..].fill(0.0);
            let x_lit = Literal::vec1(&x_buf).reshape(&x_shape)?;
            let x_dev = self.runtime.to_device(&x_lit)?;
            let args: Vec<&PjRtBuffer> = selected
                .iter()
                .copied()
                .chain(std::iter::once(&x_dev))
                .collect();
            let outs = self.runtime.execute_buffers(&self.predict_name, &args)?;
            let out = HostTensor::from_literal(&outs[0].to_literal_sync()?)?;
            scores.extend_from_slice(&out.data[..chunk.len()]);
        }
        Ok(scores)
    }

    /// AUC of predictions over `indices` against the dataset labels.
    pub fn eval_auc(&self, dataset: &Dataset, indices: &[u32]) -> crate::Result<Option<f64>> {
        let scores = self.predict(dataset, indices)?;
        let labels: Vec<f32> = indices.iter().map(|&i| dataset.y[i as usize]).collect();
        Ok(auc(&scores, &labels))
    }

    /// Full run: `epochs` epochs with per-epoch validation AUC.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        &mut self,
        dataset: &Dataset,
        subtrain: &[u32],
        validation: &[u32],
        lr: f32,
        epochs: usize,
        seed: u32,
        rng: &mut Rng,
    ) -> crate::Result<History> {
        self.init(seed)?;
        let mut history = History::new();
        for epoch in 0..epochs {
            let t0 = std::time::Instant::now();
            let stats = self.train_epoch(dataset, subtrain, lr, rng)?;
            let val_auc = if validation.is_empty() {
                None
            } else {
                self.eval_auc(dataset, validation)?
            };
            history.push(EpochRecord {
                epoch,
                train_loss: stats.mean_loss,
                val_auc,
                seconds: t0.elapsed().as_secs_f64(),
            });
            if !stats.mean_loss.is_finite() {
                break; // diverged (paper: large lr overflows the pair sum)
            }
        }
        Ok(history)
    }

    /// Download the training state for checkpointing.
    pub fn state_to_host(&self) -> crate::Result<Vec<HostTensor>> {
        self.state_ref()?
            .iter()
            .map(|b| HostTensor::from_literal(&b.to_literal_sync()?))
            .collect()
    }

    /// Restore a previously downloaded state.
    pub fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()> {
        anyhow::ensure!(tensors.len() == self.n_state, "state arity");
        let buffers = tensors
            .iter()
            // sync upload: the literal is a temporary dropped per-iteration
            .map(|t| self.runtime.to_device_sync(&t.to_literal()?))
            .collect::<crate::Result<Vec<_>>>()?;
        self.state = Some(buffers);
        Ok(())
    }
}
