//! The backend-agnostic training loop.
//!
//! A [`Trainer`] opens a [`ModelExecutor`] for (model, loss, batch) on
//! any [`Backend`] and drives it:
//!
//! ```text
//! init(seed) ──► state ──► train_step(x, p, q, lr) ──► state' ─┐
//!                 ▲                                            │
//!                 └───────────────── every batch ◄─────────────┘
//! ```
//!
//! Where the state lives is the executor's business: host vectors on the
//! native backend, device-resident `PjRtBuffer`s on PJRT.  The trainer
//! owns the parts every backend shares — epoch batching via
//! [`BatchPlan`], per-epoch validation AUC, divergence cutoff, and
//! host-side checkpoint snapshots.

use crate::data::{BatchPlan, Dataset, Rng};
use crate::metrics::auc;
use crate::runtime::{Backend, HostTensor, ModelExecutor};

use super::history::{EpochRecord, History};

/// Statistics from one training epoch.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub mean_loss: f64,
    pub n_batches: usize,
    pub n_examples: usize,
}

/// Drives one (model, loss, batch) run on an open backend.
pub struct Trainer<'b> {
    exec: Box<dyn ModelExecutor + 'b>,
    batch: usize,
    row_len: usize,
}

impl<'b> Trainer<'b> {
    /// Open the (model, loss, batch) executor on `backend`.
    pub fn new(
        backend: &'b dyn Backend,
        model: &str,
        loss: &str,
        batch: usize,
    ) -> crate::Result<Self> {
        let exec = backend.open(model, loss, batch)?;
        let batch = exec.batch_size();
        let row_len = exec.row_len();
        Ok(Self {
            exec,
            batch,
            row_len,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn n_state(&self) -> usize {
        self.exec.n_state()
    }

    /// Initialize the training state from a seed.
    pub fn init(&mut self, seed: u32) -> crate::Result<()> {
        self.exec.init(seed)
    }

    /// One shuffled epoch over `indices` of `dataset`.
    pub fn train_epoch(
        &mut self,
        dataset: &Dataset,
        indices: &[u32],
        lr: f32,
        rng: &mut Rng,
    ) -> crate::Result<EpochStats> {
        anyhow::ensure!(
            dataset.row_len() == self.row_len,
            "dataset row length {} != executor {}",
            dataset.row_len(),
            self.row_len
        );
        let plan = BatchPlan::new(indices, self.batch, rng);
        let mut iter = plan.iter(dataset);
        let mut x = vec![0.0_f32; self.batch * self.row_len];
        let mut p = vec![0.0_f32; self.batch];
        let mut q = vec![0.0_f32; self.batch];
        let mut total_loss = 0.0;
        let mut n_batches = 0;
        let mut n_examples = 0;
        while let Some(count) = iter.fill_next(&mut x, &mut p, &mut q) {
            total_loss += self.exec.train_step(&x, &p, &q, lr)?;
            n_batches += 1;
            n_examples += count;
        }
        Ok(EpochStats {
            mean_loss: if n_batches > 0 {
                total_loss / n_batches as f64
            } else {
                0.0
            },
            n_batches,
            n_examples,
        })
    }

    /// Predict scores for `indices` of `dataset`.
    ///
    /// The gather is chunked so host memory stays bounded regardless of
    /// the evaluation-set size (the executor handles any further
    /// chunking/padding its substrate needs).
    pub fn predict(&mut self, dataset: &Dataset, indices: &[u32]) -> crate::Result<Vec<f32>> {
        const GATHER_ROWS: usize = 1024;
        let row = dataset.row_len();
        anyhow::ensure!(row == self.row_len, "row length mismatch");
        let mut scores = Vec::with_capacity(indices.len());
        let mut x = Vec::with_capacity(indices.len().min(GATHER_ROWS) * row);
        for chunk in indices.chunks(GATHER_ROWS) {
            x.clear();
            for &idx in chunk {
                x.extend_from_slice(dataset.row(idx as usize));
            }
            scores.extend(self.exec.predict(&x, chunk.len())?);
        }
        Ok(scores)
    }

    /// AUC of predictions over `indices` against the dataset labels.
    pub fn eval_auc(&mut self, dataset: &Dataset, indices: &[u32]) -> crate::Result<Option<f64>> {
        let scores = self.predict(dataset, indices)?;
        let labels: Vec<f32> = indices.iter().map(|&i| dataset.y[i as usize]).collect();
        Ok(auc(&scores, &labels))
    }

    /// Full run: `epochs` epochs with per-epoch validation AUC.
    #[allow(clippy::too_many_arguments)]
    pub fn fit(
        &mut self,
        dataset: &Dataset,
        subtrain: &[u32],
        validation: &[u32],
        lr: f32,
        epochs: usize,
        seed: u32,
        rng: &mut Rng,
    ) -> crate::Result<History> {
        self.init(seed)?;
        let mut history = History::new();
        for epoch in 0..epochs {
            let t0 = std::time::Instant::now();
            let stats = self.train_epoch(dataset, subtrain, lr, rng)?;
            let val_auc = if validation.is_empty() {
                None
            } else {
                self.eval_auc(dataset, validation)?
            };
            history.push(EpochRecord {
                epoch,
                train_loss: stats.mean_loss,
                val_auc,
                seconds: t0.elapsed().as_secs_f64(),
            });
            if !stats.mean_loss.is_finite() {
                break; // diverged (paper: large lr overflows the pair sum)
            }
        }
        Ok(history)
    }

    /// Download the training state for checkpointing.
    pub fn state_to_host(&self) -> crate::Result<Vec<HostTensor>> {
        self.exec.state_to_host()
    }

    /// Restore a previously downloaded state.
    pub fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()> {
        self.exec.load_state(tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{BackendSpec, NativeSpec};

    fn toy_dataset(n: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let pos = rng.uniform() < 0.3;
            y.push(if pos { 1.0 } else { 0.0 });
            for d in 0..dim {
                let shift = if pos && d < 2 { 1.5 } else { 0.0 };
                x.push(rng.normal() as f32 + shift);
            }
        }
        Dataset::new(x, y, 0, dim)
    }

    fn native_backend(dim: usize) -> Box<dyn Backend> {
        BackendSpec::Native(NativeSpec {
            input_dim: dim,
            hidden: 8,
            margin: 1.0,
            threads: 1,
        })
        .connect()
        .unwrap()
    }

    #[test]
    fn epoch_counts_batches_and_examples() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", "hinge", 8).unwrap();
        trainer.init(0).unwrap();
        let data = toy_dataset(25, 6, 1);
        let idx: Vec<u32> = (0..25).collect();
        let stats = trainer
            .train_epoch(&data, &idx, 0.01, &mut Rng::new(2))
            .unwrap();
        assert_eq!(stats.n_batches, 4); // 8 + 8 + 8 + 1
        assert_eq!(stats.n_examples, 25);
        assert!(stats.mean_loss.is_finite());
    }

    #[test]
    fn row_length_mismatch_is_error() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", "hinge", 8).unwrap();
        trainer.init(0).unwrap();
        let data = toy_dataset(10, 4, 3);
        let idx: Vec<u32> = (0..10).collect();
        assert!(trainer
            .train_epoch(&data, &idx, 0.01, &mut Rng::new(4))
            .is_err());
        assert!(trainer.predict(&data, &idx).is_err());
    }

    #[test]
    fn fit_records_epochs_and_val_auc() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", "hinge", 16).unwrap();
        let data = toy_dataset(80, 6, 5);
        let idx: Vec<u32> = (0..80).collect();
        let history = trainer
            .fit(&data, &idx, &idx, 0.05, 3, 0, &mut Rng::new(6))
            .unwrap();
        assert_eq!(history.len(), 3);
        assert!(history.records.iter().all(|r| r.val_auc.is_some()));
    }

    #[test]
    fn predict_order_matches_indices() {
        let backend = native_backend(6);
        let mut trainer = Trainer::new(backend.as_ref(), "mlp", "hinge", 8).unwrap();
        trainer.init(1).unwrap();
        let data = toy_dataset(30, 6, 7);
        let all: Vec<u32> = (0..30).collect();
        let scores = trainer.predict(&data, &all).unwrap();
        let head: Vec<u32> = vec![3, 7, 11];
        let subset = trainer.predict(&data, &head).unwrap();
        for (s, &i) in subset.iter().zip(&head) {
            assert_eq!(*s, scores[i as usize]);
        }
    }
}
