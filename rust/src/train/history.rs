//! Per-epoch training records and model selection.
//!
//! The paper's protocol: train for a fixed number of epochs, evaluate
//! validation AUC each epoch, and select **the epoch with maximum
//! validation AUC** (section 4.2).  [`History::best_epoch`] implements
//! that selection; ties go to the earlier epoch (less overfitting).

/// Measurements from one training epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Mean per-batch training loss.
    pub train_loss: f64,
    /// Validation AUC (None when undefined, e.g. a single-class split).
    pub val_auc: Option<f64>,
    /// Wall-clock seconds spent in this epoch (train + eval).
    pub seconds: f64,
}

/// Append-only epoch log for one training run.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<EpochRecord>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: EpochRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The record with maximum validation AUC (earliest wins ties).
    pub fn best_epoch(&self) -> Option<&EpochRecord> {
        self.records
            .iter()
            .filter(|r| r.val_auc.is_some())
            .max_by(|a, b| {
                a.val_auc
                    .unwrap()
                    .partial_cmp(&b.val_auc.unwrap())
                    .unwrap()
                    // max_by keeps the *last* maximal element; reverse the
                    // epoch order so ties resolve to the earliest epoch.
                    .then(b.epoch.cmp(&a.epoch))
            })
    }

    /// Best validation AUC seen so far.
    pub fn best_val_auc(&self) -> Option<f64> {
        self.best_epoch().and_then(|r| r.val_auc)
    }

    /// Loss curve as (epoch, train_loss) pairs.
    pub fn loss_curve(&self) -> Vec<(usize, f64)> {
        self.records.iter().map(|r| (r.epoch, r.train_loss)).collect()
    }

    /// True if validation AUC has not improved in the last `patience`
    /// epochs (early-stopping predicate).
    pub fn plateaued(&self, patience: usize) -> bool {
        match self.best_epoch() {
            None => false,
            Some(best) => self
                .records
                .last()
                .map(|last| last.epoch.saturating_sub(best.epoch) >= patience)
                .unwrap_or(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(epoch: usize, auc: Option<f64>) -> EpochRecord {
        EpochRecord {
            epoch,
            train_loss: 1.0 / (epoch + 1) as f64,
            val_auc: auc,
            seconds: 0.1,
        }
    }

    #[test]
    fn best_epoch_is_max_val_auc() {
        let mut h = History::new();
        h.push(rec(0, Some(0.6)));
        h.push(rec(1, Some(0.9)));
        h.push(rec(2, Some(0.7)));
        assert_eq!(h.best_epoch().unwrap().epoch, 1);
        assert_eq!(h.best_val_auc(), Some(0.9));
    }

    #[test]
    fn ties_go_to_earliest() {
        let mut h = History::new();
        h.push(rec(0, Some(0.8)));
        h.push(rec(1, Some(0.8)));
        assert_eq!(h.best_epoch().unwrap().epoch, 0);
    }

    #[test]
    fn undefined_aucs_are_skipped() {
        let mut h = History::new();
        h.push(rec(0, None));
        h.push(rec(1, Some(0.55)));
        h.push(rec(2, None));
        assert_eq!(h.best_epoch().unwrap().epoch, 1);
        let empty = History::new();
        assert!(empty.best_epoch().is_none());
    }

    #[test]
    fn plateau_detection() {
        let mut h = History::new();
        h.push(rec(0, Some(0.9)));
        for e in 1..=4 {
            h.push(rec(e, Some(0.7)));
        }
        assert!(h.plateaued(4));
        assert!(!h.plateaued(5));
    }

    #[test]
    fn loss_curve_order() {
        let mut h = History::new();
        h.push(rec(0, Some(0.5)));
        h.push(rec(1, Some(0.6)));
        assert_eq!(h.loss_curve(), vec![(0, 1.0), (1, 0.5)]);
    }
}
