//! Binary checkpoints of the flat training state.
//!
//! Format (little-endian):
//!
//! ```text
//! magic   "APCK"            4 bytes
//! version u32               = 1
//! count   u32               number of tensors
//! per tensor:
//!   rank  u32
//!   dims  i64 * rank
//!   data  f32 * prod(dims)
//! ```
//!
//! The tensor order is the manifest's flat `tree_flatten` order, so a
//! checkpoint written by one run restores exactly into any trainer built
//! from the same (model, loss) artifacts.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::HostTensor;

const MAGIC: &[u8; 4] = b"APCK";
const VERSION: u32 = 1;

/// Write a state snapshot to `path`.
pub fn save(path: impl AsRef<Path>, tensors: &[HostTensor]) -> crate::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        for &v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(&buf)?;
    Ok(())
}

/// Read a state snapshot from `path`.
pub fn load(path: impl AsRef<Path>) -> crate::Result<Vec<HostTensor>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())?.read_to_end(&mut bytes)?;
    let mut cursor = 0usize;
    let take = |cursor: &mut usize, n: usize| -> crate::Result<&[u8]> {
        anyhow::ensure!(*cursor + n <= bytes.len(), "truncated checkpoint");
        let s = &bytes[*cursor..*cursor + n];
        *cursor += n;
        Ok(s)
    };
    anyhow::ensure!(take(&mut cursor, 4)? == MAGIC, "bad checkpoint magic");
    let version = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap());
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let count = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap()) as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap()) as usize;
        anyhow::ensure!(rank <= 8, "implausible rank {rank}");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(i64::from_le_bytes(take(&mut cursor, 8)?.try_into().unwrap()));
        }
        let elems: i64 = shape.iter().product();
        anyhow::ensure!(elems >= 0, "negative dims");
        let raw = take(&mut cursor, elems as usize * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        tensors.push(HostTensor::new(shape, data));
    }
    anyhow::ensure!(cursor == bytes.len(), "trailing bytes in checkpoint");
    Ok(tensors)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("allpairs_ckpt_{name}"))
    }

    #[test]
    fn roundtrip() {
        let tensors = vec![
            HostTensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            HostTensor::scalar(7.5),
            HostTensor::new(vec![0], vec![]),
        ];
        let p = tmp("roundtrip.bin");
        save(&p, &tensors).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let tensors = vec![HostTensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0])];
        let p = tmp("trunc.bin");
        save(&p, &tensors).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let tensors = vec![HostTensor::scalar(1.0)];
        let p = tmp("trail.bin");
        save(&p, &tensors).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
    }
}
