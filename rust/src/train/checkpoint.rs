//! Binary checkpoints of the flat training state.
//!
//! Format v2 (little-endian):
//!
//! ```text
//! magic   "APCK"            4 bytes
//! version u32               = 2
//! count   u32               number of tensors
//! per tensor:
//!   rank  u32
//!   dims  i64 * rank
//!   data  f32 * prod(dims)
//! crc32   u32               CRC-32 of every preceding byte
//! ```
//!
//! v1 is the same layout without the CRC footer; [`load`] reads both.
//! Writes go through [`crate::util::fsio::write_atomic`], so a crash
//! mid-save leaves the previous checkpoint intact rather than a torn
//! file; the CRC rejects corruption the rename protocol cannot see
//! (bit rot, truncation by a foreign tool, bad sectors).
//!
//! The tensor order is the manifest's flat `tree_flatten` order, so a
//! checkpoint written by one run restores exactly into any trainer built
//! from the same (model, loss) artifacts.

use std::io::Read;
use std::path::Path;

use crate::runtime::HostTensor;
use crate::util::crc32::crc32;

const MAGIC: &[u8; 4] = b"APCK";
const VERSION: u32 = 2;

/// Write a state snapshot to `path` (format v2, atomic replace).
pub fn save(path: impl AsRef<Path>, tensors: &[HostTensor]) -> crate::Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&d.to_le_bytes());
        }
        for &v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    crate::util::fsio::write_atomic(path, &buf)
}

/// Read a state snapshot from `path` (v1 or v2).
pub fn load(path: impl AsRef<Path>) -> crate::Result<Vec<HostTensor>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())?.read_to_end(&mut bytes)?;
    let mut cursor = 0usize;
    let take = |cursor: &mut usize, n: usize| -> crate::Result<&[u8]> {
        anyhow::ensure!(
            n <= bytes.len() - *cursor,
            "truncated checkpoint ({} bytes short)",
            n - (bytes.len() - *cursor)
        );
        let s = &bytes[*cursor..*cursor + n];
        *cursor += n;
        Ok(s)
    };
    anyhow::ensure!(take(&mut cursor, 4)? == MAGIC, "bad checkpoint magic");
    let version = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap());
    let body_len = match version {
        1 => bytes.len(),
        2 => {
            // Verify the CRC footer before trusting any header field.
            anyhow::ensure!(bytes.len() >= 12 + 4, "truncated checkpoint (no CRC footer)");
            let body_len = bytes.len() - 4;
            let stored = u32::from_le_bytes(bytes[body_len..].try_into().unwrap());
            let actual = crc32(&bytes[..body_len]);
            anyhow::ensure!(
                stored == actual,
                "checkpoint CRC mismatch (stored {stored:08x}, computed {actual:08x}): corrupt file"
            );
            body_len
        }
        other => anyhow::bail!("unsupported checkpoint version {other}"),
    };
    // lint:allow(unchecked-cast-in-parse): u32 -> usize is a widening cast on every target we build
    let count = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap()) as usize;
    let mut tensors = Vec::with_capacity(count.min(1024));
    for _ in 0..count {
        // lint:allow(unchecked-cast-in-parse): u32 -> usize widening; rank is bounds-checked below
        let rank = u32::from_le_bytes(take(&mut cursor, 4)?.try_into().unwrap()) as usize;
        anyhow::ensure!(rank <= 8, "implausible rank {rank}");
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(i64::from_le_bytes(take(&mut cursor, 8)?.try_into().unwrap()));
        }
        // Checked header math: adversarial dims must not overflow the
        // element product or the byte count before the bounds check.
        let mut elems: u64 = 1;
        for &d in &shape {
            anyhow::ensure!(d >= 0, "negative dim {d}");
            elems = elems
                // lint:allow(unchecked-cast-in-parse): d >= 0 ensured on the line above
                .checked_mul(d as u64)
                .ok_or_else(|| anyhow::anyhow!("tensor element count overflows ({shape:?})"))?;
        }
        let byte_len = elems
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("tensor byte count overflows ({shape:?})"))?;
        // Bound by the remaining payload *before* any conversion or
        // allocation, so a crafted header cannot trigger one.  (In a v2
        // file the dims reads could have crossed into the CRC footer.)
        anyhow::ensure!(cursor <= body_len, "tensor header crosses the CRC footer");
        anyhow::ensure!(
            // lint:allow(unchecked-cast-in-parse): usize -> u64 widening; cursor <= body_len above
            byte_len <= (body_len - cursor) as u64,
            "tensor claims {byte_len} bytes but only {} remain",
            body_len - cursor
        );
        // lint:allow(unchecked-cast-in-parse): byte_len <= remaining payload ensured just above
        let raw = take(&mut cursor, byte_len as usize)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        tensors.push(HostTensor::new(shape, data));
    }
    anyhow::ensure!(cursor == body_len, "trailing bytes in checkpoint");
    Ok(tensors)
}

// ---------------------------------------------------------------------------
// Change detection (serve hot-reload)
// ---------------------------------------------------------------------------

/// Identity stamp of a checkpoint file: length + mtime, plus the inode
/// on Unix.  [`save`] publishes through `write_atomic` — a fresh temp
/// file renamed over the path — so every publish lands on a new inode
/// (the temp is created while the old file still exists), making
/// back-to-back saves distinguishable even inside one mtime granule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileStamp {
    len: u64,
    mtime: Option<std::time::SystemTime>,
    #[cfg(unix)]
    ino: u64,
}

/// The stamp of `path`, or `None` while the file is missing/unreadable.
pub fn stamp(path: &Path) -> Option<FileStamp> {
    let meta = std::fs::metadata(path).ok()?;
    Some(FileStamp {
        len: meta.len(),
        mtime: meta.modified().ok(),
        #[cfg(unix)]
        ino: std::os::unix::fs::MetadataExt::ino(&meta),
    })
}

/// Polling change watcher over one checkpoint path — the serve
/// hot-reload trigger.  Each [`poll`](Watcher::poll) is one `stat`;
/// it reports `true` when the file's stamp changed since the last
/// observation.  A *missing* file is never a change: the atomic-rename
/// publish is the only transition the watcher reacts to, so a reader
/// that acts on `true` always finds a complete (CRC-checkable) file.
#[derive(Debug)]
pub struct Watcher {
    path: std::path::PathBuf,
    last: Option<FileStamp>,
}

impl Watcher {
    /// Prime the watcher with the current stamp: only *subsequent*
    /// publishes count as changes.
    pub fn new(path: impl Into<std::path::PathBuf>) -> Self {
        let path = path.into();
        let last = stamp(&path);
        Watcher { path, last }
    }

    /// The watched path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// One observation: `true` iff the file exists and its stamp
    /// differs from the previously observed one.
    pub fn poll(&mut self) -> bool {
        match stamp(&self.path) {
            None => false,
            Some(cur) => {
                let changed = self.last != Some(cur);
                self.last = Some(cur);
                changed
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("allpairs_ckpt_{}_{name}", std::process::id()))
    }

    /// Serialize in the pre-CRC v1 layout (what old checkpoints on disk
    /// look like).
    fn save_v1(path: &std::path::Path, tensors: &[HostTensor]) {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for t in tensors {
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            for &v in &t.data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, buf).unwrap();
    }

    fn sample() -> Vec<HostTensor> {
        vec![
            HostTensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            HostTensor::scalar(7.5),
            HostTensor::new(vec![0], vec![]),
        ]
    }

    #[test]
    fn roundtrip() {
        let tensors = sample();
        let p = tmp("roundtrip.bin");
        save(&p, &tensors).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn v1_checkpoints_still_load() {
        let tensors = sample();
        let p = tmp("v1.bin");
        save_v1(&p, &tensors);
        let back = load(&p).unwrap();
        assert_eq!(back, tensors);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.bin");
        std::fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let tensors = vec![HostTensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0])];
        let p = tmp("trunc.bin");
        save(&p, &tensors).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        let tensors = vec![HostTensor::scalar(1.0)];
        let p = tmp("trail.bin");
        save(&p, &tensors).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
    }

    #[test]
    fn rejects_unknown_version() {
        let p = tmp("v9.bin");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&9u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &buf).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
    }

    #[test]
    fn rejects_overflowing_header_dims() {
        // Regression: `shape.iter().product::<i64>()` wrapped on these
        // dims (2^62 * 4 = 2^64 ≡ 0), so the old loader accepted a
        // "tensor" claiming zero bytes of data for a 2^62-element shape
        // — and `elems as usize * 4` could wrap the byte count the same
        // way.  Checked math must reject both, without panicking.
        for dims in [
            vec![0x4000_0000_0000_0000_i64, 4],     // product wraps to 0
            vec![0x2000_0000_0000_0000_i64, 2, 4],  // likewise, rank 3
            vec![i64::MAX],                         // byte count overflows
            vec![1_000_000_000, 1_000_000_000],     // huge but no wrap: bound check
        ] {
            let mut buf: Vec<u8> = Vec::new();
            buf.extend_from_slice(MAGIC);
            buf.extend_from_slice(&1u32.to_le_bytes()); // v1: no CRC to fix up
            buf.extend_from_slice(&1u32.to_le_bytes()); // one tensor
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in &dims {
                buf.extend_from_slice(&d.to_le_bytes());
            }
            buf.extend_from_slice(&[0u8; 16]); // a little "data"
            let p = tmp("overflow.bin");
            std::fs::write(&p, &buf).unwrap();
            let loaded = load(&p);
            assert!(loaded.is_err(), "crafted dims {dims:?} must be rejected");
        }
    }

    #[test]
    fn watcher_detects_each_atomic_republish() {
        let p = tmp("watch.bin");
        let _ = std::fs::remove_file(&p);
        let mut w = Watcher::new(&p);
        assert_eq!(w.path(), p.as_path());
        assert!(!w.poll(), "missing file is not a change");
        save(&p, &sample()).unwrap();
        assert!(w.poll(), "first publish detected");
        assert!(!w.poll(), "stamp unchanged, no re-trigger");
        // Identical bytes republished: still a change — write_atomic
        // lands every publish on a fresh inode.
        save(&p, &sample()).unwrap();
        assert!(w.poll(), "republish of identical bytes detected");
        assert!(!w.poll());
    }

    #[test]
    fn watcher_primes_on_an_existing_checkpoint() {
        let p = tmp("watch_primed.bin");
        save(&p, &sample()).unwrap();
        let mut w = Watcher::new(&p);
        assert!(!w.poll(), "the pre-existing checkpoint is the baseline");
        save(&p, &[HostTensor::scalar(1.0)]).unwrap();
        assert!(w.poll());
        // Deleting the file is not a change; restoring it is.
        std::fs::remove_file(&p).unwrap();
        assert!(!w.poll(), "missing file: keep serving the old model");
        save(&p, &sample()).unwrap();
        assert!(w.poll());
    }

    #[test]
    fn crc_rejects_every_single_byte_corruption() {
        let tensors = sample();
        let p = tmp("bitflip.bin");
        save(&p, &tensors).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        for i in 0..bytes.len() {
            let mut corrupt = bytes.clone();
            corrupt[i] ^= 0x01;
            std::fs::write(&p, &corrupt).unwrap();
            assert!(load(&p).is_err(), "flip at byte {i}/{} accepted", bytes.len());
        }
        // and the pristine bytes still load
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(load(&p).unwrap(), tensors);
    }
}
