//! Training loop over PJRT artifacts.
//!
//! * [`trainer`] — [`trainer::Trainer`]: owns the training state as
//!   device-resident buffers and drives `init` / `train` / `predict`
//!   artifacts (one PJRT execution per step; Python is never involved).
//! * [`history`] — per-epoch records + the paper's max-validation-AUC
//!   epoch selection.
//! * [`checkpoint`] — binary snapshots of the flat training state.

//! * [`lbfgs`] — the paper's §5 future-work extension: deterministic
//!   full-batch L-BFGS over `grad_*` artifacts.

pub mod checkpoint;
pub mod history;
pub mod lbfgs;
pub mod trainer;

pub use history::{EpochRecord, History};
pub use trainer::Trainer;
