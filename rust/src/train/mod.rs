//! Training loop over pluggable backends.
//!
//! * [`trainer`] — [`trainer::Trainer`]: opens a
//!   [`crate::runtime::ModelExecutor`] on any [`crate::runtime::Backend`]
//!   and drives init / train-step / predict; state residency (host
//!   vectors vs device buffers) is the executor's concern.  Its
//!   [`trainer::Trainer::fit_stream`] entry point is the streaming
//!   epoch loop: stratified batches ([`crate::data::stream`]),
//!   validation-AUC early stopping and best-checkpoint tracking.
//! * [`history`] — per-epoch records + the paper's max-validation-AUC
//!   epoch selection.
//! * [`checkpoint`] — binary snapshots of the flat training state.
//! * [`lbfgs`] — the paper's §5 future-work extension: deterministic
//!   full-batch L-BFGS over an [`lbfgs::Objective`] oracle (native or
//!   `grad_*` artifacts).

pub mod checkpoint;
pub mod history;
pub mod lbfgs;
pub mod trainer;

pub use history::{EpochRecord, History};
pub use trainer::{BestState, FitConfig, FitOutcome, Trainer};
