//! Markdown table emitters (Table 2 and the Figure 3 companion table).

use crate::sweep::select::Cell;

/// Render rows + header as a GitHub-flavored markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push('|');
    for h in header {
        s.push_str(&format!(" {h} |"));
    }
    s.push('\n');
    s.push('|');
    for _ in header {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push('|');
        for cell in row {
            s.push_str(&format!(" {cell} |"));
        }
        s.push('\n');
    }
    s
}

/// The paper's Table 2: median selected (batch, lr) per cell.
pub fn table2(cells: &[Cell]) -> String {
    let header = [
        "Dataset", "Imratio", "Loss", "Batch (median)", "LR (median)", "Seeds",
    ];
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.dataset.clone(),
                format!("{}", c.imratio),
                c.loss.clone(),
                format!("{:.0}", c.median_batch),
                format!("{:.4}", c.median_lr),
                format!("{}", c.n_seeds),
            ]
        })
        .collect();
    markdown_table(&header, &rows)
}

/// Figure 3 as a table: test AUC mean ± sd per cell.
pub fn figure3_table(cells: &[Cell]) -> String {
    let header = ["Dataset", "Imratio", "Loss", "Test AUC (mean ± sd)", "Seeds"];
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.dataset.clone(),
                format!("{}", c.imratio),
                c.loss.clone(),
                format!("{:.4} ± {:.4}", c.test_auc.mean(), c.test_auc.std()),
                format!("{}", c.n_seeds),
            ]
        })
        .collect();
    markdown_table(&header, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Summary;

    #[test]
    fn markdown_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("| a |"));
        assert!(lines[1].starts_with("|---|"));
        assert!(lines[2].contains("| 1 |"));
    }

    #[test]
    fn table2_renders_cells() {
        let cells = vec![Cell {
            dataset: "synth-cifar".into(),
            imratio: 0.01,
            loss: "hinge".into(),
            median_batch: 500.0,
            median_lr: 0.0316,
            test_auc: Summary::from_values([0.8, 0.9]),
            n_seeds: 2,
        }];
        let t = table2(&cells);
        assert!(t.contains("synth-cifar"));
        assert!(t.contains("500"));
        assert!(t.contains("0.0316"));
        let f = figure3_table(&cells);
        assert!(f.contains("0.8500"));
    }
}
