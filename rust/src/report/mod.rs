//! Reporting: CSV series, markdown tables, ASCII log-log plots.
//!
//! Every paper table/figure has an emitter here; the examples and the CLI
//! write their outputs into `results/` via these functions so the formats
//! stay consistent between the smoke runs and the full reproduction.

pub mod figures;
pub mod table;

pub use figures::{ascii_loglog, write_csv};
pub use table::markdown_table;
