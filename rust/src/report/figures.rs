//! CSV series + ASCII log-log plots (Figure 2 / Figure 3 outputs).
//!
//! The CSVs are the canonical machine-readable outputs (EXPERIMENTS.md
//! references them); the ASCII plot gives an immediate visual check of
//! the Figure-2 claim (naive slope ≈ 2, functional slope ≈ 1) without
//! any plotting dependency.

use std::path::Path;

/// Write a CSV with the given header and rows.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> crate::Result<()> {
    let mut s = String::new();
    s.push_str(&header.join(","));
    s.push('\n');
    for row in rows {
        s.push_str(&row.join(","));
        s.push('\n');
    }
    // Atomic replace: a crash mid-write leaves the previous complete
    // CSV, never a torn one (DESIGN.md §10).
    crate::util::fsio::write_atomic(path, s.as_bytes())
}

/// A named (x, y) series for plotting.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Render series on a log-log ASCII grid (x: data size, y: seconds).
pub fn ascii_loglog(series: &[Series], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|&(x, y)| x > 0.0 && y > 0.0)
        .collect();
    if pts.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x.log10());
        x1 = x1.max(x.log10());
        y0 = y0.min(y.log10());
        y1 = y1.max(y.log10());
    }
    if (x1 - x0).abs() < 1e-9 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-9 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['n', 'N', 'f', 'F', 'l', 'x', '+', '*'];
    for (si, s) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in &s.points {
            if x <= 0.0 || y <= 0.0 {
                continue;
            }
            let cx = ((x.log10() - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y.log10() - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "log10(seconds) in [{y0:.1}, {y1:.1}] vs log10(n) in [{x0:.1}, {x1:.1}]\n"
    ));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()], s.name));
    }
    out
}

/// Least-squares slope of log10(y) vs log10(x) — the empirical complexity
/// exponent (Figure 2's asymptotic-slope claim).
pub fn loglog_slope(points: &[(f64, f64)]) -> f64 {
    let pts: Vec<(f64, f64)> = points
        .iter()
        .filter(|&&(x, y)| x > 0.0 && y > 0.0)
        .map(|&(x, y)| (x.log10(), y.log10()))
        .collect();
    let n = pts.len() as f64;
    if pts.len() < 2 {
        return f64::NAN;
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let p = std::env::temp_dir().join("allpairs_fig_test.csv");
        write_csv(
            &p,
            &["n", "seconds"],
            &[vec!["10".into(), "0.1".into()], vec!["100".into(), "1.0".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("n,seconds"));
    }

    #[test]
    fn slope_recovers_exponent() {
        // y = x^2 exactly → slope 2
        let pts: Vec<(f64, f64)> = (1..=6).map(|i| {
            let x = 10f64.powi(i);
            (x, x * x * 1e-9)
        }).collect();
        assert!((loglog_slope(&pts) - 2.0).abs() < 1e-9);
        // y = x → slope 1
        let pts: Vec<(f64, f64)> = (1..=6).map(|i| {
            let x = 10f64.powi(i);
            (x, x * 1e-9)
        }).collect();
        assert!((loglog_slope(&pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ascii_plot_contains_marks_and_legend() {
        let s = vec![Series {
            name: "naive".into(),
            points: vec![(10.0, 1e-5), (100.0, 1e-3), (1000.0, 1e-1)],
        }];
        let plot = ascii_loglog(&s, 40, 10);
        assert!(plot.contains('n'));
        assert!(plot.contains("naive"));
    }

    #[test]
    fn empty_series_safe() {
        assert_eq!(ascii_loglog(&[], 10, 5), "(no data)\n");
        assert!(loglog_slope(&[]).is_nan());
    }
}
