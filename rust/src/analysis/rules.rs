//! The invariant catalog: every rule is derived from a bug this repo
//! actually shipped (or the determinism argument that prevents one).
//! DESIGN.md §12 maps each rule to its motivation.
//!
//! Rules are *lexical*: a pattern is a short sequence of identifier /
//! punctuation tokens matched over the comment-stripped token stream,
//! scoped to the paths where the invariant holds.  That buys zero
//! dependencies and self-linting at the cost of type awareness — which
//! is why every rule's message names the escape hatch: a
//! `lint:allow(rule): reason` suppression, with the reason mandatory.

/// One element of a token pattern.
#[derive(Debug, Clone, Copy)]
pub enum Pat {
    /// An identifier with exactly this text.
    Ident(&'static str),
    /// An identifier matching any of these texts.
    AnyIdent(&'static [&'static str]),
    /// A single punctuation character.
    Punct(char),
}

/// Where a rule applies, matched against the `/`-normalized relative
/// path of each file.
#[derive(Debug, Clone, Copy)]
pub enum Scope {
    /// Every linted file.
    All,
    /// Every linted file except those matching one of the markers.
    AllExcept(&'static [&'static str]),
    /// Only files matching one of the markers.
    Paths(&'static [&'static str]),
}

impl Scope {
    /// A marker ending in `.rs` matches as a path suffix; any other
    /// marker matches as a substring (directory prefixes like
    /// `src/losses/`), so scoping works whether the scan root is the
    /// repo root or the crate root.
    fn marker_matches(path: &str, marker: &str) -> bool {
        if marker.ends_with(".rs") {
            path.ends_with(marker)
        } else {
            path.contains(marker)
        }
    }

    pub fn contains(&self, path: &str) -> bool {
        match self {
            Scope::All => true,
            Scope::AllExcept(markers) => !markers.iter().any(|m| Self::marker_matches(path, m)),
            Scope::Paths(markers) => markers.iter().any(|m| Self::marker_matches(path, m)),
        }
    }
}

/// A lint rule: name, scope, and the token patterns that fire it.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    /// Stable kebab-case id, used in findings and `lint:allow(...)`.
    pub name: &'static str,
    /// One-line description for `allpairs lint --list-rules`.
    pub summary: &'static str,
    /// Finding message: what is wrong and what to do instead.
    pub message: &'static str,
    pub scope: Scope,
    pub patterns: &'static [&'static [Pat]],
}

/// The meta-rule: a `lint:allow` comment whose reason is missing/empty,
/// or which names an unknown rule.  Implemented by the engine (it fires
/// on comment *content*, not code tokens), listed here so it shows up
/// in `--list-rules` and DESIGN.md stays the single catalog.
pub const ALLOW_NEEDS_REASON: &str = "lint-allow-needs-reason";

/// Every rule, in reporting order.
pub fn all_rules() -> &'static [Rule] {
    use Pat::{AnyIdent, Ident, Punct};
    const RULES: &[Rule] = &[
        Rule {
            name: "float-narrowing-in-kernel",
            summary: "no `as f32` on loss-kernel computation paths (PR 4 sort-key bug)",
            message: "`as f32` in a loss kernel: sweep and key math must stay f64 \
                      (an f32 sort key silently dropped near-margin pairs, PR 4); \
                      narrow only at the final store, with `lint:allow` + reason",
            scope: Scope::Paths(&["src/losses/"]),
            patterns: &[&[Ident("as"), Ident("f32")]],
        },
        Rule {
            name: "nondeterministic-iteration",
            summary: "no HashMap/HashSet on deterministic paths (hash order leaks)",
            message: "HashMap/HashSet on a deterministic path: hash iteration order \
                      can leak into results; use BTreeMap/BTreeSet or sorted keys \
                      (membership-only lookups need `lint:allow` + reason)",
            scope: Scope::Paths(&[
                "src/losses/",
                "src/runtime/",
                "src/coordinator/",
                "src/sweep/select.rs",
            ]),
            patterns: &[&[AnyIdent(&["HashMap", "HashSet"])]],
        },
        Rule {
            name: "raw-durable-write",
            summary: "durable writes go through util::fsio, never std::fs directly",
            message: "raw durable write: a crash here leaves a torn file; route the \
                      write through util::fsio::write_atomic (temp + fsync + rename, \
                      DESIGN.md \u{a7}10)",
            scope: Scope::AllExcept(&["src/util/fsio.rs"]),
            patterns: &[
                &[Ident("fs"), Punct(':'), Punct(':'), Ident("write")],
                &[Ident("File"), Punct(':'), Punct(':'), Ident("create")],
            ],
        },
        Rule {
            name: "lock-unwrap",
            summary: "no .lock().unwrap(): recover poisoned mutexes (PR 7 scheduler rule)",
            message: ".lock().unwrap() turns one panicking thread into a poison \
                      cascade; recover the guard (unwrap_or_else(|p| p.into_inner())) \
                      or propagate an error",
            scope: Scope::All,
            patterns: &[&[
                Punct('.'),
                Ident("lock"),
                Punct('('),
                Punct(')'),
                Punct('.'),
                Ident("unwrap"),
            ]],
        },
        Rule {
            name: "wallclock-in-kernel",
            summary: "no wall-clock reads in deterministic engine/loss code",
            message: "wall-clock read on a deterministic engine/loss path: timing \
                      belongs to the coordinator/bench layer, never inside code \
                      pinned bit-exact across thread counts (DESIGN.md \u{a7}7)",
            scope: Scope::Paths(&["src/losses/", "src/runtime/"]),
            patterns: &[
                &[Ident("Instant"), Punct(':'), Punct(':'), Ident("now")],
                &[Ident("SystemTime")],
            ],
        },
        Rule {
            name: "unchecked-cast-in-parse",
            summary: "no bare `as usize`/`as u64` when parsing untrusted input (PR 7)",
            message: "integer cast while parsing untrusted input: a crafted length \
                      can wrap or saturate (PR 7 checkpoint-header overflow); use \
                      checked math / try_into, or `lint:allow` + a safety argument",
            scope: Scope::Paths(&[
                "src/train/checkpoint.rs",
                "src/util/json.rs",
                "src/serve/protocol.rs",
                "src/serve/framing.rs",
                // Shard headers are untrusted bytes off disk, exactly
                // like checkpoint headers (`raw-durable-write` already
                // covers shard/ through its AllExcept scope).
                "src/data/shard/",
            ]),
            patterns: &[&[Ident("as"), AnyIdent(&["usize", "u64"])]],
        },
        Rule {
            name: ALLOW_NEEDS_REASON,
            summary: "every lint:allow carries a reason and names a real rule",
            message: "suppression without a reason: write \
                      `// lint:allow(rule): why this site is safe`",
            scope: Scope::All,
            patterns: &[], // implemented by the engine over comment content
        },
    ];
    RULES
}

/// Look up a rule by name (used to validate `lint:allow(...)` targets).
pub fn rule_named(name: &str) -> Option<&'static Rule> {
    all_rules().iter().find(|r| r.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_kebab_case() {
        let rules = all_rules();
        assert!(rules.len() >= 7, "six invariant rules + the meta-rule");
        for (i, r) in rules.iter().enumerate() {
            assert!(
                r.name
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'),
                "{} is not kebab-case",
                r.name
            );
            assert!(!r.summary.is_empty() && !r.message.is_empty());
            for other in &rules[i + 1..] {
                assert_ne!(r.name, other.name);
            }
        }
    }

    #[test]
    fn scope_markers_match_from_any_root() {
        let scope = Scope::Paths(&["src/losses/", "src/sweep/select.rs"]);
        // crate-root relative
        assert!(scope.contains("src/losses/functional.rs"));
        assert!(scope.contains("src/sweep/select.rs"));
        // repo-root relative
        assert!(scope.contains("rust/src/losses/functional.rs"));
        assert!(scope.contains("rust/src/sweep/select.rs"));
        // out of scope
        assert!(!scope.contains("src/sweep/scheduler.rs"));
        assert!(!scope.contains("src/metrics/auc.rs"));
    }

    #[test]
    fn all_except_excludes_only_the_markers() {
        let scope = Scope::AllExcept(&["src/util/fsio.rs"]);
        assert!(!scope.contains("rust/src/util/fsio.rs"));
        assert!(scope.contains("rust/src/util/bench.rs"));
        assert!(scope.contains("src/config.rs"));
    }

    #[test]
    fn meta_rule_is_registered() {
        assert!(rule_named(ALLOW_NEEDS_REASON).is_some());
        assert!(rule_named("no-such-rule").is_none());
    }
}
