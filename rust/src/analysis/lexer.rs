//! A minimal Rust lexer with byte-accurate spans.
//!
//! The rule engine ([`super::engine`]) matches invariants over *token*
//! sequences, never raw text, so the lexer's whole job is to classify
//! the tricky regions correctly: a raw string containing `as f32` must
//! never look like a cast, `'a'` (char) must not be confused with `'a`
//! (lifetime), and block comments nest.  It is deliberately lossy about
//! everything the rules never inspect — keywords are just identifiers,
//! numeric suffixes are part of the number — and it never fails: any
//! byte it cannot classify becomes a one-byte punctuation token, so a
//! half-written file still lints.

/// Token classes, at the granularity the rule engine needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`as`, `HashMap`, `r#ident`).
    Ident,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Character or byte literal (`'x'`, `'\n'`, `b'x'`).
    CharLit,
    /// String or byte-string literal (`"..."`, `b"..."`).
    StrLit,
    /// Raw (byte-)string literal (`r"..."`, `r#"..."#`, `br#"..."#`).
    RawStrLit,
    /// Numeric literal, including suffix (`1e-3`, `0x1F`, `1.0_f64`).
    NumLit,
    /// Line comment, including doc comments (`//`, `///`, `//!`).
    LineComment,
    /// Block comment, nested (`/* /* */ */`, `/** */`).
    BlockComment,
    /// Any other single character (`:`, `.`, `(`, ...).
    Punct,
}

/// One token with its half-open byte span into the source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token {
    pub kind: TokKind,
    /// Byte offset of the first byte.
    pub start: usize,
    /// Byte offset one past the last byte.
    pub end: usize,
}

impl Token {
    /// The token's text within `src` (the string it was lexed from).
    pub fn text<'s>(&self, src: &'s str) -> &'s str {
        &src[self.start..self.end]
    }
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src` into a complete token stream (whitespace dropped, comments
/// kept — the engine reads `lint:allow` suppressions out of them).
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { src, pos: 0 }.run()
}

struct Lexer<'s> {
    src: &'s str,
    pos: usize,
}

impl<'s> Lexer<'s> {
    fn run(mut self) -> Vec<Token> {
        let mut out = Vec::new();
        while let Some(c) = self.peek() {
            if c.is_whitespace() {
                self.bump(c);
                continue;
            }
            let start = self.pos;
            let kind = self.next_kind(c);
            debug_assert!(self.pos > start, "lexer must always advance");
            out.push(Token {
                kind,
                start,
                end: self.pos,
            });
        }
        out
    }

    fn peek(&self) -> Option<char> {
        self.src[self.pos..].chars().next()
    }

    fn peek_at(&self, byte_offset: usize) -> Option<char> {
        self.src.get(self.pos + byte_offset..)?.chars().next()
    }

    fn bump(&mut self, c: char) {
        self.pos += c.len_utf8();
    }

    /// Consume one token starting with `c`; returns its kind with
    /// `self.pos` advanced past it.
    fn next_kind(&mut self, c: char) -> TokKind {
        match c {
            '/' if self.peek_at(1) == Some('/') => self.line_comment(),
            '/' if self.peek_at(1) == Some('*') => self.block_comment(),
            '"' => self.string(),
            '\'' => self.char_or_lifetime(),
            'b' | 'r' if self.literal_prefix() => self.prefixed_literal(),
            _ if is_ident_start(c) => self.ident(),
            _ if c.is_ascii_digit() => self.number(),
            _ => {
                self.bump(c);
                TokKind::Punct
            }
        }
    }

    /// Does the `b`/`r` at the cursor start a string/char literal
    /// (`b"`, `b'`, `br#"`, `r"`, `r#"`) rather than an identifier?
    /// `r#ident` (raw identifier) is *not* a literal prefix.
    fn literal_prefix(&self) -> bool {
        let rest = &self.src[self.pos..];
        let raw_after = |p: &str| {
            rest.strip_prefix(p)
                .is_some_and(|r| r.trim_start_matches('#').starts_with('"'))
        };
        match rest.chars().next() {
            Some('b') => rest.starts_with("b\"") || rest.starts_with("b'") || raw_after("br"),
            Some('r') => raw_after("r"),
            _ => false,
        }
    }

    /// A literal known to start with `b"`, `b'`, `r`/`br` + hashes + `"`.
    fn prefixed_literal(&mut self) -> TokKind {
        if self.src[self.pos..].starts_with("b\"") {
            self.bump('b');
            return self.string();
        }
        if self.src[self.pos..].starts_with("b'") {
            self.bump('b');
            // a byte literal is always a char, never a lifetime
            self.bump('\'');
            self.char_body();
            return TokKind::CharLit;
        }
        // raw string: [b] r #* " ... " #*
        if self.peek() == Some('b') {
            self.bump('b');
        }
        self.bump('r');
        let mut hashes = 0usize;
        while self.peek() == Some('#') {
            self.bump('#');
            hashes += 1;
        }
        self.bump('"'); // literal_prefix guaranteed it
        loop {
            match self.peek() {
                None => break, // unterminated: run to EOF
                Some('"') => {
                    self.bump('"');
                    let tail = &self.src[self.pos..];
                    let closing = tail.chars().take_while(|&h| h == '#').count();
                    if closing >= hashes {
                        self.pos += hashes; // '#' is one byte
                        break;
                    }
                }
                Some(other) => self.bump(other),
            }
        }
        TokKind::RawStrLit
    }

    fn line_comment(&mut self) -> TokKind {
        while let Some(c) = self.peek() {
            if c == '\n' {
                break;
            }
            self.bump(c);
        }
        TokKind::LineComment
    }

    fn block_comment(&mut self) -> TokKind {
        self.bump('/');
        self.bump('*');
        let mut depth = 1usize;
        while depth > 0 {
            if self.src[self.pos..].starts_with("/*") {
                self.pos += 2;
                depth += 1;
            } else if self.src[self.pos..].starts_with("*/") {
                self.pos += 2;
                depth -= 1;
            } else if let Some(c) = self.peek() {
                self.bump(c);
            } else {
                break; // unterminated
            }
        }
        TokKind::BlockComment
    }

    fn string(&mut self) -> TokKind {
        self.bump('"');
        while let Some(c) = self.peek() {
            self.bump(c);
            match c {
                '"' => break,
                '\\' => {
                    if let Some(esc) = self.peek() {
                        self.bump(esc);
                    }
                }
                _ => {}
            }
        }
        TokKind::StrLit
    }

    /// Disambiguate `'a'` / `'\n'` / `'é'` (char literals) from `'a` /
    /// `'static` / `'_` (lifetimes).  A quote, one non-escape char and a
    /// closing quote is a char literal; a quote followed by an escape is
    /// always a char literal; anything else is a lifetime.
    fn char_or_lifetime(&mut self) -> TokKind {
        self.bump('\'');
        match self.peek() {
            Some('\\') => {
                self.char_body();
                TokKind::CharLit
            }
            Some(c) => {
                let close_at = c.len_utf8();
                if self.peek_at(close_at) == Some('\'') {
                    self.bump(c);
                    self.bump('\'');
                    TokKind::CharLit
                } else {
                    // lifetime: consume the identifier part, if any
                    while let Some(i) = self.peek() {
                        if is_ident_continue(i) {
                            self.bump(i);
                        } else {
                            break;
                        }
                    }
                    TokKind::Lifetime
                }
            }
            None => TokKind::Lifetime, // stray quote at EOF
        }
    }

    /// Body of a char literal after the opening quote, cursor past the
    /// closing quote on exit (handles `\n`, `\\`, `\u{1F600}`).
    fn char_body(&mut self) {
        while let Some(c) = self.peek() {
            self.bump(c);
            match c {
                '\'' => break,
                '\\' => {
                    if let Some(esc) = self.peek() {
                        self.bump(esc);
                    }
                }
                _ => {}
            }
        }
    }

    fn ident(&mut self) -> TokKind {
        // raw identifier prefix (`r#match`): literal_prefix() already
        // ruled out raw strings, so an `r#` here is an identifier
        if self.src[self.pos..].starts_with("r#") {
            self.pos += 2;
        }
        while let Some(c) = self.peek() {
            if is_ident_continue(c) {
                self.bump(c);
            } else {
                break;
            }
        }
        TokKind::Ident
    }

    /// Numbers including `0x1F`, `1_000`, `1e-3`, `1.5f32`; a trailing
    /// `.` that is not followed by a digit (ranges, method calls) is
    /// left for the next token.
    fn number(&mut self) -> TokKind {
        self.number_part();
        if self.peek() == Some('.') {
            if let Some(d) = self.peek_at(1) {
                if d.is_ascii_digit() {
                    self.bump('.');
                    self.number_part();
                }
            }
        }
        TokKind::NumLit
    }

    fn number_part(&mut self) {
        let mut prev = '\0';
        while let Some(c) = self.peek() {
            if is_ident_continue(c) {
                self.bump(c);
                prev = c;
            } else if (c == '+' || c == '-')
                && (prev == 'e' || prev == 'E')
                && self.peek_at(1).is_some_and(|d| d.is_ascii_digit())
            {
                self.bump(c);
                prev = c;
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, &str)> {
        lex(src).into_iter().map(|t| (t.kind, t.text(src))).collect()
    }

    #[test]
    fn idents_and_puncts() {
        assert_eq!(
            kinds("let x = y as f32;"),
            vec![
                (TokKind::Ident, "let"),
                (TokKind::Ident, "x"),
                (TokKind::Punct, "="),
                (TokKind::Ident, "y"),
                (TokKind::Ident, "as"),
                (TokKind::Ident, "f32"),
                (TokKind::Punct, ";"),
            ]
        );
    }

    #[test]
    fn strings_hide_their_content() {
        let toks = kinds(r#"let s = "y as f32"; t"#);
        assert_eq!(toks[3], (TokKind::StrLit, r#""y as f32""#));
        assert_eq!(toks[5], (TokKind::Ident, "t"));
    }

    #[test]
    fn raw_strings_with_hashes_and_quotes() {
        let src = r###"r#"contains "as f32" quoted"# after"###;
        let toks = kinds(src);
        assert_eq!(toks[0].0, TokKind::RawStrLit);
        assert_eq!(toks[0].1, r###"r#"contains "as f32" quoted"#"###);
        assert_eq!(toks[1], (TokKind::Ident, "after"));
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let toks = kinds(r##"b"as f32" br#"HashMap"# b'x' end"##);
        assert_eq!(toks[0].0, TokKind::StrLit);
        assert_eq!(toks[1].0, TokKind::RawStrLit);
        assert_eq!(toks[2].0, TokKind::CharLit);
        assert_eq!(toks[3], (TokKind::Ident, "end"));
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("x<'a> = 'a'; '\\n' 'static '_");
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Lifetime)
            .map(|(_, t)| *t)
            .collect();
        let chars: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::CharLit)
            .map(|(_, t)| *t)
            .collect();
        assert_eq!(lifetimes, vec!["'a", "'static", "'_"]);
        assert_eq!(chars, vec!["'a'", "'\\n'"]);
    }

    #[test]
    fn multibyte_char_literal() {
        let toks = kinds("let c = 'é'; x");
        assert_eq!(toks[3], (TokKind::CharLit, "'é'"));
        assert_eq!(toks[5], (TokKind::Ident, "x"));
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("a /* outer /* HashMap */ still comment */ b");
        assert_eq!(toks[0], (TokKind::Ident, "a"));
        assert_eq!(toks[1].0, TokKind::BlockComment);
        assert_eq!(toks[2], (TokKind::Ident, "b"));
    }

    #[test]
    fn line_comments_stop_at_newline() {
        let toks = kinds("a // as f32 HashMap\nb");
        assert_eq!(toks[0], (TokKind::Ident, "a"));
        assert_eq!(toks[1], (TokKind::LineComment, "// as f32 HashMap"));
        assert_eq!(toks[2], (TokKind::Ident, "b"));
    }

    #[test]
    fn numbers_do_not_eat_ranges_or_methods() {
        let toks = kinds("0..n 1.max(2) 1.5e-3_f64 0x1F");
        assert_eq!(toks[0], (TokKind::NumLit, "0"));
        assert_eq!(toks[1], (TokKind::Punct, "."));
        assert_eq!(toks[2], (TokKind::Punct, "."));
        assert_eq!(toks[3], (TokKind::Ident, "n"));
        assert_eq!(toks[4], (TokKind::NumLit, "1"));
        assert_eq!(toks[6], (TokKind::Ident, "max"));
        let nums: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::NumLit)
            .map(|(_, t)| *t)
            .collect();
        assert!(nums.contains(&"1.5e-3_f64"), "{nums:?}");
        assert!(nums.contains(&"0x1F"), "{nums:?}");
    }

    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let toks = kinds("r#match r#\"raw\"#");
        assert_eq!(toks[0], (TokKind::Ident, "r#match"));
        assert_eq!(toks[1].0, TokKind::RawStrLit);
    }

    #[test]
    fn spans_are_byte_accurate_around_multibyte() {
        let src = "é as f32";
        let toks = lex(src);
        assert_eq!(toks[0].text(src), "é");
        assert_eq!(toks[1].text(src), "as");
        assert_eq!(toks[1].start, 3, "é is 2 bytes + 1 space");
        assert_eq!(toks[2].text(src), "f32");
    }

    #[test]
    fn unterminated_inputs_do_not_hang_or_panic() {
        for src in ["\"open", "/* open /* nested", "r#\"open", "'", "b'"] {
            let toks = lex(src);
            assert!(!toks.is_empty(), "{src:?}");
            assert_eq!(toks.last().unwrap().end, src.len());
        }
    }
}
