//! `allpairs-lint`: an in-repo static-analysis pass that turns this
//! repo's shipped-bug postmortems into enforced invariants.
//!
//! The tool is deliberately small and dependency-free (the vendored-shim
//! policy applies to dev tooling too): a minimal Rust lexer with
//! byte-accurate spans ([`lexer`]), a catalog of path-scoped token-pattern
//! rules ([`rules`]), and an engine ([`engine`]) that applies them with
//! two escape hatches:
//!
//! - `#[cfg(test)]` items and `tests/` subtrees are exempt — test code
//!   may use HashMap, raw writes, wall clocks freely;
//! - an inline suppression comment silences one rule on its own line and
//!   the line below, and **must** carry a reason:
//!
//!   ```text
//!   // lint:allow(float-narrowing-in-kernel): f64 sweep ends here; final grad store is f32
//!   ```
//!
//!   A reasonless or unknown-rule suppression is itself a finding
//!   (`lint-allow-needs-reason`), so nothing can be grandfathered
//!   silently.
//!
//! Run it as `allpairs lint [--root DIR]`; exit status is nonzero when
//! any finding is reported, which is what the CI lint job keys on.
//! DESIGN.md §12 maps each rule to the bug class that motivates it.

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{lint_source, run_lint, Finding};
pub use rules::{all_rules, Rule};
