//! The rule engine: lex a file, carve out `#[cfg(test)]` regions,
//! collect `lint:allow` suppressions, then match every in-scope rule's
//! token patterns and report what survives.
//!
//! Suppression contract: `// lint:allow(rule-name): reason` silences
//! `rule-name` on the comment's own line and on the line directly
//! below it — so both trailing comments and own-line comments work.
//! The reason is mandatory; a reasonless or unknown-rule `lint:allow`
//! is itself a finding ([`super::rules::ALLOW_NEEDS_REASON`]), so
//! suppressions can never silently rot into a baseline.

use super::lexer::{lex, TokKind, Token};
use super::rules::{all_rules, rule_named, Pat, Rule, ALLOW_NEEDS_REASON};

/// One lint finding, formatted `file:line:col [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// 1-based column, counted in characters (multi-byte aware).
    pub col: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}:{} [{}] {}",
            self.path, self.line, self.col, self.rule, self.message
        )
    }
}

/// Is `rel_path` test code by location?  Integration tests, their
/// fixtures and bench/example-support trees under a `tests/` directory
/// are exempt from every rule, like `#[cfg(test)]` modules.
pub fn is_test_path(rel_path: &str) -> bool {
    rel_path.starts_with("tests/") || rel_path.contains("/tests/")
}

/// Lint one file's source.  `rel_path` is `/`-normalized and is only
/// used for rule scoping — it does not need to exist on disk (the
/// fixture tests feed synthetic paths).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    if is_test_path(rel_path) {
        return Vec::new();
    }
    let tokens = lex(src);
    let lines = LineIndex::new(src);
    let test_regions = test_regions(&tokens, src);
    let in_test = |byte: usize| test_regions.iter().any(|&(s, e)| byte >= s && byte < e);

    let mut findings = Vec::new();

    // Pass 1: suppressions (and the meta-rule) from comment content.
    let mut allows: Vec<(&'static str, usize)> = Vec::new();
    for tok in &tokens {
        if !matches!(tok.kind, TokKind::LineComment | TokKind::BlockComment) {
            continue;
        }
        let Some(spec) = allow_comment_body(tok, src) else {
            continue;
        };
        let (line, col) = lines.locate(tok.start);
        if in_test(tok.start) {
            continue; // test code is exempt, suppressions included
        }
        match parse_allow(spec) {
            Ok((rule, _reason)) => {
                // Valid: silences `rule` on this line and the next.
                allows.push((rule.name, line));
                allows.push((rule.name, line + 1));
            }
            Err(problem) => findings.push(Finding {
                path: rel_path.to_string(),
                line,
                col,
                rule: ALLOW_NEEDS_REASON,
                message: problem,
            }),
        }
    }

    // Pass 2: token patterns over the comment-stripped stream.
    let code: Vec<&Token> = tokens
        .iter()
        .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
        .collect();
    for rule in all_rules() {
        if rule.patterns.is_empty() || !rule.scope.contains(rel_path) {
            continue;
        }
        for pattern in rule.patterns {
            for window in code.windows(pattern.len()) {
                if !pattern_matches(pattern, window, src) {
                    continue;
                }
                let at = window[0].start;
                if in_test(at) {
                    continue;
                }
                let (line, col) = lines.locate(at);
                if allows.contains(&(rule.name, line)) {
                    continue;
                }
                findings.push(Finding {
                    path: rel_path.to_string(),
                    line,
                    col,
                    rule: rule.name,
                    message: rule.message.to_string(),
                });
            }
        }
    }
    findings.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    findings
}

/// Lint every `.rs` file under `root`, in sorted path order.  Skips
/// build output (`target/`), vendored code, and `.git`; `tests/`
/// subtrees are walked but exempted by [`is_test_path`].
pub fn run_lint(root: &std::path::Path) -> crate::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("lint: read {}: {e}", path.display()))?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

fn collect_rs_files(dir: &std::path::Path, out: &mut Vec<std::path::PathBuf>) -> crate::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("lint: walk {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("lint: walk {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if name == "target" || name == "vendor" || name == ".git" || name == "node_modules" {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn pattern_matches(pattern: &[Pat], window: &[&Token], src: &str) -> bool {
    pattern.iter().zip(window).all(|(pat, tok)| match pat {
        Pat::Ident(name) => tok.kind == TokKind::Ident && tok.text(src) == *name,
        Pat::AnyIdent(names) => tok.kind == TokKind::Ident && names.contains(&tok.text(src)),
        Pat::Punct(c) => tok.kind == TokKind::Punct && tok.text(src).starts_with(*c),
    })
}

/// If `tok` is a comment whose content *is* a `lint:allow` directive,
/// return the text after `lint:allow` (starting at `(`).  Prose that
/// merely mentions `lint:allow(...)` mid-sentence is not a directive.
fn allow_comment_body<'s>(tok: &Token, src: &'s str) -> Option<&'s str> {
    let mut body = tok.text(src);
    if tok.kind == TokKind::LineComment {
        body = body.trim_start_matches('/').trim_start_matches('!');
    } else {
        body = body
            .trim_start_matches('/')
            .trim_start_matches('*')
            .trim_start_matches('!')
            .trim_end_matches('/')
            .trim_end_matches('*');
    }
    body.trim().strip_prefix("lint:allow")
}

/// Parse `(rule-name): reason` → the rule and its reason, or a
/// human-readable description of what is wrong.
fn parse_allow(spec: &str) -> Result<(&'static Rule, &str), String> {
    let inner = spec
        .strip_prefix('(')
        .and_then(|s| s.split_once(')'))
        .ok_or_else(|| "malformed suppression: write `lint:allow(rule): reason`".to_string())?;
    let (name, rest) = (inner.0.trim(), inner.1);
    let rule = rule_named(name).ok_or_else(|| {
        let known: Vec<&str> = all_rules().iter().map(|r| r.name).collect();
        format!("`lint:allow({name})` names an unknown rule (known: {})", known.join(", "))
    })?;
    let reason = rest.trim().strip_prefix(':').map(str::trim).unwrap_or("");
    if reason.is_empty() {
        return Err(format!(
            "suppression without a reason: write `lint:allow({name}): why this site is safe`"
        ));
    }
    Ok((rule, reason))
}

/// Byte ranges covered by `#[cfg(test)]` items (usually `mod tests`).
/// The range starts at the attribute's `#` and ends after the item's
/// closing `}` (or `;` for brace-less items), so everything inside an
/// exempted module — including nested attributes — is exempt.
fn test_regions(tokens: &[Token], src: &str) -> Vec<(usize, usize)> {
    let code: Vec<&Token> = tokens
        .iter()
        .filter(|t| !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment))
        .collect();
    let mut regions = Vec::new();
    let mut i = 0;
    while i < code.len() {
        if !is_attr_start(&code, i, src) {
            i += 1;
            continue;
        }
        let attr_start = code[i].start;
        let Some(attr_end) = matching_bracket(&code, i + 1, '[', ']', src) else {
            break; // malformed attribute: nothing more to find
        };
        if !attr_mentions_cfg_test(&code[i + 2..attr_end], src) {
            i = attr_end + 1;
            continue;
        }
        // Skip any further attributes between #[cfg(test)] and the item.
        let mut j = attr_end + 1;
        while is_attr_start(&code, j, src) {
            match matching_bracket(&code, j + 1, '[', ']', src) {
                Some(end) => j = end + 1,
                None => return regions,
            }
        }
        // The item runs to its first top-level `{...}` block, or to a
        // `;` if none opens first (e.g. `#[cfg(test)] use ...;`).
        let mut depth = 0usize;
        let mut item_end = None;
        for (k, tok) in code.iter().enumerate().skip(j) {
            if tok.kind != TokKind::Punct {
                continue;
            }
            match tok.text(src).chars().next() {
                Some('{') | Some('(') | Some('[') => depth += 1,
                Some('}') | Some(')') | Some(']') => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 && tok.text(src).starts_with('}') {
                        item_end = Some((k, tok.end));
                        break;
                    }
                }
                Some(';') if depth == 0 => {
                    item_end = Some((k, tok.end));
                    break;
                }
                _ => {}
            }
        }
        match item_end {
            Some((k, end_byte)) => {
                regions.push((attr_start, end_byte));
                i = k + 1;
            }
            None => {
                // Unterminated item: exempt to EOF.
                regions.push((attr_start, src.len()));
                break;
            }
        }
    }
    regions
}

fn is_attr_start(code: &[&Token], i: usize, src: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text(src) == "#")
        && code.get(i + 1).is_some_and(|t| t.kind == TokKind::Punct && t.text(src) == "[")
}

/// Index of the token closing the bracket opened at `open_idx`.
fn matching_bracket(
    code: &[&Token],
    open_idx: usize,
    open: char,
    close: char,
    src: &str,
) -> Option<usize> {
    let mut depth = 0usize;
    for (k, tok) in code.iter().enumerate().skip(open_idx) {
        if tok.kind != TokKind::Punct {
            continue;
        }
        let c = tok.text(src).chars().next()?;
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Do the attribute's inner tokens contain both `cfg` and `test`?
/// Loose on purpose: `#[cfg(test)]` and `#[cfg(all(test, ...))]` both
/// count, and a false positive only widens an exemption (conservative
/// in the safe direction for an attribute that names `test`).
fn attr_mentions_cfg_test(inner: &[&Token], src: &str) -> bool {
    let has = |name: &str| {
        inner
            .iter()
            .any(|t| t.kind == TokKind::Ident && t.text(src) == name)
    };
    has("cfg") && has("test")
}

/// Line-start index for byte→(line, col) conversion; columns count
/// characters, so a finding after multi-byte UTF-8 still points at the
/// column an editor shows.
struct LineIndex<'s> {
    src: &'s str,
    starts: Vec<usize>,
}

impl<'s> LineIndex<'s> {
    fn new(src: &'s str) -> Self {
        let mut starts = vec![0usize];
        for (i, b) in src.bytes().enumerate() {
            if b == b'\n' {
                starts.push(i + 1);
            }
        }
        Self { src, starts }
    }

    fn locate(&self, byte: usize) -> (usize, usize) {
        let line = match self.starts.binary_search(&byte) {
            Ok(i) => i,
            Err(i) => i - 1,
        };
        let col = self.src[self.starts[line]..byte].chars().count() + 1;
        (line + 1, col)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find(path: &str, src: &str) -> Vec<(usize, usize, &'static str)> {
        lint_source(path, src)
            .into_iter()
            .map(|f| (f.line, f.col, f.rule))
            .collect()
    }

    #[test]
    fn fires_with_exact_line_and_col() {
        let src = "fn kernel(y: f64) -> f32 {\n    y as f32\n}\n";
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(2, 7, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn out_of_scope_paths_are_silent() {
        let src = "fn f(y: f64) -> f32 { y as f32 }\n";
        assert!(find("src/metrics/auc.rs", src).is_empty());
    }

    #[test]
    fn string_and_comment_content_never_fires() {
        let src = concat!(
            "// as f32 in a comment\n",
            "/* HashMap in /* a nested */ comment */\n",
            "const S: &str = \"Instant::now as f32\";\n",
            "const R: &str = r#\"std::fs::write('a') HashMap\"#;\n",
        );
        assert!(find("src/losses/fake.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_module_is_exempt() {
        let src = concat!(
            "pub fn prod(y: f64) -> f32 {\n    y as f32\n}\n",
            "#[cfg(test)]\nmod tests {\n",
            "    fn helper(y: f64) -> f32 { y as f32 }\n",
            "    use std::collections::HashMap;\n",
            "}\n",
        );
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(2, 7, "float-narrowing-in-kernel")], "only the non-test cast");
    }

    #[test]
    fn code_after_a_test_module_is_linted_again() {
        let src = concat!(
            "#[cfg(test)]\nmod tests {\n    fn h(y: f64) -> f32 { y as f32 }\n}\n",
            "pub fn prod(y: f64) -> f32 {\n    y as f32\n}\n",
        );
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(6, 7, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn trailing_allow_with_reason_suppresses() {
        let src = concat!(
            "fn f(y: f64) -> f32 {\n",
            "    y as f32 // lint:allow(float-narrowing-in-kernel): final store\n",
            "}\n",
        );
        assert!(find("src/losses/fake.rs", src).is_empty());
    }

    #[test]
    fn preceding_line_allow_suppresses() {
        let src = concat!(
            "fn f(y: f64) -> f32 {\n",
            "    // lint:allow(float-narrowing-in-kernel): final store\n",
            "    y as f32\n}\n",
        );
        assert!(find("src/losses/fake.rs", src).is_empty());
    }

    #[test]
    fn allow_does_not_leak_past_the_next_line() {
        let src = concat!(
            "fn f(a: f64, b: f64) -> (f32, f32) {\n",
            "    // lint:allow(float-narrowing-in-kernel): only the next line\n",
            "    let x = a as f32;\n",
            "    let y = b as f32;\n",
            "    (x, y)\n}\n",
        );
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(4, 15, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn allow_for_a_different_rule_does_not_suppress() {
        let src = "fn f(y: f64) -> f32 {\n    y as f32 // lint:allow(lock-unwrap): wrong rule\n}\n";
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(2, 7, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn reasonless_allow_is_a_finding() {
        let src = "// lint:allow(float-narrowing-in-kernel)\nfn f() {}\n";
        let got = find("src/anything.rs", src);
        assert_eq!(got, vec![(1, 1, ALLOW_NEEDS_REASON)]);
    }

    #[test]
    fn unknown_rule_in_allow_is_a_finding() {
        let src = "// lint:allow(made-up-rule): sounds legit\nfn f() {}\n";
        let got = find("src/anything.rs", src);
        assert_eq!(got, vec![(1, 1, ALLOW_NEEDS_REASON)]);
        let msg = &lint_source("src/anything.rs", src)[0].message;
        assert!(msg.contains("made-up-rule"), "{msg}");
    }

    #[test]
    fn prose_mentioning_lint_allow_is_not_a_directive() {
        let src = "//! Suppress with `// lint:allow(rule): reason` comments.\nfn f() {}\n";
        assert!(find("src/anything.rs", src).is_empty());
    }

    #[test]
    fn multibyte_utf8_columns_are_character_accurate() {
        // "é" is 2 bytes, 1 character: a byte-counting column would be 16.
        let src = "fn f() { let é = x as f32; }\n";
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(1, 20, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn lifetime_tick_does_not_derail_later_matches() {
        let src = "fn f<'a>(y: &'a f64) -> f32 {\n    *y as f32\n}\n";
        let got = find("src/losses/fake.rs", src);
        assert_eq!(got, vec![(2, 8, "float-narrowing-in-kernel")]);
    }

    #[test]
    fn every_invariant_rule_pattern_fires_somewhere() {
        let cases: &[(&str, &str, &str)] = &[
            ("float-narrowing-in-kernel", "src/losses/x.rs", "let k = y as f32;"),
            ("nondeterministic-iteration", "src/runtime/x.rs", "let m = HashMap::new();"),
            ("nondeterministic-iteration", "src/coordinator/x.rs", "let s: HashSet<u32>;"),
            ("raw-durable-write", "src/report/x.rs", "std::fs::write(p, b)?;"),
            ("raw-durable-write", "src/report/x.rs", "let f = File::create(p)?;"),
            ("lock-unwrap", "src/anywhere.rs", "let g = m.lock().unwrap();"),
            ("wallclock-in-kernel", "src/runtime/x.rs", "let t = Instant::now();"),
            ("wallclock-in-kernel", "src/losses/x.rs", "let t: SystemTime;"),
            ("unchecked-cast-in-parse", "src/util/json.rs", "let n = x as usize;"),
            ("unchecked-cast-in-parse", "src/train/checkpoint.rs", "let n = d as u64;"),
        ];
        for (rule, path, src) in cases {
            let got = lint_source(path, src);
            assert!(
                got.iter().any(|f| f.rule == *rule),
                "{rule} did not fire on {src:?} at {path}: {got:?}"
            );
        }
    }

    #[test]
    fn fsio_itself_may_create_files() {
        let src = "let f = std::fs::File::create(&tmp)?;";
        assert!(find("rust/src/util/fsio.rs", src).is_empty());
        assert_eq!(find("rust/src/util/bench.rs", src).len(), 1);
    }

    #[test]
    fn tests_directories_are_exempt_wholesale() {
        let src = "std::fs::write(p, b).unwrap(); let m = HashMap::new();";
        assert!(find("tests/crash_safety.rs", src).is_empty());
        assert!(find("rust/tests/fixtures/lint/x.rs", src).is_empty());
    }

    #[test]
    fn findings_sort_by_position() {
        let src = "fn f(m: &M) {\n    let t = Instant::now();\n    let h = HashMap::new();\n}\n";
        let got = find("src/runtime/x.rs", src);
        assert_eq!(
            got,
            vec![(2, 13, "wallclock-in-kernel"), (3, 13, "nondeterministic-iteration")]
        );
    }
}
