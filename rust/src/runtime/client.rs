//! The PJRT runtime: compile-once, execute-many artifact host.
//!
//! One [`Runtime`] owns a `PjRtClient` (CPU) and a lazy cache of compiled
//! executables keyed by artifact name.  `PjRtClient` is `Rc`-based, so a
//! `Runtime` is intentionally `!Send` — the sweep scheduler creates one
//! per worker thread.
//!
//! ## Output handling
//!
//! All artifacts are lowered with `return_tuple=True`, so the HLO root is
//! a tuple.  Depending on the PJRT plugin version the execute API either
//! unpacks the root tuple into one buffer per leaf, or returns a single
//! tuple buffer.  [`Runtime::execute`] normalizes both cases to a flat
//! `Vec<Literal>` (checked against the manifest's `n_outputs`), and
//! [`Runtime::execute_buffers`] does the same at the buffer level for the
//! device-resident hot path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::Manifest;

/// A PJRT CPU client plus a compiled-executable cache over a manifest.
pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn executable(&self, name: &str) -> crate::Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let artifact = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&artifact.path)?;
        let computation = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&computation)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute by name with literal inputs; returns flat output literals.
    /// Accepts owned or borrowed literals (the C++ side synchronously
    /// awaits the input transfers, so borrowed inputs are safe here).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> crate::Result<Vec<Literal>> {
        let n_outputs = self.manifest.get(name)?.n_outputs;
        let exe = self.executable(name)?;
        let mut results = exe.execute(args)?;
        Self::normalize_outputs(&mut results, n_outputs)
    }

    /// Execute with device-resident buffers; returns flat output buffers
    /// when the plugin unpacks the root tuple, otherwise falls back to a
    /// literal round-trip (correct either way, slower on old plugins).
    /// Accepts borrowed buffers so callers can chain state without copies.
    pub fn execute_buffers<L: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        name: &str,
        args: &[L],
    ) -> crate::Result<Vec<PjRtBuffer>> {
        let n_outputs = self.manifest.get(name)?.n_outputs;
        let exe = self.executable(name)?;
        let results = exe.execute_b(args)?;
        let first: Vec<PjRtBuffer> = results
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no results from {name}"))?;
        // The CPU plugin untuples multi-leaf root tuples into one buffer
        // per leaf, but a single-leaf root arrives as one *tuple* buffer
        // (observed empirically; load_hlo in /opt/xla-example relies on
        // the same behaviour).  Only trust an arity match when the buffer
        // is not itself a tuple.
        if first.len() == n_outputs {
            let tupled = n_outputs == 1
                && matches!(first[0].on_device_shape(), Ok(xla::Shape::Tuple(_)));
            if !tupled {
                return Ok(first);
            }
        }
        // Root tuple not unpacked: round-trip through literals and rebuffer.
        anyhow::ensure!(
            first.len() == 1,
            "{name}: unexpected output arity {} (want {n_outputs})",
            first.len()
        );
        let mut tuple = first[0].to_literal_sync()?;
        let leaves = tuple.decompose_tuple()?;
        anyhow::ensure!(
            leaves.len() == n_outputs,
            "{name}: tuple arity {} (want {n_outputs})",
            leaves.len()
        );
        leaves
            .iter()
            .map(|lit| {
                let buffer = self.client.buffer_from_host_literal(None, lit)?;
                // Force the async host→device copy before `leaves` drops.
                let _ = buffer.to_literal_sync()?;
                Ok(buffer)
            })
            .collect()
    }

    /// Upload a literal to the device.
    ///
    /// SAFETY CONTRACT: `buffer_from_host_literal` enqueues the host→device
    /// copy on a worker thread; the caller must keep `lit` alive until the
    /// copy is forced (by executing with the buffer and synchronizing on an
    /// output, or via [`Runtime::to_device_sync`]).  Dropping the literal
    /// early is a use-after-free inside the PJRT plugin.
    pub fn to_device(&self, lit: &Literal) -> crate::Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Upload and block until the device copy completed, so the source
    /// literal may be dropped immediately afterwards.  (The only
    /// readiness-forcing operation this PJRT API exposes is a read-back,
    /// so this costs one extra device→host copy — use on cold paths.)
    pub fn to_device_sync(&self, lit: &Literal) -> crate::Result<PjRtBuffer> {
        let buffer = self.client.buffer_from_host_literal(None, lit)?;
        let _ = buffer.to_literal_sync()?;
        Ok(buffer)
    }

    fn normalize_outputs(
        results: &mut Vec<Vec<PjRtBuffer>>,
        n_outputs: usize,
    ) -> crate::Result<Vec<Literal>> {
        let first = results
            .drain(..)
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty execution result"))?;
        if first.len() == n_outputs && n_outputs != 1 {
            return first.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        anyhow::ensure!(first.len() == 1, "unexpected output arity {}", first.len());
        let mut lit = first[0].to_literal_sync()?;
        // return_tuple=True means even single outputs arrive as a 1-tuple,
        // unless the plugin already unpacked it.
        match lit.decompose_tuple() {
            Ok(leaves) => {
                anyhow::ensure!(
                    leaves.len() == n_outputs,
                    "tuple arity {} (want {n_outputs})",
                    leaves.len()
                );
                Ok(leaves)
            }
            Err(_) if n_outputs == 1 => Ok(vec![lit]),
            Err(e) => Err(e.into()),
        }
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled_count())
            .finish()
    }
}
