//! Host tensors: the backend-neutral value type of the runtime layer.
//!
//! [`HostTensor`] is what crosses every backend boundary: the native
//! backend's state tensors live here directly, and the PJRT backend
//! converts through it at init/checkpoint boundaries (the conversions to
//! `xla::Literal` live in `runtime::pjrt`, behind the `pjrt` feature, so
//! the default build carries no XLA types).

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<i64>, data: Vec<f32>) -> Self {
        let elems: i64 = shape.iter().product();
        assert_eq!(elems as usize, data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn vec1(data: Vec<f32>) -> Self {
        Self {
            shape: vec![data.len() as i64],
            data,
        }
    }

    pub fn zeros(shape: Vec<i64>) -> Self {
        let elems: i64 = shape.iter().product();
        Self {
            data: vec![0.0; elems as usize],
            shape,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_has_right_size() {
        let t = HostTensor::zeros(vec![4, 4, 3]);
        assert_eq!(t.len(), 48);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn constructors_shape_correctly() {
        assert!(HostTensor::scalar(3.5).shape.is_empty());
        assert_eq!(HostTensor::vec1(vec![1.0, 2.0]).shape, vec![2]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_shape_panics() {
        HostTensor::new(vec![2, 2], vec![1.0]);
    }
}
