//! Host tensors and conversions to/from `xla::Literal`.
//!
//! The runtime moves three kinds of values across the PJRT boundary:
//! f32 arrays (batches, parameters, scores), f32 scalars (learning rate,
//! loss) and one u32 scalar (the init seed).  [`HostTensor`] is the
//! host-side owner; state tensors stay device-resident as `PjRtBuffer`s
//! in the hot loop (see `train::trainer`) and only cross through here at
//! init/checkpoint boundaries.

use xla::Literal;

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<i64>, data: Vec<f32>) -> Self {
        let elems: i64 = shape.iter().product();
        assert_eq!(elems as usize, data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn vec1(data: Vec<f32>) -> Self {
        Self {
            shape: vec![data.len() as i64],
            data,
        }
    }

    pub fn zeros(shape: Vec<i64>) -> Self {
        let elems: i64 = shape.iter().product();
        Self {
            data: vec![0.0; elems as usize],
            shape,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an XLA literal (rank 0 becomes a true scalar literal).
    pub fn to_literal(&self) -> crate::Result<Literal> {
        if self.shape.is_empty() {
            return Ok(Literal::scalar(self.data[0]));
        }
        let lit = Literal::vec1(&self.data);
        Ok(lit.reshape(&self.shape)?)
    }

    /// Read a literal back into a host tensor (f32 only).
    pub fn from_literal(lit: &Literal) -> crate::Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<i64> = shape.dims().to_vec();
        let data = lit.to_vec::<f32>()?;
        Ok(Self::new(dims, data))
    }
}

/// Build the u32 seed literal for init artifacts.
pub fn seed_literal(seed: u32) -> Literal {
    Literal::scalar(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_vector() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn roundtrip_scalar() {
        let t = HostTensor::scalar(3.5);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![3.5]);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn zeros_has_right_size() {
        let t = HostTensor::zeros(vec![4, 4, 3]);
        assert_eq!(t.len(), 48);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatched_shape_panics() {
        HostTensor::new(vec![2, 2], vec![1.0]);
    }
}
