//! The pluggable execution layer: every way of running a model — the
//! pure-Rust native backend, the PJRT artifact runtime, whatever comes
//! next (sharded, remote, GPU) — implements [`Backend`], and everything
//! above (trainer, sweep, coordinator, CLI) is written against the trait.
//! See DESIGN.md §5 for the layering argument.
//!
//! Losses cross this boundary as a typed [`LossSpec`] — validated at
//! the API edge (CLI / config parse), never re-parsed from a string
//! inside a backend (DESIGN.md §8).
//!
//! Threading contract: a [`BackendSpec`] is plain `Send + Sync` data that
//! can cross threads freely; a connected [`Backend`] may be thread-bound
//! (the PJRT client is `Rc`-based), so the sweep scheduler ships the
//! *spec* to each worker and connects per thread.  The native backend is
//! freely shareable — which is what lets future PRs shard one backend
//! across workers instead of one-runtime-per-thread.

use std::path::PathBuf;

use crate::losses::LossSpec;
use crate::util::json::Json;

use super::native::{NativeBackend, NativeSpec};
use super::tensor::HostTensor;

/// A connected execution backend: a factory of per-(model, loss, batch)
/// executors plus the §5 full-set loss-monitoring entry point.
pub trait Backend {
    /// Short backend name for logs and reports (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Open an executor for one (model, loss, batch) combination.
    ///
    /// The executor may borrow the backend (the PJRT executor shares the
    /// backend's compiled-executable cache), hence the lifetime tie.
    fn open<'a>(
        &'a self,
        model: &str,
        loss: &LossSpec,
        batch: usize,
    ) -> crate::Result<Box<dyn ModelExecutor + 'a>>;

    /// Full-set training-loss evaluation (paper §5 monitoring): the
    /// specified loss over `scores`/`is_pos`, normalized per pair (or
    /// per example for pointwise losses).
    fn eval_loss(&self, loss: &LossSpec, scores: &[f32], is_pos: &[f32]) -> crate::Result<f64>;
}

/// One model bound to one (loss, batch): holds the training state and
/// runs init / train-step / predict.
///
/// Batch buffers follow the sampler convention: fixed shape
/// `batch_size() * row_len()`, padding rows zeroed with both masks zero.
pub trait ModelExecutor {
    /// Static train-batch size.
    fn batch_size(&self) -> usize;

    /// Scalars per example.
    fn row_len(&self) -> usize;

    /// Number of state tensors (parameters + optimizer slots).
    fn n_state(&self) -> usize;

    /// (Re)initialize the training state from a seed.
    fn init(&mut self, seed: u32) -> crate::Result<()>;

    /// One optimizer step on a filled batch; returns the batch loss
    /// (normalized per pair / per example, matching the AOT kernels).
    fn train_step(
        &mut self,
        x: &[f32],
        is_pos: &[f32],
        is_neg: &[f32],
        lr: f32,
    ) -> crate::Result<f64>;

    /// Scores for `rows` examples stored row-major in `x`
    /// (`rows * row_len()` scalars).  The executor handles any internal
    /// chunking/padding its substrate needs.
    fn predict(&mut self, x: &[f32], rows: usize) -> crate::Result<Vec<f32>>;

    /// Serving entry point: append the scores for `rows` examples to
    /// `out` without clearing it.  Semantically identical to
    /// [`predict`](Self::predict) — same arithmetic, same bits — but
    /// lets a caller with a long-lived buffer (the serve micro-batcher)
    /// avoid a per-request allocation.  Backends with an internal score
    /// buffer should override the default, which delegates to `predict`.
    fn predict_into(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> crate::Result<()> {
        out.extend(self.predict(x, rows)?);
        Ok(())
    }

    /// Download the training state (parameters first, optimizer slots
    /// after, in a stable order) for checkpointing.
    fn state_to_host(&self) -> crate::Result<Vec<HostTensor>>;

    /// Restore a previously downloaded state.
    fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()>;
}

/// Serializable description of a backend: plain data, `Send + Sync`,
/// cheap to clone — the form in which backends cross thread and config
/// boundaries.  `connect()` turns it into a live [`Backend`].
#[derive(Debug, Clone, PartialEq)]
pub enum BackendSpec {
    /// The self-contained pure-Rust backend (default build).
    Native(NativeSpec),
    /// The PJRT artifact runtime (requires the `pjrt` cargo feature and
    /// `make artifacts`).
    Pjrt { artifacts_dir: PathBuf },
}

impl Default for BackendSpec {
    fn default() -> Self {
        BackendSpec::Native(NativeSpec::default())
    }
}

impl BackendSpec {
    /// The default native backend.
    pub fn native() -> Self {
        Self::default()
    }

    /// A PJRT spec over an artifacts directory.
    pub fn pjrt(artifacts_dir: impl Into<PathBuf>) -> Self {
        BackendSpec::Pjrt {
            artifacts_dir: artifacts_dir.into(),
        }
    }

    /// Short name for logs.
    pub fn kind(&self) -> &'static str {
        match self {
            BackendSpec::Native(_) => "native",
            BackendSpec::Pjrt { .. } => "pjrt",
        }
    }

    /// Connect: instantiate the described backend on this thread.
    pub fn connect(&self) -> crate::Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Native(spec) => Ok(Box::new(NativeBackend::new(spec.clone()))),
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { artifacts_dir } => Ok(Box::new(
                super::pjrt::PjrtBackend::new(artifacts_dir)?,
            )),
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt { .. } => anyhow::bail!(
                "this binary was built without the `pjrt` feature; \
                 rebuild with `cargo build --features pjrt` or use the native backend"
            ),
        }
    }

    /// JSON form (used inside sweep configs).
    pub fn to_json(&self) -> Json {
        match self {
            BackendSpec::Native(s) => Json::obj([
                ("kind", Json::str("native")),
                ("input_dim", Json::num(s.input_dim as f64)),
                ("hidden", Json::num(s.hidden as f64)),
                ("threads", Json::num(s.threads as f64)),
                ("sort", Json::str(s.sort.name())),
            ]),
            BackendSpec::Pjrt { artifacts_dir } => Json::obj([
                ("kind", Json::str("pjrt")),
                ("artifacts", Json::str(artifacts_dir.display().to_string())),
            ]),
        }
    }

    /// Parse the JSON form; absent native fields keep their defaults.
    ///
    /// Back-compat: pre-LossSpec configs carried a `margin` field here.
    /// The margin now travels with the loss spec (`"hinge@margin=2"`),
    /// so a legacy `margin` key at the old default (1.0) is accepted and
    /// ignored — but a *non-default* legacy margin is rejected rather
    /// than silently dropped, which would reproduce different losses
    /// than the config's original run.
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let kind = j
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("backend kind must be a string"))?;
        match kind {
            "native" => {
                let mut spec = NativeSpec::default();
                if let Some(v) = j.get("input_dim") {
                    spec.input_dim = v
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("input_dim must be a non-negative integer"))?;
                }
                if let Some(v) = j.get("hidden") {
                    spec.hidden = v
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("hidden must be a non-negative integer"))?;
                }
                if let Some(v) = j.get("threads") {
                    spec.threads = v
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("threads must be a non-negative integer"))?;
                }
                if let Some(v) = j.get("sort") {
                    let s = v
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("sort must be a strategy name string"))?;
                    spec.sort = s.parse()?;
                }
                if let Some(v) = j.get("margin") {
                    let m = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("margin must be a number"))?;
                    anyhow::ensure!(
                        m == crate::losses::spec::DEFAULT_MARGIN as f64,
                        "the backend no longer carries a margin; move the legacy \
                         \"margin\": {m} into the loss specs (e.g. \"hinge@margin={m}\")"
                    );
                }
                Ok(BackendSpec::Native(spec))
            }
            "pjrt" => {
                let dir = j
                    .req("artifacts")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifacts must be a string path"))?;
                Ok(BackendSpec::pjrt(dir))
            }
            other => anyhow::bail!("unknown backend kind {other:?} (native | pjrt)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        // a non-default sort strategy must survive the round trip
        let native = BackendSpec::Native(NativeSpec {
            input_dim: 64,
            hidden: 16,
            threads: 2,
            sort: crate::losses::SortStrategy::Radix,
        });
        let back = BackendSpec::from_json(&native.to_json()).unwrap();
        assert_eq!(back, native);

        let j = Json::parse(r#"{"kind": "native", "sort": "quantum"}"#).unwrap();
        assert!(BackendSpec::from_json(&j).is_err(), "bad strategy rejected");

        let pjrt = BackendSpec::pjrt("artifacts");
        let back = BackendSpec::from_json(&pjrt.to_json()).unwrap();
        assert_eq!(back, pjrt);
    }

    #[test]
    fn legacy_margin_field_default_ignored_nondefault_rejected() {
        // pre-LossSpec configs serialized the margin on the backend; the
        // old default parses (and is dropped), a non-default one must
        // fail loudly instead of silently training at margin 1
        let j = Json::parse(
            r#"{"kind": "native", "input_dim": 8, "hidden": 4, "margin": 1.0, "threads": 1}"#,
        )
        .unwrap();
        let spec = BackendSpec::from_json(&j).unwrap();
        assert_eq!(
            spec,
            BackendSpec::Native(NativeSpec {
                input_dim: 8,
                hidden: 4,
                threads: 1,
                ..NativeSpec::default()
            })
        );
        let j = Json::parse(r#"{"kind": "native", "margin": 0.5}"#).unwrap();
        let err = BackendSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("hinge@margin=0.5"), "{err}");
    }

    #[test]
    fn native_connects_and_names() {
        let backend = BackendSpec::native().connect().unwrap();
        assert_eq!(backend.name(), "native");
    }

    #[test]
    fn unknown_kind_rejected() {
        let j = Json::obj([("kind", Json::str("quantum"))]);
        assert!(BackendSpec::from_json(&j).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_spec_errors_without_feature() {
        let err = BackendSpec::pjrt("artifacts").connect().err().unwrap();
        assert!(err.to_string().contains("pjrt"));
    }

    #[test]
    fn spec_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<BackendSpec>();
    }
}
