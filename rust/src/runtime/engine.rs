//! The deterministic parallel train-step engine (DESIGN.md §7).
//!
//! One object owns the parallel execution of a native train step:
//! chunked forward → (serial) functional loss → chunked backward with a
//! fixed-order f64 reduction.  The determinism contract is the point:
//!
//! * **Chunk layout is a pure function of the row count** —
//!   [`chunk_layout`] never looks at the thread count, so every thread
//!   count sees the same chunk boundaries.
//! * **Each chunk is computed serially by exactly one worker**, in row
//!   order, accumulating its parameter-gradient partial in f64.
//! * **Partials are reduced in chunk-index order** on the calling
//!   thread, also in f64, then rounded to f32 once.
//!
//! A result therefore depends only on the inputs, never on the thread
//! count or on which worker happened to run which chunk: the parallel
//! path is bit-identical to the serial path (threads = 1), which runs
//! the very same chunk loop sequentially.  The serial loss step has
//! its own speed axis, the hinge-sort strategy (DESIGN.md §9): the
//! executor's `LossWorkspace` persists across train steps precisely so
//! the adaptive strategy can seed from the previous step's
//! permutation, and because every strategy yields the identical
//! canonical permutation this never perturbs results.
//! `tests/proptest_engine.rs` pins bit-identity across the full
//! thread-count {1, 2, 8} × sort-strategy matrix and non-chunk-aligned
//! row counts; this is what lets PR 3's bit-reproducibility guarantees
//! survive parallel execution.
//!
//! Workers are scoped threads (the offline build has no rayon; see
//! DESIGN.md §5.4): chunks are dealt round-robin to `threads` workers,
//! which is load-balanced here because per-row cost is uniform.

use std::ops::Range;

/// Chunk granularity in rows.  Row counts at or below this stay a
/// single chunk (and hence serial): below ~256 rows per-step
/// thread-spawn cost rivals the compute, and sweep workers (which
/// already parallelize at the job level) would oversubscribe the
/// machine.
pub const CHUNK_ROWS: usize = 256;

/// Upper bound on chunks per step, which bounds the f64 partial-buffer
/// memory at `MAX_CHUNKS × n_params` doubles while still keeping ≥ 8×
/// more chunks than any sensible worker count for load balance.
pub const MAX_CHUNKS: usize = 64;

/// The chunk layout for `rows` rows: `(n_chunks, rows_per_chunk)`,
/// where the final chunk may be ragged.  A pure function of `rows` —
/// never of the thread count — so chunk boundaries (and therefore
/// every f64 partial and the reduction order) are identical whether
/// the step runs on 1 thread or 16.
pub fn chunk_layout(rows: usize) -> (usize, usize) {
    if rows == 0 {
        return (0, 0);
    }
    let n = rows.div_ceil(CHUNK_ROWS).min(MAX_CHUNKS);
    let per = rows.div_ceil(n);
    (rows.div_ceil(per), per)
}

/// The row ranges of the chunks of [`chunk_layout`], in chunk order.
pub fn chunk_ranges(rows: usize) -> impl Iterator<Item = Range<usize>> {
    let (n_chunks, per) = chunk_layout(rows);
    (0..n_chunks).map(move |c| c * per..((c + 1) * per).min(rows))
}

/// A model the engine can execute: per-chunk forward and backward
/// kernels over row-major example data.  Implemented by the native
/// backend's architectures (`runtime/native.rs`); the engine supplies
/// the chunking, threading and deterministic reduction around them.
pub trait ChunkModel: Sync {
    /// Flat parameter-vector length.
    fn n_params(&self) -> usize;

    /// Hidden-activation scalars cached per row (0 = none).
    fn hidden_units(&self) -> usize;

    /// Forward over `rows` (absolute row indices into `x`), writing
    /// into the chunk-local `scores`/`hidden` slices (lengths
    /// `rows.len()` and `rows.len() * hidden_units()`).
    fn forward_chunk(
        &self,
        params: &[f32],
        x: &[f32],
        rows: Range<usize>,
        scores: &mut [f32],
        hidden: &mut [f32],
    );

    /// Accumulate `dL/dparams` over `rows` into the chunk's f64
    /// `partial` (length `n_params()`).  `dscores` and `hidden` are
    /// full-batch slices indexed absolutely; per-term products stay in
    /// f32 (matching the serial reference math) — only the
    /// accumulation is widened.
    fn backward_chunk(
        &self,
        params: &[f32],
        x: &[f32],
        rows: Range<usize>,
        dscores: &[f32],
        hidden: &[f32],
        partial: &mut [f64],
    );
}

/// The engine: worker-count policy plus the reusable f64 partial and
/// reduction scratch.  The `O(n_params)`-sized buffers are reused
/// across steps (no warm-path allocation that scales with the model);
/// a parallel call additionally builds a few pointer-sized work-item
/// lists, which cannot be cached because they hold per-call `&mut`
/// chunk borrows.
#[derive(Debug, Default)]
pub struct Engine {
    /// Requested worker threads (0 = one per available core).
    threads: usize,
    /// Per-chunk f64 gradient partials, indexed by chunk.
    partials: Vec<Vec<f64>>,
    /// Fixed-order reduction accumulator.
    accum: Vec<f64>,
}

impl Engine {
    pub fn new(threads: usize) -> Self {
        Self {
            threads,
            partials: Vec::new(),
            accum: Vec::new(),
        }
    }

    /// Workers actually spawned for `rows`: capped by full chunks of
    /// work (`rows / CHUNK_ROWS`) so small batches stay serial, and by
    /// the chunk count.
    fn resolve_threads(&self, rows: usize, n_chunks: usize) -> usize {
        let by_work = (rows / CHUNK_ROWS).min(n_chunks);
        if by_work <= 1 {
            return 1;
        }
        let hw = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        };
        hw.clamp(1, by_work)
    }

    /// Chunked parallel forward: scores (and the hidden cache) for
    /// `rows` examples.  Bit-identical across thread counts because
    /// rows are independent and chunks write disjoint slices.
    pub fn forward<M: ChunkModel + ?Sized>(
        &self,
        model: &M,
        params: &[f32],
        x: &[f32],
        rows: usize,
        scores: &mut [f32],
        hidden: &mut [f32],
    ) {
        let h = model.hidden_units();
        debug_assert_eq!(scores.len(), rows);
        debug_assert_eq!(hidden.len(), rows * h);
        let (n_chunks, _) = chunk_layout(rows);
        if n_chunks == 0 {
            return;
        }
        let t = self.resolve_threads(rows, n_chunks);
        if t <= 1 {
            for r in chunk_ranges(rows) {
                let (s, hid) = (&mut scores[r.clone()], &mut hidden[r.start * h..r.end * h]);
                model.forward_chunk(params, x, r, s, hid);
            }
            return;
        }
        // Deal (range, score slice, hidden slice) work items round-robin.
        let mut buckets: Vec<Vec<_>> = (0..t).map(|_| Vec::new()).collect();
        let (mut s_rest, mut h_rest) = (scores, hidden);
        for (i, r) in chunk_ranges(rows).enumerate() {
            let take = r.len();
            let (s_head, s_tail) = s_rest.split_at_mut(take);
            let (h_head, h_tail) = h_rest.split_at_mut(take * h);
            s_rest = s_tail;
            h_rest = h_tail;
            buckets[i % t].push((r, s_head, h_head));
        }
        std::thread::scope(|scope| {
            for bucket in buckets {
                scope.spawn(move || {
                    for (r, s, hid) in bucket {
                        model.forward_chunk(params, x, r, s, hid);
                    }
                });
            }
        });
    }

    /// Chunked parallel backward: writes `dL/dparams` into `grad`
    /// (overwritten).  Per-chunk f64 partials, reduced in chunk-index
    /// order — bit-identical across thread counts (module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn backward<M: ChunkModel + ?Sized>(
        &mut self,
        model: &M,
        params: &[f32],
        x: &[f32],
        rows: usize,
        dscores: &[f32],
        hidden: &[f32],
        grad: &mut [f32],
    ) {
        let p = grad.len();
        debug_assert_eq!(p, model.n_params());
        let (n_chunks, _) = chunk_layout(rows);
        if n_chunks == 0 {
            grad.fill(0.0);
            return;
        }
        if self.partials.len() < n_chunks {
            self.partials.resize_with(n_chunks, Vec::new);
        }
        for part in self.partials[..n_chunks].iter_mut() {
            part.clear();
            part.resize(p, 0.0);
        }
        let t = self.resolve_threads(rows, n_chunks);
        if t <= 1 {
            for (r, part) in chunk_ranges(rows).zip(self.partials[..n_chunks].iter_mut()) {
                model.backward_chunk(params, x, r, dscores, hidden, part);
            }
        } else {
            let mut buckets: Vec<Vec<_>> = (0..t).map(|_| Vec::new()).collect();
            for (i, (r, part)) in chunk_ranges(rows)
                .zip(self.partials[..n_chunks].iter_mut())
                .enumerate()
            {
                buckets[i % t].push((r, part));
            }
            std::thread::scope(|scope| {
                for bucket in buckets {
                    scope.spawn(move || {
                        for (r, part) in bucket {
                            model.backward_chunk(params, x, r, dscores, hidden, part);
                        }
                    });
                }
            });
        }
        // Fixed chunk-order f64 reduction, rounded to f32 once.
        self.accum.clear();
        self.accum.resize(p, 0.0);
        for part in self.partials[..n_chunks].iter() {
            for (a, &v) in self.accum.iter_mut().zip(part) {
                *a += v;
            }
        }
        for (g, &a) in grad.iter_mut().zip(&self.accum) {
            *g = a as f32;
        }
    }

    /// The fused train-step data path: chunked forward, then the
    /// caller's (serial, f64) score-loss — `loss(scores, dscores)`
    /// returns the loss value and fills the per-score gradient — then
    /// chunked backward into `grad`.  One call per batch; every
    /// model-sized buffer is caller-owned and reused.
    #[allow(clippy::too_many_arguments)]
    pub fn fused_step<M: ChunkModel + ?Sized, L>(
        &mut self,
        model: &M,
        params: &[f32],
        x: &[f32],
        rows: usize,
        scores: &mut [f32],
        hidden: &mut [f32],
        dscores: &mut [f32],
        loss: L,
        grad: &mut [f32],
    ) -> f64
    where
        L: FnOnce(&[f32], &mut [f32]) -> f64,
    {
        self.forward(model, params, x, rows, &mut *scores, &mut *hidden);
        let value = loss(&*scores, &mut *dscores);
        self.backward(model, params, x, rows, dscores, hidden, grad);
        value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_pure_and_covers_rows() {
        for rows in [0usize, 1, 7, 255, 256, 257, 511, 512, 1000, 16_384, 100_000, 1_000_000] {
            let (n, per) = chunk_layout(rows);
            assert_eq!(chunk_layout(rows), (n, per), "pure function of rows");
            if rows == 0 {
                assert_eq!((n, per), (0, 0));
                continue;
            }
            assert!((1..=MAX_CHUNKS).contains(&n));
            assert!(per >= 1);
            let ranges: Vec<_> = chunk_ranges(rows).collect();
            assert_eq!(ranges.len(), n);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, rows);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous chunks");
                assert_eq!(w[0].len(), per, "only the final chunk may be ragged");
            }
            assert!(!ranges.last().unwrap().is_empty());
        }
    }

    #[test]
    fn small_row_counts_are_one_chunk() {
        for rows in 1..=CHUNK_ROWS {
            assert_eq!(chunk_layout(rows), (1, rows));
        }
    }

    /// Toy model: one weight, score = w * x[r], dL/dw = Σ ds_r * x[r].
    struct Scale;

    impl ChunkModel for Scale {
        fn n_params(&self) -> usize {
            1
        }
        fn hidden_units(&self) -> usize {
            0
        }
        fn forward_chunk(
            &self,
            params: &[f32],
            x: &[f32],
            rows: Range<usize>,
            scores: &mut [f32],
            _hidden: &mut [f32],
        ) {
            for (i, r) in rows.enumerate() {
                scores[i] = params[0] * x[r];
            }
        }
        fn backward_chunk(
            &self,
            _params: &[f32],
            x: &[f32],
            rows: Range<usize>,
            dscores: &[f32],
            _hidden: &[f32],
            partial: &mut [f64],
        ) {
            for r in rows {
                partial[0] += (dscores[r] * x[r]) as f64;
            }
        }
    }

    #[test]
    fn forward_and_backward_match_hand_computation() {
        // Integer data keeps every f64 partial exact, so the expected
        // values are exact too.
        let rows = 600; // 3 chunks of 200
        let x: Vec<f32> = (0..rows).map(|i| (i % 7) as f32).collect();
        let ds: Vec<f32> = (0..rows).map(|i| ((i % 3) as f32) - 1.0).collect();
        let want: f64 = (0..rows).map(|i| (ds[i] * x[i]) as f64).sum();
        let mut engine = Engine::new(1);
        let mut scores = vec![0.0; rows];
        engine.forward(&Scale, &[2.0], &x, rows, &mut scores, &mut []);
        assert!(scores.iter().zip(&x).all(|(s, v)| *s == 2.0 * v));
        let mut grad = vec![0.0_f32; 1];
        engine.backward(&Scale, &[2.0], &x, rows, &ds, &[], &mut grad);
        assert_eq!(grad[0] as f64, want);
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        // Irrational-ish magnitudes so any reduction-order difference
        // would actually show; includes non-chunk-aligned row counts.
        for rows in [1usize, 255, 256, 257, 600, 1000, 1537] {
            let x: Vec<f32> = (0..rows)
                .map(|i| ((i as f32) * 0.7310586).sin() * 100.0)
                .collect();
            let ds: Vec<f32> = (0..rows).map(|i| ((i as f32) * 1.618).cos()).collect();
            let mut grads = Vec::new();
            let mut all_scores = Vec::new();
            for threads in [1usize, 2, 8] {
                let mut engine = Engine::new(threads);
                let mut scores = vec![0.0; rows];
                engine.forward(&Scale, &[1.5], &x, rows, &mut scores, &mut []);
                let mut grad = vec![0.0_f32; 1];
                engine.backward(&Scale, &[1.5], &x, rows, &ds, &[], &mut grad);
                grads.push(grad);
                all_scores.push(scores);
            }
            assert_eq!(grads[0], grads[1], "rows {rows}: 1 vs 2 threads");
            assert_eq!(grads[0], grads[2], "rows {rows}: 1 vs 8 threads");
            assert_eq!(all_scores[0], all_scores[1]);
            assert_eq!(all_scores[0], all_scores[2]);
        }
    }

    #[test]
    fn fused_step_is_forward_loss_backward() {
        let rows = 300;
        let x: Vec<f32> = (0..rows).map(|i| i as f32 * 0.01).collect();
        let mut engine = Engine::new(2);
        let mut scores = vec![0.0; rows];
        let mut dscores = vec![0.0; rows];
        let mut grad = vec![0.0_f32; 1];
        // loss = Σ scores, dL/ds = 1 → dL/dw = Σ x
        let value = engine.fused_step(
            &Scale,
            &[1.0],
            &x,
            rows,
            &mut scores,
            &mut vec![],
            &mut dscores,
            |s, ds| {
                ds.fill(1.0);
                s.iter().map(|&v| v as f64).sum()
            },
            &mut grad,
        );
        let want_loss: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((value - want_loss).abs() < 1e-9);
        let want_grad: f64 = x.iter().map(|&v| v as f64).sum();
        assert!((grad[0] as f64 - want_grad).abs() < 1e-3);
    }

    #[test]
    fn zero_rows_are_a_no_op() {
        let mut engine = Engine::new(4);
        engine.forward(&Scale, &[1.0], &[], 0, &mut [], &mut []);
        let mut grad = vec![7.0_f32];
        engine.backward(&Scale, &[1.0], &[], 0, &[], &[], &mut grad);
        assert_eq!(grad[0], 0.0, "backward overwrites");
    }
}
