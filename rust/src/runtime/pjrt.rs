//! The PJRT backend (feature `pjrt`): compile-once, execute-many
//! artifact host, wrapped behind the [`Backend`] trait.
//!
//! One [`Runtime`] owns a `PjRtClient` (CPU) and a lazy cache of compiled
//! executables keyed by artifact name.  `PjRtClient` is `Rc`-based, so a
//! `Runtime` is intentionally `!Send` — the sweep scheduler ships a
//! [`super::BackendSpec`] to each worker and connects one backend per
//! thread.
//!
//! ## Output handling
//!
//! All artifacts are lowered with `return_tuple=True`, so the HLO root is
//! a tuple.  Depending on the PJRT plugin version the execute API either
//! unpacks the root tuple into one buffer per leaf, or returns a single
//! tuple buffer.  [`Runtime::execute`] normalizes both cases to a flat
//! `Vec<Literal>` (checked against the manifest's `n_outputs`), and
//! [`Runtime::execute_buffers`] does the same at the buffer level for the
//! device-resident hot path.  HLO **text** is the interchange format —
//! see DESIGN.md §4 for why serialized protos are rejected here.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;

use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::{Artifact, ArtifactKind, Manifest};
use super::backend::{Backend, ModelExecutor};
use super::tensor::HostTensor;

/// Convert a host tensor to an XLA literal (rank 0 → true scalar).
pub fn tensor_to_literal(t: &HostTensor) -> crate::Result<Literal> {
    if t.shape.is_empty() {
        return Ok(Literal::scalar(t.data[0]));
    }
    let lit = Literal::vec1(&t.data);
    Ok(lit.reshape(&t.shape)?)
}

/// Read a literal back into a host tensor (f32 only).
pub fn tensor_from_literal(lit: &Literal) -> crate::Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let data = lit.to_vec::<f32>()?;
    Ok(HostTensor::new(dims, data))
}

/// A PJRT CPU client plus a compiled-executable cache over a manifest.
pub struct Runtime {
    client: PjRtClient,
    manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn new(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(BTreeMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn executable(&self, name: &str) -> crate::Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let artifact = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&artifact.path)?;
        let computation = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&computation)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for tests/diagnostics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute by name with literal inputs; returns flat output literals.
    /// Accepts owned or borrowed literals (the C++ side synchronously
    /// awaits the input transfers, so borrowed inputs are safe here).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        args: &[L],
    ) -> crate::Result<Vec<Literal>> {
        let n_outputs = self.manifest.get(name)?.n_outputs;
        let exe = self.executable(name)?;
        let mut results = exe.execute(args)?;
        Self::normalize_outputs(&mut results, n_outputs)
    }

    /// Execute with device-resident buffers; returns flat output buffers
    /// when the plugin unpacks the root tuple, otherwise falls back to a
    /// literal round-trip (correct either way, slower on old plugins).
    /// Accepts borrowed buffers so callers can chain state without copies.
    pub fn execute_buffers<L: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        name: &str,
        args: &[L],
    ) -> crate::Result<Vec<PjRtBuffer>> {
        let n_outputs = self.manifest.get(name)?.n_outputs;
        let exe = self.executable(name)?;
        let results = exe.execute_b(args)?;
        let first: Vec<PjRtBuffer> = results
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no results from {name}"))?;
        // The CPU plugin untuples multi-leaf root tuples into one buffer
        // per leaf, but a single-leaf root arrives as one *tuple* buffer
        // (observed empirically).  Only trust an arity match when the
        // buffer is not itself a tuple.
        if first.len() == n_outputs {
            let tupled = n_outputs == 1
                && matches!(first[0].on_device_shape(), Ok(xla::Shape::Tuple(_)));
            if !tupled {
                return Ok(first);
            }
        }
        // Root tuple not unpacked: round-trip through literals and rebuffer.
        anyhow::ensure!(
            first.len() == 1,
            "{name}: unexpected output arity {} (want {n_outputs})",
            first.len()
        );
        let mut tuple = first[0].to_literal_sync()?;
        let leaves = tuple.decompose_tuple()?;
        anyhow::ensure!(
            leaves.len() == n_outputs,
            "{name}: tuple arity {} (want {n_outputs})",
            leaves.len()
        );
        leaves
            .iter()
            .map(|lit| {
                let buffer = self.client.buffer_from_host_literal(None, lit)?;
                // Force the async host→device copy before `leaves` drops.
                let _ = buffer.to_literal_sync()?;
                Ok(buffer)
            })
            .collect()
    }

    /// Upload a literal to the device.
    ///
    /// SAFETY CONTRACT: `buffer_from_host_literal` enqueues the host→device
    /// copy on a worker thread; the caller must keep `lit` alive until the
    /// copy is forced (by executing with the buffer and synchronizing on an
    /// output, or via [`Runtime::to_device_sync`]).  Dropping the literal
    /// early is a use-after-free inside the PJRT plugin.
    pub fn to_device(&self, lit: &Literal) -> crate::Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Upload and block until the device copy completed, so the source
    /// literal may be dropped immediately afterwards.  (The only
    /// readiness-forcing operation this PJRT API exposes is a read-back,
    /// so this costs one extra device→host copy — use on cold paths.)
    pub fn to_device_sync(&self, lit: &Literal) -> crate::Result<PjRtBuffer> {
        let buffer = self.client.buffer_from_host_literal(None, lit)?;
        let _ = buffer.to_literal_sync()?;
        Ok(buffer)
    }

    fn normalize_outputs(
        results: &mut Vec<Vec<PjRtBuffer>>,
        n_outputs: usize,
    ) -> crate::Result<Vec<Literal>> {
        let first = results
            .drain(..)
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty execution result"))?;
        if first.len() == n_outputs && n_outputs != 1 {
            return first.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        anyhow::ensure!(first.len() == 1, "unexpected output arity {}", first.len());
        let mut lit = first[0].to_literal_sync()?;
        // return_tuple=True means even single outputs arrive as a 1-tuple,
        // unless the plugin already unpacked it.
        match lit.decompose_tuple() {
            Ok(leaves) => {
                anyhow::ensure!(
                    leaves.len() == n_outputs,
                    "tuple arity {} (want {n_outputs})",
                    leaves.len()
                );
                Ok(leaves)
            }
            Err(_) if n_outputs == 1 => Ok(vec![lit]),
            Err(e) => Err(e.into()),
        }
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.compiled_count())
            .finish()
    }
}

/// Ensure a loss spec's margin matches the margin the artifacts were
/// compiled with (the AOT kernels bake it in at lowering time).  The
/// comparison rounds the manifest's f64 margin to f32 — the spec's
/// precision — so a matching non-dyadic margin (e.g. 0.3) is not
/// rejected over f32→f64 representation error.
pub(crate) fn check_artifact_margin(
    runtime: &Runtime,
    loss: &crate::losses::LossSpec,
) -> crate::Result<()> {
    if let Some(m) = loss.margin() {
        let compiled = runtime.manifest().margin;
        anyhow::ensure!(
            m == compiled as f32,
            "the artifacts were compiled at margin {compiled}; loss spec {loss} requests a \
             different one (recompile the artifacts or drop the @margin override)"
        );
    }
    Ok(())
}

/// Full-set loss via the `loss_eval_<loss>_n<N>` artifact.  Scores are
/// padded (mask zero) up to the artifact's static size N; inputs longer
/// than N are an error.  The returned value is normalized per pair (the
/// L2 training losses normalize internally).
pub fn loss_eval(
    runtime: &Runtime,
    spec: &crate::losses::LossSpec,
    scores: &[f32],
    is_pos: &[f32],
) -> crate::Result<f64> {
    check_artifact_margin(runtime, spec)?;
    let loss = spec.base_name();
    let art = runtime
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == ArtifactKind::LossEval && a.loss == loss)
        .ok_or_else(|| anyhow::anyhow!("no loss_eval artifact for {loss}"))?;
    let n = art.batch;
    anyhow::ensure!(
        scores.len() <= n,
        "loss_eval artifact holds {n} elements, got {}",
        scores.len()
    );
    let name = Manifest::loss_eval_name(loss, n);
    let mut s = scores.to_vec();
    s.resize(n, 0.0);
    let mut p = is_pos.to_vec();
    p.resize(n, 0.0);
    let q: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .map(|(_, &pi)| if pi != 0.0 { 0.0 } else { 1.0 })
        .chain(std::iter::repeat(0.0))
        .take(n)
        .collect();
    let outs = runtime.execute(
        &name,
        &[Literal::vec1(&s), Literal::vec1(&p), Literal::vec1(&q)],
    )?;
    Ok(outs[0].to_vec::<f32>()?[0] as f64)
}

/// The PJRT [`Backend`]: a [`Runtime`] behind the pluggable-backend API.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> crate::Result<Self> {
        Ok(Self {
            runtime: Runtime::new(artifacts_dir)?,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn open<'a>(
        &'a self,
        model: &str,
        loss: &crate::losses::LossSpec,
        batch: usize,
    ) -> crate::Result<Box<dyn ModelExecutor + 'a>> {
        Ok(Box::new(PjrtExecutor::new(&self.runtime, model, loss, batch)?))
    }

    fn eval_loss(
        &self,
        loss: &crate::losses::LossSpec,
        scores: &[f32],
        is_pos: &[f32],
    ) -> crate::Result<f64> {
        loss_eval(&self.runtime, loss, scores, is_pos)
    }
}

/// PJRT [`ModelExecutor`]: binds the `init_*`, `train_*_bs<B>` and
/// `predict_*_bs<P>` artifacts of one (model, loss, batch) and keeps the
/// training state device-resident between steps (state buffers are
/// passed by reference; no donation is configured, so they stay valid).
pub struct PjrtExecutor<'rt> {
    runtime: &'rt Runtime,
    train_name: String,
    init_name: String,
    predict_art: Artifact,
    batch: usize,
    predict_batch: usize,
    n_state: usize,
    row_len: usize,
    x_shape: Vec<i64>,
    /// Device-resident training state (params + optimizer slots).
    state: Option<Vec<PjRtBuffer>>,
}

impl<'rt> PjrtExecutor<'rt> {
    /// Resolve artifacts for (model, loss, batch) and validate signatures.
    pub fn new(
        runtime: &'rt Runtime,
        model: &str,
        spec: &crate::losses::LossSpec,
        batch: usize,
    ) -> crate::Result<Self> {
        check_artifact_margin(runtime, spec)?;
        let loss = spec.base_name();
        let manifest = runtime.manifest();
        let train_name = Manifest::train_name(model, loss, batch);
        let train_art = manifest.get(&train_name)?.clone();
        anyhow::ensure!(train_art.kind == ArtifactKind::Train, "{train_name} kind");
        let predict_batch = manifest.predict_batch(model, loss)?;
        let predict_name = Manifest::predict_name(model, loss, predict_batch);
        let init_name = Manifest::init_name(model, loss);
        manifest.get(&init_name)?;
        let predict_art = manifest.get(&predict_name)?.clone();

        let n_state = train_art.n_state;
        // x is the tensor right after the state block; its trailing dims
        // give the per-example row length.
        let x_sig = &train_art.inputs[n_state];
        anyhow::ensure!(x_sig.shape[0] == batch, "batch dim mismatch");
        let row_len: usize = x_sig.shape[1..].iter().product();
        let x_shape: Vec<i64> = x_sig.shape.iter().map(|&d| d as i64).collect();
        Ok(Self {
            runtime,
            train_name,
            init_name,
            predict_art,
            batch,
            predict_batch,
            n_state,
            row_len,
            x_shape,
            state: None,
        })
    }

    fn state_ref(&self) -> crate::Result<&Vec<PjRtBuffer>> {
        self.state
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("executor not initialized; call init()"))
    }
}

impl ModelExecutor for PjrtExecutor<'_> {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn row_len(&self) -> usize {
        self.row_len
    }

    fn n_state(&self) -> usize {
        self.n_state
    }

    fn init(&mut self, seed: u32) -> crate::Result<()> {
        let seed_lit = Literal::scalar(seed);
        let outs = self.runtime.execute(&self.init_name, &[seed_lit])?;
        anyhow::ensure!(outs.len() == self.n_state, "init arity");
        // to_device_sync: the source literals are dropped at the end of
        // this function, so the async host→device copies must be forced.
        let buffers = outs
            .iter()
            .map(|lit| self.runtime.to_device_sync(lit))
            .collect::<crate::Result<Vec<_>>>()?;
        self.state = Some(buffers);
        Ok(())
    }

    fn train_step(
        &mut self,
        x: &[f32],
        is_pos: &[f32],
        is_neg: &[f32],
        lr: f32,
    ) -> crate::Result<f64> {
        anyhow::ensure!(
            x.len() == self.batch * self.row_len,
            "x buffer size {} != {}",
            x.len(),
            self.batch * self.row_len
        );
        // The input literals MUST outlive the loss read-back below: the
        // host→device copies run asynchronously and are only guaranteed
        // complete once an output of the execution has been synchronized.
        let x_lit = Literal::vec1(x).reshape(&self.x_shape)?;
        let pos_lit = Literal::vec1(is_pos);
        let neg_lit = Literal::vec1(is_neg);
        let lr_lit = Literal::scalar(lr);
        let inputs = [
            self.runtime.to_device(&x_lit)?,
            self.runtime.to_device(&pos_lit)?,
            self.runtime.to_device(&neg_lit)?,
            self.runtime.to_device(&lr_lit)?,
        ];
        let mut outs = {
            let state = self.state_ref()?;
            let args: Vec<&PjRtBuffer> = state.iter().chain(inputs.iter()).collect();
            self.runtime.execute_buffers(&self.train_name, &args)?
        };
        anyhow::ensure!(outs.len() == self.n_state + 2, "train arity");
        let _scores = outs.pop().unwrap(); // per-batch scores unused here
        let loss_buf = outs.pop().unwrap();
        self.state = Some(outs);
        // Synchronizes the whole step (and thus the input copies).
        let loss = loss_buf.to_literal_sync()?.to_vec::<f32>()?[0] as f64;
        Ok(loss)
    }

    /// Chunked + padded prediction through the predict artifact, which
    /// consumes only the model-parameter slots of the training state
    /// (`state_indices` in the manifest); optimizer slots stay put.
    ///
    /// Known trade-off of the slice-based executor contract: rows arrive
    /// already gathered by the trainer and are copied once more into the
    /// padded `x_buf` here.  Both copies are bounded by the trainer's
    /// gather-chunk size; revisit only if per-epoch evaluation staging
    /// shows up in profiles.
    fn predict(&mut self, x: &[f32], rows: usize) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == rows * self.row_len,
            "x buffer size {} != {}",
            x.len(),
            rows * self.row_len
        );
        let state = self.state_ref()?;
        let selected: Vec<&PjRtBuffer> = self.predict_art.select_state(state);
        let pb = self.predict_batch;
        let row = self.row_len;
        let mut x_shape = self.x_shape.clone();
        x_shape[0] = pb as i64;
        let mut scores = Vec::with_capacity(rows);
        let mut x_buf = vec![0.0_f32; pb * row];
        let mut done = 0;
        while done < rows {
            let take = pb.min(rows - done);
            x_buf[..take * row].copy_from_slice(&x[done * row..(done + take) * row]);
            x_buf[take * row..].fill(0.0);
            let x_lit = Literal::vec1(&x_buf).reshape(&x_shape)?;
            let x_dev = self.runtime.to_device(&x_lit)?;
            let args: Vec<&PjRtBuffer> = selected
                .iter()
                .copied()
                .chain(std::iter::once(&x_dev))
                .collect();
            let outs = self
                .runtime
                .execute_buffers(&self.predict_art.name, &args)?;
            let out = tensor_from_literal(&outs[0].to_literal_sync()?)?;
            scores.extend_from_slice(&out.data[..take]);
            done += take;
        }
        Ok(scores)
    }

    fn state_to_host(&self) -> crate::Result<Vec<HostTensor>> {
        self.state_ref()?
            .iter()
            .map(|b| tensor_from_literal(&b.to_literal_sync()?))
            .collect()
    }

    fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()> {
        anyhow::ensure!(tensors.len() == self.n_state, "state arity");
        let buffers = tensors
            .iter()
            // sync upload: the literal is a temporary dropped per-iteration
            .map(|t| self.runtime.to_device_sync(&tensor_to_literal(t)?))
            .collect::<crate::Result<Vec<_>>>()?;
        self.state = Some(buffers);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_tensor_roundtrip() {
        let t = HostTensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = tensor_to_literal(&t).unwrap();
        let back = tensor_from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_tensor_roundtrip() {
        let t = HostTensor::scalar(3.5);
        let lit = tensor_to_literal(&t).unwrap();
        let back = tensor_from_literal(&lit).unwrap();
        assert_eq!(back.data, vec![3.5]);
        assert!(back.shape.is_empty());
    }
}
