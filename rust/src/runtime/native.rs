//! The pure-Rust native backend: forward/gradient execution built
//! directly on the [`crate::losses`] kernel layer and [`HostTensor`],
//! with the parallel train-step data path delegated to the deterministic
//! chunked [`Engine`] (`runtime/engine.rs`, DESIGN.md §7).
//!
//! Losses arrive as a typed [`LossSpec`] (validated at the API edge) and
//! are instantiated once, at `open`, into a boxed allocation-free
//! [`LossFn`] kernel — there is no loss-name dispatch anywhere in this
//! module (DESIGN.md §8).
//!
//! Models are the reproduction-scale stand-ins for the paper's networks:
//! a linear scorer (`"linear"`) and a one-hidden-layer tanh MLP (every
//! other model name, including the `"mlp"` and `"resnet"` names used by
//! the AOT manifests).  The optimizer is heavy-ball SGD
//! (`v ← μv + g`, `p ← p − lr·v`, μ = 0.9), matching
//! `python/compile/optim.py`, and losses are normalized per pair (or per
//! example), matching the L2 loss wrappers — so learning rates transfer
//! between the native and PJRT backends.
//!
//! Everything is deterministic from the init seed — including across
//! thread counts: the engine's chunk layout and fixed-order f64
//! reduction make the parallel gradient bit-identical to the serial
//! one (`tests/proptest_engine.rs`).

use std::ops::Range;

use crate::data::Rng;
use crate::losses::{BatchView, LossFn, LossSpec, LossWorkspace, SortStrategy};

use super::backend::{Backend, ModelExecutor};
use super::engine::{ChunkModel, Engine};
use super::tensor::HostTensor;

/// Heavy-ball momentum, as in `python/compile/optim.py::SGDMomentum`.
const MOMENTUM: f32 = 0.9;

/// Configuration of the native backend.
///
/// Loss identity (including the margin) lives in [`LossSpec`], not here:
/// the same backend serves every loss an executor is opened with.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeSpec {
    /// Scalars per example (the flattened input row length).
    pub input_dim: usize,
    /// Hidden units of the MLP stand-in (0 = every model is linear).
    pub hidden: usize,
    /// Worker threads for forward/gradient (0 = one per available core).
    pub threads: usize,
    /// Hinge-sort strategy of the loss kernels (DESIGN.md §9).  Every
    /// strategy produces the identical permutation, so this is a pure
    /// speed knob: results stay bit-identical across strategies.
    pub sort: SortStrategy,
}

impl Default for NativeSpec {
    fn default() -> Self {
        Self {
            // The synthetic image datasets: 16 x 16 x 3 (NHWC).
            input_dim: crate::data::synth::IMAGE_HW
                * crate::data::synth::IMAGE_HW
                * crate::data::synth::CHANNELS,
            hidden: 32,
            threads: 0,
            sort: SortStrategy::default(),
        }
    }
}

/// The self-contained pure-Rust backend.  `Send + Sync`: one instance
/// may be shared across sweep workers.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    spec: NativeSpec,
}

impl NativeBackend {
    pub fn new(spec: NativeSpec) -> Self {
        Self { spec }
    }

    pub fn spec(&self) -> &NativeSpec {
        &self.spec
    }

    /// A full-batch (loss, gradient) oracle over `labels.len()` examples
    /// for deterministic optimizers (L-BFGS, paper §5).  `rows` is
    /// row-major example data, `labels` the {0,1} positive indicators.
    pub fn objective(
        &self,
        model: &str,
        loss: &LossSpec,
        rows: &[f32],
        labels: &[f32],
    ) -> crate::Result<NativeObjective> {
        let arch = ModelArch::parse(model, &self.spec);
        let loss = loss.build()?;
        anyhow::ensure!(
            rows.len() == labels.len() * arch.dim(),
            "rows/labels mismatch: {} scalars for {} examples of dim {}",
            rows.len(),
            labels.len(),
            arch.dim()
        );
        Ok(NativeObjective {
            arch,
            loss,
            engine: Engine::new(self.spec.threads),
            x: rows.to_vec(),
            is_pos: labels.to_vec(),
            rows: labels.len(),
            scores: Vec::new(),
            hidden: Vec::new(),
            dscores: Vec::new(),
            ws: LossWorkspace::with_sort_strategy(self.spec.sort),
            evals: 0,
        })
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn open<'a>(
        &'a self,
        model: &str,
        loss: &LossSpec,
        batch: usize,
    ) -> crate::Result<Box<dyn ModelExecutor + 'a>> {
        anyhow::ensure!(batch > 0, "batch size must be positive");
        let arch = ModelArch::parse(model, &self.spec);
        let loss = loss.build()?;
        Ok(Box::new(NativeExecutor::new(arch, loss, batch, &self.spec)))
    }

    fn eval_loss(&self, loss: &LossSpec, scores: &[f32], is_pos: &[f32]) -> crate::Result<f64> {
        anyhow::ensure!(scores.len() == is_pos.len(), "scores/is_pos length mismatch");
        let kernel = loss.build()?;
        // Fresh workspace per call (no prior order to adapt from): the
        // adaptive default simply falls back to radix here.
        let mut ws = LossWorkspace::with_sort_strategy(self.spec.sort);
        let view = BatchView::new(scores, is_pos);
        // The §5 monitoring entry point: the gradient-free sweep.
        Ok(kernel.loss_only(view, &mut ws) / kernel.norm(view))
    }
}

// ---------------------------------------------------------------------------
// Model architectures
// ---------------------------------------------------------------------------

/// Native model architecture (flat parameter vector layouts below).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ModelArch {
    /// `s = w·x + b`; params `[w (dim), b (1)]`.
    Linear { dim: usize },
    /// `s = w2·tanh(W1 x + b1) + b2`;
    /// params `[W1 (h*dim), b1 (h), w2 (h), b2 (1)]`.
    Mlp { dim: usize, hidden: usize },
}

impl ModelArch {
    fn parse(model: &str, spec: &NativeSpec) -> Self {
        if model == "linear" || spec.hidden == 0 {
            ModelArch::Linear { dim: spec.input_dim }
        } else {
            // "mlp", "resnet", ...: the MLP stand-in at reproduction scale.
            ModelArch::Mlp {
                dim: spec.input_dim,
                hidden: spec.hidden,
            }
        }
    }

    fn dim(&self) -> usize {
        match *self {
            ModelArch::Linear { dim } => dim,
            ModelArch::Mlp { dim, .. } => dim,
        }
    }

    fn hidden_units(&self) -> usize {
        match *self {
            ModelArch::Linear { .. } => 0,
            ModelArch::Mlp { hidden, .. } => hidden,
        }
    }

    /// Shapes of the parameter tensors, in flat layout order.
    fn param_shapes(&self) -> Vec<Vec<i64>> {
        match *self {
            ModelArch::Linear { dim } => vec![vec![dim as i64], vec![]],
            ModelArch::Mlp { dim, hidden } => vec![
                vec![hidden as i64, dim as i64],
                vec![hidden as i64],
                vec![hidden as i64],
                vec![],
            ],
        }
    }

    fn n_params(&self) -> usize {
        match *self {
            ModelArch::Linear { dim } => dim + 1,
            ModelArch::Mlp { dim, hidden } => hidden * dim + 2 * hidden + 1,
        }
    }

    /// Seeded initialization: weights ~ N(0, 1/fan_in), biases zero.
    fn init_params(&self, seed: u32) -> Vec<f32> {
        let mut rng = Rng::new((seed as u64) ^ 0xA11_9A125_0001);
        let mut params = vec![0.0_f32; self.n_params()];
        match *self {
            ModelArch::Linear { dim } => {
                let scale = 1.0 / (dim as f64).sqrt();
                for w in &mut params[..dim] {
                    *w = (rng.normal() * scale) as f32;
                }
            }
            ModelArch::Mlp { dim, hidden } => {
                let w1_scale = 1.0 / (dim as f64).sqrt();
                for w in &mut params[..hidden * dim] {
                    *w = (rng.normal() * w1_scale) as f32;
                }
                let o_w2 = hidden * dim + hidden;
                let w2_scale = 1.0 / (hidden as f64).sqrt();
                for w in &mut params[o_w2..o_w2 + hidden] {
                    *w = (rng.normal() * w2_scale) as f32;
                }
            }
        }
        params
    }
}

#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0_f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// The engine's view of the native architectures: per-chunk forward
/// and f64-accumulating backward kernels.  Per-term products stay in
/// f32 (the same arithmetic as a serial f32 step); only the
/// accumulation is widened, which is what makes the chunked reduction
/// both deterministic and summation-error-free (DESIGN.md §7).
impl ChunkModel for ModelArch {
    fn n_params(&self) -> usize {
        ModelArch::n_params(self)
    }

    fn hidden_units(&self) -> usize {
        ModelArch::hidden_units(self)
    }

    fn forward_chunk(
        &self,
        params: &[f32],
        x: &[f32],
        rows: Range<usize>,
        scores: &mut [f32],
        hidden: &mut [f32],
    ) {
        match *self {
            ModelArch::Linear { dim } => {
                let w = &params[..dim];
                let b = params[dim];
                for (i, r) in rows.enumerate() {
                    scores[i] = b + dot(w, &x[r * dim..(r + 1) * dim]);
                }
            }
            ModelArch::Mlp { dim, hidden: h } => {
                let o_b1 = h * dim;
                let o_w2 = o_b1 + h;
                let o_b2 = o_w2 + h;
                let w1 = &params[..o_b1];
                let b1 = &params[o_b1..o_w2];
                let w2 = &params[o_w2..o_b2];
                let b2 = params[o_b2];
                for (i, r) in rows.enumerate() {
                    let row = &x[r * dim..(r + 1) * dim];
                    let hrow = &mut hidden[i * h..(i + 1) * h];
                    for (j, hj) in hrow.iter_mut().enumerate() {
                        *hj = (b1[j] + dot(&w1[j * dim..(j + 1) * dim], row)).tanh();
                    }
                    scores[i] = b2 + dot(w2, hrow);
                }
            }
        }
    }

    fn backward_chunk(
        &self,
        params: &[f32],
        x: &[f32],
        rows: Range<usize>,
        dscores: &[f32],
        hidden: &[f32],
        partial: &mut [f64],
    ) {
        match *self {
            ModelArch::Linear { dim } => {
                let (gw, gb) = partial.split_at_mut(dim);
                for r in rows {
                    let ds = dscores[r];
                    if ds == 0.0 {
                        continue;
                    }
                    let row = &x[r * dim..(r + 1) * dim];
                    for (g, &v) in gw.iter_mut().zip(row) {
                        *g += (ds * v) as f64;
                    }
                    gb[0] += ds as f64;
                }
            }
            ModelArch::Mlp { dim, hidden: h } => {
                let o_b1 = h * dim;
                let o_w2 = o_b1 + h;
                let o_b2 = o_w2 + h;
                let w2 = &params[o_w2..o_b2];
                for r in rows {
                    let ds = dscores[r];
                    if ds == 0.0 {
                        continue;
                    }
                    let row = &x[r * dim..(r + 1) * dim];
                    let hrow = &hidden[r * h..(r + 1) * h];
                    partial[o_b2] += ds as f64;
                    for j in 0..h {
                        let hj = hrow[j];
                        partial[o_w2 + j] += (ds * hj) as f64;
                        let dz = ds * w2[j] * (1.0 - hj * hj);
                        if dz != 0.0 {
                            partial[o_b1 + j] += dz as f64;
                            for (g, &v) in partial[j * dim..(j + 1) * dim].iter_mut().zip(row) {
                                *g += (dz * v) as f64;
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// Native [`ModelExecutor`]: flat parameter + momentum vectors, a boxed
/// [`LossFn`] kernel with its [`LossWorkspace`], reusable scratch
/// buffers, and a per-executor [`Engine`] driving the parallel data
/// path.  The train step is allocation-free after warm-up for every
/// loss (see EXPERIMENTS.md §Perf) and bit-identical across thread
/// counts (DESIGN.md §7).
struct NativeExecutor {
    arch: ModelArch,
    loss: Box<dyn LossFn>,
    batch: usize,
    engine: Engine,
    initialized: bool,
    params: Vec<f32>,
    momentum: Vec<f32>,
    // scratch
    scores: Vec<f32>,
    hidden: Vec<f32>,
    dscores: Vec<f32>,
    grad: Vec<f32>,
    compact_scores: Vec<f32>,
    compact_pos: Vec<f32>,
    compact_idx: Vec<u32>,
    ws: LossWorkspace,
}

impl NativeExecutor {
    fn new(arch: ModelArch, loss: Box<dyn LossFn>, batch: usize, spec: &NativeSpec) -> Self {
        let n = arch.n_params();
        Self {
            arch,
            loss,
            batch,
            engine: Engine::new(spec.threads),
            initialized: false,
            params: vec![0.0; n],
            momentum: vec![0.0; n],
            scores: Vec::new(),
            hidden: Vec::new(),
            dscores: Vec::new(),
            grad: Vec::new(),
            compact_scores: Vec::new(),
            compact_pos: Vec::new(),
            compact_idx: Vec::new(),
            // The workspace — and with it the sort engine's previous
            // permutation, the adaptive seed — persists across train
            // steps for the executor's lifetime.
            ws: LossWorkspace::with_sort_strategy(spec.sort),
        }
    }

    fn forward_rows(&mut self, x: &[f32], rows: usize) {
        self.scores.clear();
        self.scores.resize(rows, 0.0);
        self.hidden.clear();
        self.hidden.resize(rows * self.arch.hidden_units(), 0.0);
        self.engine.forward(
            &self.arch,
            &self.params,
            x,
            rows,
            &mut self.scores,
            &mut self.hidden,
        );
    }
}

impl ModelExecutor for NativeExecutor {
    fn batch_size(&self) -> usize {
        self.batch
    }

    fn row_len(&self) -> usize {
        self.arch.dim()
    }

    fn n_state(&self) -> usize {
        2 * self.arch.param_shapes().len()
    }

    fn init(&mut self, seed: u32) -> crate::Result<()> {
        self.params = self.arch.init_params(seed);
        self.momentum = vec![0.0; self.params.len()];
        self.initialized = true;
        Ok(())
    }

    fn train_step(
        &mut self,
        x: &[f32],
        is_pos: &[f32],
        is_neg: &[f32],
        lr: f32,
    ) -> crate::Result<f64> {
        let b = self.batch;
        let d = self.arch.dim();
        anyhow::ensure!(self.initialized, "executor not initialized; call init()");
        anyhow::ensure!(x.len() == b * d, "x buffer size {} != {}", x.len(), b * d);
        anyhow::ensure!(is_pos.len() == b && is_neg.len() == b, "mask buffer size");

        let arch = self.arch;
        self.scores.clear();
        self.scores.resize(b, 0.0);
        self.hidden.clear();
        self.hidden.resize(b * arch.hidden_units(), 0.0);
        self.dscores.clear();
        self.dscores.resize(b, 0.0);
        self.grad.clear();
        self.grad.resize(self.params.len(), 0.0);

        // One fused engine call: chunked forward → functional loss →
        // chunked backward with the fixed-order f64 reduction.
        let Self {
            engine,
            loss,
            params,
            scores,
            hidden,
            dscores,
            grad,
            compact_scores,
            compact_pos,
            compact_idx,
            ws,
            ..
        } = self;
        let normalized = engine.fused_step(
            &arch,
            params,
            x,
            b,
            scores,
            hidden,
            dscores,
            |scores, dscores| {
                // Compact out padding rows (both masks zero): the native
                // losses would otherwise count padding as negatives.
                compact_scores.clear();
                compact_pos.clear();
                compact_idx.clear();
                for i in 0..b {
                    if is_pos[i] != 0.0 || is_neg[i] != 0.0 {
                        compact_scores.push(scores[i]);
                        compact_pos.push(is_pos[i]);
                        compact_idx.push(i as u32);
                    }
                }
                let view = BatchView::new(&compact_scores[..], &compact_pos[..]);
                let norm = loss.norm(view);
                let raw = loss.loss_and_grad(view, ws);
                // Scatter normalized score gradients to batch positions.
                let inv = 1.0 / norm;
                for (slot, &i) in compact_idx.iter().enumerate() {
                    dscores[i as usize] = (ws.grad[slot] as f64 * inv) as f32;
                }
                raw / norm
            },
            grad,
        );

        // Heavy-ball update.
        for ((v, p), &g) in self
            .momentum
            .iter_mut()
            .zip(self.params.iter_mut())
            .zip(&self.grad)
        {
            *v = MOMENTUM * *v + g;
            *p -= lr * *v;
        }
        Ok(normalized)
    }

    fn predict(&mut self, x: &[f32], rows: usize) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(self.initialized, "executor not initialized; call init()");
        anyhow::ensure!(
            x.len() == rows * self.arch.dim(),
            "x buffer size {} != {}",
            x.len(),
            rows * self.arch.dim()
        );
        self.forward_rows(x, rows);
        Ok(self.scores.clone())
    }

    fn predict_into(&mut self, x: &[f32], rows: usize, out: &mut Vec<f32>) -> crate::Result<()> {
        anyhow::ensure!(self.initialized, "executor not initialized; call init()");
        anyhow::ensure!(
            x.len() == rows * self.arch.dim(),
            "x buffer size {} != {}",
            x.len(),
            rows * self.arch.dim()
        );
        // Same forward as `predict` (identical bits), minus its per-call
        // Vec: the serve hot path reuses the caller's buffer.
        self.forward_rows(x, rows);
        out.extend_from_slice(&self.scores);
        Ok(())
    }

    fn state_to_host(&self) -> crate::Result<Vec<HostTensor>> {
        anyhow::ensure!(self.initialized, "executor not initialized; call init()");
        let shapes = self.arch.param_shapes();
        let mut out = tensors_from_flat(&shapes, &self.params)?;
        out.extend(tensors_from_flat(&shapes, &self.momentum)?);
        Ok(out)
    }

    fn load_state(&mut self, tensors: &[HostTensor]) -> crate::Result<()> {
        let shapes = self.arch.param_shapes();
        anyhow::ensure!(
            tensors.len() == 2 * shapes.len(),
            "state arity {} (want {})",
            tensors.len(),
            2 * shapes.len()
        );
        let params = flat_from_tensors(&shapes, &tensors[..shapes.len()])?;
        let momentum = flat_from_tensors(&shapes, &tensors[shapes.len()..])?;
        self.params = params;
        self.momentum = momentum;
        self.initialized = true;
        Ok(())
    }
}

fn tensors_from_flat(shapes: &[Vec<i64>], flat: &[f32]) -> crate::Result<Vec<HostTensor>> {
    let mut out = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for shape in shapes {
        let len = shape.iter().product::<i64>() as usize;
        anyhow::ensure!(off + len <= flat.len(), "flat vector too short");
        out.push(HostTensor::new(shape.clone(), flat[off..off + len].to_vec()));
        off += len;
    }
    anyhow::ensure!(off == flat.len(), "flat vector too long");
    Ok(out)
}

fn flat_from_tensors(shapes: &[Vec<i64>], tensors: &[HostTensor]) -> crate::Result<Vec<f32>> {
    let total: usize = shapes.iter().map(|s| s.iter().product::<i64>() as usize).sum();
    let mut flat = Vec::with_capacity(total);
    for (shape, t) in shapes.iter().zip(tensors) {
        anyhow::ensure!(
            &t.shape == shape,
            "state tensor shape {:?} (want {:?})",
            t.shape,
            shape
        );
        flat.extend_from_slice(&t.data);
    }
    Ok(flat)
}

// ---------------------------------------------------------------------------
// Full-batch objective (L-BFGS oracle)
// ---------------------------------------------------------------------------

/// Native full-batch (loss, gradient) oracle over flat parameters —
/// the [`crate::train::lbfgs::Objective`] the deterministic optimizers
/// consume.  Built via [`NativeBackend::objective`]; executes through
/// the same deterministic chunked [`Engine`] and [`LossFn`] kernel as
/// the train step.
pub struct NativeObjective {
    arch: ModelArch,
    loss: Box<dyn LossFn>,
    engine: Engine,
    x: Vec<f32>,
    is_pos: Vec<f32>,
    rows: usize,
    scores: Vec<f32>,
    hidden: Vec<f32>,
    dscores: Vec<f32>,
    ws: LossWorkspace,
    /// Number of oracle evaluations performed (diagnostics).
    pub evals: usize,
}

impl NativeObjective {
    /// Seeded initial parameters for this objective's architecture.
    pub fn init_params(&self, seed: u32) -> Vec<f32> {
        self.arch.init_params(seed)
    }

    /// Forward pass over the bound batch into the scratch buffers.
    fn forward(&mut self, theta: &[f32]) -> crate::Result<()> {
        anyhow::ensure!(theta.len() == self.arch.n_params(), "theta dim");
        self.scores.clear();
        self.scores.resize(self.rows, 0.0);
        self.hidden.clear();
        self.hidden.resize(self.rows * self.arch.hidden_units(), 0.0);
        self.engine.forward(
            &self.arch,
            theta,
            &self.x,
            self.rows,
            &mut self.scores,
            &mut self.hidden,
        );
        Ok(())
    }

    /// Scores of the bound batch at parameters `theta`.
    pub fn scores(&mut self, theta: &[f32]) -> crate::Result<Vec<f32>> {
        self.forward(theta)?;
        Ok(self.scores.clone())
    }
}

impl crate::train::lbfgs::Objective for NativeObjective {
    fn dim(&self) -> usize {
        self.arch.n_params()
    }

    fn eval(&mut self, theta: &[f32]) -> crate::Result<(f64, Vec<f32>)> {
        self.forward(theta)?;
        self.evals += 1;
        let view = BatchView::new(&self.scores, &self.is_pos);
        let norm = self.loss.norm(view);
        let raw = self.loss.loss_and_grad(view, &mut self.ws);
        let inv = 1.0 / norm;
        self.dscores.clear();
        self.dscores
            .extend(self.ws.grad.iter().map(|&g| (g as f64 * inv) as f32));
        let mut grad = vec![0.0_f32; self.arch.n_params()];
        self.engine.backward(
            &self.arch,
            theta,
            &self.x,
            self.rows,
            &self.dscores,
            &self.hidden,
            &mut grad,
        );
        Ok((raw / norm, grad))
    }

    fn evals(&self) -> usize {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dim: usize, hidden: usize, threads: usize) -> NativeSpec {
        NativeSpec {
            input_dim: dim,
            hidden,
            threads,
            ..NativeSpec::default()
        }
    }

    fn hinge() -> LossSpec {
        LossSpec::hinge()
    }

    fn toy_batch(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let p: Vec<f32> = (0..n)
            .map(|_| if rng.uniform() < 0.4 { 1.0 } else { 0.0 })
            .collect();
        let q: Vec<f32> = p.iter().map(|&v| 1.0 - v).collect();
        (x, p, q)
    }

    #[test]
    fn linear_forward_matches_manual() {
        let backend = NativeBackend::new(spec(3, 0, 1));
        let mut exec = backend.open("linear", &hinge(), 2).unwrap();
        exec.init(0).unwrap();
        let state = exec.state_to_host().unwrap();
        let w = &state[0].data;
        let b = state[1].data[0];
        let x = vec![1.0, 2.0, 3.0, -1.0, 0.5, 0.0];
        let scores = exec.predict(&x, 2).unwrap();
        let want0 = b + w[0] + 2.0 * w[1] + 3.0 * w[2];
        let want1 = b - w[0] + 0.5 * w[1];
        assert!((scores[0] - want0).abs() < 1e-6);
        assert!((scores[1] - want1).abs() < 1e-6);
    }

    #[test]
    fn mlp_forward_matches_manual() {
        let backend = NativeBackend::new(spec(4, 3, 1));
        let mut exec = backend.open("mlp", &hinge(), 1).unwrap();
        exec.init(7).unwrap();
        let state = exec.state_to_host().unwrap();
        let (w1, b1, w2, b2) = (&state[0].data, &state[1].data, &state[2].data, state[3].data[0]);
        let x = vec![0.3_f32, -0.2, 0.9, 0.1];
        let scores = exec.predict(&x, 1).unwrap();
        let mut want = b2;
        for j in 0..3 {
            let z: f32 = b1[j] + (0..4).map(|k| w1[j * 4 + k] * x[k]).sum::<f32>();
            want += w2[j] * z.tanh();
        }
        assert!((scores[0] - want).abs() < 1e-5, "{} vs {want}", scores[0]);
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let backend = NativeBackend::new(spec(8, 4, 1));
        let mut a = backend.open("mlp", &hinge(), 2).unwrap();
        let mut b = backend.open("mlp", &hinge(), 2).unwrap();
        a.init(3).unwrap();
        b.init(3).unwrap();
        assert_eq!(a.state_to_host().unwrap(), b.state_to_host().unwrap());
        b.init(4).unwrap();
        assert_ne!(a.state_to_host().unwrap(), b.state_to_host().unwrap());
    }

    #[test]
    fn padding_rows_are_ignored() {
        let backend = NativeBackend::new(spec(4, 0, 1));
        let mut full = backend.open("linear", &hinge(), 4).unwrap();
        let mut padded = backend.open("linear", &hinge(), 6).unwrap();
        full.init(1).unwrap();
        padded.init(1).unwrap();
        let (x, p, q) = toy_batch(4, 4, 9);
        let mut xp = x.clone();
        xp.extend([0.0; 8]);
        let mut pp = p.clone();
        pp.extend([0.0; 2]);
        let mut qp = q.clone();
        qp.extend([0.0; 2]);
        let l_full = full.train_step(&x, &p, &q, 0.1).unwrap();
        let l_padded = padded.train_step(&xp, &pp, &qp, 0.1).unwrap();
        assert!((l_full - l_padded).abs() < 1e-12);
        assert_eq!(
            full.state_to_host().unwrap(),
            padded.state_to_host().unwrap()
        );
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        // n must exceed 2 * engine::CHUNK_ROWS so the parallel path
        // runs.  The engine's fixed chunk layout + fixed-order f64
        // reduction make the whole step — loss AND parameter state —
        // bit-identical across thread counts (DESIGN.md §7).
        let n = 600;
        let (x, p, q) = toy_batch(n, 16, 5);
        let serial = NativeBackend::new(spec(16, 8, 1));
        let parallel = NativeBackend::new(spec(16, 8, 4));
        let mut a = serial.open("mlp", &hinge(), n).unwrap();
        let mut c = parallel.open("mlp", &hinge(), n).unwrap();
        a.init(2).unwrap();
        c.init(2).unwrap();
        for _ in 0..3 {
            let la = a.train_step(&x, &p, &q, 0.05).unwrap();
            let lc = c.train_step(&x, &p, &q, 0.05).unwrap();
            assert_eq!(la.to_bits(), lc.to_bits());
            assert_eq!(a.state_to_host().unwrap(), c.state_to_host().unwrap());
        }
    }

    #[test]
    fn sort_strategies_train_bit_identically() {
        // The spec's sort knob is speed-only: every strategy produces
        // the canonical permutation, so multi-step training — loss AND
        // parameter/momentum state — is bit-identical across them.
        // (The full strategy × thread-count matrix lives in
        // tests/proptest_engine.rs.)
        let n = 300;
        let (x, p, q) = toy_batch(n, 6, 31);
        let mut outputs = Vec::new();
        for strategy in SortStrategy::ALL {
            let backend = NativeBackend::new(NativeSpec {
                input_dim: 6,
                hidden: 4,
                threads: 1,
                sort: strategy,
            });
            let mut exec = backend.open("mlp", &hinge(), n).unwrap();
            exec.init(5).unwrap();
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(exec.train_step(&x, &p, &q, 0.05).unwrap().to_bits());
            }
            outputs.push((losses, exec.state_to_host().unwrap()));
        }
        for (i, out) in outputs.iter().enumerate().skip(1) {
            assert_eq!(out, &outputs[0], "strategy {}", SortStrategy::ALL[i]);
        }
    }

    #[test]
    fn predict_into_appends_identical_bits() {
        let backend = NativeBackend::new(spec(8, 4, 1));
        let mut exec = backend.open("mlp", &hinge(), 4).unwrap();
        exec.init(3).unwrap();
        let (x, _, _) = toy_batch(6, 8, 77);
        let scores = exec.predict(&x, 6).unwrap();
        let mut out = vec![42.0_f32];
        exec.predict_into(&x, 6, &mut out).unwrap();
        assert_eq!(out[0], 42.0, "appends, never clears");
        assert_eq!(&out[1..], &scores[..], "bit-identical to predict");
        assert!(exec.predict_into(&x, 7, &mut out).is_err(), "size checked");
    }

    #[test]
    fn checkpoint_roundtrip_restores_predictions() {
        let backend = NativeBackend::new(spec(8, 4, 1));
        let mut exec = backend.open("mlp", &hinge(), 16).unwrap();
        exec.init(11).unwrap();
        let (x, p, q) = toy_batch(16, 8, 13);
        exec.train_step(&x, &p, &q, 0.1).unwrap();
        let snapshot = exec.state_to_host().unwrap();
        let before = exec.predict(&x, 16).unwrap();
        exec.train_step(&x, &p, &q, 0.1).unwrap();
        exec.load_state(&snapshot).unwrap();
        assert_eq!(exec.predict(&x, 16).unwrap(), before);
    }

    #[test]
    fn aucm_rejected_with_pjrt_pointer() {
        let backend = NativeBackend::new(spec(4, 0, 1));
        let err = backend.open("linear", &LossSpec::Aucm, 4).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err:#}");
        assert!(backend.open("linear", &hinge(), 4).is_ok());
    }

    #[test]
    fn every_native_spec_opens_and_trains() {
        // The typed API's promise: every spec with a native kernel —
        // including the weighted hinge, previously dead code — opens,
        // initializes and takes a finite train step.
        let backend = NativeBackend::new(spec(6, 4, 1));
        let (x, p, q) = toy_batch(32, 6, 17);
        for loss in [
            LossSpec::hinge(),
            LossSpec::square(),
            LossSpec::logistic(),
            LossSpec::linear_hinge(),
            LossSpec::weighted_hinge(),
            LossSpec::Hinge { margin: 2.0 },
        ] {
            let mut exec = backend.open("mlp", &loss, 32).unwrap();
            exec.init(0).unwrap();
            let l = exec.train_step(&x, &p, &q, 0.01).unwrap();
            assert!(l.is_finite() && l >= 0.0, "{loss}: {l}");
        }
    }

    #[test]
    fn eval_loss_matches_monitor_convention() {
        // 1 pos, 1 neg, equal scores, m = 1: one pair of loss 1.
        let backend = NativeBackend::new(NativeSpec::default());
        let loss = backend
            .eval_loss(&LossSpec::hinge(), &[0.0, 0.0], &[1.0, 0.0])
            .unwrap();
        assert!((loss - 1.0).abs() < 1e-9);
        // margins travel with the spec: m = 2 doubles the violation
        let loss2 = backend
            .eval_loss(&LossSpec::Hinge { margin: 2.0 }, &[0.0, 0.0], &[1.0, 0.0])
            .unwrap();
        assert!((loss2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn whinge_step_matches_explicit_class_balanced_reference() {
        // One linear whinge train step == hand-built step using the
        // explicit class-balanced weighted kernel on the same scores.
        use crate::losses::weighted::{class_balanced_weights, WeightedSquaredHinge};
        let dim = 5;
        let n = 24;
        let (x, p, q) = toy_batch(n, dim, 23);
        let backend = NativeBackend::new(spec(dim, 0, 1));
        let mut exec = backend.open("linear", &LossSpec::weighted_hinge(), n).unwrap();
        exec.init(3).unwrap();
        let scores = exec.predict(&x, n).unwrap();
        let wh = WeightedSquaredHinge::new(1.0);
        let w = class_balanced_weights(&p);
        let (raw, _) = wh.loss_and_grad(&scores, &p, &w);
        // Same normalizer the executor uses (derived class-balanced masses).
        let want = raw / LossFn::norm(&wh, BatchView::new(&scores, &p));
        let got = exec.train_step(&x, &p, &q, 0.0).unwrap();
        assert!(
            (got - want).abs() <= 1e-12 * want.abs().max(1.0),
            "{got} vs {want}"
        );
    }

    #[test]
    fn training_reduces_loss_on_separable_data() {
        // Linear + squared hinge is convex in the weights, so a small
        // step size must descend monotonically-ish on separable data.
        let dim = 8;
        let n = 128;
        let mut rng = Rng::new(21);
        let mut x = Vec::with_capacity(n * dim);
        let mut p = Vec::with_capacity(n);
        for _ in 0..n {
            let pos = rng.uniform() < 0.5;
            p.push(if pos { 1.0 } else { 0.0 });
            for d in 0..dim {
                let shift = if pos && d < 4 { 2.0 } else { 0.0 };
                x.push(rng.normal() as f32 + shift);
            }
        }
        let q: Vec<f32> = p.iter().map(|&v| 1.0 - v).collect();
        let backend = NativeBackend::new(spec(dim, 0, 1));
        let mut exec = backend.open("linear", &hinge(), n).unwrap();
        exec.init(0).unwrap();
        let first = exec.train_step(&x, &p, &q, 0.05).unwrap();
        let mut last = first;
        for _ in 0..100 {
            last = exec.train_step(&x, &p, &q, 0.05).unwrap();
        }
        assert!(
            last < 0.5 * first,
            "loss did not decrease: {first} -> {last}"
        );
    }
}
