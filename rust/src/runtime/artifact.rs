//! Typed view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between the Python AOT compiler and this
//! runtime: every artifact's input signature (tensor shapes and dtypes in
//! flat `tree_flatten` order), output arity, and the number of leading
//! *state* tensors (model parameters + optimizer slots) that thread from
//! one train step to the next.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(seed: u32) -> state...`
    Init,
    /// `(state..., x, is_pos, is_neg, lr) -> (state..., loss, scores)`
    Train,
    /// `(state..., x) -> scores`
    Predict,
    /// `(scores, is_pos, is_neg) -> loss` (the §5 monitoring entry point)
    LossEval,
    /// `(params..., x, is_pos, is_neg) -> (loss, grads...)` — full-batch
    /// objective for deterministic optimizers (L-BFGS, paper §5).
    Grad,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "init" => Self::Init,
            "train" => Self::Train,
            "predict" => Self::Predict,
            "loss_eval" => Self::LossEval,
            "grad" => Self::Grad,
            _ => return None,
        })
    }
}

/// One tensor in an artifact's input signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    fn from_json(j: &Json) -> crate::Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad dim in shape"))
            })
            .collect::<crate::Result<Vec<usize>>>()?;
        let dtype = j
            .req("dtype")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("dtype must be a string"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// One loadable artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    /// Absolute path of the `.hlo.txt` file.
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub model: String,
    pub loss: String,
    /// Batch size for train/predict, n for loss_eval, 0 for init.
    pub batch: usize,
    /// Number of leading state tensors in inputs (and outputs, for train).
    pub n_state: usize,
    pub inputs: Vec<TensorSig>,
    pub n_outputs: usize,
    /// For predict artifacts: which slots of the *full* flat training
    /// state this artifact consumes (XLA prunes unused parameters, so
    /// predict is lowered over the model-parameter leaves only).
    /// Empty = identity (the first `n_state` slots).
    pub state_indices: Vec<usize>,
}

impl Artifact {
    /// Select this artifact's state inputs out of a full state slice.
    pub fn select_state<'a, T>(&self, full_state: &'a [T]) -> Vec<&'a T> {
        if self.state_indices.is_empty() {
            full_state.iter().take(self.n_state).collect()
        } else {
            self.state_indices
                .iter()
                .map(|&i| &full_state[i])
                .collect()
        }
    }
}

/// The artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub margin: f64,
    pub artifacts: Vec<Artifact>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}. Run `make artifacts` first.", path.display()))?;
        let raw = Json::parse(&text)?;
        let version = raw.req("format_version")?.as_usize().unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest format {version}");
        let margin = raw
            .req("margin")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("margin must be a number"))?;
        let str_field = |j: &Json, key: &str| -> crate::Result<String> {
            Ok(j.req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("{key} must be a string"))?
                .to_string())
        };
        let usize_field = |j: &Json, key: &str| -> crate::Result<usize> {
            j.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer"))
        };
        let artifacts = raw
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("artifacts must be an array"))?
            .iter()
            .map(|a| {
                let kind_str = str_field(a, "kind")?;
                let kind = ArtifactKind::parse(&kind_str)
                    .ok_or_else(|| anyhow::anyhow!("unknown artifact kind {kind_str:?}"))?;
                let file = str_field(a, "file")?;
                let path = dir.join(&file);
                anyhow::ensure!(path.exists(), "missing artifact file {}", path.display());
                let inputs = a
                    .req("inputs")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("inputs must be an array"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<crate::Result<Vec<_>>>()?;
                let state_indices = match a.get("state_indices") {
                    None => Vec::new(),
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("state_indices must be an array"))?
                        .iter()
                        .map(|i| {
                            i.as_usize()
                                .ok_or_else(|| anyhow::anyhow!("bad state index"))
                        })
                        .collect::<crate::Result<Vec<_>>>()?,
                };
                Ok(Artifact {
                    name: str_field(a, "name")?,
                    path,
                    kind,
                    model: str_field(a, "model")?,
                    loss: str_field(a, "loss")?,
                    batch: usize_field(a, "batch")?,
                    n_state: usize_field(a, "n_state")?,
                    inputs,
                    n_outputs: usize_field(a, "n_outputs")?,
                    state_indices,
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Self {
            dir,
            margin,
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> crate::Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    /// Artifact name helpers mirroring `aot.py` naming.
    pub fn init_name(model: &str, loss: &str) -> String {
        format!("init_{model}_{loss}")
    }

    pub fn train_name(model: &str, loss: &str, batch: usize) -> String {
        format!("train_{model}_{loss}_bs{batch}")
    }

    pub fn predict_name(model: &str, loss: &str, batch: usize) -> String {
        format!("predict_{model}_{loss}_bs{batch}")
    }

    pub fn loss_eval_name(loss: &str, n: usize) -> String {
        format!("loss_eval_{loss}_n{n}")
    }

    /// Available train batch sizes for (model, loss), ascending.
    pub fn train_batches(&self, model: &str, loss: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Train && a.model == model && a.loss == loss)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// The predict batch size registered for (model, loss).
    pub fn predict_batch(&self, model: &str, loss: &str) -> crate::Result<usize> {
        self.artifacts
            .iter()
            .find(|a| a.kind == ArtifactKind::Predict && a.model == model && a.loss == loss)
            .map(|a| a.batch)
            .ok_or_else(|| anyhow::anyhow!("no predict artifact for {model}/{loss}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
  "format_version": 1,
  "margin": 1.0,
  "artifacts": [
   {"name": "train_resnet_hinge_bs10", "file": "a.hlo.txt", "kind": "train",
    "model": "resnet", "loss": "hinge", "batch": 10, "n_state": 4,
    "inputs": [{"shape": [2,2], "dtype": "float32"}], "n_outputs": 6},
   {"name": "train_resnet_hinge_bs50", "file": "a.hlo.txt", "kind": "train",
    "model": "resnet", "loss": "hinge", "batch": 50, "n_state": 4,
    "inputs": [], "n_outputs": 6},
   {"name": "predict_resnet_hinge_bs100", "file": "a.hlo.txt", "kind": "predict",
    "model": "resnet", "loss": "hinge", "batch": 100, "n_state": 4,
    "inputs": [], "n_outputs": 1}
  ]
 }"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("allpairs_manifest_test");
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.margin, 1.0);
        assert_eq!(m.artifacts.len(), 3);
        let a = m.get("train_resnet_hinge_bs10").unwrap();
        assert_eq!(a.kind, ArtifactKind::Train);
        assert_eq!(a.inputs[0].shape, vec![2, 2]);
        assert_eq!(m.train_batches("resnet", "hinge"), vec![10, 50]);
        assert_eq!(m.predict_batch("resnet", "hinge").unwrap(), 100);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn name_helpers_match_aot_convention() {
        assert_eq!(Manifest::init_name("resnet", "hinge"), "init_resnet_hinge");
        assert_eq!(
            Manifest::train_name("resnet", "aucm", 500),
            "train_resnet_aucm_bs500"
        );
        assert_eq!(
            Manifest::predict_name("mlp", "hinge", 256),
            "predict_mlp_hinge_bs256"
        );
        assert_eq!(Manifest::loss_eval_name("square", 4096), "loss_eval_square_n4096");
    }

    #[test]
    fn missing_file_is_error() {
        let dir = std::env::temp_dir().join("allpairs_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format_version": 1, "margin": 1.0, "artifacts": [
              {"name": "x", "file": "gone.hlo.txt", "kind": "init", "model": "m",
               "loss": "l", "batch": 0, "n_state": 1, "inputs": [], "n_outputs": 1}]}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
