//! The execution layer: a pluggable [`Backend`] trait with two
//! implementations.
//!
//! * [`backend`] — the [`Backend`] / [`ModelExecutor`] traits and the
//!   serializable [`BackendSpec`] that crosses thread and config
//!   boundaries (see DESIGN.md §5).
//! * [`engine`] — the deterministic parallel train-step engine: fixed
//!   chunk layout, per-chunk f64 gradient partials, fixed chunk-order
//!   reduction — bit-identical results at every thread count (see
//!   DESIGN.md §7).
//! * [`native`] — the default, fully self-contained pure-Rust backend:
//!   forward/gradient execution built on [`crate::losses::functional`],
//!   parallelized through the engine.  `Send + Sync`.
//! * `pjrt` (feature `pjrt`) — the AOT-artifact runtime: a PJRT CPU
//!   client plus a lazy cache of compiled executables, keyed by artifact
//!   name.  HLO **text** is the interchange format
//!   (`HloModuleProto::from_text_file`) — see DESIGN.md §4 for why
//!   serialized protos are rejected here.  `xla::PjRtClient` is
//!   `Rc`-based (not `Send`), so one runtime must live and die on a
//!   single thread; the sweep scheduler connects a backend per worker
//!   from a shared [`BackendSpec`].
//! * [`artifact`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) into a typed registry.
//! * [`tensor`] — backend-neutral host tensors.

pub mod artifact;
pub mod backend;
pub mod engine;
pub mod native;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{Artifact, ArtifactKind, Manifest};
pub use backend::{Backend, BackendSpec, ModelExecutor};
pub use engine::{ChunkModel, Engine};
pub use native::{NativeBackend, NativeSpec};
pub use tensor::HostTensor;

#[cfg(feature = "pjrt")]
pub use pjrt::{PjrtBackend, Runtime};
