//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! * [`artifact`] — parses `artifacts/manifest.json` (written by
//!   `python/compile/aot.py`) into a typed registry.
//! * [`tensor`] — host-side tensors ↔ `xla::Literal` conversions.
//! * [`client`] — [`client::Runtime`]: a PJRT CPU client plus a lazy
//!   cache of compiled executables, keyed by artifact name.  HLO **text**
//!   is the interchange format (`HloModuleProto::from_text_file`) — see
//!   DESIGN.md §4 for why serialized protos are rejected here.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so one [`client::Runtime`]
//! must live and die on a single thread; the sweep scheduler gives each
//! worker thread its own runtime instance.

pub mod artifact;
pub mod client;
pub mod tensor;

pub use artifact::{Artifact, ArtifactKind, Manifest};
pub use client::Runtime;
pub use tensor::HostTensor;
