//! Aggregation of repeated measurements (the paper's "mean/median over
//! five random seeds" protocol, section 4.2).

/// Running summary of a sample of f64 measurements.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_values(values: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in values {
            s.push(v);
        }
        s
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n − 1 denominator); 0 for n < 2.
    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let mean = self.mean();
        let ss: f64 = self.values.iter().map(|v| (v - mean) * (v - mean)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Median (average of middle two for even n).  The paper reports the
    /// *median* selected hyper-parameter over seeds (Table 2).
    pub fn median(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let n = sorted.len();
        if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        }
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.4} ± {:.4} (n={})", self.mean(), self.std(), self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let s = Summary::from_values([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert!((s.std() - (2.5_f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn even_median_averages() {
        let s = Summary::from_values([4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median(), 2.5);
    }

    #[test]
    fn degenerate_sizes() {
        assert!(Summary::new().mean().is_nan());
        let one = Summary::from_values([7.0]);
        assert_eq!(one.mean(), 7.0);
        assert_eq!(one.std(), 0.0);
        assert_eq!(one.median(), 7.0);
    }
}
