//! Evaluation metrics: ROC curves, AUC, and result aggregation.
//!
//! AUC is the paper's model-selection criterion (max validation AUC picks
//! the epoch and hyper-parameters) *and* its headline evaluation metric
//! (Figure 3 reports test AUC).  [`auc`] implements the tie-corrected
//! Mann-Whitney formulation in O(n log n) — the same complexity as the
//! paper's loss, which is exactly the section-5 "monitoring" argument.

pub mod auc;
pub mod partial_auc;
pub mod roc;
pub mod summary;

pub use auc::auc;
pub use partial_auc::partial_auc;
pub use roc::{roc_curve, RocPoint};
pub use summary::Summary;
