//! Area under the ROC curve via the Mann-Whitney U statistic.
//!
//! Bamber (1975): AUC equals the probability that a uniformly random
//! positive example outranks a uniformly random negative one, with ties
//! counting half:
//!
//! ```text
//! AUC = [ #{(j,k): ŷⱼ > ŷₖ} + ½ #{(j,k): ŷⱼ = ŷₖ} ] / (n⁺ n⁻)
//! ```
//!
//! Computed in O(n log n) with one sort using the rank-sum identity
//! `U = R⁺ − n⁺(n⁺+1)/2`, where `R⁺` is the sum of (mid-)ranks of the
//! positive examples.  Midranks make the tie correction exact.

/// Tie-corrected AUC of `scores` against {0,1} positive indicators.
///
/// Returns `None` when one of the classes is empty (AUC undefined).
pub fn auc(scores: &[f32], is_pos: &[f32]) -> Option<f64> {
    assert_eq!(scores.len(), is_pos.len());
    let n = scores.len();
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return None;
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by(|&a, &b| scores[a as usize].total_cmp(&scores[b as usize]));

    // Walk tie groups assigning midranks; accumulate positive rank sum.
    let mut rank_sum_pos = 0.0_f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1] as usize] == scores[order[i] as usize] {
            j += 1;
        }
        // ranks i+1 ..= j+1 share the midrank.
        let midrank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            if is_pos[idx as usize] != 0.0 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (n_pos as f64) * (n_pos as f64 + 1.0) / 2.0;
    Some(u / (n_pos as f64 * n_neg as f64))
}

/// Brute-force O(n²) AUC — test oracle only.
#[cfg(test)]
pub fn auc_naive(scores: &[f32], is_pos: &[f32]) -> Option<f64> {
    let pos: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .filter(|(_, &p)| p != 0.0)
        .map(|(&s, _)| s)
        .collect();
    let neg: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .filter(|(_, &p)| p == 0.0)
        .map(|(&s, _)| s)
        .collect();
    if pos.is_empty() || neg.is_empty() {
        return None;
    }
    let mut u = 0.0_f64;
    for &a in &pos {
        for &b in &neg {
            if a > b {
                u += 1.0;
            } else if a == b {
                u += 0.5;
            }
        }
    }
    Some(u / (pos.len() as f64 * neg.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking_is_one() {
        let s = vec![0.9, 0.8, 0.2, 0.1];
        let p = vec![1.0, 1.0, 0.0, 0.0];
        assert_eq!(auc(&s, &p), Some(1.0));
    }

    #[test]
    fn reversed_ranking_is_zero() {
        let s = vec![0.1, 0.2, 0.8, 0.9];
        let p = vec![1.0, 1.0, 0.0, 0.0];
        assert_eq!(auc(&s, &p), Some(0.0));
    }

    #[test]
    fn constant_predictions_are_half() {
        let s = vec![0.5; 10];
        let p = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert_eq!(auc(&s, &p), Some(0.5));
    }

    #[test]
    fn undefined_for_single_class() {
        assert_eq!(auc(&[0.1, 0.2], &[1.0, 1.0]), None);
        assert_eq!(auc(&[0.1, 0.2], &[0.0, 0.0]), None);
        assert_eq!(auc(&[], &[]), None);
    }

    #[test]
    fn matches_naive_on_random_data_with_ties() {
        let mut state = 0x1234_5678_9ABC_DEF0_u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for trial in 0..30 {
            let n = 5 + (trial * 13) % 200;
            let s: Vec<f32> = (0..n)
                .map(|_| ((next() * 8.0).round() / 8.0) as f32) // heavy ties
                .collect();
            let p: Vec<f32> = (0..n)
                .map(|_| if next() < 0.3 { 1.0 } else { 0.0 })
                .collect();
            match (auc(&s, &p), auc_naive(&s, &p)) {
                (Some(a), Some(b)) => assert!((a - b).abs() < 1e-12, "{a} vs {b}"),
                (None, None) => {}
                other => panic!("definedness mismatch {other:?}"),
            }
        }
    }

    #[test]
    fn invariant_under_monotone_transform() {
        let s = vec![0.1, 0.4, 0.35, 0.8, 0.7];
        let p = vec![0.0, 1.0, 0.0, 1.0, 1.0];
        let a1 = auc(&s, &p).unwrap();
        let s2: Vec<f32> = s.iter().map(|&x| (x * 3.0).exp()).collect();
        let a2 = auc(&s2, &p).unwrap();
        assert!((a1 - a2).abs() < 1e-12);
    }

    #[test]
    fn mann_whitney_equivalence_hand_case() {
        // pos {0.9, 0.5}, neg {0.5, 0.1}: pairs (0.9,0.5)>, (0.9,0.1)>,
        // (0.5,0.5)=, (0.5,0.1)> => (3 + 0.5) / 4 = 0.875
        let s = vec![0.9, 0.5, 0.5, 0.1];
        let p = vec![1.0, 1.0, 0.0, 0.0];
        assert!((auc(&s, &p).unwrap() - 0.875).abs() < 1e-12);
    }
}
