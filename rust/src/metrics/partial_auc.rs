//! Partial AUC: area under the ROC curve restricted to an FPR interval
//! (Narasimhan & Agarwal 2013, cited by the paper's related work).
//!
//! `pauc(scores, is_pos, alpha, beta)` integrates TPR over
//! FPR ∈ [alpha, beta] and normalizes by (beta − alpha), so a perfect
//! ranker scores 1 and a random one 0.5 — directly comparable to full
//! AUC (which is the special case `[0, 1]`).

use super::roc::{roc_curve, RocPoint};

/// Normalized partial AUC over FPR in `[alpha, beta]`.
///
/// Returns `None` when a class is empty or the interval is degenerate.
pub fn partial_auc(scores: &[f32], is_pos: &[f32], alpha: f64, beta: f64) -> Option<f64> {
    if !(0.0..=1.0).contains(&alpha) || !(0.0..=1.0).contains(&beta) || beta <= alpha {
        return None;
    }
    let curve = roc_curve(scores, is_pos);
    if curve.is_empty() {
        return None;
    }
    Some(clipped_area(&curve, alpha, beta) / (beta - alpha))
}

/// Area under the piecewise-linear ROC curve clipped to [alpha, beta].
fn clipped_area(curve: &[RocPoint], alpha: f64, beta: f64) -> f64 {
    let mut area = 0.0;
    for w in curve.windows(2) {
        let (x0, y0) = (w[0].fpr, w[0].tpr);
        let (x1, y1) = (w[1].fpr, w[1].tpr);
        if x1 <= alpha || x0 >= beta || x1 == x0 {
            // vertical segments (x1 == x0) carry no area
            continue;
        }
        let lo = x0.max(alpha);
        let hi = x1.min(beta);
        // linear interpolation of TPR at the clip points
        let t = |x: f64| y0 + (y1 - y0) * (x - x0) / (x1 - x0);
        area += (hi - lo) * (t(lo) + t(hi)) / 2.0;
    }
    area
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::auc::auc;

    fn toy() -> (Vec<f32>, Vec<f32>) {
        (
            vec![0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2],
            vec![1.0, 1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0],
        )
    }

    #[test]
    fn full_interval_equals_auc() {
        let (s, p) = toy();
        let full = partial_auc(&s, &p, 0.0, 1.0).unwrap();
        let a = auc(&s, &p).unwrap();
        assert!((full - a).abs() < 1e-12, "{full} vs {a}");
    }

    #[test]
    fn perfect_ranker_is_one_everywhere() {
        let s = vec![0.9, 0.8, 0.2, 0.1];
        let p = vec![1.0, 1.0, 0.0, 0.0];
        for (a, b) in [(0.0, 0.1), (0.0, 0.5), (0.3, 0.9)] {
            assert!((partial_auc(&s, &p, a, b).unwrap() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn random_scores_near_half() {
        // diagonal ROC: TPR == FPR, so normalized pAUC of [a,b] is (a+b)/2.
        let n = 1000;
        let mut state = 99_u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        let s: Vec<f32> = (0..n).map(|_| next() as f32).collect();
        let p: Vec<f32> = (0..n).map(|_| if next() < 0.5 { 1.0 } else { 0.0 }).collect();
        let got = partial_auc(&s, &p, 0.0, 0.2).unwrap();
        assert!((got - 0.1).abs() < 0.05, "{got}");
    }

    #[test]
    fn low_fpr_region_discriminates_early_errors() {
        // Both rankers misrank exactly 3 of the 9 pairs (full AUC = 2/3),
        // but A's errors are an early false positive (a negative ranked
        // first) while B's are a late positive.  pAUC at low FPR must
        // penalize A much harder.
        let p_a = vec![0.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let a = vec![0.9, 0.8, 0.7, 0.6, 0.5, 0.4]; // neg on top
        let p_b = vec![1.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let b = vec![0.9, 0.8, 0.7, 0.6, 0.5, 0.4]; // pos at bottom
        let auc_a = auc(&a, &p_a).unwrap();
        let auc_b = auc(&b, &p_b).unwrap();
        assert!((auc_a - auc_b).abs() < 1e-9, "{auc_a} vs {auc_b}");
        let pa = partial_auc(&a, &p_a, 0.0, 1.0 / 3.0).unwrap();
        let pb = partial_auc(&b, &p_b, 0.0, 1.0 / 3.0).unwrap();
        assert!(pa < pb - 0.3, "{pa} vs {pb}");
    }

    #[test]
    fn invalid_intervals_rejected() {
        let (s, p) = toy();
        assert!(partial_auc(&s, &p, 0.5, 0.5).is_none());
        assert!(partial_auc(&s, &p, 0.7, 0.2).is_none());
        assert!(partial_auc(&s, &p, -0.1, 0.5).is_none());
        assert!(partial_auc(&s, &[1.0; 8], 0.0, 1.0).is_none());
    }
}
