//! Full ROC curves (TPR vs FPR over every threshold).
//!
//! Used by the `quickstart` example and the reporting layer to emit the
//! curve behind the AUC number; the trapezoid integral of the curve must
//! equal the Mann-Whitney AUC from [`super::auc`] (tested below — that is
//! Bamber's 1975 equivalence, the identity the whole paper builds on).

/// One operating point of the ROC curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RocPoint {
    /// Score threshold: predict positive iff `score >= threshold`.
    pub threshold: f32,
    /// False positive rate at this threshold.
    pub fpr: f64,
    /// True positive rate at this threshold.
    pub tpr: f64,
}

/// ROC curve from (0,0) to (1,1), one point per distinct score.
///
/// Returns an empty vector when either class is absent.
pub fn roc_curve(scores: &[f32], is_pos: &[f32]) -> Vec<RocPoint> {
    assert_eq!(scores.len(), is_pos.len());
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count();
    let n_neg = scores.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return Vec::new();
    }
    let mut order: Vec<u32> = (0..scores.len() as u32).collect();
    // Descending: highest score first (lowest threshold last).
    order.sort_unstable_by(|&a, &b| scores[b as usize].total_cmp(&scores[a as usize]));

    let mut points = vec![RocPoint {
        threshold: f32::INFINITY,
        fpr: 0.0,
        tpr: 0.0,
    }];
    let (mut tp, mut fp) = (0usize, 0usize);
    let mut i = 0;
    while i < order.len() {
        let thresh = scores[order[i] as usize];
        // absorb the whole tie group before emitting a point
        while i < order.len() && scores[order[i] as usize] == thresh {
            if is_pos[order[i] as usize] != 0.0 {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        points.push(RocPoint {
            threshold: thresh,
            fpr: fp as f64 / n_neg as f64,
            tpr: tp as f64 / n_pos as f64,
        });
    }
    points
}

/// Trapezoidal area under a ROC curve from [`roc_curve`].
pub fn trapezoid_auc(points: &[RocPoint]) -> f64 {
    points
        .windows(2)
        .map(|w| (w[1].fpr - w[0].fpr) * (w[1].tpr + w[0].tpr) / 2.0)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::auc::auc;

    #[test]
    fn endpoints_are_corners() {
        let s = vec![0.9, 0.1, 0.5, 0.4];
        let p = vec![1.0, 0.0, 1.0, 0.0];
        let curve = roc_curve(&s, &p);
        assert_eq!((curve[0].fpr, curve[0].tpr), (0.0, 0.0));
        let last = curve.last().unwrap();
        assert_eq!((last.fpr, last.tpr), (1.0, 1.0));
    }

    #[test]
    fn trapezoid_equals_mann_whitney() {
        // Bamber 1975: the equivalence this paper's losses relax.
        let mut state = 42_u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 11) as f64 / (1u64 << 53) as f64
        };
        for trial in 0..20 {
            let n = 10 + trial * 17;
            let s: Vec<f32> = (0..n).map(|_| ((next() * 4.0).round() / 4.0) as f32).collect();
            let p: Vec<f32> = (0..n).map(|_| if next() < 0.4 { 1.0 } else { 0.0 }).collect();
            let curve = roc_curve(&s, &p);
            if curve.is_empty() {
                continue;
            }
            let a_trap = trapezoid_auc(&curve);
            let a_mw = auc(&s, &p).unwrap();
            assert!((a_trap - a_mw).abs() < 1e-12, "{a_trap} vs {a_mw}");
        }
    }

    #[test]
    fn monotone_nondecreasing() {
        let s = vec![0.3, 0.9, 0.5, 0.2, 0.8, 0.1];
        let p = vec![0.0, 1.0, 1.0, 0.0, 0.0, 1.0];
        let curve = roc_curve(&s, &p);
        for w in curve.windows(2) {
            assert!(w[1].fpr >= w[0].fpr);
            assert!(w[1].tpr >= w[0].tpr);
        }
    }

    #[test]
    fn empty_for_single_class() {
        assert!(roc_curve(&[0.1, 0.2], &[1.0, 1.0]).is_empty());
    }
}
