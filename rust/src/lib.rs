//! # allpairs — log-linear all-pairs losses for unbalanced classification
//!
//! Production-grade reproduction of Rust & Hocking (2023), *"A Log-linear
//! Gradient Descent Algorithm for Unbalanced Binary Classification using
//! the All Pairs Squared Hinge Loss"*, as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1 (Pallas, build time)** — the paper's Algorithm 1 / Algorithm 2
//!   sweeps as TPU-style kernels (`python/compile/kernels/`), lowered via
//!   `jax.export`-style HLO-text AOT into `artifacts/`.
//! * **L2 (JAX, build time)** — MiniResNet / MLP models, SGD+momentum and
//!   PESG optimizers, four training losses (`hinge`, `square`,
//!   `logistic`, `aucm`).
//! * **L3 (this crate, run time)** — everything that runs: native Rust
//!   implementations of the paper's algorithms ([`losses`]), ROC/AUC
//!   metrics ([`metrics`]), synthetic data substrates ([`data`]), a PJRT
//!   runtime that executes the AOT artifacts ([`runtime`]), the training
//!   loop ([`train`]), the cross-validation hyper-parameter sweep engine
//!   ([`sweep`]), reporting ([`report`]) and experiment orchestration
//!   ([`coordinator`]).
//!
//! Python never runs on the training path: after `make artifacts`, the
//! `allpairs` binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use allpairs::losses::{functional, PairwiseLoss};
//!
//! // The paper's O(n log n) squared hinge loss + gradient:
//! let scores = vec![0.9_f32, 0.2, 0.6, 0.1];
//! let is_pos = vec![1.0_f32, 0.0, 1.0, 0.0];
//! let loss = functional::SquaredHinge::new(1.0);
//! let (value, grad) = loss.loss_and_grad(&scores, &is_pos);
//! assert!(value >= 0.0 && grad.len() == 4);
//! ```

pub mod config;
pub mod coordinator;
pub mod data;
pub mod losses;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod sweep;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
