//! # allpairs — log-linear all-pairs losses for unbalanced classification
//!
//! Production-grade reproduction of Rust & Hocking (2023), *"A Log-linear
//! Gradient Descent Algorithm for Unbalanced Binary Classification using
//! the All Pairs Squared Hinge Loss"*, as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1 (Pallas, build time)** — the paper's Algorithm 1 / Algorithm 2
//!   sweeps as TPU-style kernels (`python/compile/kernels/`), lowered via
//!   `jax.export`-style HLO-text AOT into `artifacts/`.
//! * **L2 (JAX, build time)** — MiniResNet / MLP models, SGD+momentum and
//!   PESG optimizers, four training losses (`hinge`, `square`,
//!   `logistic`, `aucm`).
//! * **L3 (this crate, run time)** — everything that runs: native Rust
//!   implementations of the paper's algorithms ([`losses`]), ROC/AUC
//!   metrics ([`metrics`]), synthetic data substrates ([`data`])
//!   with an out-of-core shard store for n ≫ RAM ([`data::shard`],
//!   bit-identical to resident training), a
//!   pluggable execution layer ([`runtime`]) with a self-contained
//!   native backend (default) and a PJRT artifact runtime (feature
//!   `pjrt`), the training loop ([`train`]), the cross-validation
//!   hyper-parameter sweep engine ([`sweep`]), an online scoring
//!   service ([`serve`]), reporting ([`report`]), experiment
//!   orchestration ([`coordinator`]) and an in-repo invariant linter
//!   ([`analysis`], `allpairs lint`).
//!
//! The default build is fully self-contained: `cargo build && cargo test`
//! need no Python, no artifacts and no network.  With `make artifacts`
//! and `--features pjrt`, the same trainer/sweep code runs through the
//! AOT kernels instead — both implement [`runtime::Backend`].
//!
//! ## Quick tour
//!
//! Losses are *typed*: a [`losses::LossSpec`] is parsed (and validated)
//! once at the API edge and carries everything downstream — including
//! the margin, which makes `"hinge@margin=2"` a first-class sweep axis.
//! The paper's O(n log n) squared hinge loss + gradient through the
//! allocation-free kernel API:
//!
//! ```
//! use allpairs::losses::{BatchView, LossFn, LossSpec, LossWorkspace};
//!
//! let spec: LossSpec = "hinge@margin=2".parse()?;
//! let kernel = spec.build()?; // a boxed, allocation-free LossFn
//! let scores = vec![0.9_f32, 0.2, 0.6, 0.1];
//! let is_pos = vec![1.0_f32, 0.0, 1.0, 0.0];
//! let mut ws = LossWorkspace::new();
//! let value = kernel.loss_and_grad(BatchView::new(&scores, &is_pos), &mut ws);
//! assert!(value >= 0.0 && ws.grad.len() == 4);
//! assert_eq!(spec.to_string(), "hinge@margin=2"); // specs round-trip
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Training through the backend layer (one gradient step on a batch);
//! `"whinge"` selects the class-balanced weighted hinge scenario:
//!
//! ```
//! use allpairs::losses::LossSpec;
//! use allpairs::runtime::{BackendSpec, NativeSpec};
//! use allpairs::train::Trainer;
//!
//! let spec = BackendSpec::Native(NativeSpec {
//!     input_dim: 4,
//!     hidden: 8,
//!     threads: 1,
//!     ..NativeSpec::default()
//! });
//! let backend = spec.connect()?;
//! let loss: LossSpec = "whinge".parse()?;
//! let mut trainer = Trainer::new(backend.as_ref(), "mlp", &loss, 2)?;
//! trainer.init(0)?;
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod losses;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod train;
pub mod util;

/// Crate-wide result type (anyhow for rich error context).
pub type Result<T> = anyhow::Result<T>;
