//! Experiment configuration: JSON-loadable, CLI-overridable.
//!
//! The defaults reproduce the paper's protocol (section 4.2) at
//! reproduction scale: three synthetic datasets, imratio grid
//! {0.1, 0.01, 0.001}, batch grid {10, 50, 100, 500, 1000},
//! loss-dependent learning-rate grids, five seeds, max-validation-AUC
//! model selection.

use std::path::Path;

use crate::losses::LossSpec;
use crate::runtime::BackendSpec;
use crate::util::json::Json;

/// Learning-rate grid for one loss (the paper uses wider grids for the
/// baselines than for the hinge loss, which diverges at large rates).
pub fn default_lr_grid(loss: &LossSpec) -> Vec<f64> {
    match loss {
        // paper: 1e-4 .. 1e-1 for the proposed squared hinge (the whole
        // pairwise hinge family shares its divergence behavior)
        LossSpec::Hinge { .. }
        | LossSpec::Square { .. }
        | LossSpec::LinearHinge { .. }
        | LossSpec::WeightedHinge { .. } => vec![1e-3, 1e-2, 3.16e-2, 1e-1],
        // paper: 1e-4 .. 1e2 for LIBAUC and logistic
        LossSpec::Logistic | LossSpec::Aucm => vec![1e-3, 1e-2, 1e-1, 1.0],
    }
}

/// Full sweep configuration (Table 2 / Figure 3 protocol).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepConfig {
    /// Synthetic dataset names (see `data::synth::SYNTH_DATASETS`).
    pub datasets: Vec<String>,
    /// Train-set positive-label proportions.
    pub imratios: Vec<f64>,
    /// Training losses to compare (parsed loss specs; the per-loss
    /// margin is a sweepable part of the spec, e.g. `"hinge@margin=2"`).
    pub losses: Vec<LossSpec>,
    /// Batch sizes (must have matching AOT artifacts).
    pub batch_sizes: Vec<usize>,
    /// Random seeds (model init + subtrain/validation split).
    pub seeds: Vec<u32>,
    /// Training epochs per run (an upper bound when `patience` is set).
    pub epochs: usize,
    /// Early-stopping patience in epochs: stop a run once validation
    /// AUC has not improved for this many consecutive epochs
    /// (None = the paper's fixed-epoch protocol).
    pub patience: Option<usize>,
    /// Mini-batch sampling modes to sweep — a hyper-parameter axis like
    /// `batch_sizes`.  Names per [`crate::data::SamplingMode::parse`]:
    /// `"preserve"`, `"rebalance"`, `"rebalance:F"`.
    pub sampling_modes: Vec<String>,
    /// Validation fraction of the (imbalanced) train set.
    pub val_fraction: f64,
    /// Model name (must have matching AOT artifacts).
    pub model: String,
    /// Dataset generation seed (shared across the sweep).
    pub data_seed: u64,
    /// Execution backend (native by default; each sweep worker connects
    /// its own instance from this spec).
    pub backend: BackendSpec,
    /// Worker threads.
    pub workers: usize,
    /// Optional cap on train-pool size (smoke runs).
    pub max_train: Option<usize>,
    /// Use only the largest `k` learning rates of each loss's grid
    /// (budgeted reproduction runs; None = the full paper grid).
    pub max_lrs: Option<usize>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            datasets: vec![
                "synth-cifar".into(),
                "synth-stl".into(),
                "synth-pets".into(),
            ],
            imratios: vec![0.1, 0.01, 0.001],
            losses: vec![LossSpec::hinge(), LossSpec::aucm(), LossSpec::logistic()],
            batch_sizes: vec![10, 50, 100, 500, 1000],
            seeds: vec![0, 1, 2, 3, 4],
            epochs: 20,
            patience: None,
            sampling_modes: vec!["preserve".into()],
            val_fraction: 0.2,
            model: "resnet".into(),
            data_seed: 20230223, // the paper's date, for flavor
            backend: BackendSpec::default(),
            workers: num_cpus(),
            max_train: None,
            max_lrs: None,
        }
    }
}

impl SweepConfig {
    /// Load from JSON; absent fields keep their defaults.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let mut c = Self::default();
        let strings = |v: &Json| -> crate::Result<Vec<String>> {
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("expected array of strings"))?
                .iter()
                .map(|s| {
                    Ok(s.as_str()
                        .ok_or_else(|| anyhow::anyhow!("expected string"))?
                        .to_string())
                })
                .collect()
        };
        let f64s = |v: &Json| -> crate::Result<Vec<f64>> {
            v.as_arr()
                .ok_or_else(|| anyhow::anyhow!("expected array of numbers"))?
                .iter()
                .map(|n| n.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
                .collect()
        };
        if let Some(v) = j.get("datasets") {
            c.datasets = strings(v)?;
        }
        if let Some(v) = j.get("imratios") {
            c.imratios = f64s(v)?;
        }
        if let Some(v) = j.get("losses") {
            // Validated here, at config-parse time: a typo'd loss fails
            // before any data is generated or job scheduled.
            c.losses = strings(v)?
                .iter()
                .map(|name| name.parse::<LossSpec>())
                .collect::<crate::Result<Vec<_>>>()?;
        }
        if let Some(v) = j.get("batch_sizes") {
            c.batch_sizes = f64s(v)?.into_iter().map(|n| n as usize).collect();
        }
        if let Some(v) = j.get("seeds") {
            c.seeds = f64s(v)?.into_iter().map(|n| n as u32).collect();
        }
        if let Some(v) = j.get("epochs") {
            c.epochs = v.as_usize().ok_or_else(|| anyhow::anyhow!("epochs"))?;
        }
        if let Some(v) = j.get("patience") {
            c.patience = match v {
                Json::Null => None,
                other => Some(
                    other
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("patience must be an integer"))?,
                ),
            };
        }
        if let Some(v) = j.get("sampling_modes") {
            c.sampling_modes = strings(v)?;
            for name in &c.sampling_modes {
                crate::data::SamplingMode::parse(name)?;
            }
        }
        if let Some(v) = j.get("val_fraction") {
            c.val_fraction = v.as_f64().ok_or_else(|| anyhow::anyhow!("val_fraction"))?;
        }
        if let Some(v) = j.get("model") {
            c.model = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("model"))?
                .to_string();
        }
        if let Some(v) = j.get("data_seed") {
            c.data_seed = v.as_f64().ok_or_else(|| anyhow::anyhow!("data_seed"))? as u64;
        }
        if let Some(v) = j.get("backend") {
            c.backend = BackendSpec::from_json(v)?;
        }
        if let Some(v) = j.get("workers") {
            c.workers = v.as_usize().ok_or_else(|| anyhow::anyhow!("workers"))?;
        }
        if let Some(v) = j.get("max_train") {
            c.max_train = v.as_usize();
        }
        if let Some(v) = j.get("max_lrs") {
            c.max_lrs = v.as_usize();
        }
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        let strings = |v: &[String]| Json::Arr(v.iter().map(Json::str).collect());
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&n| Json::num(n)).collect());
        Json::obj([
            ("datasets", strings(&self.datasets)),
            ("imratios", nums(&self.imratios)),
            (
                "losses",
                Json::Arr(self.losses.iter().map(|l| Json::str(l.to_string())).collect()),
            ),
            (
                "batch_sizes",
                Json::Arr(self.batch_sizes.iter().map(|&b| Json::num(b as f64)).collect()),
            ),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
            ("epochs", Json::num(self.epochs as f64)),
            (
                "patience",
                match self.patience {
                    Some(p) => Json::num(p as f64),
                    None => Json::Null,
                },
            ),
            ("sampling_modes", strings(&self.sampling_modes)),
            ("val_fraction", Json::num(self.val_fraction)),
            ("model", Json::str(&self.model)),
            ("data_seed", Json::num(self.data_seed as f64)),
            ("backend", self.backend.to_json()),
            ("workers", Json::num(self.workers as f64)),
            (
                "max_train",
                match self.max_train {
                    Some(v) => Json::num(v as f64),
                    None => Json::Null,
                },
            ),
            (
                "max_lrs",
                match self.max_lrs {
                    Some(v) => Json::num(v as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        crate::util::fsio::write_atomic(path, self.to_json().dumps().as_bytes())
    }

    /// Drop losses the configured backend cannot run (the `aucm` LIBAUC
    /// baseline exists only as an AOT artifact).  With `keep_three`, a
    /// default-protocol list that lost `aucm` gets the native `square`
    /// loss substituted so three losses are still compared.  Returns
    /// whether the list changed (callers log the adjustment).
    pub fn adapt_losses_to_backend(&mut self, keep_three: bool) -> bool {
        if !matches!(self.backend, BackendSpec::Native(_)) {
            return false;
        }
        if !self.losses.iter().any(|l| matches!(l, LossSpec::Aucm)) {
            return false;
        }
        self.losses.retain(|l| !matches!(l, LossSpec::Aucm));
        if keep_three && !self.losses.iter().any(|l| matches!(l, LossSpec::Square { .. })) {
            self.losses.push(LossSpec::square());
        }
        true
    }

    /// Learning-rate grid for a loss, optionally truncated to the
    /// largest `max_lrs` entries (the grids are sorted ascending).
    pub fn lr_grid(&self, loss: &LossSpec) -> Vec<f64> {
        let grid = default_lr_grid(loss);
        match self.max_lrs {
            Some(k) if k < grid.len() => grid[grid.len() - k..].to_vec(),
            _ => grid,
        }
    }

    /// Total number of training runs the sweep will schedule.
    pub fn n_runs(&self) -> usize {
        let lrs: usize = self.losses.iter().map(|l| self.lr_grid(l).len()).sum();
        self.datasets.len()
            * self.imratios.len()
            * self.seeds.len()
            * self.batch_sizes.len()
            * self.sampling_modes.len()
            * lrs
    }
}

/// Best-effort physical parallelism.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = SweepConfig::default();
        assert_eq!(c.imratios, vec![0.1, 0.01, 0.001]);
        assert_eq!(c.batch_sizes, vec![10, 50, 100, 500, 1000]);
        assert_eq!(c.seeds.len(), 5);
        assert!((c.val_fraction - 0.2).abs() < 1e-12);
    }

    #[test]
    fn lr_grid_is_loss_dependent() {
        assert!(default_lr_grid(&LossSpec::hinge()).iter().all(|&lr| lr <= 0.1));
        assert!(default_lr_grid(&LossSpec::weighted_hinge())
            .iter()
            .all(|&lr| lr <= 0.1));
        assert!(default_lr_grid(&LossSpec::logistic()).contains(&1.0));
    }

    #[test]
    fn json_roundtrip() {
        let c = SweepConfig {
            epochs: 3,
            max_train: Some(100),
            ..Default::default()
        };
        let path = std::env::temp_dir().join("allpairs_cfg_test.json");
        c.save(&path).unwrap();
        let back = SweepConfig::load(&path).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn patience_and_sampling_roundtrip() {
        let c = SweepConfig {
            patience: Some(3),
            sampling_modes: vec!["preserve".into(), "rebalance:0.25".into()],
            ..Default::default()
        };
        let path = std::env::temp_dir().join("allpairs_cfg_stream.json");
        c.save(&path).unwrap();
        let back = SweepConfig::load(&path).unwrap();
        assert_eq!(back, c);
        // the sampling axis multiplies the run count
        assert_eq!(back.n_runs(), 2 * SweepConfig::default().n_runs());
        // invalid mode names are rejected at load time
        std::fs::write(&path, r#"{"sampling_modes": ["bogus"]}"#).unwrap();
        assert!(SweepConfig::load(&path).is_err());
        // non-integer patience is an error, not a silent None ...
        std::fs::write(&path, r#"{"patience": "5"}"#).unwrap();
        assert!(SweepConfig::load(&path).is_err());
        // ... while an explicit null means "no early stopping"
        std::fs::write(&path, r#"{"patience": null}"#).unwrap();
        assert_eq!(SweepConfig::load(&path).unwrap().patience, None);
    }

    #[test]
    fn n_runs_counts_product() {
        let c = SweepConfig {
            datasets: vec!["a".into()],
            imratios: vec![0.1],
            losses: vec![LossSpec::hinge()],
            batch_sizes: vec![10, 50],
            seeds: vec![0, 1],
            ..Default::default()
        };
        assert_eq!(c.n_runs(), 2 * 2 * default_lr_grid(&LossSpec::hinge()).len());
    }

    #[test]
    fn adapt_losses_drops_aucm_only_on_native() {
        let mut c = SweepConfig::default(); // native backend, aucm present
        assert!(c.adapt_losses_to_backend(true));
        assert_eq!(
            c.losses,
            vec![LossSpec::hinge(), LossSpec::logistic(), LossSpec::square()]
        );
        assert!(!c.adapt_losses_to_backend(true)); // idempotent

        let mut user = SweepConfig {
            losses: vec![LossSpec::hinge(), LossSpec::aucm()],
            ..Default::default()
        };
        assert!(user.adapt_losses_to_backend(false));
        assert_eq!(user.losses, vec![LossSpec::hinge()]); // no substitution

        let mut pjrt = SweepConfig {
            backend: BackendSpec::pjrt("artifacts"),
            ..Default::default()
        };
        assert!(!pjrt.adapt_losses_to_backend(true));
        assert!(pjrt.losses.contains(&LossSpec::aucm()));
    }

    #[test]
    fn unknown_loss_fails_at_parse_time_listing_valid_specs() {
        // The fail-fast guarantee: a typo'd loss is rejected while
        // loading the config — long before data generation or
        // Backend::open — with an error naming the valid specs.
        let path = std::env::temp_dir().join("allpairs_cfg_badloss.json");
        std::fs::write(&path, r#"{"losses": ["typo"]}"#).unwrap();
        let err = SweepConfig::load(&path).unwrap_err().to_string();
        assert!(err.contains("hinge") && err.contains("whinge"), "{err}");
        // malformed margins are caught the same way
        std::fs::write(&path, r#"{"losses": ["hinge@margin=-2"]}"#).unwrap();
        assert!(SweepConfig::load(&path).is_err());
    }

    #[test]
    fn loss_margins_are_sweepable_and_roundtrip() {
        let c = SweepConfig {
            losses: vec![
                LossSpec::hinge(),
                LossSpec::Hinge { margin: 2.0 },
                LossSpec::weighted_hinge(),
            ],
            ..Default::default()
        };
        let path = std::env::temp_dir().join("allpairs_cfg_margins.json");
        c.save(&path).unwrap();
        let back = SweepConfig::load(&path).unwrap();
        assert_eq!(back, c);
        // the two hinge margins are distinct sweep axis entries
        let single = SweepConfig {
            losses: vec![LossSpec::hinge()],
            ..Default::default()
        };
        assert_eq!(back.n_runs(), 3 * single.n_runs());
    }

    #[test]
    fn backend_roundtrips_through_json() {
        let c = SweepConfig {
            backend: BackendSpec::pjrt("my/artifacts"),
            ..Default::default()
        };
        let path = std::env::temp_dir().join("allpairs_cfg_backend.json");
        c.save(&path).unwrap();
        let back = SweepConfig::load(&path).unwrap();
        assert_eq!(back.backend, BackendSpec::pjrt("my/artifacts"));
    }

    #[test]
    fn partial_json_uses_defaults() {
        let path = std::env::temp_dir().join("allpairs_cfg_partial.json");
        std::fs::write(&path, r#"{"epochs": 7}"#).unwrap();
        let c = SweepConfig::load(&path).unwrap();
        assert_eq!(c.epochs, 7);
        assert_eq!(c.model, "resnet");
    }
}
