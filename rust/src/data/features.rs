//! Synthetic feature-vector datasets (the MLP-path analogue of
//! [`super::synth`]'s image generators).
//!
//! Used by the quickstart / L-BFGS examples and the integration tests:
//! positives are shifted along a subset of dimensions (optionally with
//! anisotropic scales to produce the ill-conditioned regime the paper's
//! §5 LBFGS discussion targets).

use super::dataset::Dataset;
use super::rng::Rng;

/// Specification for a feature dataset.
#[derive(Debug, Clone, Copy)]
pub struct FeatureSpec {
    /// Feature dimension.
    pub dim: usize,
    /// Number of leading dimensions carrying class signal.
    pub signal_dims: usize,
    /// Mean shift applied to positive examples on the signal dimensions.
    pub shift: f32,
    /// Positive-class probability.
    pub pos_frac: f64,
    /// If true, dimension `d` is scaled by `1 + 0.25 d` (bad conditioning).
    pub anisotropic: bool,
}

impl Default for FeatureSpec {
    fn default() -> Self {
        Self {
            dim: 64,
            signal_dims: 8,
            shift: 1.5,
            pos_frac: 0.3,
            anisotropic: false,
        }
    }
}

/// Generate `n` examples under `spec`, deterministically from `rng`.
pub fn generate(spec: &FeatureSpec, n: usize, rng: &mut Rng) -> Dataset {
    assert!(spec.signal_dims <= spec.dim);
    let mut x = Vec::with_capacity(n * spec.dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let pos = rng.uniform() < spec.pos_frac;
        y.push(if pos { 1.0 } else { 0.0 });
        for d in 0..spec.dim {
            let scale = if spec.anisotropic {
                1.0 + d as f32 * 0.25
            } else {
                1.0
            };
            let shift = if pos && d < spec.signal_dims {
                spec.shift
            } else {
                0.0
            };
            x.push(rng.normal() as f32 * scale + shift);
        }
    }
    Dataset::new(x, y, 0, spec.dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = FeatureSpec::default();
        let a = generate(&spec, 50, &mut Rng::new(1));
        let b = generate(&spec, 50, &mut Rng::new(1));
        assert_eq!(a.x, b.x);
        assert_eq!(a.len(), 50);
        assert_eq!(a.row_len(), 64);
    }

    #[test]
    fn signal_separates_class_means() {
        let spec = FeatureSpec {
            pos_frac: 0.5,
            ..Default::default()
        };
        let d = generate(&spec, 2000, &mut Rng::new(2));
        let (mut pos_mean, mut neg_mean) = (0.0_f64, 0.0_f64);
        let (mut np_, mut nn) = (0.0, 0.0);
        for i in 0..d.len() {
            let v = d.row(i)[0] as f64; // a signal dimension
            if d.y[i] != 0.0 {
                pos_mean += v;
                np_ += 1.0;
            } else {
                neg_mean += v;
                nn += 1.0;
            }
        }
        assert!(pos_mean / np_ - neg_mean / nn > 1.0);
    }

    #[test]
    fn anisotropic_scales_grow() {
        let spec = FeatureSpec {
            anisotropic: true,
            pos_frac: 0.0,
            ..Default::default()
        };
        let d = generate(&spec, 3000, &mut Rng::new(3));
        let var = |dim: usize| -> f64 {
            let vs: Vec<f64> = (0..d.len()).map(|i| d.row(i)[dim] as f64).collect();
            let m = vs.iter().sum::<f64>() / vs.len() as f64;
            vs.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / vs.len() as f64
        };
        assert!(var(63) > 50.0 * var(0));
    }

    #[test]
    fn pos_frac_respected() {
        let spec = FeatureSpec {
            pos_frac: 0.1,
            ..Default::default()
        };
        let d = generate(&spec, 5000, &mut Rng::new(4));
        let frac = d.pos_fraction();
        assert!((frac - 0.1).abs() < 0.02, "{frac}");
    }
}
