//! Deterministic pseudo-random number generation: splitmix64 seeding +
//! xoshiro256++ core (Blackman & Vigna).  No external crate — the data
//! substrate must be bit-reproducible across toolchain updates, and the
//! algorithms are ~40 lines.

/// xoshiro256++ PRNG seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-split RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, bound) (Lemire-style rejection-free enough
    /// for bound « 2^64; exact via 128-bit multiply).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices sampled without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        // partial Fisher-Yates over an index vector
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for bound in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
