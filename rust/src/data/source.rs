//! The `DatasetSource` seam: one trait that resident ([`Dataset`]) and
//! out-of-core ([`crate::data::shard::ShardedDataset`]) data implement,
//! so [`crate::data::EpochSampler`], `Trainer::fit_stream` and the
//! sweep runner drive either without caring where the feature bytes
//! live.
//!
//! The contract that makes the seam safe (DESIGN.md §13):
//!
//! * **labels are always resident** — [`DatasetSource::labels`] returns
//!   the full label vector in logical row order (n × 4 bytes, small
//!   even at n = 10⁸), so epoch-order construction is byte-for-byte the
//!   same computation on every source;
//! * **rows are bit-exact** — [`DatasetSource::fetch_rows`] returns the
//!   exact f32 bits of the logical dataset's rows, wherever they are
//!   stored (an f32 survives a raw little-endian round trip unchanged);
//! * **batching may buffer, never transform** —
//!   [`DatasetSource::batches`] is free to prefetch on background
//!   threads; buffering affects timing only, never the bytes a batch
//!   delivers.
//!
//! Together with the deterministic parallel engine (DESIGN.md §7)
//! these make training on any source bit-identical to training on the
//! resident `Dataset` holding the same logical data, at every thread
//! count — pinned by `tests/shard.rs`.

use std::sync::Arc;

use super::dataset::Dataset;
use super::sampler::{BatchIter, BatchPlan};

/// Batch-buffer filler for one epoch plan (the streaming hot loop).
///
/// Mirrors [`BatchIter::fill_next`] but is fallible: an out-of-core
/// source surfaces IO errors here as structured errors instead of
/// panicking mid-epoch.
pub trait BatchFill {
    /// Fill `x` (`batch_size * row_len`), `is_pos`, `is_neg`
    /// (`batch_size`) for the next batch.  Returns the number of real
    /// (non-padding) rows, or `None` when the epoch is exhausted.
    /// Padding rows are zeroed in all three buffers.
    fn fill_next(
        &mut self,
        x: &mut [f32],
        is_pos: &mut [f32],
        is_neg: &mut [f32],
    ) -> crate::Result<Option<usize>>;
}

/// A logical dataset the training loop can stream from.
pub trait DatasetSource {
    /// Number of logical rows.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat feature length of one row.
    fn row_len(&self) -> usize;

    /// The resident label vector (1.0 positive / 0.0 negative), in
    /// logical row order, length [`DatasetSource::len`].
    fn labels(&self) -> &[f32];

    /// Copy the rows at `indices` (in the given order) into `out`,
    /// which must hold exactly `indices.len() * row_len()` f32 values.
    /// The copy is bit-exact.
    fn fetch_rows(&self, indices: &[u32], out: &mut [f32]) -> crate::Result<()>;

    /// Open a batch filler over `plan`.  Out-of-core sources start
    /// prefetching here.
    fn batches<'a>(&'a self, plan: &'a BatchPlan) -> crate::Result<Box<dyn BatchFill + 'a>>;
}

/// Shared ownership forwards to the inner source, so an `&Arc<Dataset>`
/// (the sweep runner's shared test set) is a `&dyn DatasetSource` too —
/// deref and unsizing coercions do not chain, so without this impl
/// every `Arc` call site would need an explicit `&**`.
impl<T: DatasetSource> DatasetSource for Arc<T> {
    fn len(&self) -> usize {
        (**self).len()
    }

    fn row_len(&self) -> usize {
        (**self).row_len()
    }

    fn labels(&self) -> &[f32] {
        (**self).labels()
    }

    fn fetch_rows(&self, indices: &[u32], out: &mut [f32]) -> crate::Result<()> {
        (**self).fetch_rows(indices, out)
    }

    fn batches<'a>(&'a self, plan: &'a BatchPlan) -> crate::Result<Box<dyn BatchFill + 'a>> {
        (**self).batches(plan)
    }
}

/// Resident filler: a zero-cost wrapper over the existing in-memory
/// [`BatchIter`], which cannot fail.
struct ResidentFill<'a> {
    iter: BatchIter<'a>,
}

impl BatchFill for ResidentFill<'_> {
    fn fill_next(
        &mut self,
        x: &mut [f32],
        is_pos: &mut [f32],
        is_neg: &mut [f32],
    ) -> crate::Result<Option<usize>> {
        Ok(self.iter.fill_next(x, is_pos, is_neg))
    }
}

impl DatasetSource for Dataset {
    fn len(&self) -> usize {
        Dataset::len(self)
    }

    fn row_len(&self) -> usize {
        Dataset::row_len(self)
    }

    fn labels(&self) -> &[f32] {
        &self.y
    }

    fn fetch_rows(&self, indices: &[u32], out: &mut [f32]) -> crate::Result<()> {
        let row = Dataset::row_len(self);
        anyhow::ensure!(
            out.len() == indices.len() * row,
            "fetch_rows: output buffer holds {} f32, need {} ({} rows × {} features)",
            out.len(),
            indices.len() * row,
            indices.len(),
            row
        );
        for (slot, &idx) in indices.iter().enumerate() {
            let i = idx as usize;
            anyhow::ensure!(
                i < Dataset::len(self),
                "fetch_rows: index {i} out of range for {} rows",
                Dataset::len(self)
            );
            out[slot * row..(slot + 1) * row].copy_from_slice(self.row(i));
        }
        Ok(())
    }

    fn batches<'a>(&'a self, plan: &'a BatchPlan) -> crate::Result<Box<dyn BatchFill + 'a>> {
        Ok(Box::new(ResidentFill {
            iter: plan.iter(self),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn toy(n: usize) -> Dataset {
        let y: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let x: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        Dataset::new(x, y, 0, 2)
    }

    #[test]
    fn resident_fetch_rows_is_bit_exact() {
        let d = toy(10);
        let mut out = vec![0.0f32; 3 * 2];
        d.fetch_rows(&[7, 0, 9], &mut out).unwrap();
        for (slot, &idx) in [7usize, 0, 9].iter().enumerate() {
            for k in 0..2 {
                assert_eq!(out[slot * 2 + k].to_bits(), d.row(idx)[k].to_bits());
            }
        }
    }

    #[test]
    fn resident_fetch_rows_rejects_bad_buffer_and_index() {
        let d = toy(4);
        let mut small = vec![0.0f32; 3];
        assert!(d.fetch_rows(&[0, 1], &mut small).is_err());
        let mut out = vec![0.0f32; 2];
        assert!(d.fetch_rows(&[4], &mut out).is_err());
    }

    #[test]
    fn resident_batches_match_batch_iter() {
        let d = toy(11);
        let indices: Vec<u32> = (0..11).collect();
        let plan = BatchPlan::new(&indices, 4, &mut Rng::new(5)).unwrap();
        let (mut x1, mut p1, mut q1) = (vec![0.0; 8], vec![0.0; 4], vec![0.0; 4]);
        let (mut x2, mut p2, mut q2) = (vec![0.0; 8], vec![0.0; 4], vec![0.0; 4]);
        let mut direct = plan.iter(&d);
        let mut seam = DatasetSource::batches(&d, &plan).unwrap();
        loop {
            let a = direct.fill_next(&mut x1, &mut p1, &mut q1);
            let b = seam.fill_next(&mut x2, &mut p2, &mut q2).unwrap();
            assert_eq!(a, b);
            assert_eq!(
                x1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                x2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(p1, p2);
            assert_eq!(q1, q2);
            if a.is_none() {
                break;
            }
        }
    }
}
