//! Out-of-core sharded dataset store (DESIGN.md §13).
//!
//! A *shard store* is a directory holding the feature matrix of one
//! logical [`crate::data::Dataset`] split across contiguous binary
//! shard files ([`format`]), described by a JSON manifest
//! ([`manifest`]).  [`ShardedDataset`] ([`reader`]) opens a store,
//! keeps only the labels resident, and streams feature rows from disk
//! through a double-buffered background prefetch thread — implementing
//! [`crate::data::DatasetSource`] so `Trainer::fit_stream` trains on
//! n ≫ RAM **bit-identically** to resident training on the same
//! logical data (pinned by `tests/shard.rs`).
//!
//! Durability follows the repo-wide rules: every file is published via
//! `util/fsio::write_atomic` (shards first, manifest last as the
//! commit point) and carries a CRC-32 footer that is verified *before*
//! any header field is trusted — the PR 7 checkpoint discipline,
//! enforced over this directory by `allpairs lint`
//! (`raw-durable-write`, `unchecked-cast-in-parse`).

pub mod format;
pub mod manifest;
pub mod reader;
pub mod store;

pub use format::{ShardFile, ShardHeader};
pub use manifest::{Manifest, ShardMeta, MANIFEST_NAME};
pub use reader::ShardedDataset;
pub use store::{validate_store, write_store, StoreCheck};

// The two lossless casts the subsystem needs, funneled through named
// helpers so `unchecked-cast-in-parse` findings stay at exactly two
// reasoned sites instead of one per call.

/// `usize → u64`, for file offsets and size arithmetic.
#[inline]
pub(crate) fn as_u64(v: usize) -> u64 {
    // lint:allow(unchecked-cast-in-parse): usize -> u64 widens losslessly on every supported target (no 128-bit usize)
    v as u64
}

/// `u32 → usize`, for row indices and header fields that have already
/// been range-validated against the CRC-checked file length.
#[inline]
pub(crate) fn as_usize(v: u32) -> usize {
    // lint:allow(unchecked-cast-in-parse): u32 -> usize widens losslessly (rust_pallas has no 16-bit targets)
    v as usize
}
