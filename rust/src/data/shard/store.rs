//! Shard store construction and validation.
//!
//! [`write_store`] splits a resident [`Dataset`] into `k` shard files
//! of *contiguous* logical row ranges whose lengths differ by at most
//! one (the first `n mod k` shards get the extra row), then publishes
//! the manifest last — the commit point.  A crash mid-build leaves
//! either no manifest (store does not exist yet) or a complete,
//! CRC-valid store; never a half-store that loads.
//!
//! [`validate_store`] is the `allpairs shard --validate` entry point:
//! it re-opens every shard (full streaming CRC), cross-checks each
//! header against the manifest, and recounts labels against the
//! per-shard pos/neg declarations.

use std::ops::Range;
use std::path::Path;

use anyhow::Context;

use super::format::{write_shard, ShardFile};
use super::manifest::{Manifest, ShardMeta};
use crate::data::dataset::Dataset;

/// Split `0..n` into `k` contiguous ranges with sizes differing by at
/// most one row (first `n mod k` ranges get the extra).
pub fn shard_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    assert!(k >= 1 && k <= n, "shard_ranges({n}, {k})");
    let base = n / k;
    let extra = n % k;
    let mut ranges = Vec::with_capacity(k);
    let mut start = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        ranges.push(start..start + len);
        start += len;
    }
    ranges
}

/// Canonical shard file name for shard index `i`.
pub fn shard_file_name(i: usize) -> String {
    format!("shard-{i:05}.bin")
}

/// Write `d` as an `n_shards`-file store under `dir` (created if
/// needed).  Returns the published manifest.
pub fn write_store(dir: &Path, d: &Dataset, n_shards: usize) -> crate::Result<Manifest> {
    anyhow::ensure!(n_shards >= 1, "shard store: need at least one shard");
    anyhow::ensure!(!d.is_empty(), "shard store: dataset is empty");
    anyhow::ensure!(
        n_shards <= d.len(),
        "shard store: {n_shards} shards for only {} rows (shards may not be empty)",
        d.len()
    );
    std::fs::create_dir_all(dir).with_context(|| format!("create store dir {}", dir.display()))?;
    let mut shards = Vec::with_capacity(n_shards);
    for (i, range) in shard_ranges(d.len(), n_shards).into_iter().enumerate() {
        let file = shard_file_name(i);
        let pos = d.y[range.clone()].iter().filter(|&&v| v != 0.0).count();
        let meta = ShardMeta { file, rows: range.len(), pos, neg: range.len() - pos };
        write_shard(&dir.join(&meta.file), d, range)
            .with_context(|| format!("write shard {}", meta.file))?;
        shards.push(meta);
    }
    let manifest = Manifest { n_rows: d.len(), hw: d.hw, channels: d.channels, shards };
    manifest.save(dir)?;
    Ok(manifest)
}

/// Summary returned by a successful [`validate_store`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreCheck {
    pub n_rows: usize,
    pub n_shards: usize,
    pub n_pos: usize,
    pub n_neg: usize,
}

/// Fully validate the store at `dir`: manifest consistency, per-shard
/// CRC over every byte, header ↔ manifest agreement, and a recount of
/// the label vector against the declared pos/neg split.
pub fn validate_store(dir: &Path) -> crate::Result<StoreCheck> {
    let manifest = Manifest::load(dir)?;
    for (i, meta) in manifest.shards.iter().enumerate() {
        let shard = ShardFile::open(&dir.join(&meta.file))
            .with_context(|| format!("shard {i} ({})", meta.file))?;
        let h = shard.header();
        anyhow::ensure!(
            h.n_rows == meta.rows && h.hw == manifest.hw && h.channels == manifest.channels,
            "shard {i} ({}): header (rows {} hw {} channels {}) disagrees with manifest (rows {} hw {} channels {})",
            meta.file,
            h.n_rows,
            h.hw,
            h.channels,
            meta.rows,
            manifest.hw,
            manifest.channels
        );
        let labels = shard.read_labels()?;
        let pos = labels.iter().filter(|&&v| v != 0.0).count();
        anyhow::ensure!(
            pos == meta.pos,
            "shard {i} ({}): {} positive labels on disk, manifest declares {}",
            meta.file,
            pos,
            meta.pos
        );
    }
    Ok(StoreCheck {
        n_rows: manifest.n_rows,
        n_shards: manifest.shards.len(),
        n_pos: manifest.n_pos(),
        n_neg: manifest.n_neg(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use std::path::PathBuf;

    fn toy(n: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        // Deterministic label pattern: every third row positive.
        let y: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        Dataset::new(x, y, 0, dim)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("allpairs_store_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn ranges_are_contiguous_and_balanced() {
        for (n, k) in [(10, 1), (10, 3), (101, 7), (7, 7)] {
            let ranges = shard_ranges(n, k);
            assert_eq!(ranges.len(), k);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges[k - 1].end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
                assert!(w[0].len() >= w[1].len());
                assert!(w[0].len() - w[1].len() <= 1);
            }
        }
    }

    #[test]
    fn write_then_validate_succeeds() {
        let d = toy(23, 4, 1);
        let dir = tmp("ok");
        let manifest = write_store(&dir, &d, 3).unwrap();
        assert_eq!(manifest.n_rows, 23);
        assert_eq!(manifest.shards.len(), 3);
        let check = validate_store(&dir).unwrap();
        assert_eq!(check.n_rows, 23);
        assert_eq!(check.n_shards, 3);
        assert_eq!(check.n_pos, d.n_pos());
        assert_eq!(check.n_pos + check.n_neg, 23);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_catches_missing_and_mismatched_shards() {
        let d = toy(12, 2, 2);
        let dir = tmp("bad");
        write_store(&dir, &d, 2).unwrap();

        // Missing shard file.
        let victim = dir.join(shard_file_name(1));
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::remove_file(&victim).unwrap();
        assert!(validate_store(&dir).is_err());
        std::fs::write(&victim, &bytes).unwrap();
        validate_store(&dir).unwrap();

        // Shard swapped in from a different dataset: CRC passes, but
        // the label recount disagrees with the manifest — `other` is
        // all-positive while rows 6..12 of `d` are 1/3 positive.
        let other = Dataset::new(vec![0.5; 12], vec![1.0; 6], 0, 2);
        crate::data::shard::format::write_shard(&victim, &other, 0..6).unwrap();
        assert!(validate_store(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_store_rejects_degenerate_configs() {
        let d = toy(3, 2, 3);
        let dir = tmp("degenerate");
        assert!(write_store(&dir, &d, 0).is_err());
        assert!(write_store(&dir, &d, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
