//! Shard store manifest: `manifest.json` at the store root.
//!
//! ```json
//! {
//!   "schema": 1,
//!   "n_rows": 2000, "hw": 0, "channels": 32,
//!   "shards": [
//!     {"file": "shard-00000.bin", "rows": 667, "pos": 66, "neg": 601},
//!     ...
//!   ]
//! }
//! ```
//!
//! Shards hold *contiguous* logical row ranges in listing order, so
//! logical row `i` lives in the shard whose cumulative row count
//! covers `i`.  Per-shard pos/neg counts let tooling reason about
//! stratification without opening any shard.  The manifest is written
//! **last** (shards first) via `write_atomic`, making it the commit
//! point of store construction; loading cross-validates every internal
//! sum before anything else trusts the numbers.

use std::path::Path;

use anyhow::Context;

use crate::util::json::Json;

pub const MANIFEST_NAME: &str = "manifest.json";
pub const SCHEMA: usize = 1;

/// One shard's entry in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMeta {
    /// File name relative to the store directory.
    pub file: String,
    pub rows: usize,
    pub pos: usize,
    pub neg: usize,
}

/// Parsed, internally-consistent store manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub n_rows: usize,
    pub hw: usize,
    pub channels: usize,
    pub shards: Vec<ShardMeta>,
}

impl Manifest {
    /// Flat feature length of one row (same rule as `Dataset::row_len`).
    pub fn row_len(&self) -> usize {
        if self.hw == 0 {
            self.channels
        } else {
            self.hw * self.hw * self.channels
        }
    }

    pub fn n_pos(&self) -> usize {
        self.shards.iter().map(|s| s.pos).sum()
    }

    pub fn n_neg(&self) -> usize {
        self.shards.iter().map(|s| s.neg).sum()
    }

    /// Logical first row of each shard, in listing order.
    pub fn shard_starts(&self) -> Vec<usize> {
        let mut starts = Vec::with_capacity(self.shards.len());
        let mut acc = 0usize;
        for s in &self.shards {
            starts.push(acc);
            acc += s.rows;
        }
        starts
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("schema", Json::num(SCHEMA as f64)),
            ("n_rows", Json::num(self.n_rows as f64)),
            ("hw", Json::num(self.hw as f64)),
            ("channels", Json::num(self.channels as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|s| {
                            Json::obj([
                                ("file", Json::str(s.file.clone())),
                                ("rows", Json::num(s.rows as f64)),
                                ("pos", Json::num(s.pos as f64)),
                                ("neg", Json::num(s.neg as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> crate::Result<Manifest> {
        let usize_field = |j: &Json, key: &str| -> crate::Result<usize> {
            j.req(key)?
                .as_usize()
                .with_context(|| format!("manifest: `{key}` must be a non-negative integer"))
        };
        let schema = usize_field(doc, "schema")?;
        anyhow::ensure!(
            schema == SCHEMA,
            "manifest: unsupported schema {schema} (expected {SCHEMA})"
        );
        let mut shards = Vec::new();
        for (i, entry) in doc
            .req("shards")?
            .as_arr()
            .context("manifest: `shards` must be an array")?
            .iter()
            .enumerate()
        {
            let file = entry
                .req("file")?
                .as_str()
                .context("manifest: shard `file` must be a string")?
                .to_string();
            anyhow::ensure!(!file.is_empty(), "manifest: shard {i} has an empty file name");
            shards.push(ShardMeta {
                file,
                rows: usize_field(entry, "rows")?,
                pos: usize_field(entry, "pos")?,
                neg: usize_field(entry, "neg")?,
            });
        }
        let m = Manifest {
            n_rows: usize_field(doc, "n_rows")?,
            hw: usize_field(doc, "hw")?,
            channels: usize_field(doc, "channels")?,
            shards,
        };
        m.check()?;
        Ok(m)
    }

    /// Internal consistency: non-empty, per-shard pos+neg = rows,
    /// no empty shards, row sum matches the store total.
    fn check(&self) -> crate::Result<()> {
        anyhow::ensure!(!self.shards.is_empty(), "manifest: store has no shards");
        anyhow::ensure!(
            self.row_len() > 0,
            "manifest: zero-length rows (hw {} channels {})",
            self.hw,
            self.channels
        );
        let mut sum = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            anyhow::ensure!(s.rows > 0, "manifest: shard {i} ({}) is empty", s.file);
            anyhow::ensure!(
                s.pos + s.neg == s.rows,
                "manifest: shard {i} ({}) counts {} pos + {} neg != {} rows",
                s.file,
                s.pos,
                s.neg,
                s.rows
            );
            sum += s.rows;
        }
        anyhow::ensure!(
            sum == self.n_rows,
            "manifest: shard rows sum to {sum} but store declares {}",
            self.n_rows
        );
        Ok(())
    }

    /// Atomically publish the manifest at `dir/manifest.json`.
    pub fn save(&self, dir: &Path) -> crate::Result<()> {
        crate::util::fsio::write_atomic(&dir.join(MANIFEST_NAME), self.to_json().dumps().as_bytes())
    }

    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Manifest> {
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        let doc = Json::parse(&text).with_context(|| format!("parse manifest {}", path.display()))?;
        Self::from_json(&doc).with_context(|| format!("validate manifest {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            n_rows: 10,
            hw: 0,
            channels: 3,
            shards: vec![
                ShardMeta { file: "shard-00000.bin".into(), rows: 4, pos: 1, neg: 3 },
                ShardMeta { file: "shard-00001.bin".into(), rows: 6, pos: 2, neg: 4 },
            ],
        }
    }

    #[test]
    fn json_round_trip() {
        let m = sample();
        let text = m.to_json().dumps();
        let back = Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.shard_starts(), vec![0, 4]);
        assert_eq!(back.n_pos(), 3);
        assert_eq!(back.n_neg(), 7);
    }

    #[test]
    fn inconsistent_manifests_are_rejected() {
        let mut bad_sum = sample();
        bad_sum.n_rows = 11;
        assert!(Manifest::from_json(&bad_sum.to_json()).is_err());

        let mut bad_counts = sample();
        bad_counts.shards[0].pos = 2;
        assert!(Manifest::from_json(&bad_counts.to_json()).is_err());

        let mut empty_shard = sample();
        empty_shard.shards[1].rows = 0;
        empty_shard.shards[1].pos = 0;
        empty_shard.shards[1].neg = 0;
        empty_shard.n_rows = 4;
        assert!(Manifest::from_json(&empty_shard.to_json()).is_err());

        let mut wrong_schema = sample().to_json();
        if let Json::Obj(map) = &mut wrong_schema {
            map.insert("schema".into(), Json::num(2.0));
        }
        assert!(Manifest::from_json(&wrong_schema).is_err());
    }
}
