//! Out-of-core reader: [`ShardedDataset`] implements
//! [`DatasetSource`] over a shard store directory.
//!
//! Residency split: **labels live in memory** (read once at open, in
//! logical row order — n × 4 bytes), **features stay on disk** and are
//! fetched per batch.  Because epoch-order construction
//! ([`crate::data::EpochSampler`]) consumes only labels + RNG, the
//! epoch order over a sharded store is byte-for-byte the order the
//! resident dataset would produce — the heart of the bit-identity
//! contract (DESIGN.md §13).
//!
//! Batch delivery is double-buffered: a background thread walks the
//! epoch order ahead of the trainer, filling one of
//! [`PREFETCH_DEPTH`] recycled feature buffers per batch via
//! positioned reads (`pread` — shard files are never seeked, so one
//! open handle serves both the trainer thread and the prefetcher).
//! The consumer copies the prefetched bits verbatim and computes the
//! `is_pos`/`is_neg` masks from the resident labels; prefetching can
//! change *when* IO happens, never *what* a batch contains.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Context;

use super::format::ShardFile;
use super::manifest::Manifest;
use super::as_usize;
use crate::data::sampler::BatchPlan;
use crate::data::source::{BatchFill, DatasetSource};

/// Number of in-flight batch buffers (the trainer consumes one while
/// the prefetcher fills the other).
pub const PREFETCH_DEPTH: usize = 2;

/// Immutable shard lookup table, shared with the prefetch thread.
#[derive(Debug)]
struct ShardTable {
    shards: Vec<ShardFile>,
    /// Logical first row of each shard (ascending, starts[0] == 0).
    starts: Vec<usize>,
    n: usize,
    row_len: usize,
}

impl ShardTable {
    /// Map a logical row to (shard index, local row).
    fn locate(&self, i: usize) -> (usize, usize) {
        debug_assert!(i < self.n);
        let s = self.starts.partition_point(|&st| st <= i) - 1;
        (s, i - self.starts[s])
    }

    /// Copy the rows at `indices` into `out`, bit-exactly, coalescing
    /// runs of consecutive logical indices within one shard into a
    /// single positioned read.
    fn fetch_rows(&self, indices: &[u32], out: &mut [f32]) -> crate::Result<()> {
        let row = self.row_len;
        anyhow::ensure!(
            out.len() == indices.len() * row,
            "fetch_rows: output buffer holds {} f32, need {} ({} rows × {} features)",
            out.len(),
            indices.len() * row,
            indices.len(),
            row
        );
        let mut slot = 0usize;
        while slot < indices.len() {
            let i = as_usize(indices[slot]);
            anyhow::ensure!(i < self.n, "fetch_rows: index {i} out of range for {} rows", self.n);
            let (s, local) = self.locate(i);
            let shard_rows = self.shards[s].header().n_rows;
            let mut run = 1usize;
            while slot + run < indices.len()
                && local + run < shard_rows
                && as_usize(indices[slot + run]) == i + run
            {
                run += 1;
            }
            self.shards[s].read_rows_at(local, run, &mut out[slot * row..(slot + run) * row])?;
            slot += run;
        }
        Ok(())
    }
}

/// A shard store opened for training: resident labels, on-disk
/// features, prefetched batches.
#[derive(Debug)]
pub struct ShardedDataset {
    table: Arc<ShardTable>,
    labels: Vec<f32>,
    hw: usize,
    channels: usize,
    dir: PathBuf,
}

impl ShardedDataset {
    /// Open the store at `dir`: load + validate the manifest, open
    /// every shard (full streaming CRC verification), cross-check each
    /// header against the manifest, and read all labels resident.
    pub fn open(dir: &Path) -> crate::Result<ShardedDataset> {
        let manifest = Manifest::load(dir)?;
        let mut shards = Vec::with_capacity(manifest.shards.len());
        let mut labels = Vec::with_capacity(manifest.n_rows);
        for (i, meta) in manifest.shards.iter().enumerate() {
            let shard = ShardFile::open(&dir.join(&meta.file))
                .with_context(|| format!("store {}: shard {i} ({})", dir.display(), meta.file))?;
            let h = shard.header();
            anyhow::ensure!(
                h.n_rows == meta.rows && h.hw == manifest.hw && h.channels == manifest.channels,
                "store {}: shard {i} ({}) header disagrees with manifest",
                dir.display(),
                meta.file
            );
            labels.extend_from_slice(&shard.read_labels()?);
            shards.push(shard);
        }
        Ok(ShardedDataset {
            table: Arc::new(ShardTable {
                starts: manifest.shard_starts(),
                n: manifest.n_rows,
                row_len: manifest.row_len(),
                shards,
            }),
            labels,
            hw: manifest.hw,
            channels: manifest.channels,
            dir: dir.to_path_buf(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.table.shards.len()
    }

    pub fn n_pos(&self) -> usize {
        self.labels.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn hw(&self) -> usize {
        self.hw
    }

    pub fn channels(&self) -> usize {
        self.channels
    }
}

impl DatasetSource for ShardedDataset {
    fn len(&self) -> usize {
        self.table.n
    }

    fn row_len(&self) -> usize {
        self.table.row_len
    }

    fn labels(&self) -> &[f32] {
        &self.labels
    }

    fn fetch_rows(&self, indices: &[u32], out: &mut [f32]) -> crate::Result<()> {
        self.table.fetch_rows(indices, out)
    }

    fn batches<'a>(&'a self, plan: &'a BatchPlan) -> crate::Result<Box<dyn BatchFill + 'a>> {
        Ok(Box::new(ShardedFill::start(
            Arc::clone(&self.table),
            plan,
            &self.labels,
        )?))
    }
}

/// One prefetched batch: the feature buffer (padding already zeroed)
/// and its real row count.
struct PrefetchBatch {
    x: Vec<f32>,
    count: usize,
}

/// Double-buffered batch filler over a shard table.
struct ShardedFill<'a> {
    plan: &'a BatchPlan,
    labels: &'a [f32],
    row_len: usize,
    next_batch: usize,
    rx: Option<Receiver<crate::Result<PrefetchBatch>>>,
    /// Buffer-recycle channel back to the worker; dropped first on
    /// teardown so a blocked worker wakes and exits.
    pool: Option<Sender<Vec<f32>>>,
    worker: Option<JoinHandle<()>>,
}

impl<'a> ShardedFill<'a> {
    fn start(
        table: Arc<ShardTable>,
        plan: &'a BatchPlan,
        labels: &'a [f32],
    ) -> crate::Result<ShardedFill<'a>> {
        let order: Vec<u32> = plan.order().to_vec();
        let bs = plan.batch_size();
        let row = table.row_len;
        let (tx, rx) = sync_channel::<crate::Result<PrefetchBatch>>(PREFETCH_DEPTH);
        let (pool_tx, pool_rx) = channel::<Vec<f32>>();
        for _ in 0..PREFETCH_DEPTH {
            let _ = pool_tx.send(vec![0.0f32; bs * row]);
        }
        let worker = std::thread::Builder::new()
            .name("allpairs-shard-prefetch".into())
            .spawn(move || {
                let n_batches = order.len().div_ceil(bs);
                for b in 0..n_batches {
                    // Wait for a recycled buffer; a closed pool means
                    // the consumer is gone — stop quietly.
                    let Ok(mut buf) = pool_rx.recv() else { return };
                    let start = b * bs;
                    let end = (start + bs).min(order.len());
                    let count = end - start;
                    let msg = match table.fetch_rows(&order[start..end], &mut buf[..count * row]) {
                        Ok(()) => {
                            buf[count * row..].fill(0.0);
                            Ok(PrefetchBatch { x: buf, count })
                        }
                        Err(e) => Err(e),
                    };
                    let failed = msg.is_err();
                    if tx.send(msg).is_err() || failed {
                        return;
                    }
                }
            })
            .context("spawn shard prefetch thread")?;
        Ok(ShardedFill {
            plan,
            labels,
            row_len: row,
            next_batch: 0,
            rx: Some(rx),
            pool: Some(pool_tx),
            worker: Some(worker),
        })
    }
}

impl BatchFill for ShardedFill<'_> {
    fn fill_next(
        &mut self,
        x: &mut [f32],
        is_pos: &mut [f32],
        is_neg: &mut [f32],
    ) -> crate::Result<Option<usize>> {
        let bs = self.plan.batch_size();
        let row = self.row_len;
        assert_eq!(x.len(), bs * row, "x buffer size");
        assert_eq!(is_pos.len(), bs);
        assert_eq!(is_neg.len(), bs);
        let order = self.plan.order();
        let start = self.next_batch * bs;
        if start >= order.len() {
            return Ok(None);
        }
        let rx = self.rx.as_ref().expect("receiver lives until drop");
        let batch = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("shard prefetch thread exited unexpectedly"))??;
        self.next_batch += 1;
        let end = (start + bs).min(order.len());
        let count = end - start;
        anyhow::ensure!(
            batch.count == count,
            "shard prefetch desync: received {} rows for a {count}-row batch",
            batch.count
        );
        // Features arrive bit-exact from disk; masks come from the
        // resident labels, exactly as the resident BatchIter computes
        // them.
        x.copy_from_slice(&batch.x);
        for (slot, &idx) in order[start..end].iter().enumerate() {
            let pos = self.labels[as_usize(idx)] != 0.0;
            is_pos[slot] = if pos { 1.0 } else { 0.0 };
            is_neg[slot] = if pos { 0.0 } else { 1.0 };
        }
        is_pos[count..].fill(0.0);
        is_neg[count..].fill(0.0);
        if let Some(pool) = &self.pool {
            let _ = pool.send(batch.x);
        }
        Ok(Some(count))
    }
}

impl Drop for ShardedFill<'_> {
    fn drop(&mut self) {
        // Closing both channels wakes the worker from whichever recv or
        // send it is blocked on; then the join cannot hang.
        self.pool.take();
        self.rx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::rng::Rng;
    use crate::data::shard::store::write_store;
    use crate::data::stream::{EpochSampler, SamplingMode};

    fn toy(n: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        Dataset::new(x, y, 0, dim)
    }

    fn store(name: &str, d: &Dataset, k: usize) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "allpairs_reader_{}_{name}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        write_store(&dir, d, k).unwrap();
        dir
    }

    #[test]
    fn open_exposes_resident_labels_in_logical_order() {
        let d = toy(17, 3, 1);
        let dir = store("labels", &d, 4);
        let s = ShardedDataset::open(&dir).unwrap();
        assert_eq!(s.len(), 17);
        assert_eq!(s.row_len(), 3);
        assert_eq!(s.n_shards(), 4);
        let got: Vec<u32> = s.labels().iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = d.y.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fetch_rows_matches_resident_in_any_order() {
        let d = toy(29, 5, 2);
        let dir = store("fetch", &d, 3);
        let s = ShardedDataset::open(&dir).unwrap();
        // Mix of runs, shard-boundary crossings and jumps.
        let indices: Vec<u32> = vec![0, 1, 2, 9, 10, 11, 28, 5, 4, 20, 21, 22, 23, 24];
        let mut got = vec![0.0f32; indices.len() * 5];
        let mut want = vec![0.0f32; indices.len() * 5];
        s.fetch_rows(&indices, &mut got).unwrap();
        d.fetch_rows(&indices, &mut want).unwrap();
        let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb);
        assert!(s.fetch_rows(&[29], &mut got[..5]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetched_epoch_is_bit_identical_to_resident_epoch() {
        let d = toy(41, 4, 3);
        let dir = store("epoch", &d, 3);
        let s = ShardedDataset::open(&dir).unwrap();
        let indices: Vec<u32> = (0..41).collect();
        for mode in [SamplingMode::Preserve, SamplingMode::Rebalance { pos_fraction: 0.5 }] {
            let mut sa = EpochSampler::new(&d.y, &indices, 8, mode).unwrap();
            let mut sb = EpochSampler::new(s.labels(), &indices, 8, mode).unwrap();
            let plan_a = sa.epoch_plan(&mut Rng::new(7));
            let plan_b = sb.epoch_plan(&mut Rng::new(7));
            assert_eq!(plan_a.order(), plan_b.order());
            let (mut x1, mut p1, mut q1) = (vec![0.0; 32], vec![0.0; 8], vec![0.0; 8]);
            let (mut x2, mut p2, mut q2) = (vec![0.0; 32], vec![0.0; 8], vec![0.0; 8]);
            let mut fa = DatasetSource::batches(&d, &plan_a).unwrap();
            let mut fb = s.batches(&plan_b).unwrap();
            loop {
                let a = fa.fill_next(&mut x1, &mut p1, &mut q1).unwrap();
                let b = fb.fill_next(&mut x2, &mut p2, &mut q2).unwrap();
                assert_eq!(a, b);
                let xb1: Vec<u32> = x1.iter().map(|v| v.to_bits()).collect();
                let xb2: Vec<u32> = x2.iter().map(|v| v.to_bits()).collect();
                assert_eq!(xb1, xb2);
                assert_eq!(p1, p2);
                assert_eq!(q1, q2);
                if a.is_none() {
                    break;
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dropping_a_filler_mid_epoch_does_not_hang() {
        let d = toy(64, 2, 4);
        let dir = store("drop", &d, 2);
        let s = ShardedDataset::open(&dir).unwrap();
        let indices: Vec<u32> = (0..64).collect();
        let plan = BatchPlan::new(&indices, 8, &mut Rng::new(0)).unwrap();
        let mut fill = s.batches(&plan).unwrap();
        let (mut x, mut p, mut q) = (vec![0.0; 16], vec![0.0; 8], vec![0.0; 8]);
        fill.fill_next(&mut x, &mut p, &mut q).unwrap();
        drop(fill); // worker still has batches queued; Drop must join cleanly
        std::fs::remove_dir_all(&dir).ok();
    }
}
