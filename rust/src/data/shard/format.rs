//! Binary shard file format v1.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       4     magic "APSD"
//! 4       4     u32 version (= 1)
//! 8       4     u32 n_rows        rows in this shard
//! 12      4     u32 hw            image side (0 = flat rows)
//! 16      4     u32 channels      channels (row_len = hw*hw*channels, or channels when hw = 0)
//! 20      4n·r  f32 features      row-major, raw IEEE-754 bits
//! 20+4nr  4n    f32 labels        1.0 positive / 0.0 negative
//! end-4   4     u32 CRC-32        over every preceding byte (util/crc32)
//! ```
//!
//! Reading discipline (the PR 7 checkpoint rule): the CRC footer is
//! verified over the *whole* file — streamed, never fully resident —
//! **before** any header field is trusted, so a corrupted row count
//! can never size an allocation or a bounds check.  All header → size
//! arithmetic is overflow-checked.  Files are published only via
//! `util/fsio::write_atomic`.

use std::fs::File;
use std::io::Read;
use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::Context;

use super::{as_u64, as_usize};
use crate::data::dataset::Dataset;
use crate::util::crc32::Crc32;

pub const MAGIC: [u8; 4] = *b"APSD";
pub const VERSION: u32 = 1;
/// magic + version + n_rows + hw + channels.
pub const HEADER_LEN: usize = 20;
/// CRC-32 footer.
pub const FOOTER_LEN: usize = 4;

/// Streaming-verify chunk size (bounds peak memory during open).
const VERIFY_CHUNK: usize = 1 << 20;

/// Parsed, validated shard header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardHeader {
    pub n_rows: usize,
    pub hw: usize,
    pub channels: usize,
}

impl ShardHeader {
    /// Flat feature length of one row (same rule as [`Dataset::row_len`]).
    pub fn row_len(&self) -> usize {
        if self.hw == 0 {
            self.channels
        } else {
            self.hw * self.hw * self.channels
        }
    }

    fn label_offset(&self) -> u64 {
        as_u64(HEADER_LEN) + 4 * as_u64(self.n_rows) * as_u64(self.row_len())
    }
}

/// Serialize rows `rows` of `d` as one shard file body (header +
/// features + labels + CRC footer).
pub fn encode_shard(d: &Dataset, rows: Range<usize>) -> crate::Result<Vec<u8>> {
    anyhow::ensure!(!rows.is_empty(), "shard encode: empty row range {rows:?}");
    anyhow::ensure!(
        rows.end <= d.len(),
        "shard encode: row range {rows:?} exceeds dataset of {} rows",
        d.len()
    );
    let n = rows.len();
    let row = d.row_len();
    let n32 = u32::try_from(n).context("shard encode: row count exceeds u32")?;
    let hw32 = u32::try_from(d.hw).context("shard encode: hw exceeds u32")?;
    let ch32 = u32::try_from(d.channels).context("shard encode: channels exceeds u32")?;

    let mut buf = Vec::with_capacity(HEADER_LEN + 4 * n * (row + 1) + FOOTER_LEN);
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&n32.to_le_bytes());
    buf.extend_from_slice(&hw32.to_le_bytes());
    buf.extend_from_slice(&ch32.to_le_bytes());
    for &v in &d.x[rows.start * row..rows.end * row] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    for &v in &d.y[rows] {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let crc = {
        let mut c = Crc32::new();
        c.update(&buf);
        c.finish()
    };
    buf.extend_from_slice(&crc.to_le_bytes());
    Ok(buf)
}

/// Encode rows `rows` of `d` and publish them atomically at `path`.
pub fn write_shard(path: &Path, d: &Dataset, rows: Range<usize>) -> crate::Result<()> {
    let bytes = encode_shard(d, rows)?;
    crate::util::fsio::write_atomic(path, &bytes)
}

/// An open, fully CRC-verified shard file.  Row reads go through
/// positioned IO (`pread`), so a `ShardFile` is shareable across
/// threads behind an `Arc` with no seek state.
#[derive(Debug)]
pub struct ShardFile {
    file: File,
    header: ShardHeader,
    path: PathBuf,
}

impl ShardFile {
    /// Open `path`, stream the whole file through CRC-32, and only
    /// after the footer matches parse and validate the header.
    pub fn open(path: &Path) -> crate::Result<ShardFile> {
        let mut file =
            File::open(path).with_context(|| format!("open shard {}", path.display()))?;
        let total = file
            .metadata()
            .with_context(|| format!("stat shard {}", path.display()))?
            .len();
        anyhow::ensure!(
            total >= as_u64(HEADER_LEN + FOOTER_LEN),
            "shard {}: file too short ({total} bytes)",
            path.display()
        );

        // Pass 1: stream everything before the footer through the CRC,
        // capturing the header bytes on the way.
        let body_len = total - as_u64(FOOTER_LEN);
        let mut crc = Crc32::new();
        let mut header_bytes = [0u8; HEADER_LEN];
        let mut captured = 0usize;
        let chunk_len = usize::try_from(body_len.min(as_u64(VERIFY_CHUNK)))
            .expect("bounded by VERIFY_CHUNK");
        let mut chunk = vec![0u8; chunk_len];
        let mut remaining = body_len;
        while remaining > 0 {
            let want = usize::try_from(remaining.min(as_u64(chunk.len())))
                .expect("bounded by chunk length");
            file.read_exact(&mut chunk[..want])
                .with_context(|| format!("shard {}: truncated mid-body", path.display()))?;
            crc.update(&chunk[..want]);
            if captured < HEADER_LEN {
                let take = want.min(HEADER_LEN - captured);
                header_bytes[captured..captured + take].copy_from_slice(&chunk[..take]);
                captured += take;
            }
            remaining -= as_u64(want);
        }
        let mut footer = [0u8; FOOTER_LEN];
        file.read_exact(&mut footer)
            .with_context(|| format!("shard {}: truncated footer", path.display()))?;
        let stored = u32::from_le_bytes(footer);
        anyhow::ensure!(
            stored == crc.finish(),
            "shard {}: CRC mismatch (stored {stored:#010x}, computed {:#010x}) — corrupt or torn file",
            path.display(),
            crc.finish()
        );

        // Pass 2: the bytes are authentic; now the header may be parsed.
        let header = parse_header(&header_bytes, total)
            .with_context(|| format!("shard {}: invalid header", path.display()))?;
        Ok(ShardFile { file, header, path: path.to_path_buf() })
    }

    pub fn header(&self) -> &ShardHeader {
        &self.header
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read the `count` consecutive rows starting at local row
    /// `first` into `out` (`count * row_len` f32), bit-exactly.
    pub fn read_rows_at(&self, first: usize, count: usize, out: &mut [f32]) -> crate::Result<()> {
        let row = self.header.row_len();
        anyhow::ensure!(
            first + count <= self.header.n_rows,
            "shard {}: rows {first}..{} out of range (shard has {})",
            self.path.display(),
            first + count,
            self.header.n_rows
        );
        anyhow::ensure!(
            out.len() == count * row,
            "shard {}: output buffer holds {} f32, need {}",
            self.path.display(),
            out.len(),
            count * row
        );
        if count == 0 {
            return Ok(());
        }
        let offset = as_u64(HEADER_LEN) + 4 * as_u64(first) * as_u64(row);
        let mut bytes = vec![0u8; 4 * count * row];
        read_at(&self.file, &mut bytes, offset)
            .with_context(|| format!("shard {}: row read failed", self.path.display()))?;
        for (dst, src) in out.iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().expect("chunks_exact(4)"));
        }
        Ok(())
    }

    /// Read the full label vector of this shard.
    pub fn read_labels(&self) -> crate::Result<Vec<f32>> {
        let n = self.header.n_rows;
        let mut bytes = vec![0u8; 4 * n];
        read_at(&self.file, &mut bytes, self.header.label_offset())
            .with_context(|| format!("shard {}: label read failed", self.path.display()))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|src| f32::from_le_bytes(src.try_into().expect("chunks_exact(4)")))
            .collect())
    }

    /// Materialize the whole shard as a resident [`Dataset`] (used by
    /// store validation and round-trip tests; training streams instead).
    pub fn load_dataset(&self) -> crate::Result<Dataset> {
        let n = self.header.n_rows;
        let row = self.header.row_len();
        let mut x = vec![0.0f32; n * row];
        self.read_rows_at(0, n, &mut x)?;
        let y = self.read_labels()?;
        Ok(Dataset::new(x, y, self.header.hw, self.header.channels))
    }
}

/// Parse and validate a header whose bytes have already passed the CRC.
/// `total` is the real (trusted) file length; every size implied by the
/// header must agree with it, under overflow-checked arithmetic.
fn parse_header(bytes: &[u8; HEADER_LEN], total: u64) -> crate::Result<ShardHeader> {
    anyhow::ensure!(bytes[..4] == MAGIC, "bad magic (not a shard file)");
    let field = |i: usize| {
        u32::from_le_bytes(bytes[4 + 4 * i..8 + 4 * i].try_into().expect("header slice"))
    };
    let version = field(0);
    anyhow::ensure!(version == VERSION, "unsupported shard version {version} (expected {VERSION})");
    let header = ShardHeader {
        n_rows: as_usize(field(1)),
        hw: as_usize(field(2)),
        channels: as_usize(field(3)),
    };
    anyhow::ensure!(header.n_rows > 0, "shard declares zero rows");
    let row_len = if header.hw == 0 {
        header.channels
    } else {
        header
            .hw
            .checked_mul(header.hw)
            .and_then(|s| s.checked_mul(header.channels))
            .ok_or_else(|| anyhow::anyhow!("hw/channels overflow row length"))?
    };
    anyhow::ensure!(row_len > 0, "shard declares zero-length rows");
    let elems = as_u64(header.n_rows)
        .checked_mul(as_u64(row_len))
        .ok_or_else(|| anyhow::anyhow!("n_rows × row_len overflows"))?;
    let expect = elems
        .checked_add(as_u64(header.n_rows))
        .and_then(|e| e.checked_mul(4))
        .and_then(|b| b.checked_add(as_u64(HEADER_LEN + FOOTER_LEN)))
        .ok_or_else(|| anyhow::anyhow!("declared sizes overflow file length"))?;
    anyhow::ensure!(
        expect == total,
        "declared sizes imply {expect} bytes but file has {total}"
    );
    Ok(header)
}

/// Positioned read at `offset` without touching shared seek state.
#[cfg(unix)]
fn read_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, offset)
}

#[cfg(windows)]
fn read_at(file: &File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::os::windows::fs::FileExt;
    let mut done = 0usize;
    while done < buf.len() {
        let n = file.seek_read(&mut buf[done..], offset + as_u64(done))?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "seek_read hit EOF",
            ));
        }
        done += n;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, dim: usize) -> Dataset {
        let y: Vec<f32> = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        let x: Vec<f32> = (0..n * dim).map(|i| (i as f32) * 0.25 - 3.0).collect();
        Dataset::new(x, y, 0, dim)
    }

    fn write_tmp(name: &str, bytes: &[u8]) -> PathBuf {
        let path = std::env::temp_dir().join(format!(
            "allpairs_format_{}_{name}.bin",
            std::process::id()
        ));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn encode_open_round_trip_is_bit_exact() {
        let d = toy(13, 3);
        let bytes = encode_shard(&d, 2..11).unwrap();
        let path = write_tmp("roundtrip", &bytes);
        let shard = ShardFile::open(&path).unwrap();
        assert_eq!(
            *shard.header(),
            ShardHeader { n_rows: 9, hw: 0, channels: 3 }
        );
        let loaded = shard.load_dataset().unwrap();
        for i in 0..9 {
            assert_eq!(loaded.y[i].to_bits(), d.y[2 + i].to_bits());
            for k in 0..3 {
                assert_eq!(loaded.row(i)[k].to_bits(), d.row(2 + i)[k].to_bits());
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn partial_row_reads_match_full_reads() {
        let d = toy(20, 5);
        let path = write_tmp("partial", &encode_shard(&d, 0..20).unwrap());
        let shard = ShardFile::open(&path).unwrap();
        let mut out = vec![0.0f32; 4 * 5];
        shard.read_rows_at(7, 4, &mut out).unwrap();
        for i in 0..4 {
            for k in 0..5 {
                assert_eq!(out[i * 5 + k].to_bits(), d.row(7 + i)[k].to_bits());
            }
        }
        assert!(shard.read_rows_at(18, 3, &mut out[..15]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_short_and_doctored_files() {
        let d = toy(6, 2);
        let good = encode_shard(&d, 0..6).unwrap();

        let short = write_tmp("short", &good[..HEADER_LEN]);
        assert!(ShardFile::open(&short).is_err());

        // Re-stamp a wrong magic WITH a valid CRC: must still be
        // rejected (by the header parse, after the CRC passes).
        let mut doctored = good.clone();
        doctored[..4].copy_from_slice(b"NOPE");
        let crc = crate::util::crc32::crc32(&doctored[..doctored.len() - 4]);
        let len = doctored.len();
        doctored[len - 4..].copy_from_slice(&crc.to_le_bytes());
        let bad_magic = write_tmp("badmagic", &doctored);
        assert!(ShardFile::open(&bad_magic).is_err());

        // Truncation (torn write simulation) is caught by the CRC.
        let torn = write_tmp("torn", &good[..good.len() - 9]);
        assert!(ShardFile::open(&torn).is_err());

        for p in [short, bad_magic, torn] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn encode_rejects_bad_ranges() {
        let d = toy(5, 2);
        assert!(encode_shard(&d, 3..3).is_err());
        assert!(encode_shard(&d, 2..6).is_err());
    }
}
