//! Batch sampling: shuffled fixed-shape batches with padding masks.
//!
//! The AOT train-step artifacts have a *static* batch dimension, so the
//! final ragged batch of an epoch is zero-padded and described by
//! `is_pos`/`is_neg` masks (padding rows have both masks zero — the
//! kernels then ignore them exactly; see `python/compile/kernels/`).
//!
//! [`BatchIter`] writes into caller-owned buffers so the training hot
//! loop performs no per-batch allocation.
//!
//! A [`BatchPlan`] is either a plain shuffle ([`BatchPlan::new`]) or an
//! explicit stratified order built by
//! [`crate::data::stream::EpochSampler`] ([`BatchPlan::from_order`]);
//! the iteration machinery is shared.

use super::dataset::Dataset;
use super::rng::Rng;

/// Epoch-level batch plan: a shuffled order over a subset of a dataset.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    order: Vec<u32>,
    batch_size: usize,
}

impl BatchPlan {
    /// Shuffle `indices` (a view into `dataset`) into batches of
    /// `batch_size`.
    ///
    /// Errors (structured, not a panic — these come straight from user
    /// configuration): `batch_size == 0`, or an empty index slice.
    pub fn new(indices: &[u32], batch_size: usize, rng: &mut Rng) -> crate::Result<Self> {
        anyhow::ensure!(batch_size > 0, "batch plan: batch size must be positive (got 0)");
        anyhow::ensure!(
            !indices.is_empty(),
            "batch plan: empty index set — nothing to train on"
        );
        let mut order = indices.to_vec();
        rng.shuffle(&mut order);
        Ok(Self { order, batch_size })
    }

    /// Wrap an explicit epoch order into a plan.  Batch `b` spans
    /// `order[b*batch_size ..]`, so any short batch must be the last —
    /// which is how [`crate::data::stream::EpochSampler`] builds them.
    ///
    /// Same structured errors as [`BatchPlan::new`].
    pub fn from_order(order: Vec<u32>, batch_size: usize) -> crate::Result<Self> {
        anyhow::ensure!(batch_size > 0, "batch plan: batch size must be positive (got 0)");
        anyhow::ensure!(
            !order.is_empty(),
            "batch plan: empty epoch order — nothing to train on"
        );
        Ok(Self { order, batch_size })
    }

    /// The flat epoch order (batches are consecutive `batch_size` runs).
    pub fn order(&self) -> &[u32] {
        &self.order
    }

    /// The fixed batch stride.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Number of batches in the epoch (final one possibly ragged).
    pub fn n_batches(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }

    pub fn iter<'a>(&'a self, dataset: &'a Dataset) -> BatchIter<'a> {
        BatchIter {
            plan: self,
            dataset,
            next_batch: 0,
        }
    }
}

/// Iterator filling fixed-shape buffers batch by batch.
pub struct BatchIter<'a> {
    plan: &'a BatchPlan,
    dataset: &'a Dataset,
    next_batch: usize,
}

impl<'a> BatchIter<'a> {
    /// Fill `x` (`batch_size * row_len`), `is_pos`, `is_neg`
    /// (`batch_size`) for the next batch.  Returns the number of real
    /// (non-padding) rows, or `None` when the epoch is exhausted.
    ///
    /// Padding rows are zeroed in all three buffers.
    pub fn fill_next(
        &mut self,
        x: &mut [f32],
        is_pos: &mut [f32],
        is_neg: &mut [f32],
    ) -> Option<usize> {
        let bs = self.plan.batch_size;
        let row = self.dataset.row_len();
        assert_eq!(x.len(), bs * row, "x buffer size");
        assert_eq!(is_pos.len(), bs);
        assert_eq!(is_neg.len(), bs);
        let start = self.next_batch * bs;
        if start >= self.plan.order.len() {
            return None;
        }
        self.next_batch += 1;
        let end = (start + bs).min(self.plan.order.len());
        let count = end - start;
        for (slot, &idx) in self.plan.order[start..end].iter().enumerate() {
            x[slot * row..(slot + 1) * row].copy_from_slice(self.dataset.row(idx as usize));
            let pos = self.dataset.y[idx as usize] != 0.0;
            is_pos[slot] = if pos { 1.0 } else { 0.0 };
            is_neg[slot] = if pos { 0.0 } else { 1.0 };
        }
        // zero the padding tail
        x[count * row..].fill(0.0);
        is_pos[count..].fill(0.0);
        is_neg[count..].fill(0.0);
        Some(count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let y: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
        let x: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        Dataset::new(x, y, 0, 2)
    }

    #[test]
    fn epoch_covers_every_example_once() {
        let d = toy(25);
        let indices: Vec<u32> = (0..25).collect();
        let plan = BatchPlan::new(&indices, 8, &mut Rng::new(0)).unwrap();
        assert_eq!(plan.n_batches(), 4);
        let mut seen = vec![0usize; 25];
        let (mut x, mut p, mut q) = (vec![0.0; 16], vec![0.0; 8], vec![0.0; 8]);
        let mut it = plan.iter(&d);
        let mut total = 0;
        while let Some(count) = it.fill_next(&mut x, &mut p, &mut q) {
            total += count;
            for slot in 0..count {
                // recover the example id from its first feature (2*i)
                let id = (x[slot * 2] / 2.0) as usize;
                seen[id] += 1;
            }
        }
        assert_eq!(total, 25);
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn masks_are_complementary_and_padded() {
        let d = toy(10);
        let indices: Vec<u32> = (0..10).collect();
        let plan = BatchPlan::new(&indices, 8, &mut Rng::new(1)).unwrap();
        let (mut x, mut p, mut q) = (vec![0.0; 16], vec![0.0; 8], vec![0.0; 8]);
        let mut it = plan.iter(&d);
        let c1 = it.fill_next(&mut x, &mut p, &mut q).unwrap();
        assert_eq!(c1, 8);
        for i in 0..8 {
            assert_eq!(p[i] + q[i], 1.0);
        }
        let c2 = it.fill_next(&mut x, &mut p, &mut q).unwrap();
        assert_eq!(c2, 2);
        for i in 2..8 {
            assert_eq!(p[i], 0.0);
            assert_eq!(q[i], 0.0);
            assert_eq!(x[i * 2], 0.0);
        }
        assert!(it.fill_next(&mut x, &mut p, &mut q).is_none());
    }

    #[test]
    fn shuffle_differs_by_seed_but_is_deterministic() {
        let indices: Vec<u32> = (0..100).collect();
        let a = BatchPlan::new(&indices, 10, &mut Rng::new(2)).unwrap();
        let b = BatchPlan::new(&indices, 10, &mut Rng::new(2)).unwrap();
        let c = BatchPlan::new(&indices, 10, &mut Rng::new(3)).unwrap();
        assert_eq!(a.order, b.order);
        assert_ne!(a.order, c.order);
    }

    #[test]
    fn zero_batch_size_is_a_structured_error() {
        let indices: Vec<u32> = (0..10).collect();
        let err = BatchPlan::new(&indices, 0, &mut Rng::new(0)).unwrap_err();
        assert!(err.to_string().contains("batch size"), "{err}");
        let err = BatchPlan::from_order(indices, 0).unwrap_err();
        assert!(err.to_string().contains("batch size"), "{err}");
    }

    #[test]
    fn empty_index_set_is_a_structured_error() {
        let err = BatchPlan::new(&[], 8, &mut Rng::new(0)).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        let err = BatchPlan::from_order(Vec::new(), 8).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
    }

    #[test]
    fn batch_size_larger_than_n_yields_one_ragged_batch() {
        let d = toy(5);
        let indices: Vec<u32> = (0..5).collect();
        let plan = BatchPlan::new(&indices, 64, &mut Rng::new(6)).unwrap();
        assert_eq!(plan.n_batches(), 1);
        let (mut x, mut p, mut q) = (vec![0.0; 128], vec![0.0; 64], vec![0.0; 64]);
        let mut it = plan.iter(&d);
        assert_eq!(it.fill_next(&mut x, &mut p, &mut q), Some(5));
        for i in 5..64 {
            assert_eq!(p[i], 0.0);
            assert_eq!(q[i], 0.0);
        }
        assert!(it.fill_next(&mut x, &mut p, &mut q).is_none());
    }

    #[test]
    fn subset_sampling_respects_index_view() {
        let d = toy(50);
        let indices: Vec<u32> = (40..50).collect();
        let plan = BatchPlan::new(&indices, 4, &mut Rng::new(4)).unwrap();
        let (mut x, mut p, mut q) = (vec![0.0; 8], vec![0.0; 4], vec![0.0; 4]);
        let mut it = plan.iter(&d);
        while let Some(count) = it.fill_next(&mut x, &mut p, &mut q) {
            for slot in 0..count {
                let id = (x[slot * 2] / 2.0) as usize;
                assert!((40..50).contains(&id));
            }
        }
    }
}
