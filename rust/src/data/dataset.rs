//! In-memory dataset container and the paper's split protocol.
//!
//! Protocol (paper section 4.2):
//!
//! 1. start from a balanced train pool + a balanced test set;
//! 2. **imbalance** the train pool by removing positives until the
//!    desired `imratio` (proportion of positive labels) is reached;
//! 3. split the imbalanced train set 80/20 into **subtrain** (gradients)
//!    and **validation** (hyper-parameter/epoch selection), re-randomized
//!    per seed.

use super::rng::Rng;

/// A dense NHWC f32 dataset with {0,1} labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `[n, hw, hw, channels]` pixel data (or `[n, dim]` for
    /// feature datasets with `hw == 0`).
    pub x: Vec<f32>,
    /// Labels: 1.0 positive, 0.0 negative.
    pub y: Vec<f32>,
    /// Image side (0 for flat feature data).
    pub hw: usize,
    /// Channels (or the feature dimension when `hw == 0`).
    pub channels: usize,
}

impl Dataset {
    pub fn new(x: Vec<f32>, y: Vec<f32>, hw: usize, channels: usize) -> Self {
        let row = if hw == 0 { channels } else { hw * hw * channels };
        assert_eq!(x.len(), y.len() * row, "x/y size mismatch");
        Self { x, y, hw, channels }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Scalars per example.
    pub fn row_len(&self) -> usize {
        if self.hw == 0 {
            self.channels
        } else {
            self.hw * self.hw * self.channels
        }
    }

    /// Pixel slice of example `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        let r = self.row_len();
        &self.x[i * r..(i + 1) * r]
    }

    /// Number of positive examples.
    pub fn n_pos(&self) -> usize {
        self.y.iter().filter(|&&v| v != 0.0).count()
    }

    /// Proportion of positive labels.
    pub fn pos_fraction(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.n_pos() as f64 / self.len() as f64
    }

    /// Materialize a subset by index.
    pub fn subset(&self, indices: &[u32]) -> Dataset {
        let r = self.row_len();
        let mut x = Vec::with_capacity(indices.len() * r);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            let i = i as usize;
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset::new(x, y, self.hw, self.channels)
    }

    /// Remove positives at random until `pos_fraction() ≈ imratio`
    /// (paper: "observations associated with positive examples were
    /// removed until the desired class imbalance was achieved").
    ///
    /// Keeps all negatives.  Guarantees at least one positive remains.
    pub fn imbalance(&self, imratio: f64, rng: &mut Rng) -> Dataset {
        assert!(imratio > 0.0 && imratio < 1.0, "imratio in (0,1)");
        let pos_idx: Vec<u32> = (0..self.len() as u32)
            .filter(|&i| self.y[i as usize] != 0.0)
            .collect();
        let neg_idx: Vec<u32> = (0..self.len() as u32)
            .filter(|&i| self.y[i as usize] == 0.0)
            .collect();
        let n_neg = neg_idx.len() as f64;
        // imratio = n_pos / (n_pos + n_neg)  =>  n_pos = imratio/(1-imratio) n_neg
        let keep = ((imratio / (1.0 - imratio)) * n_neg).round().max(1.0) as usize;
        let keep = keep.min(pos_idx.len());
        let mut shuffled = pos_idx;
        rng.shuffle(&mut shuffled);
        shuffled.truncate(keep);
        let mut all: Vec<u32> = neg_idx;
        all.extend_from_slice(&shuffled);
        all.sort_unstable(); // stable example order; shuffling is the sampler's job
        self.subset(&all)
    }
}

/// Index-level subtrain/validation split of a training set.
#[derive(Debug, Clone)]
pub struct Split {
    pub subtrain: Vec<u32>,
    pub validation: Vec<u32>,
}

impl Split {
    /// Random `1 - val_fraction` / `val_fraction` split (paper: 80/20),
    /// stratified so that the validation set gets its proportional share
    /// of the (possibly very few) positives — with extreme imbalance an
    /// unstratified split can easily leave validation with zero positives,
    /// making validation AUC undefined.
    pub fn stratified(y: &[f32], val_fraction: f64, rng: &mut Rng) -> Split {
        assert!((0.0..1.0).contains(&val_fraction));
        let mut subtrain = Vec::new();
        let mut validation = Vec::new();
        for class in [1.0_f32, 0.0] {
            let mut idx: Vec<u32> = (0..y.len() as u32)
                .filter(|&i| y[i as usize] == class)
                .collect();
            rng.shuffle(&mut idx);
            let n_val = ((idx.len() as f64) * val_fraction).round() as usize;
            // keep at least one of each class on both sides when possible
            let n_val = n_val.clamp(usize::from(idx.len() >= 2), idx.len().saturating_sub(1));
            validation.extend_from_slice(&idx[..n_val]);
            subtrain.extend_from_slice(&idx[n_val..]);
        }
        subtrain.sort_unstable();
        validation.sort_unstable();
        Split {
            subtrain,
            validation,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, pos_frac: f64, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let y: Vec<f32> = (0..n)
            .map(|_| if rng.uniform() < pos_frac { 1.0 } else { 0.0 })
            .collect();
        let x: Vec<f32> = (0..n * 4).map(|i| i as f32).collect();
        Dataset::new(x, y, 0, 4)
    }

    #[test]
    fn subset_preserves_rows() {
        let d = toy(10, 0.5, 1);
        let s = d.subset(&[2, 5, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.row(0), d.row(2));
        assert_eq!(s.row(2), d.row(7));
        assert_eq!(s.y, vec![d.y[2], d.y[5], d.y[7]]);
    }

    #[test]
    fn imbalance_hits_target_ratio() {
        let d = toy(10_000, 0.5, 2);
        let mut rng = Rng::new(3);
        for imratio in [0.1, 0.01, 0.001] {
            let im = d.imbalance(imratio, &mut rng);
            let achieved = im.pos_fraction();
            assert!(
                (achieved - imratio).abs() / imratio < 0.25,
                "target {imratio}, achieved {achieved}"
            );
            assert!(im.n_pos() >= 1);
            // all negatives kept
            assert_eq!(im.len() - im.n_pos(), d.len() - d.n_pos());
        }
    }

    #[test]
    fn imbalance_keeps_at_least_one_positive() {
        let d = toy(100, 0.5, 4);
        let mut rng = Rng::new(5);
        let im = d.imbalance(0.0001, &mut rng);
        assert!(im.n_pos() >= 1);
    }

    #[test]
    fn stratified_split_disjoint_and_complete() {
        let d = toy(500, 0.1, 6);
        let mut rng = Rng::new(7);
        let split = Split::stratified(&d.y, 0.2, &mut rng);
        let mut all: Vec<u32> = split
            .subtrain
            .iter()
            .chain(&split.validation)
            .copied()
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..500u32).collect::<Vec<_>>());
        let inter: Vec<u32> = split
            .subtrain
            .iter()
            .filter(|i| split.validation.contains(i))
            .copied()
            .collect();
        assert!(inter.is_empty());
    }

    #[test]
    fn stratified_split_has_positives_on_both_sides() {
        let d = toy(1000, 0.01, 8);
        let mut rng = Rng::new(9);
        let split = Split::stratified(&d.y, 0.2, &mut rng);
        let pos_sub = split.subtrain.iter().filter(|&&i| d.y[i as usize] != 0.0).count();
        let pos_val = split
            .validation
            .iter()
            .filter(|&&i| d.y[i as usize] != 0.0)
            .count();
        assert!(pos_sub >= 1, "no positives in subtrain");
        assert!(pos_val >= 1, "no positives in validation");
    }

    #[test]
    #[should_panic(expected = "imratio in (0,1)")]
    fn imbalance_validates_ratio() {
        let d = toy(10, 0.5, 1);
        d.imbalance(1.5, &mut Rng::new(0));
    }
}
