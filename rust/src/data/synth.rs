//! Synthetic image dataset generators (the CIFAR10 / STL10 / Cat&Dog
//! stand-ins — see DESIGN.md §2 for the substitution argument).
//!
//! Generation model, per dataset seed:
//!
//! 1. Each of `n_latent_classes` gets a smooth *prototype* pattern: a sum
//!    of four random 2-D sinusoids per channel (low-frequency, so a small
//!    CNN can learn it but a linear model cannot trivially).
//! 2. An example of class `c` is the prototype, randomly translated by up
//!    to ±2 pixels (toroidal shift — the nuisance transform standing in
//!    for natural image variation), scaled by `signal`, plus i.i.d.
//!    Gaussian pixel noise scaled by `noise`.
//! 3. Binary labels follow the paper's CIFAR conversion: the first half
//!    of the latent classes are negative, the rest positive.
//!
//! The three [`SYNTH_DATASETS`] mimic the *experimental roles* of the
//! paper's sets: `synth-cifar` (easiest, most data), `synth-stl` (lower
//! SNR, less data — STL10's role as the harder set), `synth-pets` (two
//! latent classes — Cat&Dog's role as the binary-native set).

use super::dataset::Dataset;
use super::rng::Rng;

/// Image side length shared by all synthetic datasets (NHWC, C = 3).
pub const IMAGE_HW: usize = 16;
/// Channels.
pub const CHANNELS: usize = 3;

/// Specification of one synthetic dataset.
#[derive(Debug, Clone, Copy)]
pub struct SynthSpec {
    /// Dataset name used in configs, reports and result files.
    pub name: &'static str,
    /// Number of latent classes (binary label = second half vs first).
    pub n_latent_classes: usize,
    /// Prototype amplitude.
    pub signal: f32,
    /// Pixel-noise amplitude.
    pub noise: f32,
    /// Balanced train-pool size (before imbalance subsetting).
    pub n_train: usize,
    /// Balanced test-set size (the paper's test sets are 50% positive).
    pub n_test: usize,
}

/// The three reproduction datasets (paper: CIFAR10, STL10, Cat&Dog).
pub const SYNTH_DATASETS: [SynthSpec; 3] = [
    SynthSpec {
        name: "synth-cifar",
        n_latent_classes: 10,
        signal: 1.0,
        noise: 1.0,
        n_train: 10_000,
        n_test: 2_000,
    },
    SynthSpec {
        name: "synth-stl",
        n_latent_classes: 10,
        signal: 0.65,
        noise: 1.3,
        n_train: 5_000,
        n_test: 2_000,
    },
    SynthSpec {
        name: "synth-pets",
        n_latent_classes: 2,
        signal: 0.85,
        noise: 1.1,
        n_train: 8_000,
        n_test: 2_000,
    },
];

/// Look a spec up by name.
pub fn spec_by_name(name: &str) -> Option<SynthSpec> {
    SYNTH_DATASETS.iter().copied().find(|s| s.name == name)
}

/// One latent class's sinusoid mixture: `4 components x 3 channels`.
struct Prototype {
    /// (amplitude, fx, fy, phase) per (component, channel)
    comps: Vec<(f32, f32, f32, f32)>,
}

impl Prototype {
    fn generate(rng: &mut Rng) -> Self {
        let mut comps = Vec::with_capacity(4 * CHANNELS);
        for _ in 0..4 * CHANNELS {
            let amp = 0.5 + 0.5 * rng.uniform() as f32;
            // low frequencies (1..=3 cycles across the image)
            let fx = (1 + rng.below(3)) as f32;
            let fy = (1 + rng.below(3)) as f32;
            let phase = (rng.uniform() * std::f64::consts::TAU) as f32;
            comps.push((amp, fx, fy, phase));
        }
        Self { comps }
    }

    /// Pixel value at (x, y, channel) with a toroidal shift (dx, dy).
    #[inline]
    fn value(&self, x: usize, y: usize, ch: usize, dx: f32, dy: f32) -> f32 {
        let mut v = 0.0;
        let inv = 1.0 / IMAGE_HW as f32;
        for c in 0..4 {
            let (amp, fx, fy, phase) = self.comps[ch * 4 + c];
            let arg = std::f32::consts::TAU
                * (fx * (x as f32 + dx) * inv + fy * (y as f32 + dy) * inv)
                + phase;
            v += amp * arg.sin();
        }
        v / 2.0
    }
}

/// Generate the balanced train pool and the balanced test set.
///
/// Both are drawn from the same latent process with *disjoint* RNG
/// streams; labels are exactly balanced in the test set (paper protocol:
/// "each test set has no class imbalance").
pub fn generate(spec: &SynthSpec, seed: u64) -> (Dataset, Dataset) {
    let mut root = Rng::new(seed ^ fxhash(spec.name));
    let mut proto_rng = root.fork(1);
    let prototypes: Vec<Prototype> = (0..spec.n_latent_classes)
        .map(|_| Prototype::generate(&mut proto_rng))
        .collect();
    let train = render_split(spec, &prototypes, &mut root.fork(2), spec.n_train, false);
    let test = render_split(spec, &prototypes, &mut root.fork(3), spec.n_test, true);
    (train, test)
}

/// FNV-1a of the dataset name, to decorrelate seeds across datasets.
fn fxhash(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325_u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn render_split(
    spec: &SynthSpec,
    prototypes: &[Prototype],
    rng: &mut Rng,
    n: usize,
    force_balanced: bool,
) -> Dataset {
    let px = IMAGE_HW * IMAGE_HW * CHANNELS;
    let mut x = vec![0.0_f32; n * px];
    let mut y = vec![0.0_f32; n];
    let half = spec.n_latent_classes / 2;
    for i in 0..n {
        // latent class: uniform; balanced test alternates pos/neg halves
        let class = if force_balanced {
            let positive = i % 2 == 1;
            let offset = rng.below(spec.n_latent_classes - half.max(1));
            if positive {
                half + offset % (spec.n_latent_classes - half)
            } else {
                rng.below(half.max(1))
            }
        } else {
            rng.below(spec.n_latent_classes)
        };
        y[i] = if class >= half { 1.0 } else { 0.0 };
        let proto = &prototypes[class];
        let dx = (rng.below(5) as f32) - 2.0; // toroidal shift in [-2, 2]
        let dy = (rng.below(5) as f32) - 2.0;
        let base = i * px;
        for yy in 0..IMAGE_HW {
            for xx in 0..IMAGE_HW {
                for ch in 0..CHANNELS {
                    let signal = spec.signal * proto.value(xx, yy, ch, dx, dy);
                    let noise = spec.noise * rng.normal() as f32 * 0.5;
                    x[base + (yy * IMAGE_HW + xx) * CHANNELS + ch] = signal + noise;
                }
            }
        }
    }
    Dataset::new(x, y, IMAGE_HW, CHANNELS)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = SynthSpec {
            n_train: 32,
            n_test: 16,
            ..SYNTH_DATASETS[0]
        };
        let (a_tr, a_te) = generate(&spec, 11);
        let (b_tr, b_te) = generate(&spec, 11);
        assert_eq!(a_tr.x, b_tr.x);
        assert_eq!(a_te.y, b_te.y);
    }

    #[test]
    fn seeds_and_datasets_decorrelated() {
        let spec = SynthSpec {
            n_train: 16,
            n_test: 8,
            ..SYNTH_DATASETS[0]
        };
        let (a, _) = generate(&spec, 1);
        let (b, _) = generate(&spec, 2);
        assert_ne!(a.x, b.x);
        let spec2 = SynthSpec {
            n_train: 16,
            n_test: 8,
            ..SYNTH_DATASETS[1]
        };
        let (c, _) = generate(&spec2, 1);
        assert_ne!(a.x[..100], c.x[..100]);
    }

    #[test]
    fn test_set_is_balanced() {
        for spec in SYNTH_DATASETS.iter() {
            let small = SynthSpec {
                n_train: 8,
                n_test: 400,
                ..*spec
            };
            let (_, test) = generate(&small, 5);
            let pos = test.y.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(pos, 200, "{}", spec.name);
        }
    }

    #[test]
    fn shapes_and_finiteness() {
        let spec = SynthSpec {
            n_train: 10,
            n_test: 4,
            ..SYNTH_DATASETS[2]
        };
        let (train, test) = generate(&spec, 0);
        assert_eq!(train.len(), 10);
        assert_eq!(test.len(), 4);
        assert_eq!(train.x.len(), 10 * 16 * 16 * 3);
        assert!(train.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn signal_is_learnable_by_class_means() {
        // Nearest-prototype-mean classification on clean-ish data must beat
        // chance by a wide margin — i.e. the generator carries real signal.
        let spec = SynthSpec {
            name: "probe",
            n_latent_classes: 2,
            signal: 1.5,
            noise: 0.3,
            n_train: 400,
            n_test: 200,
        };
        let (train, test) = generate(&spec, 3);
        let px = 16 * 16 * 3;
        let mut mean_pos = vec![0.0_f64; px];
        let mut mean_neg = vec![0.0_f64; px];
        let (mut np, mut nn) = (0.0, 0.0);
        for i in 0..train.len() {
            let target = if train.y[i] != 0.0 {
                np += 1.0;
                &mut mean_pos
            } else {
                nn += 1.0;
                &mut mean_neg
            };
            for (t, &v) in target.iter_mut().zip(&train.x[i * px..(i + 1) * px]) {
                *t += v as f64;
            }
        }
        for v in &mut mean_pos {
            *v /= np;
        }
        for v in &mut mean_neg {
            *v /= nn;
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let xs = &test.x[i * px..(i + 1) * px];
            let (mut dp, mut dn) = (0.0, 0.0);
            for (j, &v) in xs.iter().enumerate() {
                dp += (v as f64 - mean_pos[j]).powi(2);
                dn += (v as f64 - mean_neg[j]).powi(2);
            }
            let pred = if dp < dn { 1.0 } else { 0.0 };
            if pred == test.y[i] as f64 {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.7, "class-mean accuracy only {acc}");
    }

    #[test]
    fn spec_lookup() {
        assert!(spec_by_name("synth-cifar").is_some());
        assert!(spec_by_name("nope").is_none());
    }
}
