//! Streaming epoch pipeline: stratified mini-batch sampling with a
//! deterministic per-epoch reshuffle.
//!
//! The paper's point is that the O(n log n) all-pairs gradient makes
//! *large* batches affordable on imbalanced data — but a large batch
//! drawn uniformly from a 0.1%-positive training set still contains
//! mostly (or only) negatives, and an all-pairs loss over a batch with
//! no positives is identically zero.  The sampler therefore controls
//! each batch's class composition explicitly:
//!
//! * [`SamplingMode::Preserve`] — every example appears exactly once
//!   per epoch and positives are spread evenly across batches, so each
//!   batch mirrors the global imbalance as closely as integer counts
//!   allow (instead of leaving it to shuffle luck).
//! * [`SamplingMode::Rebalance`] — every batch is forced to a target
//!   positive fraction; negatives are consumed exactly once per epoch
//!   while the (scarce) positives are cycled — shuffled, drained
//!   without replacement, reshuffled on exhaustion — i.e. classical
//!   oversampling, but deterministic from the seeded [`Rng`].
//!
//! [`EpochSampler::epoch_plan`] emits a fresh [`BatchPlan`] per epoch;
//! all randomness is drawn from the caller's [`Rng`], so a run is
//! bit-reproducible from its seed.

use super::rng::Rng;
use super::sampler::BatchPlan;

/// How each mini-batch's positive/negative composition is chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplingMode {
    /// Keep the subset's imbalance: one pass over every example per
    /// epoch, positives interleaved evenly across batches.
    Preserve,
    /// Force every batch to `pos_fraction` positives by oversampling
    /// the positive class (see module docs).  Falls back to
    /// [`SamplingMode::Preserve`] when a class is empty or the batch
    /// size is 1 (no room for a quota).
    Rebalance {
        /// Target fraction of positive rows per batch, in (0, 1).
        pos_fraction: f64,
    },
}

impl SamplingMode {
    /// Parse a config/CLI name: `"preserve"`, `"rebalance"` (= 0.5) or
    /// `"rebalance:F"` with `F` in (0, 1).
    pub fn parse(name: &str) -> crate::Result<Self> {
        match name {
            "preserve" => Ok(SamplingMode::Preserve),
            "rebalance" => Ok(SamplingMode::Rebalance { pos_fraction: 0.5 }),
            other => match other.strip_prefix("rebalance:") {
                Some(frac) => {
                    let pos_fraction: f64 = frac
                        .parse()
                        .map_err(|e| anyhow::anyhow!("sampling mode {other:?}: {e}"))?;
                    anyhow::ensure!(
                        pos_fraction > 0.0 && pos_fraction < 1.0,
                        "sampling mode {other:?}: positive fraction must be in (0, 1)"
                    );
                    Ok(SamplingMode::Rebalance { pos_fraction })
                }
                None => anyhow::bail!(
                    "unknown sampling mode {other:?} (preserve | rebalance | rebalance:F)"
                ),
            },
        }
    }

    /// Canonical name; `parse(mode.name())` round-trips.
    pub fn name(&self) -> String {
        match self {
            SamplingMode::Preserve => "preserve".to_string(),
            SamplingMode::Rebalance { pos_fraction } => format!("rebalance:{pos_fraction}"),
        }
    }
}

/// Stratified epoch-batch generator over a fixed subset of a dataset.
///
/// Construct once per training run, then call [`Self::epoch_plan`] each
/// epoch; the positive-cycle cursor persists across epochs so
/// `Rebalance` oversampling rotates through all positives before
/// repeating any.
#[derive(Debug, Clone)]
pub struct EpochSampler {
    pos: Vec<u32>,
    neg: Vec<u32>,
    batch_size: usize,
    mode: SamplingMode,
    /// `Rebalance` positive cycle: shuffled, drained, reshuffled.
    pos_cycle: Vec<u32>,
    pos_cursor: usize,
}

impl EpochSampler {
    /// Partition `indices` (a view into the dataset whose label vector
    /// is `labels`) by class.
    ///
    /// Taking labels rather than a `Dataset` lets any
    /// [`crate::data::DatasetSource`] — resident or sharded — drive the
    /// sampler with the same bits: epoch orders depend only on labels
    /// and the caller's RNG (DESIGN.md §13).
    ///
    /// Errors (structured, not a panic — all reachable from user
    /// configuration): `batch_size == 0`, an empty index slice, an
    /// index out of range for `labels`, or a `Rebalance` fraction
    /// outside (0, 1).
    pub fn new(
        labels: &[f32],
        indices: &[u32],
        batch_size: usize,
        mode: SamplingMode,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            batch_size > 0,
            "epoch sampler: batch size must be positive (got 0)"
        );
        anyhow::ensure!(
            !indices.is_empty(),
            "epoch sampler: empty index set — nothing to train on"
        );
        if let SamplingMode::Rebalance { pos_fraction } = mode {
            anyhow::ensure!(
                pos_fraction > 0.0 && pos_fraction < 1.0,
                "epoch sampler: rebalance positive fraction must be in (0, 1), got {pos_fraction}"
            );
        }
        let mut pos = Vec::new();
        let mut neg = Vec::new();
        for &i in indices {
            let label = labels.get(i as usize).ok_or_else(|| {
                anyhow::anyhow!(
                    "epoch sampler: index {i} out of range for {} labels",
                    labels.len()
                )
            })?;
            if *label != 0.0 {
                pos.push(i);
            } else {
                neg.push(i);
            }
        }
        let pos_cycle = pos.clone();
        // Start the cursor exhausted: the first draw reshuffles, so the
        // cycle order never leaks the dataset's example order.
        let pos_cursor = pos_cycle.len();
        Ok(Self {
            pos,
            neg,
            batch_size,
            mode,
            pos_cycle,
            pos_cursor,
        })
    }

    pub fn n_pos(&self) -> usize {
        self.pos.len()
    }

    pub fn n_neg(&self) -> usize {
        self.neg.len()
    }

    /// The mode actually in effect (see [`SamplingMode::Rebalance`]'s
    /// fallback conditions).
    pub fn effective_mode(&self) -> SamplingMode {
        match self.mode {
            SamplingMode::Rebalance { pos_fraction }
                if self.batch_size >= 2 && !self.pos.is_empty() && !self.neg.is_empty() =>
            {
                SamplingMode::Rebalance { pos_fraction }
            }
            _ => SamplingMode::Preserve,
        }
    }

    /// Positive rows per batch under `Rebalance` (only meaningful when
    /// `effective_mode` is `Rebalance`).  The clamp guarantees at least
    /// one positive *and* one negative per batch even when
    /// `pos_fraction * batch_size` rounds to 0 (or to `batch_size`) —
    /// a batch with zero positives makes the all-pairs loss
    /// identically zero, so that gradient step would be wasted.
    fn rebalance_quota(&self, pos_fraction: f64) -> usize {
        ((self.batch_size as f64 * pos_fraction).round() as usize).clamp(1, self.batch_size - 1)
    }

    /// Number of batches every epoch will contain (the final one may be
    /// ragged).
    pub fn n_batches(&self) -> usize {
        match self.effective_mode() {
            SamplingMode::Preserve => (self.pos.len() + self.neg.len()).div_ceil(self.batch_size),
            SamplingMode::Rebalance { pos_fraction } => {
                let per_batch = self.batch_size - self.rebalance_quota(pos_fraction);
                self.neg.len().div_ceil(per_batch)
            }
        }
    }

    /// Next positive from the oversampling cycle.
    fn next_pos(&mut self, rng: &mut Rng) -> u32 {
        if self.pos_cursor >= self.pos_cycle.len() {
            rng.shuffle(&mut self.pos_cycle);
            self.pos_cursor = 0;
        }
        let v = self.pos_cycle[self.pos_cursor];
        self.pos_cursor += 1;
        v
    }

    /// One epoch's shuffled, stratified batch order.
    pub fn epoch_plan(&mut self, rng: &mut Rng) -> BatchPlan {
        let order = match self.effective_mode() {
            SamplingMode::Preserve => self.preserve_order(rng),
            SamplingMode::Rebalance { pos_fraction } => self.rebalance_order(pos_fraction, rng),
        };
        // Both order builders emit at least one index per constructor
        // invariant (non-empty index set, positive batch size), so the
        // plan guards cannot trip here.
        BatchPlan::from_order(order, self.batch_size)
            .expect("sampler invariants guarantee a valid plan")
    }

    /// Shuffle each class, then interleave proportionally (a Bresenham
    /// error accumulator), so batch `b` holds its integer share of
    /// positives.  Emits every index exactly once.
    fn preserve_order(&self, rng: &mut Rng) -> Vec<u32> {
        let mut pos = self.pos.clone();
        rng.shuffle(&mut pos);
        let mut neg = self.neg.clone();
        rng.shuffle(&mut neg);
        let n = pos.len() + neg.len();
        let mut order = Vec::with_capacity(n);
        let (mut pi, mut ni) = (0usize, 0usize);
        // Each step adds n_pos to the accumulator; crossing n emits a
        // positive.  Over n steps that emits exactly n_pos positives,
        // evenly spaced (the accumulator ends back at zero).
        let mut acc = 0usize;
        for _ in 0..n {
            acc += pos.len();
            if acc >= n {
                acc -= n;
                order.push(pos[pi]);
                pi += 1;
            } else {
                order.push(neg[ni]);
                ni += 1;
            }
        }
        debug_assert_eq!(pi, pos.len());
        debug_assert_eq!(ni, neg.len());
        order
    }

    /// Quota batches: `k_pos` positives from the cycle + negatives
    /// consumed exactly once per epoch.  Only the final batch may be
    /// short (so fixed-stride batch boundaries stay aligned).
    fn rebalance_order(&mut self, pos_fraction: f64, rng: &mut Rng) -> Vec<u32> {
        let k_pos = self.rebalance_quota(pos_fraction);
        let k_neg = self.batch_size - k_pos;
        let mut neg = self.neg.clone();
        rng.shuffle(&mut neg);
        let n_batches = neg.len().div_ceil(k_neg);
        let mut order = Vec::with_capacity(n_batches * self.batch_size);
        let mut ni = 0usize;
        for _ in 0..n_batches {
            for _ in 0..k_pos {
                let p = self.next_pos(rng);
                order.push(p);
            }
            let take = k_neg.min(neg.len() - ni);
            order.extend_from_slice(&neg[ni..ni + take]);
            ni += take;
        }
        debug_assert_eq!(ni, neg.len());
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    /// `n` examples, positive iff `i < n_pos` (feature 0 encodes `i`).
    fn toy(n: usize, n_pos: usize) -> Dataset {
        let y: Vec<f32> = (0..n).map(|i| if i < n_pos { 1.0 } else { 0.0 }).collect();
        let x: Vec<f32> = (0..n * 2).map(|i| i as f32).collect();
        Dataset::new(x, y, 0, 2)
    }

    fn batch_compositions(
        d: &Dataset,
        plan: &BatchPlan,
        batch_size: usize,
    ) -> Vec<(usize, usize)> {
        let row = d.row_len();
        let mut x = vec![0.0f32; batch_size * row];
        let mut p = vec![0.0f32; batch_size];
        let mut q = vec![0.0f32; batch_size];
        let mut out = Vec::new();
        let mut it = plan.iter(d);
        while let Some(count) = it.fill_next(&mut x, &mut p, &mut q) {
            let pos = (0..count).filter(|&i| p[i] != 0.0).count();
            out.push((pos, count - pos));
        }
        out
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [
            SamplingMode::Preserve,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
            SamplingMode::Rebalance { pos_fraction: 0.25 },
        ] {
            assert_eq!(SamplingMode::parse(&mode.name()).unwrap(), mode);
        }
        assert_eq!(
            SamplingMode::parse("rebalance").unwrap(),
            SamplingMode::Rebalance { pos_fraction: 0.5 }
        );
        assert!(SamplingMode::parse("bogus").is_err());
        assert!(SamplingMode::parse("rebalance:0").is_err());
        assert!(SamplingMode::parse("rebalance:1.5").is_err());
        assert!(SamplingMode::parse("rebalance:x").is_err());
    }

    #[test]
    fn preserve_covers_every_example_once_with_even_positives() {
        let d = toy(103, 13);
        let indices: Vec<u32> = (0..103).collect();
        let mut sampler = EpochSampler::new(&d.y, &indices, 10, SamplingMode::Preserve).unwrap();
        assert_eq!(sampler.n_batches(), 11);
        let plan = sampler.epoch_plan(&mut Rng::new(1));
        let comps = batch_compositions(&d, &plan, 10);
        assert_eq!(comps.len(), 11);
        let total_pos: usize = comps.iter().map(|c| c.0).sum();
        let total: usize = comps.iter().map(|c| c.0 + c.1).sum();
        assert_eq!(total_pos, 13);
        assert_eq!(total, 103);
        // proportional share is 13/103 ~ 1.26 per 10-row batch: every
        // full batch gets 1 or 2 positives, never 0 or 3+
        for &(pos, neg) in &comps {
            if pos + neg == 10 {
                assert!((1..=2).contains(&pos), "batch had {pos} positives");
            }
        }
    }

    #[test]
    fn preserve_epoch_is_a_permutation() {
        let d = toy(50, 20);
        let indices: Vec<u32> = (0..50).collect();
        let mut sampler = EpochSampler::new(&d.y, &indices, 7, SamplingMode::Preserve).unwrap();
        let plan = sampler.epoch_plan(&mut Rng::new(2));
        let mut order = plan.order().to_vec();
        order.sort_unstable();
        assert_eq!(order, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn rebalance_hits_the_quota_every_batch() {
        let d = toy(1000, 10); // 1% positive
        let indices: Vec<u32> = (0..1000).collect();
        let mut sampler = EpochSampler::new(
            &d.y,
            &indices,
            100,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        )
        .unwrap();
        // 990 negatives at 50 per batch -> 20 batches
        assert_eq!(sampler.n_batches(), 20);
        let plan = sampler.epoch_plan(&mut Rng::new(3));
        let comps = batch_compositions(&d, &plan, 100);
        assert_eq!(comps.len(), 20);
        for &(pos, _) in &comps {
            assert_eq!(pos, 50);
        }
        // negatives are covered exactly once
        let neg_total: usize = comps.iter().map(|c| c.1).sum();
        assert_eq!(neg_total, 990);
    }

    #[test]
    fn rebalance_cycles_all_positives_before_repeating() {
        let d = toy(200, 8);
        let indices: Vec<u32> = (0..200).collect();
        let mut sampler = EpochSampler::new(
            &d.y,
            &indices,
            32,
            SamplingMode::Rebalance { pos_fraction: 0.25 },
        )
        .unwrap();
        let plan = sampler.epoch_plan(&mut Rng::new(4));
        let positives: Vec<u32> = plan
            .order()
            .iter()
            .copied()
            .filter(|&i| d.y[i as usize] != 0.0)
            .collect();
        // within each full cycle of 8 draws, all 8 distinct positives
        for cycle in positives.chunks(8) {
            let mut c = cycle.to_vec();
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), cycle.len(), "repeat inside a cycle");
        }
    }

    #[test]
    fn rebalance_final_batch_may_be_short_but_boundaries_align() {
        let d = toy(107, 7);
        let indices: Vec<u32> = (0..107).collect();
        let mut sampler = EpochSampler::new(
            &d.y,
            &indices,
            20,
            SamplingMode::Rebalance { pos_fraction: 0.2 },
        )
        .unwrap();
        // quota 4 pos + 16 neg; 100 negatives -> 6 full + 1 short batch
        assert_eq!(sampler.n_batches(), 7);
        let plan = sampler.epoch_plan(&mut Rng::new(5));
        let comps = batch_compositions(&d, &plan, 20);
        assert_eq!(comps.len(), 7);
        for &(pos, _) in &comps {
            assert_eq!(pos, 4);
        }
        assert_eq!(comps.last().unwrap().1, 100 - 6 * 16);
    }

    #[test]
    fn rebalance_tiny_fraction_still_puts_a_positive_in_every_batch() {
        // batch_size = 8, pos_fraction = 0.05: the raw quota
        // 8 * 0.05 = 0.4 rounds to 0, which the clamp must lift to 1 —
        // a batch with zero positives makes the all-pairs loss
        // identically zero.
        let d = toy(73, 3); // 3 positives, 70 negatives
        let indices: Vec<u32> = (0..73).collect();
        let mut sampler = EpochSampler::new(
            &d.y,
            &indices,
            8,
            SamplingMode::Rebalance { pos_fraction: 0.05 },
        )
        .unwrap();
        // quota 1 pos + 7 neg; 70 negatives -> 10 batches
        assert_eq!(sampler.n_batches(), 10);
        let plan = sampler.epoch_plan(&mut Rng::new(11));
        let comps = batch_compositions(&d, &plan, 8);
        assert_eq!(comps.len(), 10);
        for &(pos, _) in &comps {
            assert_eq!(pos, 1, "every batch must contain a positive");
        }
        let neg_total: usize = comps.iter().map(|c| c.1).sum();
        assert_eq!(neg_total, 70);
    }

    #[test]
    fn degenerate_inputs_fall_back_to_preserve() {
        let all_neg = toy(30, 0);
        let indices: Vec<u32> = (0..30).collect();
        let mut s = EpochSampler::new(
            &all_neg.y,
            &indices,
            8,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        )
        .unwrap();
        assert_eq!(s.effective_mode(), SamplingMode::Preserve);
        let plan = s.epoch_plan(&mut Rng::new(6));
        assert_eq!(plan.order().len(), 30);

        let mut tiny_batch = EpochSampler::new(
            &toy(10, 5).y,
            &(0..10).collect::<Vec<u32>>(),
            1,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        )
        .unwrap();
        assert_eq!(tiny_batch.effective_mode(), SamplingMode::Preserve);
        assert_eq!(tiny_batch.epoch_plan(&mut Rng::new(7)).order().len(), 10);
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let d = toy(60, 12);
        let indices: Vec<u32> = (0..60).collect();
        for mode in [
            SamplingMode::Preserve,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        ] {
            let mut a = EpochSampler::new(&d.y, &indices, 8, mode).unwrap();
            let mut b = EpochSampler::new(&d.y, &indices, 8, mode).unwrap();
            let mut rng_a = Rng::new(9);
            let mut rng_b = Rng::new(9);
            let a1 = a.epoch_plan(&mut rng_a).order().to_vec();
            let a2 = a.epoch_plan(&mut rng_a).order().to_vec();
            let b1 = b.epoch_plan(&mut rng_b).order().to_vec();
            assert_eq!(a1, b1, "same seed, same first epoch");
            assert_ne!(a1, a2, "consecutive epochs reshuffle");
        }
    }

    #[test]
    fn bad_configs_are_structured_errors_not_panics() {
        let d = toy(10, 3);
        let indices: Vec<u32> = (0..10).collect();
        let err = EpochSampler::new(&d.y, &indices, 0, SamplingMode::Preserve).unwrap_err();
        assert!(err.to_string().contains("batch size"), "{err}");
        let err = EpochSampler::new(&d.y, &[], 4, SamplingMode::Preserve).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        let err = EpochSampler::new(&d.y, &[10], 4, SamplingMode::Preserve).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let err = EpochSampler::new(
            &d.y,
            &indices,
            4,
            SamplingMode::Rebalance { pos_fraction: 1.0 },
        )
        .unwrap_err();
        assert!(err.to_string().contains("(0, 1)"), "{err}");
    }

    #[test]
    fn batch_size_larger_than_subset_yields_one_ragged_batch() {
        let d = toy(9, 3);
        let indices: Vec<u32> = (0..9).collect();
        for mode in [
            SamplingMode::Preserve,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        ] {
            let mut sampler = EpochSampler::new(&d.y, &indices, 32, mode).unwrap();
            let plan = sampler.epoch_plan(&mut Rng::new(12));
            assert_eq!(plan.batch_size(), 32);
            assert_eq!(plan.n_batches(), 1);
            let comps = batch_compositions(&d, &plan, 32);
            assert_eq!(comps.len(), 1);
            let (pos, neg) = comps[0];
            assert!(pos >= 1 && pos + neg <= 32);
        }
    }

    #[test]
    fn subset_view_respected() {
        let d = toy(100, 50);
        let indices: Vec<u32> = (40..80).collect();
        let mut sampler = EpochSampler::new(
            &d.y,
            &indices,
            16,
            SamplingMode::Rebalance { pos_fraction: 0.5 },
        )
        .unwrap();
        assert_eq!(sampler.n_pos(), 10); // 40..50 positive
        assert_eq!(sampler.n_neg(), 30);
        let plan = sampler.epoch_plan(&mut Rng::new(10));
        assert!(plan.order().iter().all(|&i| (40..80).contains(&i)));
    }
}
