//! Data substrates: deterministic RNG, synthetic image generators,
//! dataset containers, splits, the imbalance-aware batch sampler and
//! the streaming stratified epoch pipeline ([`stream`]).
//!
//! The paper's experiments use CIFAR10 / STL10 / Cat&Dog; those downloads
//! are unavailable in this environment (repro band 0), so [`synth`]
//! provides three seeded generators with the same *experimental role*:
//! a learnable nonlinear image → binary-label signal whose difficulty and
//! class balance we control exactly.  See DESIGN.md §2 for the
//! substitution argument.
//!
//! Everything is deterministic from a `u64` seed — a sweep re-run
//! reproduces bit-identical datasets, splits and batch orders.

pub mod dataset;
pub mod features;
pub mod rng;
pub mod sampler;
pub mod shard;
pub mod source;
pub mod stream;
pub mod synth;

pub use dataset::{Dataset, Split};
pub use features::FeatureSpec;
pub use rng::Rng;
pub use sampler::{BatchIter, BatchPlan};
pub use shard::ShardedDataset;
pub use source::{BatchFill, DatasetSource};
pub use stream::{EpochSampler, SamplingMode};
pub use synth::{SynthSpec, SYNTH_DATASETS};
