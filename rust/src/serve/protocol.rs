//! The JSONL scoring protocol: request parsing and response encoding
//! over [`crate::util::json`] (DESIGN.md §11).
//!
//! Request (one JSON object per line):
//!
//! ```text
//! {"features": [0.1, -2.5, ...], "id": <any JSON value, optional>}
//! ```
//!
//! Response (one JSON object per line, always):
//!
//! ```text
//! {"id": <echoed, null if absent>, "score": 0.3728193}
//! {"id": <echoed, null if absent>, "error": "what went wrong"}
//! ```
//!
//! Every complete request line produces exactly one response line, in
//! request order; a malformed line gets a structured `error` response,
//! never a dropped response or a connection teardown.  The `id` is
//! echoed whenever the line parsed far enough to have one, so
//! pipelining clients can correlate errors too.

use crate::util::json::Json;

/// A parsed, validated scoring request.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreRequest {
    /// Opaque correlation value, echoed verbatim in the response.
    pub id: Option<Json>,
    pub features: Vec<f32>,
}

/// A request line that failed validation: the echoable id (if the line
/// parsed far enough to have one) plus a client-safe message.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestError {
    pub id: Option<Json>,
    pub message: String,
}

/// Parse one request line.  The feature values are narrowed to `f32`
/// (the model's score arithmetic) with a finiteness check: a literal
/// like `1e300` is a finite f64 but an infinite f32, and letting it
/// through would score garbage silently.  (Non-finite *literals* like
/// `1e999` never get this far — the JSON parser itself rejects them.)
pub fn parse_request(line: &str) -> Result<ScoreRequest, RequestError> {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return Err(RequestError {
                id: None,
                message: format!("invalid JSON: {e:#}"),
            })
        }
    };
    let id = j.get("id").cloned();
    let err = |message: String| RequestError {
        id: id.clone(),
        message,
    };
    if j.as_obj().is_none() {
        return Err(err("request must be a JSON object".into()));
    }
    let Some(feats) = j.get("features") else {
        return Err(err("missing \"features\"".into()));
    };
    let Some(arr) = feats.as_arr() else {
        return Err(err("\"features\" must be an array of numbers".into()));
    };
    let mut features = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let Some(n) = v.as_f64() else {
            return Err(err(format!("features[{i}] must be a number")));
        };
        let f = n as f32;
        if !f.is_finite() {
            return Err(err(format!("features[{i}] = {n:e} is not a finite f32")));
        }
        features.push(f);
    }
    Ok(ScoreRequest { id, features })
}

fn id_field(id: Option<&Json>) -> Json {
    id.cloned().unwrap_or(Json::Null)
}

/// Encode a success response.  The f32 score widens to f64 exactly, and
/// `dumps` emits the shortest round-tripping decimal — so the client
/// reads back the score bit for bit.  A non-finite score (a diverged
/// checkpoint) degrades to a structured error rather than panicking the
/// writer (`dumps` asserts finiteness).
pub fn score_response(id: Option<&Json>, score: f32) -> String {
    if !score.is_finite() {
        return error_response(id, "model produced a non-finite score");
    }
    Json::obj([("id", id_field(id)), ("score", Json::num(score as f64))]).dumps()
}

/// Encode an error response.
pub fn error_response(id: Option<&Json>, message: &str) -> String {
    Json::obj([("id", id_field(id)), ("error", Json::str(message))]).dumps()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_and_id_carrying_requests() {
        let r = parse_request(r#"{"features": [1.5, -2.0]}"#).unwrap();
        assert_eq!(r.id, None);
        assert_eq!(r.features, vec![1.5, -2.0]);

        let r = parse_request(r#"{"id": 7, "features": []}"#).unwrap();
        assert_eq!(r.id, Some(Json::num(7.0)));
        assert!(r.features.is_empty());

        let r = parse_request(r#"{"id": "req-1", "features": [0]}"#).unwrap();
        assert_eq!(r.id, Some(Json::str("req-1")));
    }

    #[test]
    fn malformed_lines_get_structured_errors() {
        for (line, needle) in [
            ("{\"features\": [1,", "invalid JSON"),
            ("[1, 2, 3]", "must be a JSON object"),
            ("{\"id\": 1}", "missing \"features\""),
            ("{\"features\": 3}", "must be an array"),
            ("{\"features\": [1, \"x\"]}", "features[1] must be a number"),
            ("{\"features\": [1e999]}", "invalid JSON"),
        ] {
            let e = parse_request(line).unwrap_err();
            assert!(e.message.contains(needle), "{line}: {}", e.message);
        }
    }

    #[test]
    fn id_is_echoed_even_on_invalid_features() {
        let e = parse_request(r#"{"id": 42, "features": "nope"}"#).unwrap_err();
        assert_eq!(e.id, Some(Json::num(42.0)));
        let resp = error_response(e.id.as_ref(), &e.message);
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(42.0));
        assert!(j.get("error").is_some());
    }

    #[test]
    fn f32_overflowing_features_are_rejected() {
        // 1e300 is a perfectly finite f64; the narrowing to the model's
        // f32 rows is where it becomes infinite.
        let e = parse_request(r#"{"features": [1e300]}"#).unwrap_err();
        assert!(e.message.contains("finite f32"), "{}", e.message);
        // but the full finite-f32 range passes
        let r = parse_request(r#"{"features": [3e38, -3e38, 1e-300]}"#).unwrap();
        assert_eq!(r.features, vec![3e38, -3e38, 0.0]);
    }

    #[test]
    fn score_responses_round_trip_the_f32_bits() {
        for score in [0.0_f32, -0.0, 0.1, -123.456, 3.4e38, 1.2e-38, 7.0] {
            let resp = score_response(Some(&Json::str("a")), score);
            let j = Json::parse(&resp).unwrap();
            let back = j.get("score").and_then(Json::as_f64).unwrap();
            assert_eq!(back, score as f64, "score {score} mangled: {resp}");
            assert_eq!(back as f32, score);
        }
    }

    #[test]
    fn non_finite_scores_degrade_to_errors_not_panics() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let resp = score_response(None, bad);
            let j = Json::parse(&resp).unwrap();
            assert!(j.get("error").is_some(), "{resp}");
            assert!(j.get("score").is_none());
        }
    }

    #[test]
    fn absent_id_echoes_null() {
        let j = Json::parse(&score_response(None, 1.0)).unwrap();
        assert_eq!(j.get("id"), Some(&Json::Null));
        let j = Json::parse(&error_response(None, "m")).unwrap();
        assert_eq!(j.get("id"), Some(&Json::Null));
    }
}
