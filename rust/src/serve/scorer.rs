//! The scoring core: one thread owns the backend and executor, and
//! micro-batches concurrent requests into single forward passes.
//!
//! Determinism (DESIGN.md §11): the native forward pass is row-
//! independent — each score is a pure function of its own feature row
//! and the parameters, and the engine's chunk layout depends only on
//! the row count — so a request scored inside a 64-row micro-batch
//! produces the *bit-identical* f32 it would get scored alone.  CI's
//! serve-smoke job pins this end to end against the offline path.
//!
//! Hot reload: a [`Msg::Reload`] makes the scoring thread re-read the
//! checkpoint between batches.  Safety comes from three layers — the
//! trainer publishes via atomic rename (never a torn file), the
//! checkpoint CRC rejects corruption, and the executor's `load_state`
//! validates arity and shapes *before* assigning — so any failed reload
//! (missing file, bad CRC, wrong architecture, injected fault) leaves
//! the previous model serving untouched.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::losses::LossSpec;
use crate::runtime::{Backend, HostTensor, ModelExecutor, NativeBackend, NativeSpec};
use crate::train::checkpoint;
use crate::util::failpoint;

/// Failpoint on the hot-reload path: tests inject a reload failure and
/// assert the old model keeps serving.
pub const FP_RELOAD: &str = "serve.reload";

/// Counters exposed by [`ScoreHandle::stats`].  Because the scoring
/// thread processes messages in order, a `stats()` call also acts as a
/// barrier: once it returns, every previously submitted request and
/// reload has been fully processed.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Forward passes executed.
    pub batches: u64,
    /// Rows scored across all batches.
    pub rows: u64,
    /// Largest micro-batch folded into one forward pass.
    pub max_batch_rows: u64,
    /// Error replies sent (wrong arity, non-finite score, engine error).
    pub errors: u64,
    pub reloads_ok: u64,
    pub reloads_failed: u64,
}

/// The architecture a checkpoint implies, recovered from its state-
/// tensor layout (parameters first, momentum mirror after):
/// linear = 4 tensors `[dim], [], [dim], []`; MLP = 8 tensors starting
/// `[h, dim], [h], [h], []`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelInfo {
    /// Model name as `Backend::open` spells it (`"linear"` | `"mlp"`).
    pub model: &'static str,
    pub dim: usize,
    pub hidden: usize,
    pub n_state: usize,
}

impl ModelInfo {
    /// The backend spec that reproduces this architecture.
    pub fn native_spec(&self, threads: usize) -> NativeSpec {
        NativeSpec {
            input_dim: self.dim,
            hidden: self.hidden,
            threads,
            ..NativeSpec::default()
        }
    }
}

/// Infer the model architecture from a checkpoint's tensors.
pub fn infer_model(tensors: &[HostTensor]) -> crate::Result<ModelInfo> {
    let shapes: Vec<&[i64]> = tensors.iter().map(|t| t.shape.as_slice()).collect();
    let half = shapes.len() / 2;
    let n_state = shapes.len();
    if n_state >= 4 && n_state % 2 == 0 && shapes[..half] == shapes[half..] {
        match &shapes[..half] {
            &[&[d], &[]] if d > 0 => {
                return Ok(ModelInfo {
                    model: "linear",
                    dim: d as usize,
                    hidden: 0,
                    n_state,
                })
            }
            &[&[h, d], &[h1], &[h2], &[]] if h > 0 && d > 0 && h1 == h && h2 == h => {
                return Ok(ModelInfo {
                    model: "mlp",
                    dim: d as usize,
                    hidden: h as usize,
                    n_state,
                })
            }
            _ => {}
        }
    }
    anyhow::bail!("unrecognized checkpoint layout {shapes:?} (not a linear or MLP state)")
}

/// How to build the scoring thread.
#[derive(Debug, Clone)]
pub struct ScorerOptions {
    pub checkpoint: PathBuf,
    /// Cap on rows folded into one forward pass.
    pub max_batch: usize,
    /// Engine worker threads (0 = one per core).
    pub threads: usize,
}

impl ScorerOptions {
    pub fn new(checkpoint: impl Into<PathBuf>) -> Self {
        Self {
            checkpoint: checkpoint.into(),
            max_batch: 1024,
            threads: 0,
        }
    }
}

struct ScoreJob {
    features: Vec<f32>,
    reply: mpsc::Sender<Result<f32, String>>,
}

enum Msg {
    Score(ScoreJob),
    Reload,
    Stats(mpsc::Sender<ServeStats>),
}

/// Cheap, cloneable submission endpoint; every connection thread holds
/// one.  The scoring thread exits when the last handle drops.
#[derive(Clone)]
pub struct ScoreHandle {
    tx: mpsc::Sender<Msg>,
    row_len: usize,
}

impl ScoreHandle {
    /// Features per request (the checkpoint's input dimension).
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Submit one request and return its reply channel immediately, so
    /// a connection can pipeline many requests while preserving its own
    /// response order.
    pub fn submit(&self, features: Vec<f32>) -> mpsc::Receiver<Result<f32, String>> {
        let (reply, rx) = mpsc::channel();
        if let Err(mpsc::SendError(Msg::Score(job))) =
            self.tx.send(Msg::Score(ScoreJob { features, reply }))
        {
            let _ = job.reply.send(Err("scoring engine is shut down".into()));
        }
        rx
    }

    /// Score one request, blocking for the reply.  Used by the `--stdin`
    /// reference path: each call completes before the next begins, so
    /// every micro-batch holds exactly one row.
    pub fn score(&self, features: Vec<f32>) -> Result<f32, String> {
        self.submit(features)
            .recv()
            .unwrap_or_else(|_| Err("scoring engine is shut down".into()))
    }

    /// Request a checkpoint reload (asynchronous; the outcome lands in
    /// [`stats`](Self::stats)).  Returns false if the scorer is gone.
    pub fn reload(&self) -> bool {
        self.tx.send(Msg::Reload).is_ok()
    }

    /// Fetch the counters; doubles as a completion barrier for all
    /// messages sent before it on this handle.
    pub fn stats(&self) -> Option<ServeStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Stats(tx)).ok()?;
        rx.recv().ok()
    }
}

/// A running scoring thread plus its submission handle.
pub struct Scorer {
    pub handle: ScoreHandle,
    pub info: ModelInfo,
    thread: std::thread::JoinHandle<()>,
}

impl Scorer {
    /// Load the checkpoint, infer the architecture, and start the
    /// scoring thread (fails fast if the state doesn't open).
    pub fn spawn(opts: ScorerOptions) -> crate::Result<Scorer> {
        anyhow::ensure!(opts.max_batch >= 1, "max_batch must be >= 1");
        let tensors = checkpoint::load(&opts.checkpoint)?;
        let info = infer_model(&tensors)?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        let thread = std::thread::Builder::new()
            .name("allpairs-scorer".into())
            .spawn(move || scorer_thread(rx, ready_tx, tensors, info, opts))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("scoring thread died during startup"))??;
        Ok(Scorer {
            handle: ScoreHandle { tx, row_len: info.dim },
            info,
            thread,
        })
    }

    /// Drop this struct's handle and join the scoring thread.  Blocks
    /// until every cloned [`ScoreHandle`] has dropped too.
    pub fn shutdown(self) {
        let Scorer { handle, thread, .. } = self;
        drop(handle);
        let _ = thread.join();
    }
}

fn scorer_thread(
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<crate::Result<()>>,
    tensors: Vec<HostTensor>,
    info: ModelInfo,
    opts: ScorerOptions,
) {
    // The executor borrows the backend, so both live (and die) on this
    // thread: one owner of all model state, no locks on the hot path.
    // The loss and train-batch size are irrelevant to `predict`; hinge
    // at batch 1 always opens.
    let backend = NativeBackend::new(info.native_spec(opts.threads));
    let mut exec = match backend
        .open(info.model, &LossSpec::hinge(), 1)
        .and_then(|mut e| e.load_state(&tensors).map(|()| e))
    {
        Ok(exec) => {
            let _ = ready.send(Ok(()));
            exec
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    drop(tensors);

    let dim = info.dim;
    let mut stats = ServeStats::default();
    let mut xbuf: Vec<f32> = Vec::new();
    let mut scores: Vec<f32> = Vec::new();
    let mut replies: Vec<mpsc::Sender<Result<f32, String>>> = Vec::new();

    while let Ok(msg) = rx.recv() {
        let job = match msg {
            Msg::Reload => {
                reload(exec.as_mut(), &opts.checkpoint, &mut stats);
                continue;
            }
            Msg::Stats(tx) => {
                let _ = tx.send(stats);
                continue;
            }
            Msg::Score(job) => job,
        };

        // Micro-batch: the blocking head request plus whatever is
        // already queued, up to max_batch rows.  A control message seen
        // mid-drain is deferred until after the forward pass, so the
        // rows already collected complete on the model they arrived
        // under — a reload never tears an in-flight batch.
        xbuf.clear();
        replies.clear();
        let mut deferred: Option<Msg> = None;
        enqueue(job, dim, &mut xbuf, &mut replies, &mut stats);
        while replies.len() < opts.max_batch {
            match rx.try_recv() {
                Ok(Msg::Score(job)) => enqueue(job, dim, &mut xbuf, &mut replies, &mut stats),
                Ok(ctrl) => {
                    deferred = Some(ctrl);
                    break;
                }
                Err(_) => break,
            }
        }

        if !replies.is_empty() {
            let rows = replies.len();
            scores.clear();
            match exec.predict_into(&xbuf, rows, &mut scores) {
                Ok(()) => {
                    stats.batches += 1;
                    stats.rows += rows as u64;
                    stats.max_batch_rows = stats.max_batch_rows.max(rows as u64);
                    for (reply, &s) in replies.iter().zip(&scores) {
                        if s.is_finite() {
                            let _ = reply.send(Ok(s));
                        } else {
                            stats.errors += 1;
                            let _ = reply.send(Err("model produced a non-finite score".into()));
                        }
                    }
                }
                Err(e) => {
                    stats.errors += rows as u64;
                    for reply in &replies {
                        let _ = reply.send(Err(format!("scoring failed: {e:#}")));
                    }
                }
            }
        }

        match deferred {
            Some(Msg::Reload) => reload(exec.as_mut(), &opts.checkpoint, &mut stats),
            Some(Msg::Stats(tx)) => {
                let _ = tx.send(stats);
            }
            Some(Msg::Score(_)) | None => {}
        }
    }
}

/// Validate and stage one request into the batch buffers.  A wrong-
/// arity request is answered immediately — it can't join the batch —
/// without disturbing the rows already staged.
fn enqueue(
    job: ScoreJob,
    dim: usize,
    xbuf: &mut Vec<f32>,
    replies: &mut Vec<mpsc::Sender<Result<f32, String>>>,
    stats: &mut ServeStats,
) {
    if job.features.len() == dim {
        xbuf.extend_from_slice(&job.features);
        replies.push(job.reply);
    } else {
        stats.errors += 1;
        let _ = job.reply.send(Err(format!(
            "expected {dim} features, got {}",
            job.features.len()
        )));
    }
}

/// Attempt a checkpoint reload; on any failure the previous state is
/// untouched (`load_state` validates before assigning) and the old
/// model keeps serving.
fn reload(exec: &mut dyn ModelExecutor, path: &Path, stats: &mut ServeStats) {
    let outcome = (|| -> crate::Result<()> {
        failpoint::check(FP_RELOAD)?;
        let tensors = checkpoint::load(path)?;
        exec.load_state(&tensors)
    })();
    match outcome {
        Ok(()) => {
            stats.reloads_ok += 1;
            eprintln!("serve: reloaded checkpoint {}", path.display());
        }
        Err(e) => {
            stats.reloads_failed += 1;
            eprintln!("serve: reload failed, keeping the current model: {e:#}");
        }
    }
}

/// Guard for a background reload-watcher thread; dropping it stops the
/// thread promptly.  A long-lived caller (the CLI) just keeps it in
/// scope for the process lifetime.
pub struct WatcherGuard {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for WatcherGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.thread().unpark();
            let _ = t.join();
        }
    }
}

/// Poll `path` every `period` and request a reload on each change.
/// Built on [`checkpoint::Watcher`], so only complete atomic-rename
/// publishes trigger (a deleted file never does).
pub fn spawn_reload_watcher(
    path: impl Into<PathBuf>,
    period: Duration,
    handle: ScoreHandle,
) -> crate::Result<WatcherGuard> {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let mut watcher = checkpoint::Watcher::new(path);
    let thread = std::thread::Builder::new()
        .name("allpairs-reload-watch".into())
        .spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                std::thread::park_timeout(period);
                if flag.load(Ordering::Relaxed) {
                    break;
                }
                if watcher.poll() && !handle.reload() {
                    break; // scorer gone: nothing left to notify
                }
            }
        })?;
    Ok(WatcherGuard {
        stop,
        thread: Some(thread),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("allpairs_scorer_{}_{name}", std::process::id()))
    }

    /// Train-free checkpoint: init an executor and snapshot its state.
    fn make_checkpoint(path: &Path, seed: u32, dim: usize, hidden: usize) -> Vec<HostTensor> {
        let backend = NativeBackend::new(NativeSpec {
            input_dim: dim,
            hidden,
            threads: 1,
            ..NativeSpec::default()
        });
        let model = if hidden == 0 { "linear" } else { "mlp" };
        let mut exec = backend.open(model, &LossSpec::hinge(), 1).unwrap();
        exec.init(seed).unwrap();
        let state = exec.state_to_host().unwrap();
        checkpoint::save(path, &state).unwrap();
        state
    }

    #[test]
    fn infers_linear_and_mlp_layouts() {
        let p = tmp("infer_linear.bin");
        make_checkpoint(&p, 0, 5, 0);
        let info = infer_model(&checkpoint::load(&p).unwrap()).unwrap();
        assert_eq!(info, ModelInfo { model: "linear", dim: 5, hidden: 0, n_state: 4 });

        let p = tmp("infer_mlp.bin");
        make_checkpoint(&p, 0, 6, 3);
        let info = infer_model(&checkpoint::load(&p).unwrap()).unwrap();
        assert_eq!(info, ModelInfo { model: "mlp", dim: 6, hidden: 3, n_state: 8 });
    }

    #[test]
    fn rejects_unrecognizable_layouts() {
        for tensors in [
            vec![],
            vec![HostTensor::vec1(vec![1.0]); 3], // odd arity
            vec![
                // momentum half doesn't mirror the params
                HostTensor::vec1(vec![1.0, 2.0]),
                HostTensor::scalar(0.0),
                HostTensor::vec1(vec![1.0]),
                HostTensor::scalar(0.0),
            ],
            vec![HostTensor::new(vec![2, 2, 2], vec![0.0; 8]); 4], // rank 3
        ] {
            assert!(infer_model(&tensors).is_err(), "{:?}", tensors.len());
        }
    }

    #[test]
    fn scores_match_the_offline_executor_bit_for_bit() {
        let p = tmp("roundtrip.bin");
        let state = make_checkpoint(&p, 7, 4, 2);
        let scorer = Scorer::spawn(ScorerOptions {
            max_batch: 8,
            threads: 1,
            ..ScorerOptions::new(&p)
        })
        .unwrap();
        assert_eq!(scorer.handle.row_len(), 4);

        // offline reference
        let backend = NativeBackend::new(scorer.info.native_spec(1));
        let mut exec = backend.open("mlp", &LossSpec::hinge(), 1).unwrap();
        exec.load_state(&state).unwrap();

        let mut rng = crate::data::Rng::new(3);
        for _ in 0..20 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            let want = exec.predict(&row, 1).unwrap()[0];
            let got = scorer.handle.score(row).unwrap();
            assert_eq!(got.to_bits(), want.to_bits());
        }
        let stats = scorer.handle.stats().unwrap();
        assert_eq!(stats.rows, 20);
        assert_eq!(stats.errors, 0);
        scorer.shutdown();
    }

    #[test]
    fn wrong_arity_is_an_immediate_structured_error() {
        let p = tmp("arity.bin");
        make_checkpoint(&p, 1, 3, 0);
        let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();
        let err = scorer.handle.score(vec![1.0; 5]).unwrap_err();
        assert!(err.contains("expected 3 features, got 5"), "{err}");
        // and the engine still serves the next valid request
        assert!(scorer.handle.score(vec![1.0; 3]).is_ok());
        let stats = scorer.handle.stats().unwrap();
        assert_eq!((stats.errors, stats.rows), (1, 1));
        scorer.shutdown();
    }

    #[test]
    fn reload_swaps_models_and_failures_keep_the_old_one() {
        let _guard = failpoint::serial_guard();
        let p = tmp("reload.bin");
        make_checkpoint(&p, 10, 4, 0);
        let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();
        let row = vec![0.5_f32, -1.0, 2.0, 0.25];
        let score_a = scorer.handle.score(row.clone()).unwrap();

        // An injected failure mid-reload must not disturb the model.
        failpoint::arm_str(FP_RELOAD, "error").unwrap();
        assert!(scorer.handle.reload());
        let stats = scorer.handle.stats().unwrap();
        assert_eq!((stats.reloads_ok, stats.reloads_failed), (0, 1));
        assert_eq!(scorer.handle.score(row.clone()).unwrap(), score_a);
        failpoint::disarm(FP_RELOAD);

        // A real republish swaps in the new parameters.
        make_checkpoint(&p, 11, 4, 0);
        assert!(scorer.handle.reload());
        let stats = scorer.handle.stats().unwrap();
        assert_eq!((stats.reloads_ok, stats.reloads_failed), (1, 1));
        let score_b = scorer.handle.score(row).unwrap();
        assert_ne!(score_a.to_bits(), score_b.to_bits());
        scorer.shutdown();
    }

    #[test]
    fn watcher_triggers_reload_on_republish() {
        let p = tmp("watch.bin");
        make_checkpoint(&p, 20, 3, 0);
        let scorer = Scorer::spawn(ScorerOptions::new(&p)).unwrap();
        let guard =
            spawn_reload_watcher(&p, Duration::from_millis(5), scorer.handle.clone()).unwrap();
        make_checkpoint(&p, 21, 3, 0);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let stats = scorer.handle.stats().unwrap();
            if stats.reloads_ok >= 1 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "watcher never fired");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(guard);
        scorer.shutdown();
    }

    #[test]
    fn spawn_fails_fast_on_a_missing_or_corrupt_checkpoint() {
        let p = tmp("nope.bin");
        let _ = std::fs::remove_file(&p);
        assert!(Scorer::spawn(ScorerOptions::new(&p)).is_err());
        std::fs::write(&p, b"garbage").unwrap();
        assert!(Scorer::spawn(ScorerOptions::new(&p)).is_err());
    }
}
