//! The serving front ends: a threaded TCP listener for concurrent
//! JSONL clients, and a blocking stdin mode that doubles as the
//! offline reference path.
//!
//! Per connection, a reader thread and a writer thread share a FIFO of
//! pending responses: the reader frames and parses request lines and
//! enqueues either a ready error response or an in-flight score; the
//! writer resolves them in order.  That queue is what keeps responses
//! in request order even though error responses are ready instantly
//! while earlier scores are still crossing the micro-batcher.
//!
//! Failure policy (DESIGN.md §11): a bad line yields a structured
//! error *response*; only a transport-level event (EOF, reset) ends a
//! connection, and a mid-line disconnect simply abandons the partial
//! line — it never completed a request, so no response is owed and the
//! listener keeps serving everyone else.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::framing::{LineFramer, DEFAULT_MAX_LINE};
use super::protocol::{self, ScoreRequest};
use super::scorer::ScoreHandle;
use crate::util::json::Json;

/// Front-end tunables.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Byte cap per request line (over-long lines get an error
    /// response, never unbounded buffering).
    pub max_line: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            max_line: DEFAULT_MAX_LINE,
        }
    }
}

/// One slot of a connection's response FIFO.
enum Pending {
    /// Response line already known (request-level error).
    Ready(String),
    /// Score in flight through the micro-batcher.
    InFlight {
        id: Option<Json>,
        reply: mpsc::Receiver<Result<f32, String>>,
    },
}

/// A listening scoring server; accepts until [`stop`](Server::stop).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an OS-assigned port) and
    /// start accepting in a background thread.
    pub fn start(addr: &str, handle: ScoreHandle, opts: ServerOptions) -> crate::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("allpairs-accept".into())
            .spawn(move || accept_loop(listener, handle, opts, flag))?;
        Ok(Server {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    /// The bound address (reports the real port after `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread; connections already
    /// established drain independently.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    handle: ScoreHandle,
    opts: ServerOptions,
    stop: Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match conn {
            Ok(stream) => {
                let handle = handle.clone();
                let opts = opts.clone();
                let spawned = std::thread::Builder::new()
                    .name("allpairs-conn".into())
                    .spawn(move || handle_connection(stream, handle, opts));
                if let Err(e) = spawned {
                    eprintln!("serve: dropping connection (thread spawn failed: {e})");
                }
            }
            Err(e) => eprintln!("serve: accept error: {e}"),
        }
    }
}

fn handle_connection(stream: TcpStream, handle: ScoreHandle, opts: ServerOptions) {
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (ptx, prx) = mpsc::channel::<Pending>();
    let writer = std::thread::Builder::new()
        .name("allpairs-conn-write".into())
        .spawn(move || write_loop(write_half, prx));
    let Ok(writer) = writer else { return };
    read_loop(stream, &handle, &opts, &ptx);
    // EOF or reset: close the FIFO so the writer drains what's still in
    // flight, then exits.
    drop(ptx);
    let _ = writer.join();
}

/// Frame, parse and submit request lines.  Every *complete* line — good
/// or bad — enqueues exactly one pending response, in arrival order.
fn read_loop(
    mut stream: TcpStream,
    handle: &ScoreHandle,
    opts: &ServerOptions,
    ptx: &mpsc::Sender<Pending>,
) {
    let mut framer = LineFramer::new(opts.max_line);
    let mut chunk = [0u8; 8192];
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => return, // EOF; a partial line is abandoned
            Ok(n) => n,
            Err(_) => return, // mid-line disconnect / reset
        };
        framer.push(&chunk[..n]);
        while let Some(line) = framer.next_line() {
            let pending = match line {
                Err(e) => Pending::Ready(protocol::error_response(None, &e.message())),
                Ok(line) if line.trim().is_empty() => continue, // keep-alive blank
                Ok(line) => match protocol::parse_request(&line) {
                    Ok(ScoreRequest { id, features }) => Pending::InFlight {
                        id,
                        reply: handle.submit(features),
                    },
                    Err(e) => Pending::Ready(protocol::error_response(e.id.as_ref(), &e.message)),
                },
            };
            if ptx.send(pending).is_err() {
                return; // writer gone: client closed its read side
            }
        }
    }
}

/// Resolve the pending FIFO in order and write one JSONL line each.
fn write_loop(stream: TcpStream, prx: mpsc::Receiver<Pending>) {
    let mut out = std::io::BufWriter::new(stream);
    for pending in prx {
        let line = match pending {
            Pending::Ready(line) => line,
            Pending::InFlight { id, reply } => match reply.recv() {
                Ok(Ok(score)) => protocol::score_response(id.as_ref(), score),
                Ok(Err(msg)) => protocol::error_response(id.as_ref(), &msg),
                Err(_) => protocol::error_response(id.as_ref(), "scoring engine is shut down"),
            },
        };
        if out.write_all(line.as_bytes()).is_err()
            || out.write_all(b"\n").is_err()
            || out.flush().is_err()
        {
            return;
        }
    }
}

/// Offline / reference mode (`allpairs serve --stdin`): read JSONL
/// requests from `input`, score each as its own single-row forward pass
/// ([`ScoreHandle::score`] blocks per line), write responses to
/// `output`, and return how many were written.  The CI serve-smoke job
/// diffs this against the concurrent TCP path to pin the batched ≡
/// single bit-identity end to end.
pub fn run_stdin(
    handle: &ScoreHandle,
    mut input: impl Read,
    output: &mut impl Write,
    max_line: usize,
) -> crate::Result<usize> {
    let mut framer = LineFramer::new(max_line);
    let mut chunk = [0u8; 8192];
    let mut n_responses = 0usize;
    loop {
        let n = input.read(&mut chunk)?;
        framer.push(&chunk[..n]);
        while let Some(line) = framer.next_line() {
            let response = match line {
                Err(e) => protocol::error_response(None, &e.message()),
                Ok(line) if line.trim().is_empty() => continue,
                Ok(line) => match protocol::parse_request(&line) {
                    Ok(req) => match handle.score(req.features) {
                        Ok(s) => protocol::score_response(req.id.as_ref(), s),
                        Err(msg) => protocol::error_response(req.id.as_ref(), &msg),
                    },
                    Err(e) => protocol::error_response(e.id.as_ref(), &e.message),
                },
            };
            writeln!(output, "{response}")?;
            n_responses += 1;
        }
        if n == 0 {
            break;
        }
    }
    output.flush()?;
    Ok(n_responses)
}
