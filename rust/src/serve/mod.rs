//! `allpairs serve` — the online scoring subsystem (DESIGN.md §11).
//!
//! A trained checkpoint becomes a long-running scoring service: clients
//! stream newline-delimited JSON requests over TCP (or stdin) and get
//! one response line per request line, in order.  Three guarantees
//! define the subsystem, each carried by one layer here:
//!
//! 1. **Batched ≡ single, bit for bit** ([`scorer`]): concurrent
//!    requests are micro-batched into one forward pass, and because the
//!    native forward is row-independent — per-row arithmetic is a pure
//!    function of that row and the parameters, and the engine's chunk
//!    layout depends only on the row count — a score never depends on
//!    which other requests shared its batch.
//! 2. **One ordered response per request line** ([`framing`],
//!    [`protocol`], [`server`]): malformed JSON, wrong arity, non-f32
//!    features, over-long lines — all produce structured `error`
//!    responses in request order; only transport-level EOF/reset ends a
//!    connection, and a mid-line disconnect abandons the incomplete
//!    line without disturbing anyone else.
//! 3. **Atomic hot reload** ([`scorer`] + [`crate::train::checkpoint`]):
//!    the trainer publishes checkpoints by atomic rename with a CRC
//!    footer, the watcher only fires on complete publishes, and the
//!    executor validates a candidate state fully before assigning — so
//!    the server swaps models between micro-batches or keeps the old
//!    one, never serves a torn mix.

pub mod framing;
pub mod protocol;
pub mod scorer;
pub mod server;

pub use framing::{FrameError, LineFramer, DEFAULT_MAX_LINE};
pub use protocol::{error_response, parse_request, score_response, RequestError, ScoreRequest};
pub use scorer::{
    infer_model, spawn_reload_watcher, ModelInfo, ScoreHandle, Scorer, ScorerOptions, ServeStats,
    WatcherGuard, FP_RELOAD,
};
pub use server::{run_stdin, Server, ServerOptions};
