//! Incremental line framing for the streaming JSONL protocol.
//!
//! Sockets deliver arbitrary byte chunks; the framer buffers them and
//! yields complete `\n`-terminated lines (a trailing `\r` is tolerated,
//! so `curl`-style CRLF clients work).  Two protections keep one bad
//! client from hurting the server:
//!
//! * a byte cap per line ([`LineFramer::new`]): an over-long line is
//!   discarded *as it streams in* (bounded memory, however much the
//!   client sends) and reported as one [`FrameError::TooLong`] when its
//!   terminating newline finally arrives — the client still receives
//!   exactly one response for it;
//! * invalid UTF-8 in a complete line is a [`FrameError::NotUtf8`]
//!   *value*, not a connection error — later lines parse normally.
//!
//! A partial line at EOF (mid-line disconnect) is simply abandoned: no
//! request line was completed, so no response is owed.  The server
//! checks [`LineFramer::pending`] only for diagnostics.

/// Default per-line byte cap: 1 MiB holds tens of thousands of decimal
/// features, far past any real request on this model family.
pub const DEFAULT_MAX_LINE: usize = 1 << 20;

/// A complete line that cannot become a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The line exceeded the configured byte cap.
    TooLong { max: usize },
    /// The line's bytes are not valid UTF-8.
    NotUtf8,
}

impl FrameError {
    /// Client-safe message for the error response.
    pub fn message(&self) -> String {
        match self {
            FrameError::TooLong { max } => format!("request line exceeds {max} bytes"),
            FrameError::NotUtf8 => "request line is not valid UTF-8".into(),
        }
    }
}

/// Reassembles `\n`-framed lines from arbitrary byte chunks.
#[derive(Debug)]
pub struct LineFramer {
    buf: Vec<u8>,
    /// Prefix of `buf` already handed out as lines.
    consumed: usize,
    max_line: usize,
    /// Inside an over-long line: discard bytes until its newline.
    overflowing: bool,
}

impl LineFramer {
    pub fn new(max_line: usize) -> Self {
        assert!(max_line > 0, "max_line must be positive");
        Self {
            buf: Vec::new(),
            consumed: 0,
            max_line,
            overflowing: false,
        }
    }

    /// Feed one raw chunk; drain with [`next_line`](Self::next_line).
    pub fn push(&mut self, chunk: &[u8]) {
        self.buf.extend_from_slice(chunk);
    }

    /// Bytes buffered beyond the last complete line.  Non-zero at EOF
    /// means a mid-line disconnect (the bytes are abandoned).
    pub fn pending(&self) -> usize {
        self.buf.len() - self.consumed
    }

    /// The next complete line, stripped of its `\n` (and a preceding
    /// `\r`), or `None` until more bytes arrive.
    pub fn next_line(&mut self) -> Option<Result<String, FrameError>> {
        match self.buf[self.consumed..].iter().position(|&b| b == b'\n') {
            Some(off) => {
                let start = self.consumed;
                let end = start + off;
                self.consumed = end + 1;
                if self.overflowing {
                    // The over-long line just ended; report it once.
                    self.overflowing = false;
                    self.compact();
                    return Some(Err(FrameError::TooLong { max: self.max_line }));
                }
                let mut bytes = &self.buf[start..end];
                if bytes.last() == Some(&b'\r') {
                    bytes = &bytes[..bytes.len() - 1];
                }
                let line = if bytes.len() > self.max_line {
                    // Whole over-cap line arrived in one push: the
                    // streaming discard above never triggered.
                    Err(FrameError::TooLong { max: self.max_line })
                } else {
                    match std::str::from_utf8(bytes) {
                        Ok(s) => Ok(s.to_string()),
                        Err(_) => Err(FrameError::NotUtf8),
                    }
                };
                self.compact();
                Some(line)
            }
            None => {
                if self.pending() > self.max_line {
                    self.overflowing = true;
                }
                if self.overflowing {
                    // Drop the oversized prefix now — memory stays
                    // bounded no matter how much the client streams.
                    self.buf.clear();
                    self.consumed = 0;
                }
                None
            }
        }
    }

    fn compact(&mut self) {
        if self.consumed == self.buf.len() {
            self.buf.clear();
            self.consumed = 0;
        } else if self.consumed >= 4096 {
            self.buf.drain(..self.consumed);
            self.consumed = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(f: &mut LineFramer) -> Vec<Result<String, FrameError>> {
        std::iter::from_fn(|| f.next_line()).collect()
    }

    #[test]
    fn reassembles_lines_across_arbitrary_chunk_boundaries() {
        let text = b"{\"a\":1}\n{\"b\":2}\r\n\n{\"c\":3}\n";
        for chunk_size in 1..=text.len() {
            let mut f = LineFramer::new(64);
            let mut lines = Vec::new();
            for chunk in text.chunks(chunk_size) {
                f.push(chunk);
                lines.extend(drain(&mut f));
            }
            assert_eq!(
                lines,
                vec![
                    Ok("{\"a\":1}".to_string()),
                    Ok("{\"b\":2}".to_string()),
                    Ok(String::new()),
                    Ok("{\"c\":3}".to_string()),
                ],
                "chunk size {chunk_size}"
            );
            assert_eq!(f.pending(), 0);
        }
    }

    #[test]
    fn overlong_line_reported_once_with_bounded_memory() {
        let mut f = LineFramer::new(8);
        f.push(b"0123456789"); // 10 bytes, no newline yet
        assert_eq!(f.next_line(), None);
        assert_eq!(f.pending(), 0, "oversized prefix discarded immediately");
        f.push(b"abcdef"); // still the same line
        assert_eq!(f.next_line(), None);
        assert_eq!(f.pending(), 0);
        f.push(b"end\nok\n");
        assert_eq!(f.next_line(), Some(Err(FrameError::TooLong { max: 8 })));
        assert_eq!(f.next_line(), Some(Ok("ok".to_string())));
        assert_eq!(f.next_line(), None);
    }

    #[test]
    fn overlong_line_in_a_single_push_is_still_rejected() {
        // The newline is already present when the cap is crossed, so
        // the streaming-discard path never runs — the length check on
        // the completed line must catch it instead.
        let mut f = LineFramer::new(8);
        f.push(b"0123456789abcdef\nok\n");
        assert_eq!(f.next_line(), Some(Err(FrameError::TooLong { max: 8 })));
        assert_eq!(f.next_line(), Some(Ok("ok".to_string())));
    }

    #[test]
    fn exactly_max_line_bytes_is_accepted() {
        let mut f = LineFramer::new(8);
        f.push(b"01234567\n");
        assert_eq!(f.next_line(), Some(Ok("01234567".to_string())));
    }

    #[test]
    fn invalid_utf8_is_a_value_not_a_wedge() {
        let mut f = LineFramer::new(64);
        f.push(b"\xff\xfe\n{\"x\":1}\n");
        assert_eq!(f.next_line(), Some(Err(FrameError::NotUtf8)));
        assert_eq!(f.next_line(), Some(Ok("{\"x\":1}".to_string())));
    }

    #[test]
    fn partial_line_stays_pending() {
        let mut f = LineFramer::new(64);
        f.push(b"{\"x\": 1");
        assert_eq!(f.next_line(), None);
        assert_eq!(f.pending(), 7, "mid-line disconnect leaves bytes unclaimed");
    }

    #[test]
    fn compaction_keeps_long_sessions_bounded() {
        let mut f = LineFramer::new(64);
        for i in 0..10_000 {
            f.push(format!("line{i}\n").as_bytes());
            assert!(matches!(f.next_line(), Some(Ok(_))));
            assert_eq!(f.next_line(), None);
            assert!(f.buf.len() <= 4096 + 64, "buffer grew unboundedly");
        }
    }
}
