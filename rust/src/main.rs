//! `allpairs` — L3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's experiments:
//!
//! * `timing`          — Figure 2 (loss+gradient wall time vs n)
//! * `sweep`           — Table 2 + Figure 3 (cross-validation protocol)
//! * `train`           — one training run (debugging / ad-hoc)
//! * `bench`           — the tracked perf trajectory (train-step /
//!                       loss / AUC wall times → `BENCH_train.json`)
//! * `serve`           — online scoring service over a checkpoint
//!                       (JSONL over TCP/stdin, hot reload)
//! * `bench-serve`     — serving-path perf trajectory
//!                       (→ `BENCH_serve.json`)
//! * `report`          — re-aggregate a saved sweep JSONL
//! * `artifacts-check` — compile every artifact and smoke-run init
//!                       (requires the `pjrt` feature)
//!
//! Execution defaults to the self-contained native backend; pass
//! `--backend pjrt` (with a build carrying `--features pjrt` and a
//! `make artifacts` directory) to run through the AOT artifacts.
//!
//! Argument parsing uses the in-tree `util::cli` (offline build: clap is
//! unavailable); run with no arguments for usage.

use std::path::{Path, PathBuf};

use allpairs::config::SweepConfig;
use allpairs::coordinator::{cv, perf, timing};
use allpairs::data::{shard, DatasetSource, Rng, SamplingMode, Split};
use allpairs::losses::LossSpec;
use allpairs::report::figures::{ascii_loglog, write_csv};
use allpairs::runtime::BackendSpec;
use allpairs::serve;
use allpairs::sweep::results;
use allpairs::train::{checkpoint, FitConfig, Trainer};
use allpairs::util::cli::Args;

const USAGE: &str = "\
allpairs — log-linear all-pairs losses: coordinator

USAGE: allpairs <COMMAND> [OPTIONS]

Global options:
  --backend B       execution backend: native | pjrt  [native]
  --artifacts DIR   artifacts directory (pjrt)        [artifacts]
  --out DIR         results directory                 [results]

COMMANDS
  timing            Figure 2: loss+gradient wall time vs data size
      --max-exp E       largest size 10^E            [7]
      --repeats R       repeats per point (median)   [3]
      --naive-cap N     largest n for O(n^2) methods [30000]
  sweep             Table 2 + Figure 3: full hyper-parameter sweep
      --config FILE     JSON config (defaults = paper protocol)
      --smoke           tiny grid + tiny data (minutes, not hours)
      --workers W       worker threads               [n_cpus]
      --patience P      early-stop after P stale epochs  [off]
      --sampling MODES  comma-separated batch sampling axis
                        (preserve | rebalance | rebalance:F)
      --resume          replay an interrupted sweep's journal and run
                        only the missing jobs (same config + seed =>
                        same final record set as an uninterrupted run)
      --retries N       attempts per job for transient errors  [3]
  train             one training run (streaming epoch loop)
      --dataset D --model M --batch B --lr LR
      --imratio R --epochs E --seed S --max-train N
      --loss L          loss spec: hinge | square | logistic | lhinge
                        | whinge (class-balanced) | aucm (pjrt only);
                        pairwise specs take "@margin=M"  [hinge]
      --patience P      early-stop after P stale epochs  [off]
      --sampling MODE   preserve | rebalance | rebalance:F  [preserve]
      --save-checkpoint FILE
                        save the best (or final) state as a binary
                        checkpoint for `serve`
      --shards DIR      stream features out-of-core from a shard store
                        built by `allpairs shard` (bit-identical to the
                        resident run on the same logical data)
  shard             build or validate an out-of-core shard store
      --dir DIR         store directory to build (required unless
                        --validate)
      --dataset D --imratio R --seed S --max-train N
                        same data pipeline as `train` (a store built
                        with seed S matches `train --seed S` exactly)
      --shards K        number of shard files       [4]
      --validate DIR    fully re-verify an existing store (manifest,
                        per-shard CRC, label counts) and exit
  serve             online scoring service over a trained checkpoint
      --checkpoint FILE checkpoint to serve (required; arch inferred)
      --host H          bind address                     [127.0.0.1]
      --port P          TCP port (0 = OS-assigned)       [0]
      --port-file FILE  write the bound port (atomic)    [off]
      --max-batch N     rows folded per forward pass     [1024]
      --threads T       engine worker threads (0 = all)  [0]
      --reload-ms MS    checkpoint watch period (0 = no hot reload)
                        [500]
      --max-line BYTES  per request line cap             [1048576]
      --stdin           score JSONL from stdin to stdout and exit
                        (single-row reference path)
  bench-serve       serving-path perf trajectory (native backend)
      --json FILE       output JSON path        [BENCH_serve.json]
      --dim D           features per request    [768]
      --hidden H        checkpoint hidden units (0 = linear) [32]
      --batches LIST    in-flight request counts [1,64,1024]
  bench             train-step/loss/AUC perf trajectory (native backend)
      --json FILE       output JSON path        [BENCH_train.json]
      --sizes LIST      comma-separated n       [10000,100000,1000000]
      --threads LIST    train-step worker counts [1,8]
      --dim D           features per row        [32]
      --sort-sizes LIST competitive sort-table n (0 to skip)
                        [100000,1000000,10000000]
      --shard-sizes LIST
                        out-of-core shard store n (0 to skip)
                        [100000,1000000]
      --huge            push the sort table to n = 1e8, streamed from a
                        temporary shard store (needs ~3 GB RAM + ~1 GB
                        disk; ignores the quick budget's size caps)
      (ALLPAIRS_BENCH_QUICK=1 shrinks the iteration budget, not sizes)
  report            re-aggregate a saved results file
      --results FILE    sweep_results.jsonl path
  lint              in-repo invariant linter (DESIGN.md \u{a7}12)
      --root DIR        tree to lint                 [.]
      --list-rules      print the rule catalog and exit
      (exit is nonzero when any finding is reported; suppress a site
       with `// lint:allow(rule): reason` — the reason is mandatory)
  artifacts-check   compile every artifact, smoke-run the inits (pjrt)
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> allpairs::Result<()> {
    let args = Args::from_env()?;
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let out = PathBuf::from(args.get_str("out", "results"));
    match args.command.as_deref() {
        Some("timing") => cmd_timing(&args, &out),
        Some("sweep") => cmd_sweep(&args, &artifacts, &out),
        Some("train") => cmd_train(&args, &artifacts),
        Some("shard") => cmd_shard(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench-serve") => cmd_bench_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("report") => cmd_report(&args, &out),
        Some("lint") => cmd_lint(&args),
        Some("artifacts-check") => cmd_artifacts_check(&artifacts),
        Some(other) => anyhow::bail!("unknown command {other:?}\n\n{USAGE}"),
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

/// Resolve `--backend` (native default; pjrt uses `--artifacts`).
fn backend_from_args(args: &Args, artifacts: &Path) -> allpairs::Result<Option<BackendSpec>> {
    match args.get_opt("backend").as_deref() {
        None => Ok(None),
        Some("native") => Ok(Some(BackendSpec::native())),
        Some("pjrt") => Ok(Some(BackendSpec::pjrt(artifacts.to_path_buf()))),
        Some(other) => anyhow::bail!("unknown backend {other:?} (native | pjrt)"),
    }
}

fn cmd_timing(args: &Args, out: &Path) -> allpairs::Result<()> {
    args.expect_known(&["artifacts", "out", "backend", "max-exp", "repeats", "naive-cap"])?;
    let max_exp: u32 = args.get("max-exp", 7)?;
    let config = timing::TimingConfig {
        sizes: (1..=max_exp).map(|e| 10usize.pow(e)).collect(),
        repeats: args.get("repeats", 3)?,
        naive_cap: args.get("naive-cap", 30_000)?,
        margin: 1.0,
    };
    eprintln!("running Figure 2 timing: sizes up to 10^{max_exp} ...");
    let points = timing::run(&config);
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.algorithm.to_string(),
                p.complexity.to_string(),
                p.n.to_string(),
                format!("{:.6e}", p.seconds),
            ]
        })
        .collect();
    std::fs::create_dir_all(out)?;
    write_csv(
        out.join("fig2.csv"),
        &["algorithm", "complexity", "n", "seconds"],
        &rows,
    )?;
    println!("{}", ascii_loglog(&timing::to_series(&points), 72, 20));
    println!("fitted log-log slopes (tail):");
    for (name, slope) in timing::slopes(&points, 3) {
        println!("  {name:28} {slope:5.2}");
    }
    println!("largest n within a 1-second budget:");
    for (name, n) in timing::max_n_within(&points, 1.0) {
        println!("  {name:28} {n}");
    }
    println!("wrote {}", out.join("fig2.csv").display());
    Ok(())
}

fn cmd_sweep(args: &Args, artifacts: &Path, out: &Path) -> allpairs::Result<()> {
    args.expect_known(&[
        "artifacts", "out", "backend", "config", "smoke", "workers", "epochs", "patience",
        "sampling", "resume", "retries",
    ])?;
    let mut cfg = match args.get_opt("config") {
        Some(path) => SweepConfig::load(path)?,
        None => SweepConfig::default(),
    };
    if args.flag("smoke") {
        cfg.datasets = vec!["synth-pets".into()];
        cfg.imratios = vec![0.1];
        cfg.losses = vec![LossSpec::hinge(), LossSpec::logistic()];
        cfg.batch_sizes = vec![50, 100];
        cfg.seeds = vec![0, 1];
        cfg.epochs = 3;
        cfg.max_train = Some(600);
    }
    if let Some(backend) = backend_from_args(args, artifacts)? {
        cfg.backend = backend;
    }
    if cfg.adapt_losses_to_backend(args.get_opt("config").is_none()) {
        eprintln!(
            "note: aucm requires the pjrt backend; sweeping losses {:?}",
            cfg.losses.iter().map(|l| l.to_string()).collect::<Vec<_>>()
        );
    }
    cfg.workers = args.get("workers", cfg.workers)?;
    cfg.epochs = args.get("epochs", cfg.epochs)?;
    if let Some(p) = args.get_opt("patience") {
        cfg.patience = Some(p.parse()?);
    }
    if let Some(modes) = args.get_opt("sampling") {
        cfg.sampling_modes = modes.split(',').map(|m| m.trim().to_string()).collect();
        for name in &cfg.sampling_modes {
            SamplingMode::parse(name)?;
        }
    }
    eprintln!(
        "sweep: {} runs on {} workers ({} backend) ...",
        cfg.n_runs(),
        cfg.workers,
        cfg.backend.kind()
    );
    let t0 = std::time::Instant::now();
    let progress: allpairs::sweep::scheduler::ProgressFn = Box::new(|done, total, msg| {
        eprintln!("[{done}/{total}] {msg}");
    });
    let mut run_opts = cv::RunOptions {
        resume: args.flag("resume"),
        ..cv::RunOptions::default()
    };
    run_opts.retry.max_attempts = args.get("retries", run_opts.retry.max_attempts)?;
    let output = cv::run_with_options(&cfg, out, Some(progress), &run_opts)?;
    let replayed = if output.replayed > 0 {
        format!(" ({} replayed from journal)", output.replayed)
    } else {
        String::new()
    };
    println!(
        "sweep finished: {} results{replayed} in {:.1}s",
        output.results.len(),
        t0.elapsed().as_secs_f64()
    );
    if !output.failures.is_empty() {
        eprintln!("{} job(s) FAILED:", output.failures.len());
        for f in output.failures.iter().take(3) {
            eprintln!(
                "  {} ({} attempt{}): {}",
                f.job_id,
                f.attempts,
                if f.attempts == 1 { "" } else { "s" },
                f.error
            );
        }
        if output.failures.len() > 3 {
            eprintln!("  ... and {} more", output.failures.len() - 3);
        }
        eprintln!("re-run with --resume to retry only the missing jobs");
    }
    println!("\n== Table 2 (median selected hyper-parameters)\n");
    print!(
        "{}",
        std::fs::read_to_string(out.join("table2.md")).unwrap_or_default()
    );
    println!("\n== Figure 3 (test AUC mean ± sd)\n");
    print!(
        "{}",
        std::fs::read_to_string(out.join("fig3.md")).unwrap_or_default()
    );
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &Path) -> allpairs::Result<()> {
    args.expect_known(&[
        "artifacts", "out", "backend", "dataset", "loss", "model", "batch", "lr", "imratio",
        "epochs", "seed", "max-train", "patience", "sampling", "save-checkpoint", "shards",
    ])?;
    let dataset = args.get_str("dataset", "synth-cifar");
    // Parsed (and validated) before any data is generated: a typo'd
    // --loss fails right here, listing the valid specs.
    let loss: LossSpec = args.get_str("loss", "hinge").parse()?;
    let model = args.get_str("model", "resnet");
    let batch: usize = args.get("batch", 100)?;
    let lr: f64 = args.get("lr", 0.01)?;
    let imratio: f64 = args.get("imratio", 0.1)?;
    let epochs: usize = args.get("epochs", 10)?;
    let seed: u32 = args.get("seed", 0)?;
    let max_train: Option<usize> = args.get_opt("max-train").map(|v| v.parse()).transpose()?;
    let patience: Option<usize> = args.get_opt("patience").map(|v| v.parse()).transpose()?;
    let sampling = SamplingMode::parse(&args.get_str("sampling", "preserve"))?;

    let cfg = SweepConfig {
        datasets: vec![dataset.clone()],
        max_train,
        ..Default::default()
    };
    let data = cv::build_datasets(&cfg)?;
    let pool = &data[&dataset];
    // Forked RNG streams, drawn unconditionally in a fixed order so the
    // resident and --shards paths see identical split/epoch randomness
    // (`allpairs shard` consumes the same fork(1) when imbalancing).
    let mut data_rng = Rng::new(seed as u64 + 1);
    let mut imbalance_rng = data_rng.fork(1);
    let mut split_rng = data_rng.fork(2);
    let mut epoch_rng = data_rng.fork(3);
    let resident;
    let sharded;
    let source: &dyn DatasetSource = match args.get_opt("shards") {
        Some(dir) => {
            let store = shard::ShardedDataset::open(Path::new(&dir))?;
            eprintln!(
                "shards: streaming {} rows from {} shard file(s) in {dir}",
                store.len(),
                store.n_shards()
            );
            sharded = store;
            &sharded
        }
        None => {
            resident = pool.train_pool.imbalance(imratio, &mut imbalance_rng);
            &resident
        }
    };
    let split = Split::stratified(source.labels(), 0.2, &mut split_rng);
    let n_pos = source.labels().iter().filter(|&&v| v != 0.0).count();
    eprintln!(
        "train: {} examples ({:.4} positive), subtrain {} / validation {}",
        source.len(),
        n_pos as f64 / source.len().max(1) as f64,
        split.subtrain.len(),
        split.validation.len()
    );
    let spec = backend_from_args(args, artifacts)?.unwrap_or_default();
    let backend = spec.connect()?;
    let mut trainer = Trainer::new(backend.as_ref(), &model, &loss, batch)?;
    let fit_cfg = FitConfig {
        lr: lr as f32,
        epochs,
        patience,
        sampling,
        seed,
    };
    let outcome = trainer.fit_stream(
        source,
        &split.subtrain,
        &split.validation,
        &fit_cfg,
        &mut epoch_rng,
    )?;
    for r in &outcome.history.records {
        println!(
            "epoch {:3}  loss {:10.6}  val_auc {}  ({:.2}s)",
            r.epoch,
            r.train_loss,
            r.val_auc
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "  n/a ".into()),
            r.seconds
        );
    }
    if outcome.stopped_early {
        println!("early stop: no improvement in {} epochs", patience.unwrap_or(0));
    }
    if outcome.diverged {
        println!("diverged (non-finite training loss)");
    }
    let test_indices: Vec<u32> = (0..pool.test.len() as u32).collect();
    if let Some(best) = &outcome.best {
        println!("best val AUC {:.4} at epoch {}", best.val_auc, best.epoch);
        trainer.load_state(&best.state)?;
        if let Some(test_auc) = trainer.eval_auc(&pool.test, &test_indices)? {
            println!("test AUC at best checkpoint: {test_auc:.4}");
        }
    } else if let Some(test_auc) = trainer.eval_auc(&pool.test, &test_indices)? {
        println!("final test AUC: {test_auc:.4}");
    }
    if let Some(path) = args.get_opt("save-checkpoint") {
        // The best state is already restored into the trainer above (or
        // the final state stands, if no epoch produced a val AUC), so
        // the snapshot is exactly what the run reported on.
        checkpoint::save(&path, &trainer.state_to_host()?)?;
        println!("saved checkpoint {path}");
    }
    Ok(())
}

fn cmd_shard(args: &Args) -> allpairs::Result<()> {
    args.expect_known(&[
        "artifacts", "out", "backend", "dir", "dataset", "imratio", "seed", "max-train",
        "shards", "validate",
    ])?;
    if let Some(dir) = args.get_opt("validate") {
        let check = shard::validate_store(Path::new(&dir))?;
        println!(
            "store OK: {} rows in {} shard(s), {} positive / {} negative",
            check.n_rows, check.n_shards, check.n_pos, check.n_neg
        );
        return Ok(());
    }
    let dir = args
        .get_opt("dir")
        .ok_or_else(|| anyhow::anyhow!("--dir DIR required (or --validate DIR)"))?;
    let dataset = args.get_str("dataset", "synth-cifar");
    let imratio: f64 = args.get("imratio", 0.1)?;
    let seed: u32 = args.get("seed", 0)?;
    let max_train: Option<usize> = args.get_opt("max-train").map(|v| v.parse()).transpose()?;
    let n_shards: usize = args.get("shards", 4)?;

    let cfg = SweepConfig {
        datasets: vec![dataset.clone()],
        max_train,
        ..Default::default()
    };
    let data = cv::build_datasets(&cfg)?;
    let pool = &data[&dataset];
    // Same forked stream `train` uses for its resident imbalance, so
    // `shard --seed S` + `train --shards --seed S` reproduce
    // `train --seed S` bit-for-bit.
    let mut data_rng = Rng::new(seed as u64 + 1);
    let train = pool.train_pool.imbalance(imratio, &mut data_rng.fork(1));
    let manifest = shard::write_store(Path::new(&dir), &train, n_shards)?;
    println!(
        "wrote {} rows ({} positive / {} negative) as {} shard(s) in {dir}",
        manifest.n_rows,
        manifest.n_pos(),
        manifest.n_neg(),
        manifest.shards.len()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> allpairs::Result<()> {
    args.expect_known(&[
        "artifacts", "out", "backend", "checkpoint", "host", "port", "port-file", "max-batch",
        "threads", "reload-ms", "max-line", "stdin",
    ])?;
    let ckpt_path = args
        .get_opt("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint FILE required"))?;
    let max_line: usize = args.get("max-line", serve::DEFAULT_MAX_LINE)?;
    anyhow::ensure!(max_line > 0, "--max-line must be positive");
    let scorer = serve::Scorer::spawn(serve::ScorerOptions {
        max_batch: args.get("max-batch", 1024)?,
        threads: args.get("threads", 0)?,
        ..serve::ScorerOptions::new(&ckpt_path)
    })?;
    eprintln!(
        "serve: loaded {ckpt_path} ({} model, dim {}, hidden {})",
        scorer.info.model, scorer.info.dim, scorer.info.hidden
    );

    if args.flag("stdin") {
        let stdin = std::io::stdin().lock();
        let mut stdout = std::io::stdout().lock();
        let n = serve::run_stdin(&scorer.handle, stdin, &mut stdout, max_line)?;
        eprintln!("serve: wrote {n} responses");
        return Ok(());
    }

    let reload_ms: u64 = args.get("reload-ms", 500)?;
    let _watch = if reload_ms > 0 {
        Some(serve::spawn_reload_watcher(
            &ckpt_path,
            std::time::Duration::from_millis(reload_ms),
            scorer.handle.clone(),
        )?)
    } else {
        None
    };
    let host = args.get_str("host", "127.0.0.1");
    let port: u16 = args.get("port", 0)?;
    let server = serve::Server::start(
        &format!("{host}:{port}"),
        scorer.handle.clone(),
        serve::ServerOptions { max_line },
    )?;
    let addr = server.addr();
    if let Some(path) = args.get_opt("port-file") {
        // Atomic publish: a launcher polling the file never reads a
        // torn port number.
        allpairs::util::fsio::write_atomic(&path, format!("{}\n", addr.port()).as_bytes())?;
    }
    println!("serving on {addr} (checkpoint {ckpt_path})");
    // Serve until the process is killed; the watcher guard and scorer
    // stay alive in scope.
    loop {
        std::thread::park();
    }
}

fn cmd_bench_serve(args: &Args) -> allpairs::Result<()> {
    args.expect_known(&["artifacts", "out", "backend", "json", "dim", "hidden", "batches"])?;
    let batches = match args.get_opt("batches") {
        None => vec![1, 64, 1024],
        Some(list) => list
            .split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--batches {v:?}: {e}"))
            })
            .collect::<allpairs::Result<Vec<usize>>>()?,
    };
    let cfg = perf::ServePerfConfig {
        dim: args.get("dim", 768)?,
        hidden: args.get("hidden", 32)?,
        batches,
    };
    let quick = allpairs::util::bench::Bench::quick_from_env();
    eprintln!(
        "bench-serve: dim {}, hidden {}, batches {:?}{} ...",
        cfg.dim,
        cfg.hidden,
        cfg.batches,
        if quick { " (quick mode)" } else { "" }
    );
    let records = perf::run_serve(&cfg)?;
    let rows = perf::serve_throughput(&records);
    if !rows.is_empty() {
        println!("\nscoring round trip (median):");
        println!("{:>8} {:>14} {:>12}", "batch", "median_s", "rows/s");
        for (b, median, rps) in rows {
            println!("{b:>8} {median:>14.6} {rps:>12.0}");
        }
    }
    let json_path = args.get_str("json", "BENCH_serve.json");
    perf::write_json(&records, quick, &json_path)?;
    println!("wrote {json_path} ({} records)", records.len());
    Ok(())
}

fn cmd_bench(args: &Args) -> allpairs::Result<()> {
    args.expect_known(&[
        "artifacts",
        "out",
        "backend",
        "json",
        "sizes",
        "threads",
        "dim",
        "sort-sizes",
        "shard-sizes",
        "huge",
    ])?;
    let parse_list = |name: &str, default: &[usize]| -> allpairs::Result<Vec<usize>> {
        match args.get_opt(name) {
            None => Ok(default.to_vec()),
            Some(list) => list
                .split(',')
                .map(|v| {
                    v.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}"))
                })
                .collect(),
        }
    };
    // `--sort-sizes 0` skips the sort suite entirely (zeros are dropped);
    // `--shard-sizes 0` likewise skips the out-of-core suite.
    let mut sort_sizes = parse_list("sort-sizes", &[100_000, 1_000_000, 10_000_000])?;
    sort_sizes.retain(|&n| n > 0);
    let mut shard_sizes = parse_list("shard-sizes", &[100_000, 1_000_000])?;
    shard_sizes.retain(|&n| n > 0);
    let cfg = perf::PerfConfig {
        sizes: parse_list("sizes", &[10_000, 100_000, 1_000_000])?,
        threads: parse_list("threads", &[1, 8])?,
        dim: args.get("dim", 32)?,
        sort_sizes,
        shard_sizes,
        huge_sort: args.flag("huge"),
    };
    anyhow::ensure!(
        !cfg.sizes.is_empty() && !cfg.threads.is_empty() && cfg.dim > 0,
        "--sizes, --threads and --dim must be non-empty / positive"
    );
    // 0 means "auto" elsewhere, but the trajectory records *requested*
    // worker counts (EXPERIMENTS.md convention 1), so it must be explicit.
    anyhow::ensure!(
        cfg.threads.iter().all(|&t| t >= 1),
        "--threads entries must be >= 1 (the recorded count is the requested one)"
    );
    let quick = allpairs::util::bench::Bench::quick_from_env();
    eprintln!(
        "bench: train-step/loss/AUC at n {:?}, threads {:?}, dim {}, sort n {:?}, shard n {:?}{}{} ...",
        cfg.sizes,
        cfg.threads,
        cfg.dim,
        cfg.sort_sizes,
        cfg.shard_sizes,
        if cfg.huge_sort { ", huge sort n=1e8" } else { "" },
        if quick { " (quick mode)" } else { "" }
    );
    let records = perf::run(&cfg)?;
    let rows = perf::speedups(&records);
    if !rows.is_empty() {
        println!("\ntrain-step speedup (serial vs best parallel, median):");
        println!(
            "{:>10} {:>14} {:>8} {:>14} {:>9}",
            "n", "serial_s", "threads", "parallel_s", "speedup"
        );
        for (n, serial, threads, parallel, speedup) in rows {
            println!("{n:>10} {serial:>14.6} {threads:>8} {parallel:>14.6} {speedup:>8.2}x");
        }
    }
    let sort_rows = perf::sort_table(&records);
    if !sort_rows.is_empty() {
        let cell = |v: Option<f64>| match v {
            Some(s) => format!("{s:>14.6}"),
            None => format!("{:>14}", "-"),
        };
        println!("\nhinge-key sort (median seconds; nosort = O(n) lhinge bound floor):");
        println!(
            "{:>10} {:>14} {:>14} {:>14} {:>14} {:>9}",
            "n", "comparison_s", "radix_s", "adaptive_s", "nosort_s", "speedup"
        );
        for row in sort_rows {
            let speedup = match row.best_speedup() {
                Some(s) => format!("{s:>8.2}x"),
                None => format!("{:>9}", "-"),
            };
            println!(
                "{:>10} {} {} {} {} {speedup}",
                row.n,
                cell(row.comparison_s),
                cell(row.radix_s),
                cell(row.adaptive_s),
                cell(row.nosort_s)
            );
        }
    }
    let json_path = args.get_str("json", "BENCH_train.json");
    perf::write_json(&records, quick, &json_path)?;
    println!("wrote {json_path} ({} records)", records.len());
    Ok(())
}

fn cmd_report(args: &Args, out: &Path) -> allpairs::Result<()> {
    args.expect_known(&["artifacts", "out", "backend", "results"])?;
    let results_path = args
        .get_opt("results")
        .ok_or_else(|| anyhow::anyhow!("--results FILE required"))?;
    // Lenient load (read-only): a journal truncated by a crash is still
    // fully analyzable from its complete lines.
    let replay = results::load_jsonl_lenient(&results_path)?;
    if replay.torn_bytes > 0 {
        eprintln!(
            "note: journal has a torn tail ({} bytes ignored); `sweep --resume` repairs it",
            replay.torn_bytes
        );
    }
    let run_results = replay.results;
    eprintln!("loaded {} results", run_results.len());
    let output = cv::summarize(run_results, out)?;
    println!(
        "{} cells aggregated; reports in {}",
        output.cells.len(),
        out.display()
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> allpairs::Result<()> {
    args.expect_known(&["artifacts", "out", "backend", "root", "list-rules"])?;
    if args.flag("list-rules") {
        for rule in allpairs::analysis::all_rules() {
            println!("{:28} {}", rule.name, rule.summary);
        }
        return Ok(());
    }
    let root = PathBuf::from(args.get_str("root", "."));
    let findings = allpairs::analysis::run_lint(&root)?;
    for finding in &findings {
        println!("{finding}");
    }
    if !findings.is_empty() {
        anyhow::bail!("lint: {} finding(s)", findings.len());
    }
    eprintln!("lint: clean");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts_check(artifacts: &Path) -> allpairs::Result<()> {
    let runtime = allpairs::runtime::Runtime::new(artifacts)?;
    let names: Vec<String> = runtime
        .manifest()
        .artifacts
        .iter()
        .map(|a| a.name.clone())
        .collect();
    println!("manifest: {} artifacts", names.len());
    for name in &names {
        let t0 = std::time::Instant::now();
        runtime.executable(name)?;
        println!("  compiled {name} ({:.2}s)", t0.elapsed().as_secs_f64());
    }
    // smoke-run every init
    for a in runtime.manifest().artifacts.clone() {
        if a.kind == allpairs::runtime::ArtifactKind::Init {
            let outs = runtime.execute(&a.name, &[xla::Literal::scalar(0u32)])?;
            println!("  init {} -> {} state tensors OK", a.name, outs.len());
        }
    }
    println!("all artifacts OK");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts_check(_artifacts: &Path) -> allpairs::Result<()> {
    anyhow::bail!(
        "artifacts-check requires the PJRT runtime; \
         rebuild with `cargo build --features pjrt`"
    )
}
