//! Figure 2 reproduction: computation time of loss + gradient vs n.
//!
//! Protocol (paper section 4.1): for each data size n, draw n standard
//! normal predictions with balanced labels, then time one loss+gradient
//! evaluation per algorithm.  The naive methods are skipped beyond
//! [`TimingConfig::naive_cap`] (they are quadratic; the paper's laptop
//! stopped around 10^4 in reasonable time too).
//!
//! Output: one row per (algorithm, n) with median-of-repeats seconds,
//! plus the fitted log-log slope per algorithm — the paper's
//! "asymptotic slope" claim made quantitative.

use std::time::Instant;

use crate::data::Rng;
use crate::losses::figure2_losses;
use crate::report::figures::{loglog_slope, Series};

/// Configuration of the timing experiment.
#[derive(Debug, Clone)]
pub struct TimingConfig {
    /// Data sizes to measure (paper: 10^1 .. 10^7).
    pub sizes: Vec<usize>,
    /// Timing repeats per point (median reported).
    pub repeats: usize,
    /// Largest n at which the O(n²) naive methods run.
    pub naive_cap: usize,
    /// Margin for the pairwise losses.
    pub margin: f32,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self {
            sizes: (1..=7).map(|e| 10usize.pow(e)).collect(),
            repeats: 3,
            naive_cap: 30_000,
            margin: 1.0,
        }
    }
}

/// One measured point.
#[derive(Debug, Clone)]
pub struct TimingPoint {
    pub algorithm: &'static str,
    pub complexity: &'static str,
    pub n: usize,
    pub seconds: f64,
}

/// Run the experiment; returns all measured points.
pub fn run(config: &TimingConfig) -> Vec<TimingPoint> {
    let losses = figure2_losses(config.margin);
    let mut rng = Rng::new(20230223);
    let mut points = Vec::new();
    for &n in &config.sizes {
        // Balanced labels, standard normal predictions (paper protocol).
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let is_pos: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        for loss in &losses {
            if loss.complexity() == "O(n^2)" && n > config.naive_cap {
                continue;
            }
            let mut times = Vec::with_capacity(config.repeats);
            for _ in 0..config.repeats {
                let t0 = Instant::now();
                let (value, grad) = loss.loss_and_grad(&scores, &is_pos);
                let dt = t0.elapsed().as_secs_f64();
                std::hint::black_box((value, grad.len()));
                times.push(dt);
            }
            times.sort_by(|a, b| a.total_cmp(b));
            points.push(TimingPoint {
                algorithm: loss.name(),
                complexity: loss.complexity(),
                n,
                seconds: times[times.len() / 2],
            });
        }
    }
    points
}

/// Group points into plot series per algorithm.
pub fn to_series(points: &[TimingPoint]) -> Vec<Series> {
    let mut names: Vec<&'static str> = points.iter().map(|p| p.algorithm).collect();
    names.dedup();
    names.sort_unstable();
    names.dedup();
    names
        .into_iter()
        .map(|name| Series {
            name: name.to_string(),
            points: points
                .iter()
                .filter(|p| p.algorithm == name)
                .map(|p| (p.n as f64, p.seconds))
                .collect(),
        })
        .collect()
}

/// Fitted log-log slope per algorithm over the largest sizes (where the
/// asymptotic regime dominates): the Figure 2 claim in one number each.
pub fn slopes(points: &[TimingPoint], tail_points: usize) -> Vec<(String, f64)> {
    to_series(points)
        .into_iter()
        .map(|s| {
            let mut pts = s.points.clone();
            pts.sort_by(|a, b| a.0.total_cmp(&b.0));
            let tail: Vec<(f64, f64)> = pts
                .iter()
                .rev()
                .take(tail_points)
                .copied()
                .collect();
            (s.name, loglog_slope(&tail))
        })
        .collect()
}

/// Largest n each algorithm completes within `budget_seconds` (the
/// paper's "in 1 second" comparison: naive ~10^3 vs functional ~10^6).
pub fn max_n_within(points: &[TimingPoint], budget_seconds: f64) -> Vec<(String, usize)> {
    to_series(points)
        .into_iter()
        .map(|s| {
            let max_n = s
                .points
                .iter()
                .filter(|&&(_, secs)| secs <= budget_seconds)
                .map(|&(n, _)| n as usize)
                .max()
                .unwrap_or(0);
            (s.name, max_n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Vec<TimingPoint> {
        run(&TimingConfig {
            sizes: vec![10, 100, 1000],
            repeats: 1,
            naive_cap: 1000,
            margin: 1.0,
        })
    }

    #[test]
    fn all_algorithms_measured() {
        let pts = small();
        let names: std::collections::BTreeSet<_> = pts.iter().map(|p| p.algorithm).collect();
        assert_eq!(names.len(), 5);
        assert!(pts.iter().all(|p| p.seconds >= 0.0));
    }

    #[test]
    fn naive_capped() {
        let pts = run(&TimingConfig {
            sizes: vec![10, 100],
            repeats: 1,
            naive_cap: 50,
            margin: 1.0,
        });
        assert!(!pts
            .iter()
            .any(|p| p.complexity == "O(n^2)" && p.n > 50));
        // functional still measured at 100
        assert!(pts
            .iter()
            .any(|p| p.algorithm == "functional_squared_hinge" && p.n == 100));
    }

    #[test]
    fn series_and_slopes_shape() {
        let pts = small();
        let series = to_series(&pts);
        assert_eq!(series.len(), 5);
        let sl = slopes(&pts, 3);
        assert_eq!(sl.len(), 5);
    }

    #[test]
    fn max_n_within_budget() {
        let pts = small();
        for (_, n) in max_n_within(&pts, 10.0) {
            assert!(n >= 1000); // everything finishes tiny sizes in 10 s
        }
    }
}
