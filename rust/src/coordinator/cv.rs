//! Table 2 + Figure 3 orchestration: generate data, expand the grid,
//! run the sweep, select, aggregate, and emit reports.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::config::SweepConfig;
use crate::data::synth;
use crate::report::figures::write_csv;
use crate::report::table::{figure3_table, table2};
use crate::sweep::runner::JobData;
use crate::sweep::scheduler::{run_sweep_with, ProgressFn};
use crate::sweep::select::{aggregate, select_per_seed, Cell};
use crate::sweep::{grid, results, RunResult};

/// Generate (and cache in memory) the shared dataset pools for a config.
pub fn build_datasets(config: &SweepConfig) -> crate::Result<HashMap<String, JobData>> {
    let mut map = HashMap::new();
    for name in &config.datasets {
        let mut spec = synth::spec_by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
        if let Some(cap) = config.max_train {
            spec.n_train = spec.n_train.min(cap);
            spec.n_test = spec.n_test.min(cap);
        }
        let (train_pool, test) = synth::generate(&spec, config.data_seed);
        map.insert(
            name.clone(),
            JobData {
                train_pool: Arc::new(train_pool),
                test: Arc::new(test),
            },
        );
    }
    Ok(map)
}

/// Artifacts of a completed sweep.
pub struct SweepOutput {
    pub results: Vec<RunResult>,
    pub cells: Vec<Cell>,
}

/// Run the full cross-validation experiment on `config.backend` and
/// write all report files into `out_dir`: `sweep_results.jsonl`,
/// `table2.md`, `fig3.md`, `fig3.csv`.
pub fn run(
    config: &SweepConfig,
    out_dir: &Path,
    progress: Option<ProgressFn>,
) -> crate::Result<SweepOutput> {
    std::fs::create_dir_all(out_dir)?;
    let datasets = build_datasets(config)?;
    let jobs = grid::expand(config);
    // Incremental persistence: each completed run lands in the JSONL
    // immediately, so a truncated sweep remains analyzable via `report`.
    let mut writer = results::JsonlWriter::create(out_dir.join("sweep_results.jsonl"))?;
    let on_result: crate::sweep::scheduler::OnResultFn = Box::new(move |r| {
        let _ = writer.append(r);
    });
    let run_results = run_sweep_with(
        &config.backend,
        jobs,
        datasets,
        config.workers,
        progress,
        Some(on_result),
    )?;
    let output = summarize(run_results, out_dir)?;
    Ok(output)
}

/// Selection + aggregation + report emission (separated so `report`ing
/// can re-run from a saved JSONL without re-training).
pub fn summarize(run_results: Vec<RunResult>, out_dir: &Path) -> crate::Result<SweepOutput> {
    let selections = select_per_seed(&run_results);
    let cells = aggregate(&selections);
    std::fs::write(out_dir.join("table2.md"), table2(&cells))?;
    std::fs::write(out_dir.join("fig3.md"), figure3_table(&cells))?;
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.dataset.clone(),
                format!("{}", c.imratio),
                c.loss.clone(),
                format!("{:.6}", c.test_auc.mean()),
                format!("{:.6}", c.test_auc.std()),
                format!("{}", c.n_seeds),
            ]
        })
        .collect();
    write_csv(
        out_dir.join("fig3.csv"),
        &["dataset", "imratio", "loss", "test_auc_mean", "test_auc_sd", "seeds"],
        &rows,
    )?;
    Ok(SweepOutput {
        results: run_results,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_datasets_respects_cap() {
        let config = SweepConfig {
            datasets: vec!["synth-pets".into()],
            max_train: Some(64),
            ..Default::default()
        };
        let ds = build_datasets(&config).unwrap();
        assert_eq!(ds["synth-pets"].train_pool.len(), 64);
        assert_eq!(ds["synth-pets"].test.len(), 64);
    }

    #[test]
    fn unknown_dataset_is_error() {
        let config = SweepConfig {
            datasets: vec!["nope".into()],
            ..Default::default()
        };
        assert!(build_datasets(&config).is_err());
    }
}
