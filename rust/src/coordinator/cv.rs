//! Table 2 + Figure 3 orchestration: generate data, expand the grid,
//! run the sweep, select, aggregate, and emit reports.
//!
//! Crash-resume (DESIGN.md §10): the sweep journal
//! (`sweep_results.jsonl`) is append-only and flushed per record.  A
//! fresh sweep *rotates* a leftover journal aside (never truncates it);
//! a resumed sweep replays it with the lenient loader, repairs a torn
//! tail, skips every job whose [`Job::id`] already has a record, and
//! appends the rest.  Because runs are seed-reproducible, an
//! interrupted-then-resumed sweep yields the same record set as an
//! uninterrupted one.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::Arc;

use crate::config::SweepConfig;
use crate::data::synth;
use crate::report::figures::write_csv;
use crate::report::table::{figure3_table, table2};
use crate::sweep::grid::{self, Job};
use crate::sweep::runner::JobData;
use crate::sweep::scheduler::{run_sweep_opts, JobFailure, ProgressFn, RetryPolicy, SweepOptions};
use crate::sweep::select::{aggregate, select_per_seed, Cell};
use crate::sweep::{results, RunResult};
use crate::util::fsio;

/// Generate (and cache in memory) the shared dataset pools for a config.
pub fn build_datasets(config: &SweepConfig) -> crate::Result<BTreeMap<String, JobData>> {
    let mut map = BTreeMap::new();
    for name in &config.datasets {
        let mut spec = synth::spec_by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
        if let Some(cap) = config.max_train {
            spec.n_train = spec.n_train.min(cap);
            spec.n_test = spec.n_test.min(cap);
        }
        let (train_pool, test) = synth::generate(&spec, config.data_seed);
        map.insert(
            name.clone(),
            JobData {
                train_pool: Arc::new(train_pool),
                test: Arc::new(test),
            },
        );
    }
    Ok(map)
}

/// Artifacts of a completed sweep.
pub struct SweepOutput {
    pub results: Vec<RunResult>,
    pub cells: Vec<Cell>,
    /// Jobs that produced no result (already surfaced FAILED via
    /// progress; callers print a summary so they are never silent).
    pub failures: Vec<JobFailure>,
    /// Jobs satisfied from the journal instead of re-run (`--resume`).
    pub replayed: usize,
}

/// Orchestration knobs beyond the config.
#[derive(Debug, Clone, Default)]
pub struct RunOptions {
    /// Replay an existing journal and complete only the missing jobs.
    pub resume: bool,
    /// Retry policy for transient job failures.
    pub retry: RetryPolicy,
}

/// Run the full cross-validation experiment on `config.backend` and
/// write all report files into `out_dir`: `sweep_results.jsonl`,
/// `table2.md`, `fig3.md`, `fig3.csv`.
pub fn run(
    config: &SweepConfig,
    out_dir: &Path,
    progress: Option<ProgressFn>,
) -> crate::Result<SweepOutput> {
    run_with_options(config, out_dir, progress, &RunOptions::default())
}

/// [`run`] with resume/retry control (the `allpairs sweep --resume`
/// entry point).
pub fn run_with_options(
    config: &SweepConfig,
    out_dir: &Path,
    progress: Option<ProgressFn>,
    options: &RunOptions,
) -> crate::Result<SweepOutput> {
    std::fs::create_dir_all(out_dir)?;
    let journal = out_dir.join("sweep_results.jsonl");
    let mut jobs = grid::expand(config);

    // Replay or rotate an existing journal — never truncate one.
    let mut prior: Vec<RunResult> = Vec::new();
    if options.resume {
        if journal.exists() {
            let replay = results::repair_journal(&journal)?;
            if replay.torn_bytes > 0 || replay.missing_newline {
                eprintln!(
                    "resume: repaired torn journal tail ({} bytes dropped)",
                    replay.torn_bytes
                );
            }
            prior = replay.results;
            let grid_ids: BTreeSet<String> = jobs.iter().map(|j| j.id()).collect();
            let known = prior.len();
            prior.retain(|r| grid_ids.contains(&r.job.id()));
            if prior.len() < known {
                eprintln!(
                    "resume: ignoring {} journal record(s) outside the configured grid",
                    known - prior.len()
                );
            }
            let done: BTreeSet<String> = prior.iter().map(|r| r.job.id()).collect();
            jobs.retain(|j: &Job| !done.contains(&j.id()));
        }
    } else if journal.exists() && std::fs::metadata(&journal)?.len() > 0 {
        let rotated = rotate_path(&journal)?;
        eprintln!(
            "note: existing journal rotated to {} (use --resume to continue it)",
            rotated.display()
        );
    }
    let replayed = prior.len();

    let datasets = build_datasets(config)?;
    // Incremental persistence: each completed run lands in the JSONL
    // immediately (append mode, flushed per record), so a crashed sweep
    // remains analyzable via `report` and resumable via `--resume`.
    let mut writer = results::JsonlWriter::append_to(&journal)?;
    let on_result: crate::sweep::scheduler::OnResultFn = Box::new(move |r| {
        let _ = writer.append(r);
    });
    let outcome = run_sweep_opts(
        &config.backend,
        jobs,
        datasets,
        SweepOptions {
            workers: config.workers,
            retry: options.retry,
            progress,
            on_result: Some(on_result),
        },
    )?;
    let mut all = prior;
    all.extend(outcome.results);
    let output = summarize(all, out_dir)?;
    Ok(SweepOutput {
        failures: outcome.failures,
        replayed,
        ..output
    })
}

/// First free `<name>.N.bak` beside `path`, with the rename done.
fn rotate_path(path: &Path) -> crate::Result<std::path::PathBuf> {
    for n in 1..10_000u32 {
        let candidate = path.with_extension(format!("jsonl.{n}.bak"));
        if !candidate.exists() {
            std::fs::rename(path, &candidate)?;
            return Ok(candidate);
        }
    }
    anyhow::bail!("no free rotation slot for {}", path.display())
}

/// Selection + aggregation + report emission (separated so `report`ing
/// can re-run from a saved JSONL without re-training).  Report files
/// are written atomically: a crash mid-summarize leaves the previous
/// complete reports, never torn ones.
pub fn summarize(run_results: Vec<RunResult>, out_dir: &Path) -> crate::Result<SweepOutput> {
    let selections = select_per_seed(&run_results);
    let cells = aggregate(&selections);
    fsio::write_atomic(out_dir.join("table2.md"), table2(&cells).as_bytes())?;
    fsio::write_atomic(out_dir.join("fig3.md"), figure3_table(&cells).as_bytes())?;
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.dataset.clone(),
                format!("{}", c.imratio),
                c.loss.clone(),
                format!("{:.6}", c.test_auc.mean()),
                format!("{:.6}", c.test_auc.std()),
                format!("{}", c.n_seeds),
            ]
        })
        .collect();
    write_csv(
        out_dir.join("fig3.csv"),
        &["dataset", "imratio", "loss", "test_auc_mean", "test_auc_sd", "seeds"],
        &rows,
    )?;
    Ok(SweepOutput {
        results: run_results,
        cells,
        failures: Vec::new(),
        replayed: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_datasets_respects_cap() {
        let config = SweepConfig {
            datasets: vec!["synth-pets".into()],
            max_train: Some(64),
            ..Default::default()
        };
        let ds = build_datasets(&config).unwrap();
        assert_eq!(ds["synth-pets"].train_pool.len(), 64);
        assert_eq!(ds["synth-pets"].test.len(), 64);
    }

    #[test]
    fn unknown_dataset_is_error() {
        let config = SweepConfig {
            datasets: vec!["nope".into()],
            ..Default::default()
        };
        assert!(build_datasets(&config).is_err());
    }

    #[test]
    fn rotate_finds_free_slot() {
        let dir = std::env::temp_dir().join(format!("allpairs_rotate_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sweep_results.jsonl");
        std::fs::write(&p, b"one\n").unwrap();
        let r1 = rotate_path(&p).unwrap();
        assert!(r1.to_string_lossy().ends_with("sweep_results.jsonl.1.bak"));
        std::fs::write(&p, b"two\n").unwrap();
        let r2 = rotate_path(&p).unwrap();
        assert!(r2.to_string_lossy().ends_with("sweep_results.jsonl.2.bak"));
        assert!(!p.exists());
        assert_eq!(std::fs::read(&r1).unwrap(), b"one\n");
        assert_eq!(std::fs::read(&r2).unwrap(), b"two\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
