//! Section-5 use case: monitor the full-set all-pairs loss every epoch.
//!
//! The paper's closing argument: because the squared hinge loss is now
//! O(n log n), it can be computed on the **entire** subtrain/validation
//! sets every epoch — the same cost as computing AUC — and used to
//! diagnose training (e.g. step size too large).
//!
//! Interchangeable evaluators, cross-checked in the integration tests:
//!
//! * [`monitor_native`] — the Rust functional implementation, directly;
//! * [`monitor_backend`] — any [`Backend`]'s `eval_loss` entry point
//!   (native backend: the same functional sweep; PJRT backend: the
//!   `loss_eval_*` AOT artifact, i.e. the Pallas kernel fed the same
//!   scores).

use crate::losses::functional::SquaredHinge;
use crate::losses::LossSpec;
use crate::runtime::Backend;

/// Full-set squared hinge loss (normalized per pair) in native Rust —
/// the gradient-free ascending sweep only.
pub fn monitor_native(scores: &[f32], is_pos: &[f32], margin: f32) -> f64 {
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = scores.len() as f64 - n_pos;
    let pairs = (n_pos * n_neg).max(1.0);
    SquaredHinge::new(margin).loss_only(scores, is_pos) / pairs
}

/// Full-set training loss through a backend's monitoring entry point.
/// Like [`monitor_native`], the returned value is normalized per pair
/// (pointwise losses: per example).
pub fn monitor_backend(
    backend: &dyn Backend,
    loss: &LossSpec,
    scores: &[f32],
    is_pos: &[f32],
) -> crate::Result<f64> {
    backend.eval_loss(loss, scores, is_pos)
}

/// Full-set loss via the `loss_eval_<loss>_n<N>` artifact (feature
/// `pjrt`).  Scores are padded (mask zero) up to the artifact's static
/// size N; inputs longer than N are an error.
#[cfg(feature = "pjrt")]
pub fn monitor_artifact(
    runtime: &crate::runtime::Runtime,
    loss: &LossSpec,
    scores: &[f32],
    is_pos: &[f32],
) -> crate::Result<f64> {
    crate::runtime::pjrt::loss_eval(runtime, loss, scores, is_pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::BackendSpec;

    #[test]
    fn native_monitor_is_normalized() {
        // 1 pos, 1 neg, equal scores 0, m = 1: single pair of loss 1.
        let loss = monitor_native(&[0.0, 0.0], &[1.0, 0.0], 1.0);
        assert!((loss - 1.0).abs() < 1e-9);
        // duplicating the data leaves the per-pair loss unchanged
        let loss2 = monitor_native(&[0.0, 0.0, 0.0, 0.0], &[1.0, 0.0, 1.0, 0.0], 1.0);
        assert!((loss2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn native_monitor_single_class_is_zero() {
        assert_eq!(monitor_native(&[0.5, 0.2], &[1.0, 1.0], 1.0), 0.0);
    }

    #[test]
    fn backend_monitor_agrees_with_native() {
        let backend = BackendSpec::native().connect().unwrap();
        let scores = [0.3_f32, -0.1, 0.8, 0.2, -0.5];
        let is_pos = [1.0_f32, 0.0, 1.0, 0.0, 0.0];
        let via_backend =
            monitor_backend(backend.as_ref(), &LossSpec::hinge(), &scores, &is_pos).unwrap();
        let native = monitor_native(&scores, &is_pos, 1.0);
        assert!((via_backend - native).abs() < 1e-12);
    }
}
