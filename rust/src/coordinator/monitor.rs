//! Section-5 use case: monitor the full-set all-pairs loss every epoch.
//!
//! The paper's closing argument: because the squared hinge loss is now
//! O(n log n), it can be computed on the **entire** subtrain/validation
//! sets every epoch — the same cost as computing AUC — and used to
//! diagnose training (e.g. step size too large).
//!
//! Two interchangeable backends, cross-checked in the integration tests:
//!
//! * [`monitor_native`] — the Rust functional implementation;
//! * [`monitor_artifact`] — the `loss_eval_*` AOT artifact (the Pallas
//!   kernel), fed the same scores through PJRT.

use xla::Literal;

use crate::losses::functional::SquaredHinge;
use crate::runtime::{Manifest, Runtime};

/// Full-set squared hinge loss (normalized per pair) in native Rust.
pub fn monitor_native(scores: &[f32], is_pos: &[f32], margin: f32) -> f64 {
    let n_pos = is_pos.iter().filter(|&&p| p != 0.0).count() as f64;
    let n_neg = scores.len() as f64 - n_pos;
    let pairs = (n_pos * n_neg).max(1.0);
    SquaredHinge::new(margin).loss_only(scores, is_pos) / pairs
}

/// Full-set loss via the `loss_eval_<loss>_n<N>` artifact.  Scores are
/// padded (mask zero) up to the artifact's static size N; inputs longer
/// than N are an error.  Like [`monitor_native`], the returned value is
/// normalized per pair (the L2 training losses normalize internally).
pub fn monitor_artifact(
    runtime: &Runtime,
    loss: &str,
    scores: &[f32],
    is_pos: &[f32],
) -> crate::Result<f64> {
    // find the registered loss_eval size
    let art = runtime
        .manifest()
        .artifacts
        .iter()
        .find(|a| a.kind == crate::runtime::ArtifactKind::LossEval && a.loss == loss)
        .ok_or_else(|| anyhow::anyhow!("no loss_eval artifact for {loss}"))?;
    let n = art.batch;
    anyhow::ensure!(
        scores.len() <= n,
        "loss_eval artifact holds {n} elements, got {}",
        scores.len()
    );
    let name = Manifest::loss_eval_name(loss, n);
    let mut s = scores.to_vec();
    s.resize(n, 0.0);
    let mut p = is_pos.to_vec();
    p.resize(n, 0.0);
    let q: Vec<f32> = scores
        .iter()
        .zip(is_pos)
        .map(|(_, &pi)| if pi != 0.0 { 0.0 } else { 1.0 })
        .chain(std::iter::repeat(0.0))
        .take(n)
        .collect();
    let outs = runtime.execute(
        &name,
        &[Literal::vec1(&s), Literal::vec1(&p), Literal::vec1(&q)],
    )?;
    Ok(outs[0].to_vec::<f32>()?[0] as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_monitor_is_normalized() {
        // 1 pos, 1 neg, equal scores 0, m = 1: single pair of loss 1.
        let loss = monitor_native(&[0.0, 0.0], &[1.0, 0.0], 1.0);
        assert!((loss - 1.0).abs() < 1e-9);
        // duplicating the data leaves the per-pair loss unchanged
        let loss2 = monitor_native(&[0.0, 0.0, 0.0, 0.0], &[1.0, 0.0, 1.0, 0.0], 1.0);
        assert!((loss2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn native_monitor_single_class_is_zero() {
        assert_eq!(monitor_native(&[0.5, 0.2], &[1.0, 1.0], 1.0), 0.0);
    }
}
