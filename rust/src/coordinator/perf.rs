//! The tracked perf trajectory: train-step / loss / AUC benches behind
//! `allpairs bench`, emitted as machine-readable `BENCH_train.json` —
//! plus the serving-path benches behind `allpairs bench-serve`
//! (`BENCH_serve.json`, same record schema and envelope).
//!
//! The paper's claim is that the functional all-pairs gradient is fast
//! enough for *large* batches, so the train step — chunked forward +
//! sort/sweep loss + feature-gradient reduction — is the hot path the
//! ROADMAP's "as fast as the hardware allows" north star lives on.
//! This module measures it at n ∈ {10⁴, 10⁵, 10⁶} at both 1 worker
//! thread and the requested parallel count, so every PR extends one
//! comparable JSON series instead of quoting ad-hoc numbers (schema
//! and conventions: EXPERIMENTS.md §Perf trajectory).
//!
//! Scope: the **linear** model on the native backend — its train step
//! is exactly sort + sweep + feature-gradient reduction, the kernel the
//! paper times; MLP numbers would mostly measure the tanh layer.
//! `ALLPAIRS_BENCH_QUICK=1` shrinks the iteration budget (CI smoke),
//! not the sizes, so quick-mode files stay schema-identical.
//!
//! The competitive sort table ("beat the sort", ROADMAP item 2) times
//! the [`SortEngine`] strategies head-to-head on the hinge keys at
//! `sort_sizes` (default up to 10⁷): the comparison reference, LSD
//! radix, the adaptive re-sort in its near-sorted steady state, and —
//! as the no-sort speed floor — the O(n) univariate linear-hinge bound
//! of Lyu & Ying (arXiv 1804.05981), which decouples the pairwise
//! hinge through per-class thresholds and needs no ordering at all
//! (records `sort/{comparison,radix,adaptive,nosort_lhinge}/nN`).

use std::path::Path;

use crate::data::Rng;
use crate::losses::functional::SquaredHinge;
use crate::losses::{BatchView, LossFn, LossSpec, LossWorkspace, SortEngine, SortStrategy};
use crate::metrics::auc;
use crate::runtime::{Backend, ModelExecutor, NativeBackend, NativeSpec};
use crate::serve::{self, Scorer, ScorerOptions};
use crate::util::bench::Bench;
use crate::util::json::Json;

/// What to measure.
#[derive(Debug, Clone)]
pub struct PerfConfig {
    /// Examples per measured batch.
    pub sizes: Vec<usize>,
    /// Worker-thread counts for the train-step bench (1 = the serial
    /// baseline of the speedup table).
    pub threads: Vec<usize>,
    /// Features per example for the train-step bench.
    pub dim: usize,
    /// Key counts for the competitive sort table (empty = skip it).
    pub sort_sizes: Vec<usize>,
    /// Row counts for the out-of-core shard suite (empty = skip it):
    /// store write, coalesced sequential read, and one full stratified
    /// epoch through the double-buffered prefetch path
    /// (`shard/{write,read_seq,epoch_fill}/nN`).
    pub shard_sizes: Vec<usize>,
    /// Push the sort table to n = 10⁸ with keys *streamed from a shard
    /// store* rather than generated resident (`allpairs bench --huge`).
    /// Off by default: needs ~3 GB RAM and ~1 GB of scratch disk.
    pub huge_sort: bool,
}

impl Default for PerfConfig {
    fn default() -> Self {
        Self {
            sizes: vec![10_000, 100_000, 1_000_000],
            threads: vec![1, 8],
            dim: 32,
            sort_sizes: vec![100_000, 1_000_000, 10_000_000],
            shard_sizes: vec![100_000, 1_000_000],
            huge_sort: false,
        }
    }
}

/// One benchmark point of the trajectory (the `BENCH_train.json`
/// record schema: name, n, threads, median_s, mean_s, min_s).
#[derive(Debug, Clone)]
pub struct PerfRecord {
    pub name: String,
    pub n: usize,
    /// Requested worker threads (1 for the serial baseline and for the
    /// inherently serial loss/AUC kernels).
    pub threads: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
}

impl PerfRecord {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("n", Json::num(self.n as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("median_s", Json::num(self.median_s)),
            ("mean_s", Json::num(self.mean_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

/// 10%-positive benchmark data: `n` rows of `dim` standard normals
/// plus the {0,1} masks, deterministic from the seed.
fn bench_data(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    let is_pos: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.1 { 1.0 } else { 0.0 })
        .collect();
    let is_neg: Vec<f32> = is_pos.iter().map(|&p| 1.0 - p).collect();
    (x, is_pos, is_neg)
}

/// Run the perf suite.  Honors `ALLPAIRS_BENCH_QUICK=1` via
/// [`Bench::from_env`].
pub fn run(cfg: &PerfConfig) -> crate::Result<Vec<PerfRecord>> {
    let mut bench = Bench::from_env();
    let mut records = Vec::new();
    for &n in &cfg.sizes {
        let (x, is_pos, is_neg) = bench_data(n, cfg.dim, 0xBE7C4 ^ n as u64);

        // The full train step (forward → hinge sort/sweep → feature-
        // gradient reduction → SGD), serial and parallel.
        for &threads in &cfg.threads {
            let backend = NativeBackend::new(NativeSpec {
                input_dim: cfg.dim,
                hidden: 0,
                threads,
                ..NativeSpec::default()
            });
            let mut exec = backend.open("linear", &LossSpec::hinge(), n)?;
            exec.init(0)?;
            // lr = 0: parameters never move, so every timed iteration
            // performs bit-identical work (a non-zero lr would fit the
            // data across iterations — pairs go hinge-inactive, scores
            // become pre-sorted — and medians would drift with the
            // iteration count instead of being comparable across runs).
            let m = bench.run(format!("train_step/hinge/n{n}/t{threads}"), || {
                exec.train_step(&x, &is_pos, &is_neg, 0.0).unwrap()
            });
            records.push(record(m, n, threads));
        }

        // The loss kernel alone (sort + sweep, gradient included) —
        // inherently serial, the O(n log n) object the paper times —
        // through the allocation-free LossFn workspace API.
        let hinge = SquaredHinge::new(1.0);
        let scores: Vec<f32> = x.iter().step_by(cfg.dim).copied().collect();
        let mut ws = LossWorkspace::default();
        let m = bench.run(format!("loss/hinge/n{n}"), || {
            hinge.loss_and_grad(BatchView::new(&scores, &is_pos), &mut ws)
        });
        records.push(record(m, n, 1));

        // AUC over the same scores (the per-epoch validation cost).
        let m = bench.run(format!("auc/n{n}"), || auc(&scores, &is_pos));
        records.push(record(m, n, 1));
    }

    // The competitive sort table (ROADMAP item 2): every SortEngine
    // strategy against the comparison reference and the O(n) no-sort
    // floor, on the exact hinge keys the kernels sort.
    for &n in &cfg.sort_sizes {
        let (scores, is_pos) = sort_bench_data(n);
        sort_suite_on(&mut bench, &mut records, n, &scores, &is_pos)?;
    }

    // The out-of-core I/O path (DESIGN.md §13).
    for &n in &cfg.shard_sizes {
        shard_suite(&mut bench, &mut records, n, cfg.dim)?;
    }

    // n = 10⁸ sort table, fed from disk instead of resident vectors.
    if cfg.huge_sort {
        huge_sort_suite(&mut bench, &mut records)?;
    }
    Ok(records)
}

/// Scores + positive mask for the sort table, deterministic in `n`.
fn sort_bench_data(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x50B7 ^ n as u64);
    let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let is_pos: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.1 { 1.0 } else { 0.0 })
        .collect();
    (scores, is_pos)
}

/// One size of the competitive sort table over caller-provided data
/// (resident for the standard sizes, streamed back out of a shard
/// store for `--huge`).  The permutations of all three strategies are
/// asserted identical at full bench scale before any timing — the same
/// invariant `tests/proptest_sort.rs` pins on adversarial
/// distributions, checked here on the real full-scale key layout.
fn sort_suite_on(
    bench: &mut Bench,
    records: &mut Vec<PerfRecord>,
    n: usize,
    scores: &[f32],
    is_pos: &[f32],
) -> crate::Result<()> {
    anyhow::ensure!(scores.len() == n && is_pos.len() == n, "sort suite: data/size mismatch");
    let mut rng = Rng::new(0x57A1E ^ n as u64);
    // the augmented-value keys of `fill_hinge_order` at margin 1
    let keys: Vec<f64> = scores
        .iter()
        .zip(is_pos)
        .map(|(&y, &p)| if p != 0.0 { y as f64 } else { y as f64 + 1.0 })
        .collect();

    // Reference permutation (untimed) + full-scale differential check.
    let mut reference = Vec::new();
    SortEngine::new(SortStrategy::Comparison).order_by_keys(&keys, is_pos, false, &mut reference);
    let mut order = Vec::new();
    for strategy in [SortStrategy::Radix, SortStrategy::Adaptive] {
        SortEngine::new(strategy).order_by_keys(&keys, is_pos, false, &mut order);
        anyhow::ensure!(
            order == reference,
            "{strategy} permutation diverged from the comparison reference at n={n}"
        );
    }

    // The adaptive steady state: the previous SGD step's permutation is
    // near-sorted for the current keys.  Model it as the canonical
    // order with 100 random adjacent transpositions (≤ 101 runs, well
    // inside the merge regime); re-seed every iteration so each timed
    // call does the full detect-and-merge work, not a no-op verify.
    let mut stale = reference.clone();
    if n >= 2 {
        for _ in 0..100 {
            let i = rng.below(n - 1);
            stale.swap(i, i + 1);
        }
    }

    for strategy in [SortStrategy::Comparison, SortStrategy::Radix] {
        let mut engine = SortEngine::new(strategy);
        let m = bench.run(format!("sort/{strategy}/n{n}"), || {
            engine.order_by_keys(&keys, is_pos, false, &mut order);
            order.len()
        });
        records.push(record(m, n, 1));
    }
    let mut engine = SortEngine::new(SortStrategy::Adaptive);
    let m = bench.run(format!("sort/adaptive/n{n}"), || {
        engine.seed_prev(&stale);
        engine.order_by_keys(&keys, is_pos, false, &mut order);
        order.len()
    });
    records.push(record(m, n, 1));

    // The no-sort floor: the O(n) univariate bound needs no ordering.
    let m = bench.run(format!("sort/nosort_lhinge/n{n}"), || {
        univariate_lhinge_bound(scores, is_pos, 1.0)
    });
    records.push(record(m, n, 1));
    Ok(())
}

/// The out-of-core I/O suite at one row count: store write, coalesced
/// sequential read, and one full stratified epoch streamed through the
/// double-buffered prefetch path (each timed `epoch_fill` iteration is
/// a complete epoch, prefetch thread spawn included).
fn shard_suite(
    bench: &mut Bench,
    records: &mut Vec<PerfRecord>,
    n: usize,
    dim: usize,
) -> crate::Result<()> {
    use crate::data::dataset::Dataset;
    use crate::data::{DatasetSource, EpochSampler, SamplingMode, ShardedDataset};

    let mut rng = Rng::new(0x5AA2D ^ n as u64);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.1 { 1.0 } else { 0.0 })
        .collect();
    let d = Dataset::new(x, y, 0, dim);
    let dir = std::env::temp_dir().join(format!(
        "allpairs_bench_shard_{}_{n}",
        std::process::id()
    ));
    let n_shards = 4.min(n);

    // Each timed iteration rebuilds the whole store (atomic publishes
    // and CRC streaming included — the real `allpairs shard` cost).
    let m = bench.run(format!("shard/write/n{n}"), || {
        crate::data::shard::write_store(&dir, &d, n_shards).unwrap().n_rows
    });
    records.push(record(m, n, 1));
    drop(d);

    let store = ShardedDataset::open(&dir)?;
    let indices: Vec<u32> = (0..n as u32).collect();
    let chunk_rows = 4096.min(n);
    let mut buf = vec![0.0f32; chunk_rows * dim];
    let m = bench.run(format!("shard/read_seq/n{n}"), || {
        let mut total = 0usize;
        for chunk in indices.chunks(chunk_rows) {
            store.fetch_rows(chunk, &mut buf[..chunk.len() * dim]).unwrap();
            total += chunk.len();
        }
        total
    });
    records.push(record(m, n, 1));

    let batch = 1024.min(n);
    let mut sampler =
        EpochSampler::new(store.labels(), &indices, batch, SamplingMode::Preserve)?;
    let mut epoch_rng = Rng::new(1);
    let (mut bx, mut bp, mut bq) =
        (vec![0.0f32; batch * dim], vec![0.0f32; batch], vec![0.0f32; batch]);
    let m = bench.run(format!("shard/epoch_fill/n{n}"), || {
        let plan = sampler.epoch_plan(&mut epoch_rng);
        let mut fill = store.batches(&plan).unwrap();
        let mut total = 0usize;
        while let Some(count) = fill.fill_next(&mut bx, &mut bp, &mut bq).unwrap() {
            total += count;
        }
        total
    });
    records.push(record(m, n, 1));

    drop(store);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// The `--huge` sort table: n = 10⁸ hinge keys whose scores and labels
/// round-trip through a 7-shard store first, so the headline number is
/// produced from disk-fed data no resident generator could hold next
/// to the sort scratch.  ~3 GB RAM (keys + permutations), ~1 GB disk.
fn huge_sort_suite(bench: &mut Bench, records: &mut Vec<PerfRecord>) -> crate::Result<()> {
    use crate::data::dataset::Dataset;
    use crate::data::{DatasetSource, ShardedDataset};

    const N: usize = 100_000_000;
    let dir = std::env::temp_dir().join(format!("allpairs_bench_huge_{}", std::process::id()));
    {
        let (scores, is_pos) = sort_bench_data(N);
        let d = Dataset::new(scores, is_pos, 0, 1);
        crate::data::shard::write_store(&dir, &d, 7)?;
    } // resident copy dropped before the read-back

    let store = ShardedDataset::open(&dir)?;
    anyhow::ensure!(store.len() == N, "huge store row count");
    let mut scores = vec![0.0f32; N];
    let indices: Vec<u32> = (0..N as u32).collect();
    for chunk_start in (0..N).step_by(1 << 20) {
        let chunk = &indices[chunk_start..(chunk_start + (1 << 20)).min(N)];
        store.fetch_rows(chunk, &mut scores[chunk_start..chunk_start + chunk.len()])?;
    }
    let is_pos = store.labels().to_vec();
    drop(store);
    let _ = std::fs::remove_dir_all(&dir);

    sort_suite_on(bench, records, N, &scores, &is_pos)
}

/// The univariate linear-hinge *upper bound* of Lyu & Ying (arXiv
/// 1804.05981): decouple each pairwise term through a fixed pivot at
/// the margin midpoint, `(m − ŷⱼ + ŷₖ)₊ ≤ (m/2 − ŷⱼ)₊ + (m/2 + ŷₖ)₊`,
/// so the double sum collapses to two per-class single passes — O(n),
/// no sort.  A speed floor for the table, not a drop-in replacement:
/// it bounds (rather than equals) the all-pairs objective.
pub fn univariate_lhinge_bound(scores: &[f32], is_pos: &[f32], margin: f64) -> f64 {
    let (mut n_pos, mut n_neg) = (0.0_f64, 0.0_f64);
    let (mut pos_sum, mut neg_sum) = (0.0_f64, 0.0_f64);
    for (&y, &p) in scores.iter().zip(is_pos) {
        let y = y as f64;
        if p != 0.0 {
            n_pos += 1.0;
            pos_sum += (margin / 2.0 - y).max(0.0);
        } else {
            n_neg += 1.0;
            neg_sum += (margin / 2.0 + y).max(0.0);
        }
    }
    n_neg * pos_sum + n_pos * neg_sum
}

/// What `allpairs bench-serve` measures (the `BENCH_serve.json`
/// trajectory): the per-request protocol costs and the end-to-end
/// scoring round trip through the real channel + micro-batch path.
#[derive(Debug, Clone)]
pub struct ServePerfConfig {
    /// Features per request (default mirrors the serve-scale row).
    pub dim: usize,
    /// Hidden units of the benchmarked checkpoint (0 = linear).
    pub hidden: usize,
    /// Concurrent in-flight request counts for the round-trip bench.
    pub batches: Vec<usize>,
}

impl Default for ServePerfConfig {
    fn default() -> Self {
        Self {
            dim: 768,
            hidden: 32,
            batches: vec![1, 64, 1024],
        }
    }
}

/// Run the serve perf suite.  Same envelope and conventions as
/// [`run`] — records land in `BENCH_serve.json` via [`write_json`]:
///
/// * `serve/parse/dD` (n = D) — request-line parse + validation
/// * `serve/encode` (n = 1) — response encoding
/// * `serve/score_roundtrip/bB` (n = B) — B requests submitted
///   concurrently, all replies drained (channel + micro-batch + forward)
/// * `serve/reload` (n = 1) — checkpoint load + CRC + validate + swap
pub fn run_serve(cfg: &ServePerfConfig) -> crate::Result<Vec<PerfRecord>> {
    anyhow::ensure!(
        cfg.dim > 0 && !cfg.batches.is_empty() && cfg.batches.iter().all(|&b| b > 0),
        "serve bench needs a positive dim and non-empty positive batches"
    );
    let mut bench = Bench::from_env();
    let mut records = Vec::new();
    let dim = cfg.dim;
    let mut rng = Rng::new(0x5E7E ^ dim as u64);

    // The per-request protocol costs, off the scoring thread.
    let feats: Vec<String> = (0..dim).map(|_| format!("{:.6}", rng.normal())).collect();
    let line = format!("{{\"id\": 12345, \"features\": [{}]}}", feats.join(", "));
    let m = bench.run(format!("serve/parse/d{dim}"), || {
        serve::parse_request(&line).unwrap().features.len()
    });
    records.push(record(m, dim, 1));
    let m = bench.run("serve/encode", || serve::score_response(None, 0.123).len());
    records.push(record(m, 1, 1));

    // A real (untrained) checkpoint for the end-to-end path.
    let ckpt = std::env::temp_dir().join(format!(
        "allpairs_bench_serve_{}.bin",
        std::process::id()
    ));
    {
        let backend = NativeBackend::new(NativeSpec {
            input_dim: dim,
            hidden: cfg.hidden,
            threads: 1,
            ..NativeSpec::default()
        });
        let model = if cfg.hidden == 0 { "linear" } else { "mlp" };
        let mut exec = backend.open(model, &LossSpec::hinge(), 1)?;
        exec.init(0)?;
        crate::train::checkpoint::save(&ckpt, &exec.state_to_host()?)?;
    }
    let max_batch = cfg.batches.iter().copied().max().unwrap_or(1);
    let scorer = Scorer::spawn(ScorerOptions {
        max_batch,
        threads: 1,
        ..ScorerOptions::new(&ckpt)
    })?;
    let rows: Vec<Vec<f32>> = (0..max_batch)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();
    for &b in &cfg.batches {
        let m = bench.run(format!("serve/score_roundtrip/b{b}"), || {
            let replies: Vec<_> = rows[..b]
                .iter()
                .map(|r| scorer.handle.submit(r.clone()))
                .collect();
            replies
                .into_iter()
                .map(|rx| rx.recv().unwrap().unwrap())
                .count()
        });
        records.push(record(m, b, 1));
    }
    // Hot reload end to end; stats() is the completion barrier.
    let m = bench.run("serve/reload", || {
        assert!(scorer.handle.reload());
        scorer.handle.stats().unwrap().reloads_ok
    });
    records.push(record(m, 1, 1));
    scorer.shutdown();
    let _ = std::fs::remove_file(&ckpt);
    Ok(records)
}

/// The round-trip throughput rows for the `bench-serve` summary:
/// `(batch, median seconds, rows per second)`.
pub fn serve_throughput(records: &[PerfRecord]) -> Vec<(usize, f64, f64)> {
    let mut rows: Vec<(usize, f64, f64)> = records
        .iter()
        .filter(|r| r.name.starts_with("serve/score_roundtrip/") && r.median_s > 0.0)
        .map(|r| (r.n, r.median_s, r.n as f64 / r.median_s))
        .collect();
    rows.sort_unstable_by_key(|&(b, ..)| b);
    rows
}

fn record(m: &crate::util::bench::Measurement, n: usize, threads: usize) -> PerfRecord {
    PerfRecord {
        name: m.name.clone(),
        n,
        threads,
        median_s: m.median.as_secs_f64(),
        mean_s: m.mean.as_secs_f64(),
        min_s: m.min.as_secs_f64(),
    }
}

/// The serial-vs-parallel speedup rows for EXPERIMENTS.md:
/// `(n, serial median, best parallel (threads, median), speedup)`.
pub fn speedups(records: &[PerfRecord]) -> Vec<(usize, f64, usize, f64, f64)> {
    let mut out = Vec::new();
    let mut sizes: Vec<usize> = records
        .iter()
        .filter(|r| r.name.starts_with("train_step/"))
        .map(|r| r.n)
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    for n in sizes {
        let serial = records
            .iter()
            .find(|r| r.name.starts_with("train_step/") && r.n == n && r.threads == 1);
        let parallel = records
            .iter()
            .filter(|r| r.name.starts_with("train_step/") && r.n == n && r.threads > 1)
            .min_by(|a, b| a.median_s.total_cmp(&b.median_s));
        if let (Some(s), Some(p)) = (serial, parallel) {
            out.push((n, s.median_s, p.threads, p.median_s, s.median_s / p.median_s));
        }
    }
    out
}

/// One row of the competitive sort table: medians per strategy at one
/// size (a field is `None` when its record is absent).
#[derive(Debug, Clone, PartialEq)]
pub struct SortTableRow {
    pub n: usize,
    pub comparison_s: Option<f64>,
    pub radix_s: Option<f64>,
    pub adaptive_s: Option<f64>,
    pub nosort_s: Option<f64>,
}

impl SortTableRow {
    /// Speedup of the best full-sort strategy over the comparison
    /// reference (the "beat the sort" headline number).
    pub fn best_speedup(&self) -> Option<f64> {
        let best = match (self.radix_s, self.adaptive_s) {
            (Some(r), Some(a)) => r.min(a),
            (Some(r), None) => r,
            (None, Some(a)) => a,
            (None, None) => return None,
        };
        Some(self.comparison_s? / best)
    }
}

/// Assemble the `sort/*` records into per-size table rows, ascending n.
pub fn sort_table(records: &[PerfRecord]) -> Vec<SortTableRow> {
    let median_of = |strategy: &str, n: usize| -> Option<f64> {
        records
            .iter()
            .find(|r| r.n == n && r.name == format!("sort/{strategy}/n{n}"))
            .map(|r| r.median_s)
    };
    let mut sizes: Vec<usize> = records
        .iter()
        .filter(|r| r.name.starts_with("sort/"))
        .map(|r| r.n)
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    sizes
        .into_iter()
        .map(|n| SortTableRow {
            n,
            comparison_s: median_of("comparison", n),
            radix_s: median_of("radix", n),
            adaptive_s: median_of("adaptive", n),
            nosort_s: median_of("nosort_lhinge", n),
        })
        .collect()
}

/// Write the records as `BENCH_train.json`: a versioned envelope so
/// future PRs can extend the schema without breaking readers.
pub fn write_json(
    records: &[PerfRecord],
    quick: bool,
    path: impl AsRef<Path>,
) -> crate::Result<()> {
    let doc = Json::obj([
        ("schema", Json::num(1.0)),
        ("quick", Json::Bool(quick)),
        ("records", Json::Arr(records.iter().map(|r| r.to_json()).collect())),
    ]);
    // Atomic replace (temp + fsync + rename): a crash mid-bench never
    // leaves a torn BENCH_train.json for CI to misparse.
    crate::util::fsio::write_atomic(path, doc.dumps().as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, n: usize, threads: usize, median_s: f64) -> PerfRecord {
        PerfRecord {
            name: name.into(),
            n,
            threads,
            median_s,
            mean_s: median_s,
            min_s: median_s,
        }
    }

    #[test]
    fn json_round_trips_through_the_strict_parser() {
        let records = vec![
            rec("train_step/hinge/n100/t1", 100, 1, 0.5),
            rec("train_step/hinge/n100/t8", 100, 8, 0.125),
        ];
        let name = format!("allpairs_bench_json_test_{}.json", std::process::id());
        let path = std::env::temp_dir().join(name);
        write_json(&records, true, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.req("schema").unwrap().as_usize(), Some(1));
        assert_eq!(doc.req("quick").unwrap().as_bool(), Some(true));
        let rows = doc.req("records").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        for (row, want) in rows.iter().zip(&records) {
            assert_eq!(row.req("name").unwrap().as_str(), Some(want.name.as_str()));
            assert_eq!(row.req("n").unwrap().as_usize(), Some(want.n));
            assert_eq!(row.req("threads").unwrap().as_usize(), Some(want.threads));
            assert_eq!(row.req("median_s").unwrap().as_f64(), Some(want.median_s));
        }
    }

    #[test]
    fn speedups_pair_serial_with_best_parallel() {
        let records = vec![
            rec("train_step/hinge/n100/t1", 100, 1, 0.8),
            rec("train_step/hinge/n100/t8", 100, 8, 0.2),
            rec("train_step/hinge/n200/t1", 200, 1, 1.0),
            rec("loss/hinge/n100", 100, 1, 0.3), // not a train step
        ];
        let rows = speedups(&records);
        assert_eq!(rows.len(), 1, "n=200 has no parallel row, loss rows skip");
        let (n, serial, threads, parallel, speedup) = rows[0];
        assert_eq!((n, threads), (100, 8));
        assert_eq!(serial, 0.8);
        assert_eq!(parallel, 0.2);
        assert!((speedup - 4.0).abs() < 1e-12);
    }

    #[test]
    fn sort_table_assembles_rows_per_size() {
        let records = vec![
            rec("sort/comparison/n100", 100, 1, 0.8),
            rec("sort/radix/n100", 100, 1, 0.2),
            rec("sort/adaptive/n100", 100, 1, 0.1),
            rec("sort/nosort_lhinge/n100", 100, 1, 0.01),
            rec("sort/comparison/n50", 50, 1, 0.4),
            rec("train_step/hinge/n100/t1", 100, 1, 0.5), // not a sort row
        ];
        let rows = sort_table(&records);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].n, 50, "rows come back in ascending n");
        assert_eq!(rows[0].comparison_s, Some(0.4));
        assert_eq!(rows[0].radix_s, None);
        assert_eq!(rows[0].best_speedup(), None);
        assert_eq!(rows[1].n, 100);
        assert_eq!(rows[1].nosort_s, Some(0.01));
        let speedup = rows[1].best_speedup().unwrap();
        assert!((speedup - 8.0).abs() < 1e-12, "0.8 / min(0.2, 0.1) = 8");
    }

    #[test]
    fn tiny_suite_runs_end_to_end() {
        // Keep it seconds-scale: small n, quick-ish budget comes from
        // the default Bench (each point still takes min_iters runs).
        let cfg = PerfConfig {
            sizes: vec![500],
            threads: vec![1],
            dim: 4,
            sort_sizes: vec![300],
            shard_sizes: vec![200],
            huge_sort: false,
        };
        let records = run(&cfg).unwrap();
        // train_step + loss + auc, the four-strategy sort suite, then
        // the three-record shard suite
        assert_eq!(records.len(), 10);
        assert!(records.iter().all(|r| r.min_s >= 0.0 && r.median_s >= r.min_s));
        assert!(records.iter().any(|r| r.name == "train_step/hinge/n500/t1"));
        for strategy in ["comparison", "radix", "adaptive", "nosort_lhinge"] {
            let name = format!("sort/{strategy}/n300");
            assert!(records.iter().any(|r| r.name == name), "missing {name}");
        }
        for suite in ["write", "read_seq", "epoch_fill"] {
            let name = format!("shard/{suite}/n200");
            assert!(records.iter().any(|r| r.name == name), "missing {name}");
        }
        assert_eq!(sort_table(&records).len(), 1);
    }

    #[test]
    fn tiny_serve_suite_runs_end_to_end() {
        let cfg = ServePerfConfig {
            dim: 6,
            hidden: 2,
            batches: vec![1, 4],
        };
        let records = run_serve(&cfg).unwrap();
        // parse + encode + two round-trip points + reload
        assert_eq!(records.len(), 5);
        for name in [
            "serve/parse/d6",
            "serve/encode",
            "serve/score_roundtrip/b1",
            "serve/score_roundtrip/b4",
            "serve/reload",
        ] {
            assert!(records.iter().any(|r| r.name == name), "missing {name}");
        }
        let rows = serve_throughput(&records);
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].0, rows[1].0), (1, 4), "ascending batch");
        assert!(rows.iter().all(|&(_, s, rps)| s > 0.0 && rps > 0.0));
    }

    #[test]
    fn univariate_bound_dominates_the_pairwise_linear_hinge() {
        let mut rng = Rng::new(7);
        let scores: Vec<f32> = (0..200).map(|_| rng.normal() as f32).collect();
        let is_pos: Vec<f32> = (0..200)
            .map(|_| if rng.uniform() < 0.25 { 1.0 } else { 0.0 })
            .collect();
        let margin = 1.0;
        let mut exact = 0.0_f64;
        for (&yp, &pp) in scores.iter().zip(&is_pos) {
            if pp == 0.0 {
                continue;
            }
            for (&yn, &pn) in scores.iter().zip(&is_pos) {
                if pn != 0.0 {
                    continue;
                }
                exact += (margin - yp as f64 + yn as f64).max(0.0);
            }
        }
        let bound = univariate_lhinge_bound(&scores, &is_pos, margin);
        assert!(bound >= exact, "bound {bound} < exact {exact}");
        assert!(bound.is_finite() && bound > 0.0);
    }
}
