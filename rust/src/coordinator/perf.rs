//! The tracked perf trajectory: train-step / loss / AUC benches behind
//! `allpairs bench`, emitted as machine-readable `BENCH_train.json`.
//!
//! The paper's claim is that the functional all-pairs gradient is fast
//! enough for *large* batches, so the train step — chunked forward +
//! sort/sweep loss + feature-gradient reduction — is the hot path the
//! ROADMAP's "as fast as the hardware allows" north star lives on.
//! This module measures it at n ∈ {10⁴, 10⁵, 10⁶} at both 1 worker
//! thread and the requested parallel count, so every PR extends one
//! comparable JSON series instead of quoting ad-hoc numbers (schema
//! and conventions: EXPERIMENTS.md §Perf trajectory).
//!
//! Scope: the **linear** model on the native backend — its train step
//! is exactly sort + sweep + feature-gradient reduction, the kernel the
//! paper times; MLP numbers would mostly measure the tanh layer.
//! `ALLPAIRS_BENCH_QUICK=1` shrinks the iteration budget (CI smoke),
//! not the sizes, so quick-mode files stay schema-identical.

use std::path::Path;

use crate::data::Rng;
use crate::losses::functional::SquaredHinge;
use crate::losses::{BatchView, LossFn, LossSpec, LossWorkspace};
use crate::metrics::auc;
use crate::runtime::{Backend, NativeBackend, NativeSpec};
use crate::util::bench::Bench;
use crate::util::json::Json;

/// What to measure.
#[derive(Debug, Clone)]
pub struct PerfConfig {
    /// Examples per measured batch.
    pub sizes: Vec<usize>,
    /// Worker-thread counts for the train-step bench (1 = the serial
    /// baseline of the speedup table).
    pub threads: Vec<usize>,
    /// Features per example for the train-step bench.
    pub dim: usize,
}

impl Default for PerfConfig {
    fn default() -> Self {
        Self {
            sizes: vec![10_000, 100_000, 1_000_000],
            threads: vec![1, 8],
            dim: 32,
        }
    }
}

/// One benchmark point of the trajectory (the `BENCH_train.json`
/// record schema: name, n, threads, median_s, mean_s, min_s).
#[derive(Debug, Clone)]
pub struct PerfRecord {
    pub name: String,
    pub n: usize,
    /// Requested worker threads (1 for the serial baseline and for the
    /// inherently serial loss/AUC kernels).
    pub threads: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
}

impl PerfRecord {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::str(self.name.clone())),
            ("n", Json::num(self.n as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("median_s", Json::num(self.median_s)),
            ("mean_s", Json::num(self.mean_s)),
            ("min_s", Json::num(self.min_s)),
        ])
    }
}

/// 10%-positive benchmark data: `n` rows of `dim` standard normals
/// plus the {0,1} masks, deterministic from the seed.
fn bench_data(n: usize, dim: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    let is_pos: Vec<f32> = (0..n)
        .map(|_| if rng.uniform() < 0.1 { 1.0 } else { 0.0 })
        .collect();
    let is_neg: Vec<f32> = is_pos.iter().map(|&p| 1.0 - p).collect();
    (x, is_pos, is_neg)
}

/// Run the perf suite.  Honors `ALLPAIRS_BENCH_QUICK=1` via
/// [`Bench::from_env`].
pub fn run(cfg: &PerfConfig) -> crate::Result<Vec<PerfRecord>> {
    let mut bench = Bench::from_env();
    let mut records = Vec::new();
    for &n in &cfg.sizes {
        let (x, is_pos, is_neg) = bench_data(n, cfg.dim, 0xBE7C4 ^ n as u64);

        // The full train step (forward → hinge sort/sweep → feature-
        // gradient reduction → SGD), serial and parallel.
        for &threads in &cfg.threads {
            let backend = NativeBackend::new(NativeSpec {
                input_dim: cfg.dim,
                hidden: 0,
                threads,
            });
            let mut exec = backend.open("linear", &LossSpec::hinge(), n)?;
            exec.init(0)?;
            // lr = 0: parameters never move, so every timed iteration
            // performs bit-identical work (a non-zero lr would fit the
            // data across iterations — pairs go hinge-inactive, scores
            // become pre-sorted — and medians would drift with the
            // iteration count instead of being comparable across runs).
            let m = bench.run(format!("train_step/hinge/n{n}/t{threads}"), || {
                exec.train_step(&x, &is_pos, &is_neg, 0.0).unwrap()
            });
            records.push(record(m, n, threads));
        }

        // The loss kernel alone (sort + sweep, gradient included) —
        // inherently serial, the O(n log n) object the paper times —
        // through the allocation-free LossFn workspace API.
        let hinge = SquaredHinge::new(1.0);
        let scores: Vec<f32> = x.iter().step_by(cfg.dim).copied().collect();
        let mut ws = LossWorkspace::default();
        let m = bench.run(format!("loss/hinge/n{n}"), || {
            hinge.loss_and_grad(BatchView::new(&scores, &is_pos), &mut ws)
        });
        records.push(record(m, n, 1));

        // AUC over the same scores (the per-epoch validation cost).
        let m = bench.run(format!("auc/n{n}"), || auc(&scores, &is_pos));
        records.push(record(m, n, 1));
    }
    Ok(records)
}

fn record(m: &crate::util::bench::Measurement, n: usize, threads: usize) -> PerfRecord {
    PerfRecord {
        name: m.name.clone(),
        n,
        threads,
        median_s: m.median.as_secs_f64(),
        mean_s: m.mean.as_secs_f64(),
        min_s: m.min.as_secs_f64(),
    }
}

/// The serial-vs-parallel speedup rows for EXPERIMENTS.md:
/// `(n, serial median, best parallel (threads, median), speedup)`.
pub fn speedups(records: &[PerfRecord]) -> Vec<(usize, f64, usize, f64, f64)> {
    let mut out = Vec::new();
    let mut sizes: Vec<usize> = records
        .iter()
        .filter(|r| r.name.starts_with("train_step/"))
        .map(|r| r.n)
        .collect();
    sizes.sort_unstable();
    sizes.dedup();
    for n in sizes {
        let serial = records
            .iter()
            .find(|r| r.name.starts_with("train_step/") && r.n == n && r.threads == 1);
        let parallel = records
            .iter()
            .filter(|r| r.name.starts_with("train_step/") && r.n == n && r.threads > 1)
            .min_by(|a, b| a.median_s.total_cmp(&b.median_s));
        if let (Some(s), Some(p)) = (serial, parallel) {
            out.push((n, s.median_s, p.threads, p.median_s, s.median_s / p.median_s));
        }
    }
    out
}

/// Write the records as `BENCH_train.json`: a versioned envelope so
/// future PRs can extend the schema without breaking readers.
pub fn write_json(
    records: &[PerfRecord],
    quick: bool,
    path: impl AsRef<Path>,
) -> crate::Result<()> {
    let doc = Json::obj([
        ("schema", Json::num(1.0)),
        ("quick", Json::Bool(quick)),
        ("records", Json::Arr(records.iter().map(|r| r.to_json()).collect())),
    ]);
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.dumps())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &str, n: usize, threads: usize, median_s: f64) -> PerfRecord {
        PerfRecord {
            name: name.into(),
            n,
            threads,
            median_s,
            mean_s: median_s,
            min_s: median_s,
        }
    }

    #[test]
    fn json_round_trips_through_the_strict_parser() {
        let records = vec![
            rec("train_step/hinge/n100/t1", 100, 1, 0.5),
            rec("train_step/hinge/n100/t8", 100, 8, 0.125),
        ];
        let name = format!("allpairs_bench_json_test_{}.json", std::process::id());
        let path = std::env::temp_dir().join(name);
        write_json(&records, true, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.req("schema").unwrap().as_usize(), Some(1));
        assert_eq!(doc.req("quick").unwrap().as_bool(), Some(true));
        let rows = doc.req("records").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        for (row, want) in rows.iter().zip(&records) {
            assert_eq!(row.req("name").unwrap().as_str(), Some(want.name.as_str()));
            assert_eq!(row.req("n").unwrap().as_usize(), Some(want.n));
            assert_eq!(row.req("threads").unwrap().as_usize(), Some(want.threads));
            assert_eq!(row.req("median_s").unwrap().as_f64(), Some(want.median_s));
        }
    }

    #[test]
    fn speedups_pair_serial_with_best_parallel() {
        let records = vec![
            rec("train_step/hinge/n100/t1", 100, 1, 0.8),
            rec("train_step/hinge/n100/t8", 100, 8, 0.2),
            rec("train_step/hinge/n200/t1", 200, 1, 1.0),
            rec("loss/hinge/n100", 100, 1, 0.3), // not a train step
        ];
        let rows = speedups(&records);
        assert_eq!(rows.len(), 1, "n=200 has no parallel row, loss rows skip");
        let (n, serial, threads, parallel, speedup) = rows[0];
        assert_eq!((n, threads), (100, 8));
        assert_eq!(serial, 0.8);
        assert_eq!(parallel, 0.2);
        assert!((speedup - 4.0).abs() < 1e-12);
    }

    #[test]
    fn tiny_suite_runs_end_to_end() {
        // Keep it seconds-scale: small n, quick-ish budget comes from
        // the default Bench (each point still takes min_iters runs).
        let cfg = PerfConfig {
            sizes: vec![500],
            threads: vec![1],
            dim: 4,
        };
        let records = run(&cfg).unwrap();
        assert_eq!(records.len(), 3); // train_step + loss + auc
        assert!(records.iter().all(|r| r.min_s >= 0.0 && r.median_s >= r.min_s));
        assert!(records.iter().any(|r| r.name == "train_step/hinge/n500/t1"));
    }
}
