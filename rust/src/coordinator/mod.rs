//! Experiment orchestration: one module per paper experiment.
//!
//! * [`timing`] — Figure 2: loss+gradient wall time vs data size for the
//!   naive / functional / logistic implementations.
//! * [`cv`] — Table 2 + Figure 3: the full cross-validation sweep over
//!   datasets × imratios × losses × batch sizes × learning rates × seeds,
//!   driven through any [`crate::runtime::Backend`].
//! * [`monitor`] — the paper's section-5 use case: monitoring the
//!   full-set all-pairs loss every epoch in the same O(n log n) as AUC.
//! * [`perf`] — the tracked perf trajectory (`allpairs bench` →
//!   `BENCH_train.json`): train-step / loss / AUC wall times at
//!   n ∈ {10⁴, 10⁵, 10⁶}, serial vs parallel.

pub mod cv;
pub mod monitor;
pub mod perf;
pub mod timing;
