//! Experiment orchestration: one module per paper experiment.
//!
//! * [`timing`] — Figure 2: loss+gradient wall time vs data size for the
//!   naive / functional / logistic implementations.
//! * [`cv`] — Table 2 + Figure 3: the full cross-validation sweep over
//!   datasets × imratios × losses × batch sizes × learning rates × seeds,
//!   driven through any [`crate::runtime::Backend`].
//! * [`monitor`] — the paper's section-5 use case: monitoring the
//!   full-set all-pairs loss every epoch in the same O(n log n) as AUC.

pub mod cv;
pub mod monitor;
pub mod timing;
