//! Multi-threaded sweep execution, hardened for long-running sweeps.
//!
//! Workers receive a [`BackendSpec`] (plain `Send + Sync` data) and
//! connect their own backend instance: the PJRT client is `Rc`-based
//! (not `Send`), so it cannot cross threads, and the native backend is
//! cheap to instantiate.  Jobs are pulled from a shared queue; results
//! stream back over a channel so the caller can persist incrementally
//! and print progress.
//!
//! Crash-safety (DESIGN.md §10): every job attempt runs behind a panic
//! boundary ([`super::runner::run_job_guarded`]), so a panicking job is
//! reported as a failure while the other workers keep draining the
//! queue; the queue lock is *recovered*, never unwrapped, so even a
//! poisoned mutex cannot cascade; transient errors are retried with a
//! deterministic bounded backoff; and the caller receives a
//! [`SweepOutcome`] carrying both results and per-job failures —
//! nothing is silently dropped.
//!
//! Memory note: the train pools are shared read-only via `Arc`; each
//! worker's executor/executable cache holds only the (model, loss,
//! batch) variants its jobs actually touch.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Duration;

use super::grid::Job;
use super::results::RunResult;
use super::runner::{run_job_guarded, JobData};
use crate::runtime::BackendSpec;

/// Progress callback: (finished, total, last result or error message).
pub type ProgressFn = Box<dyn FnMut(usize, usize, &str) + Send>;

/// Per-result callback (e.g. incremental JSONL persistence).
pub type OnResultFn = Box<dyn FnMut(&RunResult) + Send>;

/// Failpoint evaluated on the collector thread after each result is
/// recorded (journal append + progress): `exit` mode simulates a crash
/// with exactly N durable journal records.
pub const FP_RECORD: &str = "sweep.record";

/// One job that did not produce a result, with the error of its final
/// attempt.
#[derive(Debug, Clone)]
pub struct JobFailure {
    /// [`Job::id`] of the failed job (or `worker-N` for a worker that
    /// could not connect its backend).
    pub job_id: String,
    /// Error message of the last attempt.
    pub error: String,
    /// Attempts made (1 = no retries were possible or allowed).
    pub attempts: usize,
    /// The final attempt panicked (panics are never retried).
    pub panicked: bool,
}

/// Everything a sweep produced: completed results *and* failures.
/// Callers decide how loud to be about partial failure; the scheduler
/// no longer swallows errors when some jobs succeed.
#[derive(Debug)]
pub struct SweepOutcome {
    pub results: Vec<RunResult>,
    pub failures: Vec<JobFailure>,
}

/// Bounded retry with deterministic backoff for transient job errors.
/// Backoff for attempt `k` (1-based) is `base * 2^(k-1)` — deterministic
/// so reproducibility holds wall-clock-wise too; panics and unknown
/// datasets are permanent and never retried.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts per job (1 = no retry).
    pub max_attempts: usize,
    /// Base backoff before the second attempt.
    pub base_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_backoff: Duration::from_millis(50),
        }
    }
}

impl RetryPolicy {
    /// Backoff slept *after* failed attempt `attempt` (1-based).
    pub fn backoff_after(&self, attempt: usize) -> Duration {
        // cap the shift so a mis-configured policy cannot overflow
        self.base_backoff * (1u32 << (attempt - 1).min(16) as u32)
    }
}

/// Scheduler knobs beyond the job list.
#[derive(Default)]
pub struct SweepOptions {
    pub workers: usize,
    pub retry: RetryPolicy,
    pub progress: Option<ProgressFn>,
    pub on_result: Option<OnResultFn>,
}

/// Execute `jobs` on `workers` threads.  `datasets` maps dataset name →
/// shared data.  Failed jobs are retried per the default policy and
/// reported in the outcome.
pub fn run_sweep(
    backend: &BackendSpec,
    jobs: Vec<Job>,
    datasets: BTreeMap<String, JobData>,
    workers: usize,
    progress: Option<ProgressFn>,
) -> crate::Result<SweepOutcome> {
    run_sweep_opts(
        backend,
        jobs,
        datasets,
        SweepOptions {
            workers,
            progress,
            ..SweepOptions::default()
        },
    )
}

/// [`run_sweep`] with an additional per-result hook, invoked on the
/// collector thread in completion order.
pub fn run_sweep_with(
    backend: &BackendSpec,
    jobs: Vec<Job>,
    datasets: BTreeMap<String, JobData>,
    workers: usize,
    progress: Option<ProgressFn>,
    on_result: Option<OnResultFn>,
) -> crate::Result<SweepOutcome> {
    run_sweep_opts(
        backend,
        jobs,
        datasets,
        SweepOptions {
            workers,
            progress,
            on_result,
            ..SweepOptions::default()
        },
    )
}

/// Lock the queue, recovering from poisoning: the queue itself (a
/// `VecDeque` of plain data) is always in a consistent state between
/// `push`/`pop` calls, so a worker that panicked while holding the lock
/// cannot leave it mid-mutation — recovery is safe, and it keeps one
/// bad job from cascading into every worker.
fn lock_queue(queue: &Mutex<VecDeque<Job>>) -> MutexGuard<'_, VecDeque<Job>> {
    queue.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Full-control entry point: retry policy, progress and persistence
/// hooks.  Returns `Err` only when *no* job produced a result (total
/// loss); partial failure is data, in [`SweepOutcome::failures`].
pub fn run_sweep_opts(
    backend: &BackendSpec,
    jobs: Vec<Job>,
    datasets: BTreeMap<String, JobData>,
    options: SweepOptions,
) -> crate::Result<SweepOutcome> {
    let SweepOptions {
        workers,
        retry,
        mut progress,
        mut on_result,
    } = options;
    anyhow::ensure!(retry.max_attempts >= 1, "retry.max_attempts must be >= 1");
    let total = jobs.len();
    let queue = Arc::new(Mutex::new(VecDeque::from(jobs)));
    let datasets = Arc::new(datasets);
    let (tx, rx) = mpsc::channel::<Result<RunResult, JobFailure>>();
    let done = Arc::new(AtomicUsize::new(0));
    let workers = workers.clamp(1, total.max(1));

    // Job-level parallelism already saturates the cores: with several
    // workers, an auto-threaded (threads = 0) native backend would add
    // per-step data parallelism on top and oversubscribe the machine.
    // An explicit thread count in the spec is respected.
    let worker_spec = {
        let mut spec = backend.clone();
        if workers > 1 {
            if let BackendSpec::Native(native) = &mut spec {
                if native.threads == 0 {
                    native.threads = 1;
                }
            }
        }
        spec
    };

    let mut handles = Vec::with_capacity(workers);
    for worker_id in 0..workers {
        let queue = queue.clone();
        let datasets = datasets.clone();
        let tx = tx.clone();
        let spec = worker_spec.clone();
        let done = done.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("sweep-{worker_id}"))
                .spawn(move || {
                    // One backend per worker thread (the spec crosses
                    // threads; a connected backend may not).
                    let backend = match spec.connect() {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = tx.send(Err(JobFailure {
                                job_id: format!("worker-{worker_id}"),
                                error: format!("backend connect failed: {e:#}"),
                                attempts: 1,
                                panicked: false,
                            }));
                            return;
                        }
                    };
                    loop {
                        let job = match lock_queue(&queue).pop_front() {
                            Some(j) => j,
                            None => break,
                        };
                        let outcome = match datasets.get(&job.dataset) {
                            // permanent config error: no retry
                            None => Err(JobFailure {
                                job_id: job.id(),
                                error: "unknown dataset".into(),
                                attempts: 1,
                                panicked: false,
                            }),
                            Some(data) => {
                                let mut attempt = 1;
                                loop {
                                    match run_job_guarded(backend.as_ref(), &job, data) {
                                        Ok(r) => break Ok(r),
                                        Err(e) => {
                                            // panics are bugs, not transients
                                            let retryable =
                                                !e.panicked && attempt < retry.max_attempts;
                                            if !retryable {
                                                break Err(JobFailure {
                                                    job_id: job.id(),
                                                    error: e.to_string(),
                                                    attempts: attempt,
                                                    panicked: e.panicked,
                                                });
                                            }
                                            std::thread::sleep(retry.backoff_after(attempt));
                                            attempt += 1;
                                        }
                                    }
                                }
                            }
                        };
                        done.fetch_add(1, Ordering::Relaxed);
                        if tx.send(outcome).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn sweep worker"),
        );
    }
    drop(tx);

    let mut results = Vec::with_capacity(total);
    let mut failures = Vec::new();
    let mut record_fault = None;
    for outcome in rx {
        let finished = done.load(Ordering::Relaxed);
        match outcome {
            Ok(r) => {
                if let Some(h) = on_result.as_mut() {
                    h(&r);
                }
                if let Some(p) = progress.as_mut() {
                    let msg = format!(
                        "{} val_auc={:.4} test_auc={:.4}",
                        r.job.id(),
                        r.best_val_auc.unwrap_or(f64::NAN),
                        r.test_auc.unwrap_or(f64::NAN)
                    );
                    p(finished, total, &msg);
                }
                results.push(r);
                // The crash-simulation hook: hit N here == N results
                // durably journaled by the on_result hook above.
                if let Err(e) = crate::util::failpoint::check(FP_RECORD) {
                    record_fault = Some(e);
                    break;
                }
            }
            Err(f) => {
                if let Some(p) = progress.as_mut() {
                    let attempts = if f.attempts > 1 {
                        format!(" after {} attempts", f.attempts)
                    } else {
                        String::new()
                    };
                    p(finished, total, &format!("FAILED {}: {}{attempts}", f.job_id, f.error));
                }
                failures.push(f);
            }
        }
    }
    // Stop the workers before joining if the collector bailed early:
    // dropping the receiver makes every pending send fail, so workers
    // fall out of their loops instead of blocking forever.
    drop(rx);
    for h in handles {
        let _ = h.join();
    }
    if let Some(e) = record_fault {
        return Err(e.context("sweep aborted by record failpoint"));
    }
    if !failures.is_empty() && results.is_empty() && total > 0 {
        anyhow::bail!(
            "all {} jobs failed; first error: {}: {}",
            failures.len(),
            failures[0].job_id,
            failures[0].error
        );
    }
    Ok(SweepOutcome { results, failures })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::runtime::NativeSpec;
    use crate::sweep::runner::FP_RUN_JOB;
    use crate::util::failpoint;
    use std::sync::Arc;

    fn tiny_data(dim: usize, n: usize) -> JobData {
        let mut rng = crate::data::Rng::new(3);
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let pos = i % 4 == 0;
            y.push(if pos { 1.0 } else { 0.0 });
            for d in 0..dim {
                let shift = if pos && d < 2 { 1.5 } else { 0.0 };
                x.push(rng.normal() as f32 + shift);
            }
        }
        let set = Dataset::new(x, y, 0, dim);
        JobData {
            train_pool: Arc::new(set.clone()),
            test: Arc::new(set),
        }
    }

    fn tiny_job(seed: u32) -> Job {
        Job {
            dataset: "toy".into(),
            imratio: 0.2,
            loss: "hinge".parse().unwrap(),
            batch: 16,
            lr: 0.01,
            seed,
            model: "mlp".into(),
            epochs: 1,
            patience: None,
            sampling: "preserve".into(),
        }
    }

    fn native_spec(dim: usize) -> BackendSpec {
        BackendSpec::Native(NativeSpec {
            input_dim: dim,
            hidden: 4,
            threads: 1,
            ..NativeSpec::default()
        })
    }

    fn fast_retry(max_attempts: usize) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            base_backoff: Duration::from_millis(1),
        }
    }

    #[test]
    fn zero_workers_clamped_and_jobs_complete() {
        // failpoint state is process-global: any test that drives the
        // scheduler (and thus hits FP_RUN_JOB) must serialize against
        // the tests that arm it
        let _g = failpoint::serial_guard();
        let mut datasets = BTreeMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let jobs = vec![tiny_job(0), tiny_job(1)];
        let outcome = run_sweep(&native_spec(6), jobs, datasets, 0, None).unwrap();
        assert_eq!(outcome.results.len(), 2);
        assert!(outcome.failures.is_empty());
    }

    #[test]
    fn unknown_dataset_reports_failure() {
        let _g = failpoint::serial_guard();
        let mut datasets = BTreeMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let mut bad = tiny_job(0);
        bad.dataset = "missing".into();
        let bad_id = bad.id();
        let jobs = vec![bad, tiny_job(1)];
        let failures = Arc::new(AtomicUsize::new(0));
        let seen = failures.clone();
        let progress: ProgressFn = Box::new(move |_, _, msg| {
            if msg.starts_with("FAILED") {
                seen.fetch_add(1, Ordering::Relaxed);
            }
        });
        let outcome = run_sweep(&native_spec(6), jobs, datasets, 2, Some(progress)).unwrap();
        // the bad job is surfaced as a failure, the good one completes
        assert_eq!(outcome.results.len(), 1);
        assert_eq!(outcome.failures.len(), 1);
        assert_eq!(outcome.failures[0].job_id, bad_id);
        assert_eq!(outcome.failures[0].attempts, 1, "config errors are not retried");
        assert_eq!(failures.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn all_failed_is_an_error() {
        let datasets = BTreeMap::new(); // nothing registered
        let jobs = vec![tiny_job(0)];
        assert!(run_sweep(&native_spec(6), jobs, datasets, 1, None).is_err());
    }

    #[test]
    fn empty_job_list_is_a_clean_noop() {
        // resume with everything already journaled hits this path
        let outcome = run_sweep(&native_spec(6), vec![], BTreeMap::new(), 4, None).unwrap();
        assert!(outcome.results.is_empty());
        assert!(outcome.failures.is_empty());
    }

    #[test]
    fn transient_errors_are_retried_to_success() {
        let _g = failpoint::serial_guard();
        failpoint::arm_str(FP_RUN_JOB, "error@1x2").unwrap();
        let mut datasets = BTreeMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let outcome = run_sweep_opts(
            &native_spec(6),
            vec![tiny_job(0)],
            datasets,
            SweepOptions {
                workers: 1,
                retry: fast_retry(3),
                ..SweepOptions::default()
            },
        );
        failpoint::disarm(FP_RUN_JOB);
        let outcome = outcome.unwrap();
        // attempts 1 and 2 hit the failpoint; attempt 3 succeeds
        assert_eq!(outcome.results.len(), 1);
        assert!(outcome.failures.is_empty());
        assert_eq!(failpoint::hits(FP_RUN_JOB), 0, "disarmed");
    }

    #[test]
    fn exhausted_retries_report_failed_with_attempt_count() {
        let _g = failpoint::serial_guard();
        // fires on every one of job 1's three attempts; job 2 (hit 4) runs clean
        failpoint::arm_str(FP_RUN_JOB, "error@1x3").unwrap();
        let mut datasets = BTreeMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let outcome = run_sweep_opts(
            &native_spec(6),
            vec![tiny_job(0), tiny_job(1)],
            datasets,
            SweepOptions {
                workers: 1,
                retry: fast_retry(3),
                ..SweepOptions::default()
            },
        );
        failpoint::disarm(FP_RUN_JOB);
        let outcome = outcome.unwrap();
        assert_eq!(outcome.results.len(), 1);
        assert_eq!(outcome.failures.len(), 1);
        assert_eq!(outcome.failures[0].attempts, 3);
        assert!(!outcome.failures[0].panicked);
        assert!(outcome.failures[0].error.contains("failpoint"));
    }

    #[test]
    fn deterministic_backoff_schedule() {
        let p = RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(50),
        };
        assert_eq!(p.backoff_after(1), Duration::from_millis(50));
        assert_eq!(p.backoff_after(2), Duration::from_millis(100));
        assert_eq!(p.backoff_after(3), Duration::from_millis(200));
        // the shift is capped: no overflow panic on silly attempt counts
        let _ = p.backoff_after(10_000);
    }
}
