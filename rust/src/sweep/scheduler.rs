//! Multi-threaded sweep execution.
//!
//! Workers receive a [`BackendSpec`] (plain `Send + Sync` data) and
//! connect their own backend instance: the PJRT client is `Rc`-based
//! (not `Send`), so it cannot cross threads, and the native backend is
//! cheap to instantiate.  Jobs are pulled from a shared queue; results
//! stream back over a channel so the caller can persist incrementally
//! and print progress.
//!
//! Memory note: the train pools are shared read-only via `Arc`; each
//! worker's executor/executable cache holds only the (model, loss,
//! batch) variants its jobs actually touch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use super::grid::Job;
use super::results::RunResult;
use super::runner::{run_job, JobData};
use crate::runtime::BackendSpec;

/// Progress callback: (finished, total, last result or error message).
pub type ProgressFn = Box<dyn FnMut(usize, usize, &str) + Send>;

/// Per-result callback (e.g. incremental JSONL persistence).
pub type OnResultFn = Box<dyn FnMut(&RunResult) + Send>;

/// Execute `jobs` on `workers` threads.  `datasets` maps dataset name →
/// shared data.  Failed jobs are reported (not retried) and skipped.
pub fn run_sweep(
    backend: &BackendSpec,
    jobs: Vec<Job>,
    datasets: HashMap<String, JobData>,
    workers: usize,
    progress: Option<ProgressFn>,
) -> crate::Result<Vec<RunResult>> {
    run_sweep_with(backend, jobs, datasets, workers, progress, None)
}

/// [`run_sweep`] with an additional per-result hook, invoked on the
/// collector thread in completion order.
pub fn run_sweep_with(
    backend: &BackendSpec,
    jobs: Vec<Job>,
    datasets: HashMap<String, JobData>,
    workers: usize,
    mut progress: Option<ProgressFn>,
    mut on_result: Option<OnResultFn>,
) -> crate::Result<Vec<RunResult>> {
    let total = jobs.len();
    let queue = Arc::new(Mutex::new(std::collections::VecDeque::from(jobs)));
    let datasets = Arc::new(datasets);
    let (tx, rx) = mpsc::channel::<Result<RunResult, String>>();
    let done = Arc::new(AtomicUsize::new(0));
    let workers = workers.clamp(1, total.max(1));

    // Job-level parallelism already saturates the cores: with several
    // workers, an auto-threaded (threads = 0) native backend would add
    // per-step data parallelism on top and oversubscribe the machine.
    // An explicit thread count in the spec is respected.
    let worker_spec = {
        let mut spec = backend.clone();
        if workers > 1 {
            if let BackendSpec::Native(native) = &mut spec {
                if native.threads == 0 {
                    native.threads = 1;
                }
            }
        }
        spec
    };

    let mut handles = Vec::with_capacity(workers);
    for worker_id in 0..workers {
        let queue = queue.clone();
        let datasets = datasets.clone();
        let tx = tx.clone();
        let spec = worker_spec.clone();
        let done = done.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("sweep-{worker_id}"))
                .spawn(move || {
                    // One backend per worker thread (the spec crosses
                    // threads; a connected backend may not).
                    let backend = match spec.connect() {
                        Ok(b) => b,
                        Err(e) => {
                            let _ = tx.send(Err(format!("worker {worker_id}: {e}")));
                            return;
                        }
                    };
                    loop {
                        let job = {
                            let mut q = queue.lock().unwrap();
                            match q.pop_front() {
                                Some(j) => j,
                                None => break,
                            }
                        };
                        let outcome = match datasets.get(&job.dataset) {
                            None => Err(format!("{}: unknown dataset", job.id())),
                            Some(data) => run_job(backend.as_ref(), &job, data)
                                .map_err(|e| format!("{}: {e}", job.id())),
                        };
                        done.fetch_add(1, Ordering::Relaxed);
                        if tx.send(outcome).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn sweep worker"),
        );
    }
    drop(tx);

    let mut results = Vec::with_capacity(total);
    let mut errors = Vec::new();
    for outcome in rx {
        let finished = done.load(Ordering::Relaxed);
        match outcome {
            Ok(r) => {
                if let Some(h) = on_result.as_mut() {
                    h(&r);
                }
                if let Some(p) = progress.as_mut() {
                    let msg = format!(
                        "{} val_auc={:.4} test_auc={:.4}",
                        r.job.id(),
                        r.best_val_auc.unwrap_or(f64::NAN),
                        r.test_auc.unwrap_or(f64::NAN)
                    );
                    p(finished, total, &msg);
                }
                results.push(r);
            }
            Err(msg) => {
                if let Some(p) = progress.as_mut() {
                    p(finished, total, &format!("FAILED {msg}"));
                }
                errors.push(msg);
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if !errors.is_empty() && results.is_empty() {
        anyhow::bail!("all {} jobs failed; first error: {}", errors.len(), errors[0]);
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::runtime::NativeSpec;
    use std::sync::Arc;

    fn tiny_data(dim: usize, n: usize) -> JobData {
        let mut rng = crate::data::Rng::new(3);
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let pos = i % 4 == 0;
            y.push(if pos { 1.0 } else { 0.0 });
            for d in 0..dim {
                let shift = if pos && d < 2 { 1.5 } else { 0.0 };
                x.push(rng.normal() as f32 + shift);
            }
        }
        let set = Dataset::new(x, y, 0, dim);
        JobData {
            train_pool: Arc::new(set.clone()),
            test: Arc::new(set),
        }
    }

    fn tiny_job(seed: u32) -> Job {
        Job {
            dataset: "toy".into(),
            imratio: 0.2,
            loss: "hinge".parse().unwrap(),
            batch: 16,
            lr: 0.01,
            seed,
            model: "mlp".into(),
            epochs: 1,
            patience: None,
            sampling: "preserve".into(),
        }
    }

    fn native_spec(dim: usize) -> BackendSpec {
        BackendSpec::Native(NativeSpec {
            input_dim: dim,
            hidden: 4,
            threads: 1,
            ..NativeSpec::default()
        })
    }

    #[test]
    fn zero_workers_clamped_and_jobs_complete() {
        let mut datasets = HashMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let jobs = vec![tiny_job(0), tiny_job(1)];
        let results = run_sweep(&native_spec(6), jobs, datasets, 0, None).unwrap();
        assert_eq!(results.len(), 2);
    }

    #[test]
    fn unknown_dataset_reports_failure() {
        let mut datasets = HashMap::new();
        datasets.insert("toy".to_string(), tiny_data(6, 64));
        let mut bad = tiny_job(0);
        bad.dataset = "missing".into();
        let jobs = vec![bad, tiny_job(1)];
        let failures = Arc::new(AtomicUsize::new(0));
        let seen = failures.clone();
        let progress: ProgressFn = Box::new(move |_, _, msg| {
            if msg.starts_with("FAILED") {
                seen.fetch_add(1, Ordering::Relaxed);
            }
        });
        let results = run_sweep(&native_spec(6), jobs, datasets, 2, Some(progress)).unwrap();
        // the bad job is reported as FAILED and skipped, the good one completes
        assert_eq!(results.len(), 1);
        assert_eq!(failures.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn all_failed_is_an_error() {
        let datasets = HashMap::new(); // nothing registered
        let jobs = vec![tiny_job(0)];
        assert!(run_sweep(&native_spec(6), jobs, datasets, 1, None).is_err());
    }
}
