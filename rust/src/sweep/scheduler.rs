//! Multi-threaded sweep execution.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so parallelism is at the
//! *job* level with one full [`Runtime`] per worker thread.  Jobs are
//! pulled from a shared queue; results stream back over a channel so the
//! caller can persist incrementally and print progress.
//!
//! Memory note: the train pools are shared read-only via `Arc`; each
//! worker's executable cache holds only the (model, loss, batch) variants
//! its jobs actually touch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use super::grid::Job;
use super::results::RunResult;
use super::runner::{run_job, JobData};
use crate::runtime::Runtime;

/// Progress callback: (finished, total, last result or error message).
pub type ProgressFn = Box<dyn FnMut(usize, usize, &str) + Send>;

/// Per-result callback (e.g. incremental JSONL persistence).
pub type OnResultFn = Box<dyn FnMut(&RunResult) + Send>;

/// Execute `jobs` on `workers` threads.  `datasets` maps dataset name →
/// shared data.  Failed jobs are reported (not retried) and skipped.
pub fn run_sweep(
    artifacts_dir: &std::path::Path,
    jobs: Vec<Job>,
    datasets: HashMap<String, JobData>,
    workers: usize,
    progress: Option<ProgressFn>,
) -> crate::Result<Vec<RunResult>> {
    run_sweep_with(artifacts_dir, jobs, datasets, workers, progress, None)
}

/// [`run_sweep`] with an additional per-result hook, invoked on the
/// collector thread in completion order.
pub fn run_sweep_with(
    artifacts_dir: &std::path::Path,
    jobs: Vec<Job>,
    datasets: HashMap<String, JobData>,
    workers: usize,
    mut progress: Option<ProgressFn>,
    mut on_result: Option<OnResultFn>,
) -> crate::Result<Vec<RunResult>> {
    let total = jobs.len();
    let queue = Arc::new(Mutex::new(std::collections::VecDeque::from(jobs)));
    let datasets = Arc::new(datasets);
    let (tx, rx) = mpsc::channel::<Result<RunResult, String>>();
    let done = Arc::new(AtomicUsize::new(0));
    let workers = workers.max(1).min(total.max(1));

    let mut handles = Vec::with_capacity(workers);
    for worker_id in 0..workers {
        let queue = queue.clone();
        let datasets = datasets.clone();
        let tx = tx.clone();
        let dir = artifacts_dir.to_path_buf();
        let done = done.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("sweep-{worker_id}"))
                .spawn(move || {
                    // One PJRT runtime per worker thread.
                    let runtime = match Runtime::new(&dir) {
                        Ok(rt) => rt,
                        Err(e) => {
                            let _ = tx.send(Err(format!("worker {worker_id}: {e}")));
                            return;
                        }
                    };
                    loop {
                        let job = {
                            let mut q = queue.lock().unwrap();
                            match q.pop_front() {
                                Some(j) => j,
                                None => break,
                            }
                        };
                        let outcome = match datasets.get(&job.dataset) {
                            None => Err(format!("{}: unknown dataset", job.id())),
                            Some(data) => run_job(&runtime, &job, data)
                                .map_err(|e| format!("{}: {e}", job.id())),
                        };
                        done.fetch_add(1, Ordering::Relaxed);
                        if tx.send(outcome).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn sweep worker"),
        );
    }
    drop(tx);

    let mut results = Vec::with_capacity(total);
    let mut errors = Vec::new();
    for outcome in rx {
        let finished = done.load(Ordering::Relaxed);
        match outcome {
            Ok(r) => {
                if let Some(h) = on_result.as_mut() {
                    h(&r);
                }
                if let Some(p) = progress.as_mut() {
                    let msg = format!(
                        "{} val_auc={:.4} test_auc={:.4}",
                        r.job.id(),
                        r.best_val_auc.unwrap_or(f64::NAN),
                        r.test_auc.unwrap_or(f64::NAN)
                    );
                    p(finished, total, &msg);
                }
                results.push(r);
            }
            Err(msg) => {
                if let Some(p) = progress.as_mut() {
                    p(finished, total, &format!("FAILED {msg}"));
                }
                errors.push(msg);
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if !errors.is_empty() && results.is_empty() {
        anyhow::bail!("all {} jobs failed; first error: {}", errors.len(), errors[0]);
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    // The scheduler's queue/channel mechanics are covered by the
    // integration test (rust/tests/integration_sweep.rs) which needs real
    // artifacts; here we only test the pure helpers.

    #[test]
    fn worker_count_clamped() {
        // covered implicitly: run_sweep with 0 workers must still work via
        // the .max(1); compile-time presence test.
        assert_eq!(0usize.max(1).min(5), 1);
    }
}
