//! Hyper-parameter sweep engine (the paper's section 4.2 protocol).
//!
//! * [`grid`] — expands a [`crate::config::SweepConfig`] into the full
//!   cartesian job list (dataset × imratio × loss × batch × sampling
//!   mode × lr × seed).
//! * [`runner`] — runs one job end to end: imbalance the train pool,
//!   stratified 80/20 subtrain/validation split, stream stratified
//!   epochs with per-epoch validation AUC and optional early stopping,
//!   track the best-epoch state, and evaluate **test** AUC at that
//!   state.
//! * [`scheduler`] — executes the job list on worker threads; each
//!   worker connects its own backend from a shared
//!   [`crate::runtime::BackendSpec`] (the PJRT client is not `Send`).
//!   Hardened for long sweeps: panicking jobs are isolated behind
//!   `catch_unwind`, transient errors retried with deterministic
//!   backoff, and every failure surfaced in a
//!   [`scheduler::SweepOutcome`] (DESIGN.md §10).
//! * [`select`] — max-validation-AUC selection per (dataset, imratio,
//!   loss, seed), then the paper's aggregations: median selected
//!   hyper-parameters (Table 2) and mean ± sd test AUC (Figure 3).
//! * [`results`] — result records + JSONL persistence: an append-only
//!   journal with a lenient torn-tail loader, the substrate of
//!   `allpairs sweep --resume`.

pub mod grid;
pub mod results;
pub mod runner;
pub mod scheduler;
pub mod select;

pub use grid::Job;
pub use results::RunResult;
