//! Result records and JSONL persistence.
//!
//! The sweep journal (`sweep_results.jsonl`) is the crash-resume
//! substrate (DESIGN.md §10): one flushed line per completed job, opened
//! in *append* mode by resumed sweeps, replayed by the lenient loader
//! which recovers every complete line of a torn file and truncates the
//! partial tail so appends never concatenate onto garbage.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::util::json::Json;

use super::grid::Job;

/// Outcome of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub job: Job,
    /// Best validation AUC over epochs (None: undefined all run long).
    pub best_val_auc: Option<f64>,
    /// Epoch achieving it.
    pub best_epoch: Option<usize>,
    /// Test AUC of the best-epoch model state.
    pub test_auc: Option<f64>,
    /// Final-epoch mean training loss.
    pub final_train_loss: f64,
    /// Training diverged (non-finite loss observed).
    pub diverged: bool,
    /// Wall-clock seconds for the whole run.
    pub seconds: f64,
    /// Achieved positive fraction of the (imbalanced) train set.
    pub achieved_imratio: f64,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj([
            ("job", self.job.to_json()),
            ("best_val_auc", opt_num(self.best_val_auc)),
            (
                "best_epoch",
                opt_num(self.best_epoch.map(|e| e as f64)),
            ),
            ("test_auc", opt_num(self.test_auc)),
            (
                "final_train_loss",
                if self.final_train_loss.is_finite() {
                    Json::Num(self.final_train_loss)
                } else {
                    Json::Null // JSON has no NaN/Inf; Null = diverged
                },
            ),
            ("diverged", Json::Bool(self.diverged)),
            ("seconds", Json::Num(self.seconds)),
            ("achieved_imratio", Json::Num(self.achieved_imratio)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let opt_num = |k: &str| -> Option<f64> { j.get(k).and_then(|v| v.as_f64()) };
        Ok(RunResult {
            job: Job::from_json(j.req("job")?)?,
            best_val_auc: opt_num("best_val_auc"),
            best_epoch: opt_num("best_epoch").map(|e| e as usize),
            test_auc: opt_num("test_auc"),
            final_train_loss: opt_num("final_train_loss").unwrap_or(f64::NAN),
            diverged: j
                .get("diverged")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            seconds: opt_num("seconds").unwrap_or(0.0),
            achieved_imratio: opt_num("achieved_imratio").unwrap_or(f64::NAN),
        })
    }
}

/// Incremental JSONL writer: one line per result, flushed immediately,
/// so a truncated sweep (crash, budget kill) loses nothing completed.
pub struct JsonlWriter {
    file: std::io::BufWriter<std::fs::File>,
}

impl JsonlWriter {
    /// Create a *new* journal.  Refuses to clobber an existing file —
    /// restarting a sweep must never destroy the record that could
    /// resume it; rotate or use [`JsonlWriter::append_to`] instead.
    pub fn create(path: impl AsRef<Path>) -> crate::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(path.as_ref())
            .map_err(|e| {
                anyhow::anyhow!(
                    "journal {} already exists or cannot be created ({e}); \
                     rotate it or resume with append_to",
                    path.as_ref().display()
                )
            })?;
        Ok(Self {
            file: std::io::BufWriter::new(file),
        })
    }

    /// Open a journal in append mode (created if missing) — the
    /// `--resume` entry point: prior records are preserved verbatim.
    pub fn append_to(path: impl AsRef<Path>) -> crate::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self {
            file: std::io::BufWriter::new(
                std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(path)?,
            ),
        })
    }

    pub fn append(&mut self, result: &RunResult) -> crate::Result<()> {
        self.file.write_all(result.to_json().dumps().as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()?;
        Ok(())
    }
}

/// Write `results` as a complete JSONL file (atomic replace).
pub fn save_jsonl(path: impl AsRef<Path>, results: &[RunResult]) -> crate::Result<()> {
    let mut buf = String::new();
    for r in results {
        buf.push_str(&r.to_json().dumps());
        buf.push('\n');
    }
    crate::util::fsio::write_atomic(path, buf.as_bytes())
}

/// Load results from a JSONL file (strict: any malformed line is an
/// error).  Use [`load_jsonl_lenient`] to replay a possibly-torn
/// journal.
pub fn load_jsonl(path: impl AsRef<Path>) -> crate::Result<Vec<RunResult>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for line in f.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(RunResult::from_json(&Json::parse(&line)?)?);
    }
    Ok(out)
}

/// Outcome of a lenient journal replay.
#[derive(Debug)]
pub struct JournalReplay {
    /// Every record recovered from complete (parseable) lines.
    pub results: Vec<RunResult>,
    /// Byte length of the clean prefix: all recovered lines, each
    /// newline-terminated.  Everything past it is torn tail.
    pub clean_len: u64,
    /// Bytes past `clean_len` (0 = the journal was clean).
    pub torn_bytes: u64,
    /// The final recovered record parsed but lacked its newline (a
    /// crash between the write and the `\n`); repair re-terminates it.
    pub missing_newline: bool,
}

/// Replay a journal, tolerating a torn final record: every complete
/// line is recovered; an unparseable *tail* (truncated mid-record by a
/// crash) is measured, not fatal.  Corruption anywhere but the tail is
/// still a hard error — that is not what a crash produces.
pub fn load_jsonl_lenient(path: impl AsRef<Path>) -> crate::Result<JournalReplay> {
    let bytes = std::fs::read(path.as_ref())?;
    let mut results = Vec::new();
    let mut clean_len = 0u64; // end of the last good, terminated line
    let mut missing_newline = false;
    let mut offset = 0usize;
    while offset < bytes.len() {
        let (line_end, terminated) = match bytes[offset..].iter().position(|&b| b == b'\n') {
            Some(i) => (offset + i, true),
            None => (bytes.len(), false),
        };
        let raw = &bytes[offset..line_end];
        let next = if terminated { line_end + 1 } else { line_end };
        let is_blank = raw.iter().all(|b| b.is_ascii_whitespace());
        if is_blank {
            // blank lines are legal padding; they stay in the clean prefix
            if terminated {
                clean_len = next as u64;
            }
            offset = next;
            continue;
        }
        let parsed = std::str::from_utf8(raw)
            .map_err(anyhow::Error::from)
            .and_then(|text| Ok(RunResult::from_json(&Json::parse(text)?)?));
        match parsed {
            Ok(r) => {
                results.push(r);
                if terminated {
                    clean_len = next as u64;
                } else {
                    // recovered, but the journal ends without '\n':
                    // appending would concatenate onto this record.
                    clean_len = line_end as u64;
                    missing_newline = true;
                }
            }
            Err(e) => {
                // Only the *final* chunk of the file may be torn.
                anyhow::ensure!(
                    next >= bytes.len(),
                    "corrupt journal line at byte {offset} (not a torn tail): {e}"
                );
            }
        }
        offset = next;
    }
    // In the missing-newline case clean_len reaches the file end, so
    // torn_bytes is 0: nothing is dropped, only the '\n' restored.
    Ok(JournalReplay {
        results,
        torn_bytes: bytes.len() as u64 - clean_len,
        clean_len,
        missing_newline,
    })
}

/// Replay `path` leniently and repair it in place for appending: the
/// torn tail is truncated and a missing final newline restored, so the
/// next [`JsonlWriter::append_to`] writes a well-formed journal.
pub fn repair_journal(path: impl AsRef<Path>) -> crate::Result<JournalReplay> {
    let replay = load_jsonl_lenient(path.as_ref())?;
    if replay.torn_bytes > 0 || replay.missing_newline {
        let f = std::fs::OpenOptions::new().write(true).open(path.as_ref())?;
        f.set_len(replay.clean_len)?;
        f.sync_all()?;
        if replay.missing_newline {
            let mut f = std::fs::OpenOptions::new().append(true).open(path.as_ref())?;
            f.write_all(b"\n")?;
            f.sync_all()?;
        }
    }
    Ok(replay)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(seed: u32, auc: f64) -> RunResult {
        RunResult {
            job: Job {
                dataset: "synth-cifar".into(),
                imratio: 0.1,
                loss: "hinge".parse().unwrap(),
                batch: 50,
                lr: 0.01,
                seed,
                model: "resnet".into(),
                epochs: 2,
                patience: None,
                sampling: "preserve".into(),
            },
            best_val_auc: Some(auc),
            best_epoch: Some(1),
            test_auc: Some(auc - 0.02),
            final_train_loss: 0.4,
            diverged: false,
            seconds: 1.5,
            achieved_imratio: 0.099,
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("allpairs_results_{}_{name}", std::process::id()))
    }

    #[test]
    fn jsonl_roundtrip() {
        let path = tmp("roundtrip.jsonl");
        let _ = std::fs::remove_file(&path);
        let rs = vec![fake(0, 0.9), fake(1, 0.8)];
        save_jsonl(&path, &rs).unwrap();
        let back = load_jsonl(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].job.seed, 0);
        assert_eq!(back[1].best_val_auc, Some(0.8));
    }

    #[test]
    fn skips_blank_lines() {
        let path = tmp("blank.jsonl");
        let rs = vec![fake(0, 0.9)];
        save_jsonl(&path, &rs).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("\n\n");
        std::fs::write(&path, text).unwrap();
        assert_eq!(load_jsonl(&path).unwrap().len(), 1);
        let replay = load_jsonl_lenient(&path).unwrap();
        assert_eq!(replay.results.len(), 1);
        assert_eq!(replay.torn_bytes, 0);
    }

    #[test]
    fn create_refuses_to_clobber_and_append_preserves() {
        let path = tmp("noclobber.jsonl");
        let _ = std::fs::remove_file(&path);
        let mut w = JsonlWriter::create(&path).unwrap();
        w.append(&fake(0, 0.9)).unwrap();
        w.append(&fake(1, 0.8)).unwrap();
        drop(w);
        // a second `create` on the same path must fail, not truncate
        let err = JsonlWriter::create(&path).unwrap_err().to_string();
        assert!(err.contains("already exists"), "{err}");
        assert_eq!(load_jsonl(&path).unwrap().len(), 2, "create clobbered the journal");
        // append mode extends without touching prior records
        let before = std::fs::read(&path).unwrap();
        let mut w = JsonlWriter::append_to(&path).unwrap();
        w.append(&fake(2, 0.7)).unwrap();
        drop(w);
        let after = std::fs::read(&path).unwrap();
        assert!(after.starts_with(&before), "append rewrote prior bytes");
        let all = load_jsonl(&path).unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(all[2].job.seed, 2);
    }

    #[test]
    fn lenient_loader_recovers_clean_file_fully() {
        let path = tmp("lenient_clean.jsonl");
        save_jsonl(&path, &[fake(0, 0.9), fake(1, 0.8)]).unwrap();
        let replay = load_jsonl_lenient(&path).unwrap();
        assert_eq!(replay.results.len(), 2);
        assert_eq!(replay.torn_bytes, 0);
        assert!(!replay.missing_newline);
        assert_eq!(replay.clean_len, std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn lenient_loader_truncates_torn_tail() {
        let path = tmp("lenient_torn.jsonl");
        save_jsonl(&path, &[fake(0, 0.9), fake(1, 0.8)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let keep = bytes.len() - 17; // chop into the final record
        bytes.truncate(keep);
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_jsonl(&path).is_err(), "strict loader must reject the torn line");
        let replay = repair_journal(&path).unwrap();
        assert_eq!(replay.results.len(), 1);
        assert!(replay.torn_bytes > 0);
        // after repair: strict-loadable, and appendable
        assert_eq!(load_jsonl(&path).unwrap().len(), 1);
        let mut w = JsonlWriter::append_to(&path).unwrap();
        w.append(&fake(5, 0.5)).unwrap();
        drop(w);
        let all = load_jsonl(&path).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[1].job.seed, 5);
    }

    #[test]
    fn lenient_loader_restores_missing_final_newline() {
        let path = tmp("lenient_nonewline.jsonl");
        save_jsonl(&path, &[fake(0, 0.9), fake(1, 0.8)]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.pop(); // drop only the trailing '\n'
        std::fs::write(&path, &bytes).unwrap();
        let replay = repair_journal(&path).unwrap();
        assert_eq!(replay.results.len(), 2, "unterminated final record is recoverable");
        assert!(replay.missing_newline);
        let mut w = JsonlWriter::append_to(&path).unwrap();
        w.append(&fake(7, 0.6)).unwrap();
        drop(w);
        assert_eq!(load_jsonl(&path).unwrap().len(), 3);
    }

    #[test]
    fn lenient_loader_rejects_mid_file_corruption() {
        let path = tmp("lenient_midfile.jsonl");
        save_jsonl(&path, &[fake(0, 0.9), fake(1, 0.8)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // drop the quotes: an unquoted key is a JSON parse error
        let corrupted = text.replacen("\"diverged\"", "diverged", 1);
        std::fs::write(&path, corrupted).unwrap();
        // first line is broken but the file continues: not a torn tail
        assert!(load_jsonl_lenient(&path).is_err());
    }
}
