//! Result records and JSONL persistence.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::util::json::Json;

use super::grid::Job;

/// Outcome of one training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub job: Job,
    /// Best validation AUC over epochs (None: undefined all run long).
    pub best_val_auc: Option<f64>,
    /// Epoch achieving it.
    pub best_epoch: Option<usize>,
    /// Test AUC of the best-epoch model state.
    pub test_auc: Option<f64>,
    /// Final-epoch mean training loss.
    pub final_train_loss: f64,
    /// Training diverged (non-finite loss observed).
    pub diverged: bool,
    /// Wall-clock seconds for the whole run.
    pub seconds: f64,
    /// Achieved positive fraction of the (imbalanced) train set.
    pub achieved_imratio: f64,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::obj([
            ("job", self.job.to_json()),
            ("best_val_auc", opt_num(self.best_val_auc)),
            (
                "best_epoch",
                opt_num(self.best_epoch.map(|e| e as f64)),
            ),
            ("test_auc", opt_num(self.test_auc)),
            (
                "final_train_loss",
                if self.final_train_loss.is_finite() {
                    Json::Num(self.final_train_loss)
                } else {
                    Json::Null // JSON has no NaN/Inf; Null = diverged
                },
            ),
            ("diverged", Json::Bool(self.diverged)),
            ("seconds", Json::Num(self.seconds)),
            ("achieved_imratio", Json::Num(self.achieved_imratio)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let opt_num = |k: &str| -> Option<f64> { j.get(k).and_then(|v| v.as_f64()) };
        Ok(RunResult {
            job: Job::from_json(j.req("job")?)?,
            best_val_auc: opt_num("best_val_auc"),
            best_epoch: opt_num("best_epoch").map(|e| e as usize),
            test_auc: opt_num("test_auc"),
            final_train_loss: opt_num("final_train_loss").unwrap_or(f64::NAN),
            diverged: j
                .get("diverged")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            seconds: opt_num("seconds").unwrap_or(0.0),
            achieved_imratio: opt_num("achieved_imratio").unwrap_or(f64::NAN),
        })
    }
}

/// Incremental JSONL writer: one line per result, flushed immediately,
/// so a truncated sweep (crash, budget kill) loses nothing completed.
pub struct JsonlWriter {
    file: std::io::BufWriter<std::fs::File>,
}

impl JsonlWriter {
    pub fn create(path: impl AsRef<Path>) -> crate::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(Self {
            file: std::io::BufWriter::new(std::fs::File::create(path)?),
        })
    }

    pub fn append(&mut self, result: &RunResult) -> crate::Result<()> {
        self.file.write_all(result.to_json().dumps().as_bytes())?;
        self.file.write_all(b"\n")?;
        self.file.flush()?;
        Ok(())
    }
}

/// Append results to a JSONL file.
pub fn save_jsonl(path: impl AsRef<Path>, results: &[RunResult]) -> crate::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for r in results {
        f.write_all(r.to_json().dumps().as_bytes())?;
        f.write_all(b"\n")?;
    }
    f.flush()?;
    Ok(())
}

/// Load results from a JSONL file.
pub fn load_jsonl(path: impl AsRef<Path>) -> crate::Result<Vec<RunResult>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for line in f.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(RunResult::from_json(&Json::parse(&line)?)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(seed: u32, auc: f64) -> RunResult {
        RunResult {
            job: Job {
                dataset: "synth-cifar".into(),
                imratio: 0.1,
                loss: "hinge".parse().unwrap(),
                batch: 50,
                lr: 0.01,
                seed,
                model: "resnet".into(),
                epochs: 2,
                patience: None,
                sampling: "preserve".into(),
            },
            best_val_auc: Some(auc),
            best_epoch: Some(1),
            test_auc: Some(auc - 0.02),
            final_train_loss: 0.4,
            diverged: false,
            seconds: 1.5,
            achieved_imratio: 0.099,
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let path = std::env::temp_dir().join("allpairs_results_test.jsonl");
        let rs = vec![fake(0, 0.9), fake(1, 0.8)];
        save_jsonl(&path, &rs).unwrap();
        let back = load_jsonl(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].job.seed, 0);
        assert_eq!(back[1].best_val_auc, Some(0.8));
    }

    #[test]
    fn skips_blank_lines() {
        let path = std::env::temp_dir().join("allpairs_results_blank.jsonl");
        let rs = vec![fake(0, 0.9)];
        save_jsonl(&path, &rs).unwrap();
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("\n\n");
        std::fs::write(&path, text).unwrap();
        assert_eq!(load_jsonl(&path).unwrap().len(), 1);
    }
}
