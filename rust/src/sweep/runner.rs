//! End-to-end execution of a single sweep job.
//!
//! Protocol per job (paper section 4.2, streaming pipeline):
//!
//! 1. imbalance the shared train pool to `job.imratio`, seeded by
//!    [`Job::data_key`] (dataset, imratio, seed) so every run competing
//!    in the same selection group — across batch, lr, sampling mode and
//!    patience — sees the *identical* subset, and each seed removes a
//!    different random positive subset;
//! 2. stratified 80/20 subtrain/validation split (seeded likewise);
//! 3. [`Trainer::fit_stream`]: up to `job.epochs` stratified epochs
//!    under `job.sampling`, per-epoch validation AUC, best-checkpoint
//!    tracking, early stopping after `job.patience` stale epochs;
//! 4. restore the best checkpoint and evaluate **test** AUC on the
//!    balanced test set.
//!
//! The trainer consumes data through the [`crate::data::DatasetSource`]
//! seam, so this protocol runs unchanged over an out-of-core
//! [`crate::data::ShardedDataset`] (DESIGN.md §13) — `&Dataset` here is
//! just the resident implementation of that seam.

use std::sync::Arc;

use crate::data::{Dataset, Rng, SamplingMode, Split};
use crate::runtime::Backend;
use crate::train::{FitConfig, Trainer};

use super::grid::Job;
use super::results::RunResult;

/// Shared, read-only data for all jobs on one dataset.
#[derive(Debug, Clone)]
pub struct JobData {
    /// Balanced train pool (imbalanced per job).
    pub train_pool: Arc<Dataset>,
    /// Balanced test set.
    pub test: Arc<Dataset>,
}

/// Failpoint evaluated at the top of every job attempt (see
/// [`crate::util::failpoint`]): `error` mode exercises the scheduler's
/// retry path, `panic` mode its panic isolation, `exit` mode a hard
/// crash for end-to-end `--resume` tests.
pub const FP_RUN_JOB: &str = "sweep.run_job";

/// A failed job attempt, classified for the retry policy: panics are
/// bugs (never retried), plain errors may be transient.
#[derive(Debug, Clone)]
pub struct JobError {
    pub message: String,
    pub panicked: bool,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.panicked {
            write!(f, "panicked: {}", self.message)
        } else {
            f.write_str(&self.message)
        }
    }
}

/// [`run_job`] behind a panic boundary: a panicking job becomes a
/// reported [`JobError`] instead of unwinding the worker thread (which
/// would silently lose the job and, if the panic ever crossed a held
/// lock, poison the shared queue for every other worker).
pub fn run_job_guarded(backend: &dyn Backend, job: &Job, data: &JobData) -> Result<RunResult, JobError> {
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        crate::util::failpoint::check(FP_RUN_JOB)?;
        run_job(backend, job, data)
    }));
    match attempt {
        Ok(Ok(result)) => Ok(result),
        Ok(Err(e)) => Err(JobError {
            message: format!("{e:#}"),
            panicked: false,
        }),
        Err(payload) => Err(JobError {
            message: panic_message(payload),
            panicked: true,
        }),
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run one job to completion on the given backend.
pub fn run_job(backend: &dyn Backend, job: &Job, data: &JobData) -> crate::Result<RunResult> {
    let t0 = std::time::Instant::now();
    // Data stream: keyed by (dataset, imratio, seed) ONLY, so jobs that
    // differ in batch/lr/sampling/patience train on identical data.
    let mut data_rng = Rng::new(0x5EED ^ fnv(&job.data_key()));
    let train = data.train_pool.imbalance(job.imratio, &mut data_rng.fork(1));
    let achieved_imratio = train.pos_fraction();
    let split = Split::stratified(&train.y, 0.2, &mut data_rng.fork(2));

    let mut trainer = Trainer::new(backend, &job.model, &job.loss, job.batch)?;
    let fit_cfg = FitConfig {
        lr: job.lr as f32,
        epochs: job.epochs,
        patience: job.patience,
        sampling: SamplingMode::parse(&job.sampling)?,
        seed: job.seed,
    };
    // Epoch stream: per full job id (reshuffle order may differ across
    // hyper-parameter combinations; the data above does not).
    let mut epoch_rng = Rng::new(0xE90C ^ fnv(&job.id()));
    let outcome = trainer.fit_stream(
        &train,
        &split.subtrain,
        &split.validation,
        &fit_cfg,
        &mut epoch_rng,
    )?;

    // Test AUC at the best-validation-AUC checkpoint.
    let (best_val_auc, best_epoch, test_auc) = match &outcome.best {
        Some(best) => {
            trainer.load_state(&best.state)?;
            let test_indices: Vec<u32> = (0..data.test.len() as u32).collect();
            let t_auc = trainer.eval_auc(&data.test, &test_indices)?;
            (Some(best.val_auc), Some(best.epoch), t_auc)
        }
        None => (None, None, None),
    };

    Ok(RunResult {
        job: job.clone(),
        best_val_auc,
        best_epoch,
        test_auc,
        final_train_loss: outcome
            .history
            .records
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN),
        diverged: outcome.diverged,
        seconds: t0.elapsed().as_secs_f64(),
        achieved_imratio,
    })
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325_u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
