//! End-to-end execution of a single sweep job.
//!
//! Protocol per job (paper section 4.2):
//!
//! 1. imbalance the shared train pool to `job.imratio` (seeded by
//!    `job.seed` — each seed removes a different random positive subset);
//! 2. stratified 80/20 subtrain/validation split (seeded likewise);
//! 3. train `job.epochs` epochs; after each epoch compute validation AUC
//!    and snapshot the state to host whenever it improves;
//! 4. restore the best state and evaluate **test** AUC on the balanced
//!    test set.

use std::sync::Arc;

use crate::data::{Dataset, Rng, Split};
use crate::runtime::Backend;
use crate::train::{EpochRecord, History, Trainer};

use super::grid::Job;
use super::results::RunResult;

/// Shared, read-only data for all jobs on one dataset.
#[derive(Debug, Clone)]
pub struct JobData {
    /// Balanced train pool (imbalanced per job).
    pub train_pool: Arc<Dataset>,
    /// Balanced test set.
    pub test: Arc<Dataset>,
}

/// Run one job to completion on the given backend.
pub fn run_job(backend: &dyn Backend, job: &Job, data: &JobData) -> crate::Result<RunResult> {
    let t0 = std::time::Instant::now();
    // Seed streams: independent per (job id), reproducible across runs.
    let mut rng = Rng::new(0x5EED ^ fnv(&job.id()));
    let train = data.train_pool.imbalance(job.imratio, &mut rng.fork(1));
    let achieved_imratio = train.pos_fraction();
    let split = Split::stratified(&train.y, 0.2, &mut rng.fork(2));

    let mut trainer = Trainer::new(backend, &job.model, &job.loss, job.batch)?;
    trainer.init(job.seed)?;

    let mut history = History::new();
    let mut best: Option<(f64, usize, Vec<crate::runtime::HostTensor>)> = None;
    let mut epoch_rng = rng.fork(3);
    let mut diverged = false;
    for epoch in 0..job.epochs {
        let te = std::time::Instant::now();
        let stats = trainer.train_epoch(&train, &split.subtrain, job.lr as f32, &mut epoch_rng)?;
        if !stats.mean_loss.is_finite() {
            diverged = true;
            history.push(EpochRecord {
                epoch,
                train_loss: stats.mean_loss,
                val_auc: None,
                seconds: te.elapsed().as_secs_f64(),
            });
            break;
        }
        let val_auc = trainer.eval_auc(&train, &split.validation)?;
        if let Some(v) = val_auc {
            let improved = best.as_ref().map(|(b, _, _)| v > *b).unwrap_or(true);
            if improved {
                best = Some((v, epoch, trainer.state_to_host()?));
            }
        }
        history.push(EpochRecord {
            epoch,
            train_loss: stats.mean_loss,
            val_auc,
            seconds: te.elapsed().as_secs_f64(),
        });
    }

    // Test AUC at the best-validation-AUC state.
    let (best_val_auc, best_epoch, test_auc) = match best {
        Some((v, e, state)) => {
            trainer.load_state(&state)?;
            let test_indices: Vec<u32> = (0..data.test.len() as u32).collect();
            let t_auc = trainer.eval_auc(&data.test, &test_indices)?;
            (Some(v), Some(e), t_auc)
        }
        None => (None, None, None),
    };

    Ok(RunResult {
        job: job.clone(),
        best_val_auc,
        best_epoch,
        test_auc,
        final_train_loss: history
            .records
            .last()
            .map(|r| r.train_loss)
            .unwrap_or(f64::NAN),
        diverged,
        seconds: t0.elapsed().as_secs_f64(),
        achieved_imratio,
    })
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325_u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
