//! Model selection and aggregation: from raw run results to the paper's
//! Table 2 (median selected hyper-parameters) and Figure 3 (test AUC
//! mean ± sd) entries.
//!
//! Per (dataset, imratio, loss, **seed**) the winning run is the one with
//! the highest validation AUC over the whole (batch, lr, epoch) grid —
//! exactly the paper's "the parameter combination and number of epochs
//! that achieved the maximum validation AUC was selected".  Aggregation
//! over seeds then reports the *median* selected batch and learning rate
//! (Table 2) and the *mean ± sd* test AUC (Figure 3).

use std::collections::BTreeMap;

use crate::metrics::Summary;

use super::results::RunResult;

/// The per-seed winner of one selection group.
#[derive(Debug, Clone)]
pub struct SeedSelection {
    pub dataset: String,
    pub imratio: f64,
    pub loss: String,
    pub seed: u32,
    pub batch: usize,
    pub lr: f64,
    pub best_epoch: Option<usize>,
    pub val_auc: f64,
    pub test_auc: Option<f64>,
}

/// Aggregated cell: one (dataset, imratio, loss) entry of Table 2 / Fig 3.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: String,
    pub imratio: f64,
    pub loss: String,
    /// Median selected batch size over seeds (Table 2).
    pub median_batch: f64,
    /// Median selected learning rate over seeds (Table 2).
    pub median_lr: f64,
    /// Test AUC summary over seeds (Figure 3: mean ± sd).
    pub test_auc: Summary,
    /// Number of seeds with a defined winner.
    pub n_seeds: usize,
}

/// Group key ordering: dataset, imratio (desc, paper order), loss.
fn cell_key(dataset: &str, imratio: f64, loss: &str) -> (String, i64, String) {
    // negate so BTreeMap iterates imratio descending (0.1, 0.01, 0.001)
    (
        dataset.to_string(),
        -(imratio * 1e9) as i64,
        loss.to_string(),
    )
}

/// Per-seed max-validation-AUC selection.
pub fn select_per_seed(results: &[RunResult]) -> Vec<SeedSelection> {
    let mut best: BTreeMap<(String, i64, String, u32), &RunResult> = BTreeMap::new();
    for r in results {
        let Some(val) = r.best_val_auc else { continue };
        let key = (
            r.job.dataset.clone(),
            -(r.job.imratio * 1e9) as i64,
            r.job.loss.to_string(),
            r.job.seed,
        );
        let replace = match best.get(&key) {
            None => true,
            Some(cur) => val > cur.best_val_auc.unwrap(),
        };
        if replace {
            best.insert(key, r);
        }
    }
    best.into_values()
        .map(|r| SeedSelection {
            dataset: r.job.dataset.clone(),
            imratio: r.job.imratio,
            loss: r.job.loss.to_string(),
            seed: r.job.seed,
            batch: r.job.batch,
            lr: r.job.lr,
            best_epoch: r.best_epoch,
            val_auc: r.best_val_auc.unwrap(),
            test_auc: r.test_auc,
        })
        .collect()
}

/// Aggregate per-seed selections into Table 2 / Figure 3 cells.
pub fn aggregate(selections: &[SeedSelection]) -> Vec<Cell> {
    let mut groups: BTreeMap<(String, i64, String), Vec<&SeedSelection>> = BTreeMap::new();
    for s in selections {
        groups
            .entry(cell_key(&s.dataset, s.imratio, &s.loss))
            .or_default()
            .push(s);
    }
    groups
        .into_values()
        .map(|sels| {
            let batches = Summary::from_values(sels.iter().map(|s| s.batch as f64));
            let lrs = Summary::from_values(sels.iter().map(|s| s.lr));
            let aucs = Summary::from_values(sels.iter().filter_map(|s| s.test_auc));
            let first = sels[0];
            Cell {
                dataset: first.dataset.clone(),
                imratio: first.imratio,
                loss: first.loss.clone(),
                median_batch: batches.median(),
                median_lr: lrs.median(),
                test_auc: aucs,
                n_seeds: sels.len(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::grid::Job;

    fn result(
        loss: &str,
        imratio: f64,
        batch: usize,
        lr: f64,
        seed: u32,
        val: f64,
        test: f64,
    ) -> RunResult {
        RunResult {
            job: Job {
                dataset: "d".into(),
                imratio,
                loss: loss.parse().unwrap(),
                batch,
                lr,
                seed,
                model: "resnet".into(),
                epochs: 2,
                patience: None,
                sampling: "preserve".into(),
            },
            best_val_auc: Some(val),
            best_epoch: Some(1),
            test_auc: Some(test),
            final_train_loss: 0.1,
            diverged: false,
            seconds: 1.0,
            achieved_imratio: imratio,
        }
    }

    #[test]
    fn picks_max_val_auc_within_seed() {
        let rs = vec![
            result("hinge", 0.1, 10, 0.01, 0, 0.80, 0.78),
            result("hinge", 0.1, 500, 0.1, 0, 0.92, 0.90), // winner
            result("hinge", 0.1, 100, 0.01, 0, 0.85, 0.84),
        ];
        let sel = select_per_seed(&rs);
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].batch, 500);
        assert_eq!(sel[0].test_auc, Some(0.90));
    }

    #[test]
    fn seeds_selected_independently() {
        let rs = vec![
            result("hinge", 0.1, 10, 0.01, 0, 0.9, 0.88),
            result("hinge", 0.1, 500, 0.1, 0, 0.7, 0.69),
            result("hinge", 0.1, 10, 0.01, 1, 0.6, 0.59),
            result("hinge", 0.1, 500, 0.1, 1, 0.8, 0.82),
        ];
        let sel = select_per_seed(&rs);
        assert_eq!(sel.len(), 2);
        let by_seed: std::collections::HashMap<u32, usize> =
            sel.iter().map(|s| (s.seed, s.batch)).collect();
        assert_eq!(by_seed[&0], 10);
        assert_eq!(by_seed[&1], 500);
    }

    #[test]
    fn undefined_val_auc_runs_ignored() {
        let mut bad = result("hinge", 0.1, 10, 0.01, 0, 0.0, 0.0);
        bad.best_val_auc = None;
        let good = result("hinge", 0.1, 50, 0.01, 0, 0.7, 0.7);
        let sel = select_per_seed(&[bad, good]);
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].batch, 50);
    }

    #[test]
    fn aggregation_medians_and_means() {
        let rs = vec![
            result("hinge", 0.01, 10, 0.001, 0, 0.9, 0.80),
            result("hinge", 0.01, 500, 0.1, 1, 0.9, 0.90),
            result("hinge", 0.01, 1000, 0.0316, 2, 0.9, 0.85),
        ];
        let cells = aggregate(&select_per_seed(&rs));
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.median_batch, 500.0);
        assert!((c.test_auc.mean() - 0.85).abs() < 1e-12);
        assert_eq!(c.n_seeds, 3);
    }

    #[test]
    fn cells_ordered_paper_style() {
        let rs = vec![
            result("hinge", 0.001, 10, 0.01, 0, 0.6, 0.55),
            result("hinge", 0.1, 10, 0.01, 0, 0.9, 0.88),
            result("hinge", 0.01, 10, 0.01, 0, 0.8, 0.75),
        ];
        let cells = aggregate(&select_per_seed(&rs));
        let ratios: Vec<f64> = cells.iter().map(|c| c.imratio).collect();
        assert_eq!(ratios, vec![0.1, 0.01, 0.001]);
    }
}
