//! Model selection and aggregation: from raw run results to the paper's
//! Table 2 (median selected hyper-parameters) and Figure 3 (test AUC
//! mean ± sd) entries.
//!
//! Per (dataset, imratio, loss, **seed**) the winning run is the one with
//! the highest validation AUC over the whole (batch, lr, epoch) grid —
//! exactly the paper's "the parameter combination and number of epochs
//! that achieved the maximum validation AUC was selected".  Aggregation
//! over seeds then reports the *median* selected batch and learning rate
//! (Table 2) and the *mean ± sd* test AUC (Figure 3).

use std::cmp::Ordering;
use std::collections::BTreeMap;

use crate::metrics::Summary;

use super::grid::Job;
use super::results::RunResult;

/// The per-seed winner of one selection group.
#[derive(Debug, Clone)]
pub struct SeedSelection {
    pub dataset: String,
    pub imratio: f64,
    pub loss: String,
    pub seed: u32,
    pub batch: usize,
    pub lr: f64,
    pub best_epoch: Option<usize>,
    pub val_auc: f64,
    pub test_auc: Option<f64>,
}

/// Aggregated cell: one (dataset, imratio, loss) entry of Table 2 / Fig 3.
#[derive(Debug, Clone)]
pub struct Cell {
    pub dataset: String,
    pub imratio: f64,
    pub loss: String,
    /// Median selected batch size over seeds (Table 2).
    pub median_batch: f64,
    /// Median selected learning rate over seeds (Table 2).
    pub median_lr: f64,
    /// Test AUC summary over seeds (Figure 3: mean ± sd).
    pub test_auc: Summary,
    /// Number of seeds with a defined winner.
    pub n_seeds: usize,
}

/// Group key ordering: dataset, imratio (desc, paper order), loss.
fn cell_key(dataset: &str, imratio: f64, loss: &str) -> (String, i64, String) {
    // negate so BTreeMap iterates imratio descending (0.1, 0.01, 0.001)
    (
        dataset.to_string(),
        -(imratio * 1e9) as i64,
        loss.to_string(),
    )
}

/// Monotone, order-preserving `u64` image of an `f64` — the classic
/// sign-flip bit transform, consistent with [`f64::total_cmp`].  Lets a
/// float participate in a totally ordered (`Ord`) tuple key.
fn f64_order_key(v: f64) -> u64 {
    let b = v.to_bits() as i64;
    (if b < 0 { !b } else { b ^ i64::MIN }) as u64
}

/// Total, order-independent tie-break key over a job's grid coordinates.
/// On an exact validation-AUC tie the record whose key sorts *first*
/// wins, whatever order the journal presents the records in.  (Not
/// `Job::id()`: its `{:.0e}` learning-rate formatting can collide for
/// distinct grid points, which would make the key non-total.)
fn tie_key(job: &Job) -> (usize, u64, usize, usize, &str, &str) {
    (
        job.batch,
        f64_order_key(job.lr),
        job.epochs,
        job.patience.map_or(0, |p| p.saturating_add(1)),
        job.sampling.as_str(),
        job.model.as_str(),
    )
}

/// Per-seed max-validation-AUC selection.
///
/// Exact ties are broken by [`tie_key`], a total order over the job's
/// grid coordinates, so the selected model is a pure function of the
/// record *set* — `sweep --resume` appends completed-last jobs at the
/// journal tail, and an order-dependent tie-break would let a resumed
/// run select a different model than the uninterrupted run it must
/// match (DESIGN.md §10 resume equivalence).
pub fn select_per_seed(results: &[RunResult]) -> Vec<SeedSelection> {
    let mut best: BTreeMap<(String, i64, String, u32), &RunResult> = BTreeMap::new();
    for r in results {
        let Some(val) = r.best_val_auc else { continue };
        let key = (
            r.job.dataset.clone(),
            -(r.job.imratio * 1e9) as i64,
            r.job.loss.to_string(),
            r.job.seed,
        );
        let replace = match best.get(&key) {
            None => true,
            Some(cur) => match val.total_cmp(&cur.best_val_auc.unwrap()) {
                Ordering::Greater => true,
                Ordering::Less => false,
                Ordering::Equal => tie_key(&r.job) < tie_key(&cur.job),
            },
        };
        if replace {
            best.insert(key, r);
        }
    }
    best.into_values()
        .map(|r| SeedSelection {
            dataset: r.job.dataset.clone(),
            imratio: r.job.imratio,
            loss: r.job.loss.to_string(),
            seed: r.job.seed,
            batch: r.job.batch,
            lr: r.job.lr,
            best_epoch: r.best_epoch,
            val_auc: r.best_val_auc.unwrap(),
            test_auc: r.test_auc,
        })
        .collect()
}

/// Aggregate per-seed selections into Table 2 / Figure 3 cells.
pub fn aggregate(selections: &[SeedSelection]) -> Vec<Cell> {
    let mut groups: BTreeMap<(String, i64, String), Vec<&SeedSelection>> = BTreeMap::new();
    for s in selections {
        groups
            .entry(cell_key(&s.dataset, s.imratio, &s.loss))
            .or_default()
            .push(s);
    }
    groups
        .into_values()
        .map(|sels| {
            let batches = Summary::from_values(sels.iter().map(|s| s.batch as f64));
            let lrs = Summary::from_values(sels.iter().map(|s| s.lr));
            let aucs = Summary::from_values(sels.iter().filter_map(|s| s.test_auc));
            let first = sels[0];
            Cell {
                dataset: first.dataset.clone(),
                imratio: first.imratio,
                loss: first.loss.clone(),
                median_batch: batches.median(),
                median_lr: lrs.median(),
                test_auc: aucs,
                n_seeds: sels.len(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::grid::Job;

    fn result(
        loss: &str,
        imratio: f64,
        batch: usize,
        lr: f64,
        seed: u32,
        val: f64,
        test: f64,
    ) -> RunResult {
        RunResult {
            job: Job {
                dataset: "d".into(),
                imratio,
                loss: loss.parse().unwrap(),
                batch,
                lr,
                seed,
                model: "resnet".into(),
                epochs: 2,
                patience: None,
                sampling: "preserve".into(),
            },
            best_val_auc: Some(val),
            best_epoch: Some(1),
            test_auc: Some(test),
            final_train_loss: 0.1,
            diverged: false,
            seconds: 1.0,
            achieved_imratio: imratio,
        }
    }

    #[test]
    fn picks_max_val_auc_within_seed() {
        let rs = vec![
            result("hinge", 0.1, 10, 0.01, 0, 0.80, 0.78),
            result("hinge", 0.1, 500, 0.1, 0, 0.92, 0.90), // winner
            result("hinge", 0.1, 100, 0.01, 0, 0.85, 0.84),
        ];
        let sel = select_per_seed(&rs);
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].batch, 500);
        assert_eq!(sel[0].test_auc, Some(0.90));
    }

    #[test]
    fn seeds_selected_independently() {
        let rs = vec![
            result("hinge", 0.1, 10, 0.01, 0, 0.9, 0.88),
            result("hinge", 0.1, 500, 0.1, 0, 0.7, 0.69),
            result("hinge", 0.1, 10, 0.01, 1, 0.6, 0.59),
            result("hinge", 0.1, 500, 0.1, 1, 0.8, 0.82),
        ];
        let sel = select_per_seed(&rs);
        assert_eq!(sel.len(), 2);
        let by_seed: std::collections::HashMap<u32, usize> =
            sel.iter().map(|s| (s.seed, s.batch)).collect();
        assert_eq!(by_seed[&0], 10);
        assert_eq!(by_seed[&1], 500);
    }

    #[test]
    fn tied_val_auc_selects_order_independently() {
        // Three records tied at val AUC 0.9 for seed 0 (plus a control
        // group at seed 1): whatever order the journal presents them
        // in — an uninterrupted run, or a resumed run with the
        // completed-last jobs appended at the tail — the selection must
        // be identical.  The tie-break is the smallest (batch, lr, ...)
        // grid key.
        use crate::data::Rng;
        let mut rs = vec![
            result("hinge", 0.1, 500, 0.1, 0, 0.9, 0.81),
            result("hinge", 0.1, 10, 0.0316, 0, 0.9, 0.82),
            result("hinge", 0.1, 10, 0.01, 0, 0.9, 0.83), // tie winner
            result("hinge", 0.1, 100, 0.01, 1, 0.7, 0.65),
        ];
        let snapshot = |rs: &[RunResult]| -> Vec<(u32, usize, f64, Option<f64>)> {
            select_per_seed(rs)
                .into_iter()
                .map(|s| (s.seed, s.batch, s.lr, s.test_auc))
                .collect()
        };
        let want = snapshot(&rs);
        assert_eq!(want.len(), 2);
        assert_eq!(
            (want[0].1, want[0].2, want[0].3),
            (10, 0.01, Some(0.83)),
            "smallest grid key wins the tie"
        );
        let mut rng = Rng::new(42);
        for round in 0..50 {
            // Fisher–Yates on the repo Rng: every permutation reachable.
            for i in (1..rs.len()).rev() {
                let j = rng.below(i + 1);
                rs.swap(i, j);
            }
            assert_eq!(snapshot(&rs), want, "permutation round {round}");
        }
    }

    #[test]
    fn higher_val_auc_still_beats_any_tie_key() {
        // The tie-break only applies on *exact* ties: a strictly higher
        // validation AUC wins regardless of grid position.
        let rs = vec![
            result("hinge", 0.1, 10, 0.01, 0, 0.90, 0.80), // smaller key
            result("hinge", 0.1, 500, 0.1, 0, 0.91, 0.89), // higher AUC
        ];
        let sel = select_per_seed(&rs);
        assert_eq!(sel.len(), 1);
        assert_eq!((sel[0].batch, sel[0].test_auc), (500, Some(0.89)));
    }

    #[test]
    fn undefined_val_auc_runs_ignored() {
        let mut bad = result("hinge", 0.1, 10, 0.01, 0, 0.0, 0.0);
        bad.best_val_auc = None;
        let good = result("hinge", 0.1, 50, 0.01, 0, 0.7, 0.7);
        let sel = select_per_seed(&[bad, good]);
        assert_eq!(sel.len(), 1);
        assert_eq!(sel[0].batch, 50);
    }

    #[test]
    fn aggregation_medians_and_means() {
        let rs = vec![
            result("hinge", 0.01, 10, 0.001, 0, 0.9, 0.80),
            result("hinge", 0.01, 500, 0.1, 1, 0.9, 0.90),
            result("hinge", 0.01, 1000, 0.0316, 2, 0.9, 0.85),
        ];
        let cells = aggregate(&select_per_seed(&rs));
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.median_batch, 500.0);
        assert!((c.test_auc.mean() - 0.85).abs() < 1e-12);
        assert_eq!(c.n_seeds, 3);
    }

    #[test]
    fn cells_ordered_paper_style() {
        let rs = vec![
            result("hinge", 0.001, 10, 0.01, 0, 0.6, 0.55),
            result("hinge", 0.1, 10, 0.01, 0, 0.9, 0.88),
            result("hinge", 0.01, 10, 0.01, 0, 0.8, 0.75),
        ];
        let cells = aggregate(&select_per_seed(&rs));
        let ratios: Vec<f64> = cells.iter().map(|c| c.imratio).collect();
        assert_eq!(ratios, vec![0.1, 0.01, 0.001]);
    }
}
