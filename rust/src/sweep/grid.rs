//! Cartesian expansion of the sweep configuration into jobs.

use crate::config::SweepConfig;
use crate::losses::LossSpec;
use crate::util::json::Json;

/// One training run to schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub dataset: String,
    pub imratio: f64,
    /// Typed loss spec (serialized as its spec string, e.g. `"hinge"` or
    /// `"hinge@margin=2"` — pre-redesign JSONL lines parse unchanged).
    pub loss: LossSpec,
    pub batch: usize,
    pub lr: f64,
    pub seed: u32,
    pub model: String,
    pub epochs: usize,
    /// Early-stopping patience (None = fixed-epoch protocol).
    pub patience: Option<usize>,
    /// Mini-batch sampling mode name (see
    /// [`crate::data::SamplingMode::parse`]); a sweepable axis.
    pub sampling: String,
}

impl Job {
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("dataset", Json::str(&self.dataset)),
            ("imratio", Json::num(self.imratio)),
            ("loss", Json::str(self.loss.to_string())),
            ("batch", Json::num(self.batch as f64)),
            ("lr", Json::num(self.lr)),
            ("seed", Json::num(self.seed as f64)),
            ("model", Json::str(&self.model)),
            ("epochs", Json::num(self.epochs as f64)),
            (
                "patience",
                match self.patience {
                    Some(p) => Json::num(p as f64),
                    None => Json::Null,
                },
            ),
            ("sampling", Json::str(&self.sampling)),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let s = |k: &str| -> crate::Result<String> {
            Ok(j.req(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("{k} must be string"))?
                .to_string())
        };
        let n = |k: &str| -> crate::Result<f64> {
            j.req(k)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{k} must be number"))
        };
        Ok(Job {
            dataset: s("dataset")?,
            imratio: n("imratio")?,
            // spec strings are validated right here, at parse time
            loss: s("loss")?.parse::<LossSpec>()?,
            batch: n("batch")? as usize,
            lr: n("lr")?,
            seed: n("seed")? as u32,
            model: s("model")?,
            epochs: n("epochs")? as usize,
            // absent in pre-streaming JSONL files: default to the old
            // fixed-epoch, plain-shuffle behavior
            patience: j.get("patience").and_then(|v| v.as_usize()),
            sampling: j
                .get("sampling")
                .and_then(|v| v.as_str())
                .unwrap_or("preserve")
                .to_string(),
        })
    }

    /// Stable id for logs and result files.  Streaming knobs appear
    /// only when non-default, so pre-streaming ids are unchanged.
    pub fn id(&self) -> String {
        let mut id = format!(
            "{}_im{}_{}_bs{}_lr{:.0e}_s{}",
            self.dataset, self.imratio, self.loss, self.batch, self.lr, self.seed
        );
        if self.sampling != "preserve" {
            id.push('_');
            id.push_str(&self.sampling);
        }
        if let Some(p) = self.patience {
            id.push_str(&format!("_pat{p}"));
        }
        id
    }

    /// Key of the *data* a job sees: dataset, imratio and seed — and
    /// nothing else.  Jobs differing only in training hyper-parameters
    /// (batch, lr, sampling, patience) must train and validate on the
    /// identical imbalanced subset and split, or hyper-parameter
    /// comparisons confound data with the knob under study.
    pub fn data_key(&self) -> String {
        format!("{}_im{}_s{}", self.dataset, self.imratio, self.seed)
    }

    /// Selection group: runs competing for the same Table-2 cell.
    pub fn group(&self) -> (String, String, String, u32) {
        (
            self.dataset.clone(),
            format!("{}", self.imratio),
            self.loss.to_string(),
            self.seed,
        )
    }
}

/// Expand the config into the full job list (deterministic order).
///
/// Ordering is **coverage-first**: the (dataset, imratio, loss) cells are
/// the innermost loops, so if a sweep is truncated (wall-clock budget,
/// crash) the completed prefix still covers *every* Table-2/Figure-3
/// cell with the hyper-parameter combinations processed so far, and the
/// incremental results file remains fully analyzable via
/// `allpairs report`.
pub fn expand(config: &SweepConfig) -> Vec<Job> {
    let max_lr_len = config
        .losses
        .iter()
        .map(|l| config.lr_grid(l).len())
        .max()
        .unwrap_or(0);
    let mut jobs = Vec::with_capacity(config.n_runs());
    for &seed in &config.seeds {
        for lr_idx in 0..max_lr_len {
            for sampling in &config.sampling_modes {
                for &batch in &config.batch_sizes {
                    for dataset in &config.datasets {
                        for &imratio in &config.imratios {
                            for loss in &config.losses {
                                let grid = config.lr_grid(loss);
                                let Some(&lr) = grid.get(lr_idx) else {
                                    continue;
                                };
                                jobs.push(Job {
                                    dataset: dataset.clone(),
                                    imratio,
                                    loss: *loss,
                                    batch,
                                    lr,
                                    seed,
                                    model: config.model.clone(),
                                    epochs: config.epochs,
                                    patience: config.patience,
                                    sampling: sampling.clone(),
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config() -> SweepConfig {
        SweepConfig {
            datasets: vec!["synth-cifar".into()],
            imratios: vec![0.1, 0.01],
            losses: vec![LossSpec::hinge(), LossSpec::logistic()],
            batch_sizes: vec![10, 100],
            seeds: vec![0, 1],
            ..Default::default()
        }
    }

    #[test]
    fn expansion_count_matches_config() {
        let c = small_config();
        let jobs = expand(&c);
        assert_eq!(jobs.len(), c.n_runs());
    }

    #[test]
    fn every_combination_appears_exactly_once() {
        let c = small_config();
        let jobs = expand(&c);
        let mut ids: Vec<String> = jobs.iter().map(|j| j.id()).collect();
        let before = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), before, "duplicate jobs in expansion");
        // spot-check presence of a specific combination
        assert!(jobs.iter().any(|j| j.dataset == "synth-cifar"
            && j.imratio == 0.01
            && j.loss == LossSpec::logistic()
            && j.batch == 100
            && j.seed == 1));
    }

    #[test]
    fn lr_grid_is_loss_specific() {
        let jobs = expand(&small_config());
        let hinge_lrs: std::collections::BTreeSet<_> = jobs
            .iter()
            .filter(|j| j.loss == LossSpec::hinge())
            .map(|j| format!("{:.0e}", j.lr))
            .collect();
        let logistic_lrs: std::collections::BTreeSet<_> = jobs
            .iter()
            .filter(|j| j.loss == LossSpec::logistic())
            .map(|j| format!("{:.0e}", j.lr))
            .collect();
        assert!(logistic_lrs.contains("1e0"));
        assert!(!hinge_lrs.contains("1e0"));
    }

    #[test]
    fn coverage_first_ordering() {
        // The first |cells| jobs must cover every (dataset, imratio, loss)
        // cell exactly once — the truncation-tolerance guarantee.
        let c = SweepConfig {
            datasets: vec!["a".into(), "b".into()],
            imratios: vec![0.1, 0.01],
            losses: vec![LossSpec::hinge(), LossSpec::logistic()],
            batch_sizes: vec![10, 1000],
            seeds: vec![0, 1],
            ..Default::default()
        };
        let jobs = expand(&c);
        let n_cells = 2 * 2 * 2;
        let first: std::collections::BTreeSet<_> = jobs[..n_cells]
            .iter()
            .map(|j| (j.dataset.clone(), format!("{}", j.imratio), j.loss.to_string()))
            .collect();
        assert_eq!(first.len(), n_cells, "first block must cover all cells");
        // and both batch sizes appear before the second seed
        let first_seed1 = jobs.iter().position(|j| j.seed == 1).unwrap();
        let batches_before: std::collections::BTreeSet<_> =
            jobs[..first_seed1].iter().map(|j| j.batch).collect();
        assert_eq!(batches_before.len(), 2);
    }

    #[test]
    fn job_id_is_unique_key() {
        let mut j = Job {
            dataset: "d".into(),
            imratio: 0.01,
            loss: LossSpec::hinge(),
            batch: 500,
            lr: 0.0316,
            seed: 3,
            model: "resnet".into(),
            epochs: 5,
            patience: None,
            sampling: "preserve".into(),
        };
        assert_eq!(j.id(), "d_im0.01_hinge_bs500_lr3e-2_s3");
        j.sampling = "rebalance:0.5".into();
        j.patience = Some(4);
        assert_eq!(j.id(), "d_im0.01_hinge_bs500_lr3e-2_s3_rebalance:0.5_pat4");
    }

    #[test]
    fn data_key_ignores_training_knobs() {
        // Jobs competing in one selection group must see identical data
        // (runner seeds the imbalance/split RNG from data_key).
        let a = Job {
            dataset: "d".into(),
            imratio: 0.01,
            loss: LossSpec::hinge(),
            batch: 50,
            lr: 0.01,
            seed: 3,
            model: "resnet".into(),
            epochs: 5,
            patience: None,
            sampling: "preserve".into(),
        };
        let mut b = a.clone();
        b.loss = LossSpec::logistic();
        b.batch = 1000;
        b.lr = 1.0;
        b.sampling = "rebalance:0.5".into();
        b.patience = Some(9);
        assert_eq!(a.data_key(), b.data_key());
        assert_ne!(a.id(), b.id());
        let mut c = a.clone();
        c.seed = 7;
        assert_ne!(a.data_key(), c.data_key());
    }

    #[test]
    fn sampling_axis_expands_and_roundtrips() {
        let c = SweepConfig {
            sampling_modes: vec!["preserve".into(), "rebalance:0.5".into()],
            patience: Some(3),
            ..small_config()
        };
        let jobs = expand(&c);
        assert_eq!(jobs.len(), c.n_runs());
        let preserve = jobs.iter().filter(|j| j.sampling == "preserve").count();
        assert_eq!(preserve * 2, jobs.len());
        assert!(jobs.iter().all(|j| j.patience == Some(3)));
        // JSON round-trip carries the new fields...
        let j = &jobs[0];
        assert_eq!(&Job::from_json(&j.to_json()).unwrap(), j);
        // ...and pre-streaming records (no such keys) parse to defaults
        let mut legacy = j.to_json();
        if let crate::util::json::Json::Obj(fields) = &mut legacy {
            fields.remove("patience");
            fields.remove("sampling");
        }
        let parsed = Job::from_json(&legacy).unwrap();
        assert_eq!(parsed.patience, None);
        assert_eq!(parsed.sampling, "preserve");
    }
}
