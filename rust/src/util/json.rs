//! A small, complete JSON implementation (RFC 8259 subset sufficient for
//! this repo: no surrogate-pair escapes beyond \uXXXX pass-through).
//!
//! Used for `artifacts/manifest.json` (read), sweep configs (read/write)
//! and result JSONL files (read/write).  Deliberately strict: trailing
//! garbage, unterminated strings, bad escapes and non-finite numbers are
//! errors — the manifest is a cross-language contract and should fail
//! loudly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for test fixtures and reproducible outputs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            // Only integers that an f64 represents *exactly* are
            // accepted: above 2^53 consecutive integers collide, and a
            // plain `as usize` cast saturates huge floats (1e300 →
            // usize::MAX) — both silent corruptions, not conversions.
            if n >= 0.0 && n.fract() == 0.0 && n < 9_007_199_254_740_992.0 && n <= usize::MAX as f64
            {
                // lint:allow(unchecked-cast-in-parse): exact-integer range proven just above
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with context instead of returning None.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field {key:?}"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(fields: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- serialization -----------------------------------------------------

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                assert!(n.is_finite(), "JSON cannot represent non-finite {n}");
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- parsing -----------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing garbage at byte {pos}");
        Ok(value)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of JSON");
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated array");
                match b[*pos] {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                anyhow::ensure!(
                    *pos < b.len() && b[*pos] == b':',
                    "expected ':' after object key"
                );
                *pos += 1;
                let value = parse_value(b, pos)?;
                map.insert(key, value);
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated object");
                match b[*pos] {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => anyhow::bail!("unexpected character {:?} at byte {pos}", c as char),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> anyhow::Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "invalid literal at byte {pos}"
    );
    *pos += lit.len();
    Ok(value)
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(*pos < b.len() && b[*pos] == b'"', "expected string");
    *pos += 1;
    let mut out = String::new();
    loop {
        anyhow::ensure!(*pos < b.len(), "unterminated string");
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "truncated \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = text
        .parse()
        .map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
    // `f64::from_str` saturates overflowing literals to ±inf instead of
    // erroring, which would violate this module's "non-finite numbers
    // are errors" contract — and `dumps()` asserts on non-finite, so an
    // accepted `1e999` would turn a later serialization into a panic.
    // (Underflow to 0.0 or a subnormal is fine: still finite.)
    anyhow::ensure!(
        n.is_finite(),
        "number {text:?} overflows f64 (JSON numbers must be finite)"
    );
    Ok(Json::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_preserves_value() {
        let src = r#"{"name":"train_bs10","shape":[2,3],"lr":0.0316,"ok":true,"note":"a\"b\\c\nd"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dumps()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("tab\t newline\n quote\" back\\ ctrl\u{1}".into());
        let parsed = Json::parse(&j.dumps()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::str("é"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).dumps(), "5");
        assert_eq!(Json::Num(0.5).dumps(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"unterminated", "1 2", "{'a':1}", "nul", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn overflowing_number_literals_are_rejected() {
        // Regression: `f64::from_str` saturates these to ±inf, so the
        // parser used to accept them as Num(inf) — and dumps() would
        // then panic on its is_finite assert.  One malformed line must
        // be a parse error, never a later panic.
        for bad in ["1e999", "-1e999", "1e308001", "[1, 2e999]", r#"{"x": -3e999}"#] {
            let err = Json::parse(bad).unwrap_err().to_string();
            assert!(err.contains("finite"), "{bad:?}: {err}");
        }
        // Underflow is not overflow: subnormals flush toward 0.0 and
        // stay finite — accepted.
        assert_eq!(Json::parse("1e-999").unwrap(), Json::Num(0.0));
        assert_eq!(Json::parse("-1e-999").unwrap(), Json::Num(-0.0));
        // Near-max finite literals still parse.
        assert_eq!(Json::parse("1e308").unwrap(), Json::Num(1e308));
    }

    #[test]
    fn parse_dumps_roundtrip_property() {
        // Property: any value the parser accepts serializes to a string
        // the parser accepts again, equal to the original value — in
        // particular dumps() can never hit its non-finite assert on
        // parsed input.  Hand-rolled generator on the repo Rng.
        use crate::data::Rng;
        let mut rng = Rng::new(0x15C4_1EAF);
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.uniform() < 0.5),
                2 => {
                    // numbers across the whole finite exponent range
                    let exp = rng.below(613) as i32 - 306;
                    Json::Num(rng.normal() * 10f64.powi(exp))
                }
                3 => {
                    const ALPHABET: [char; 7] = ['a', 'é', '"', '\\', '\n', '\u{1}', 'π'];
                    let len = rng.below(8);
                    Json::Str((0..len).map(|_| ALPHABET[rng.below(7)]).collect())
                }
                4 => Json::Arr((0..rng.below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        for _ in 0..500 {
            let v = gen(&mut rng, 3);
            let text = v.dumps();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse {text:?}: {e}"));
            // -0.0 == 0.0 under PartialEq, and integer-styled output
            // (write! as i64) drops the sign of -0.0 — value equality
            // is the contract, not bit equality.
            assert_eq!(back, v, "through {text:?}");
        }
    }

    #[test]
    fn as_usize_rejects_inexact_and_out_of_range() {
        // 2^53 - 1 is the largest integer every neighbor of which f64
        // still represents exactly; at 2^53 consecutive integers start
        // to collide, so conversion would silently misrepresent.
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_usize(), Some(9_007_199_254_740_991));
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_usize(), None); // 2^53
        // 2^53 + 1 is not representable: the literal rounds to 2^53,
        // which the exact-range check rejects all the same.
        assert_eq!(Json::Num(9_007_199_254_740_993.0).as_usize(), None);
        assert_eq!(Json::Num(usize::MAX as f64).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None, "used to saturate to usize::MAX");
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
        assert_eq!(Json::parse("4294967296").unwrap().as_usize(), Some(1 << 32));
    }

    #[test]
    fn accessors_type_checked() {
        let j = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("s").unwrap().as_f64(), None);
        assert!(j.req("missing").is_err());
    }

    #[test]
    fn manifest_shaped_document() {
        let src = r#"{
          "format_version": 1, "margin": 1.0,
          "artifacts": [{"name": "init_mlp_hinge", "inputs":
             [{"shape": [], "dtype": "uint32"}], "n_outputs": 12}]
        }"#;
        let j = Json::parse(src).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("init_mlp_hinge"));
        assert_eq!(
            arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            0
        );
    }
}
