//! A small, complete JSON implementation (RFC 8259 subset sufficient for
//! this repo: no surrogate-pair escapes beyond \uXXXX pass-through).
//!
//! Used for `artifacts/manifest.json` (read), sweep configs (read/write)
//! and result JSONL files (read/write).  Deliberately strict: trailing
//! garbage, unterminated strings, bad escapes and non-finite numbers are
//! errors — the manifest is a cross-language contract and should fail
//! loudly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.  Object keys are sorted (BTreeMap) so serialization is
/// deterministic — handy for test fixtures and reproducible outputs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` that errors with context instead of returning None.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field {key:?}"))
    }

    // -- constructors ------------------------------------------------------

    pub fn obj(fields: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -- serialization -----------------------------------------------------

    /// Compact serialization.
    pub fn dumps(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                assert!(n.is_finite(), "JSON cannot represent non-finite {n}");
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- parsing -----------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        anyhow::ensure!(pos == bytes.len(), "trailing garbage at byte {pos}");
        Ok(value)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of JSON");
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated array");
                match b[*pos] {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    c => anyhow::bail!("expected ',' or ']', got {:?}", c as char),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                anyhow::ensure!(
                    *pos < b.len() && b[*pos] == b':',
                    "expected ':' after object key"
                );
                *pos += 1;
                let value = parse_value(b, pos)?;
                map.insert(key, value);
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated object");
                match b[*pos] {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    c => anyhow::bail!("expected ',' or '}}', got {:?}", c as char),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => anyhow::bail!("unexpected character {:?} at byte {pos}", c as char),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> anyhow::Result<Json> {
    anyhow::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "invalid literal at byte {pos}"
    );
    *pos += lit.len();
    Ok(value)
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(*pos < b.len() && b[*pos] == b'"', "expected string");
    *pos += 1;
    let mut out = String::new();
    loop {
        anyhow::ensure!(*pos < b.len(), "unterminated string");
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "truncated \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = text
        .parse()
        .map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
    Ok(Json::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_preserves_value() {
        let src = r#"{"name":"train_bs10","shape":[2,3],"lr":0.0316,"ok":true,"note":"a\"b\\c\nd"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dumps()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("tab\t newline\n quote\" back\\ ctrl\u{1}".into());
        let parsed = Json::parse(&j.dumps()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::str("é"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).dumps(), "5");
        assert_eq!(Json::Num(0.5).dumps(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "\"unterminated", "1 2", "{'a':1}", "nul", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn accessors_type_checked() {
        let j = Json::parse(r#"{"n": 3, "s": "x"}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("s").unwrap().as_f64(), None);
        assert!(j.req("missing").is_err());
    }

    #[test]
    fn manifest_shaped_document() {
        let src = r#"{
          "format_version": 1, "margin": 1.0,
          "artifacts": [{"name": "init_mlp_hinge", "inputs":
             [{"shape": [], "dtype": "uint32"}], "n_outputs": 12}]
        }"#;
        let j = Json::parse(src).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("init_mlp_hinge"));
        assert_eq!(
            arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            0
        );
    }
}
