//! Micro-benchmark harness (criterion stand-in) for `cargo bench`
//! targets (`harness = false`).
//!
//! Methodology: warm-up runs, then timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met; reports
//! median / mean / min over per-iteration times.  Good enough to read
//! asymptotic slopes and before/after deltas; not a statistics suite.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:44} {:>12} median {:>12} mean {:>12} min  ({} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.min),
            self.iters
        )
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Median / mean / min over raw per-iteration times.  The median of an
/// even sample count is the average of the two middle samples (the
/// textbook definition — picking the upper middle biases repeated
/// short runs upward).
fn summarize(name: String, mut times: Vec<Duration>) -> Measurement {
    assert!(!times.is_empty(), "summarize needs at least one sample");
    times.sort_unstable();
    let n = times.len();
    let median = if n % 2 == 0 {
        (times[n / 2 - 1] + times[n / 2]) / 2
    } else {
        times[n / 2]
    };
    let sum: Duration = times.iter().sum();
    Measurement {
        name,
        iters: n,
        median,
        mean: sum / n as u32,
        min: times[0],
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bench {
    min_iters: usize,
    max_iters: usize,
    budget: Duration,
    warmup: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            min_iters: 5,
            max_iters: 1_000_000,
            budget: Duration::from_millis(700),
            warmup: 2,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether `ALLPAIRS_BENCH_QUICK=1` is set — the single source of
    /// truth for quick mode, shared by [`Self::from_env`] and anything
    /// that records which mode a run used (e.g. `BENCH_train.json`).
    pub fn quick_from_env() -> bool {
        std::env::var("ALLPAIRS_BENCH_QUICK").as_deref() == Ok("1")
    }

    /// Quick-mode harness (smaller budget) when `ALLPAIRS_BENCH_QUICK=1`.
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if Self::quick_from_env() {
            b.budget = Duration::from_millis(120);
            b.warmup = 1;
            b.min_iters = 2;
        }
        b
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run one benchmark; `f` must return something observable (it is
    /// passed through `std::hint::black_box`).
    pub fn run<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (start.elapsed() < self.budget && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        let m = summarize(name.into(), times);
        println!("{m}");
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write results as CSV (used by EXPERIMENTS.md bookkeeping).
    /// Atomic replace: a crash mid-write can never leave a torn CSV
    /// next to a BENCH json that claims the run completed.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        let mut s = String::from("name,iters,median_s,mean_s,min_s\n");
        for m in &self.results {
            s.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9}\n",
                m.name,
                m.iters,
                m.median.as_secs_f64(),
                m.mean.as_secs_f64(),
                m.min.as_secs_f64()
            ));
        }
        crate::util::fsio::write_atomic(path.as_ref(), s.as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new().with_budget(Duration::from_millis(20));
        let m = b.run("noop-ish", || (0..100).sum::<usize>());
        assert!(m.iters >= 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn ordering_reflects_work() {
        // A data-dependent xorshift chain: LLVM cannot closed-form it
        // (unlike a sum of squares), so runtime genuinely scales with the
        // iteration count.  Compare min (robust to scheduling noise).
        fn chain(iters: u64) -> u64 {
            let mut x = std::hint::black_box(0x9E3779B97F4A7C15u64);
            for _ in 0..iters {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
            }
            x
        }
        let mut b = Bench::new().with_budget(Duration::from_millis(60));
        let small = b.run("small", || chain(100)).min;
        let large = b.run("large", || chain(1_000_000)).min;
        assert!(large > small * 50, "{large:?} vs {small:?}");
    }

    #[test]
    fn median_of_even_sample_count_averages_middle_pair() {
        let ms = Duration::from_millis;
        let odd = summarize("odd".into(), vec![ms(30), ms(10), ms(20)]);
        assert_eq!(odd.median, ms(20));
        // even count: (20 + 40) / 2, not the upper middle 40
        let even = summarize("even".into(), vec![ms(40), ms(10), ms(20), ms(90)]);
        assert_eq!(even.median, ms(30));
        assert_eq!(even.min, ms(10));
        assert_eq!(even.mean, ms(40));
        let pair = summarize("pair".into(), vec![ms(10), ms(20)]);
        assert_eq!(pair.median, ms(15));
    }

    #[test]
    fn csv_output() {
        let mut b = Bench::new().with_budget(Duration::from_millis(10));
        b.run("x", || 1 + 1);
        // Unique per test process: a fixed path collides when several
        // `cargo test` invocations run concurrently on one machine.
        let name = format!("allpairs_bench_test_{}.csv", std::process::id());
        let p = std::env::temp_dir().join(name);
        b.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert!(text.starts_with("name,iters"));
        assert!(text.lines().count() == 2);
    }
}
